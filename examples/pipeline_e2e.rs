//! End-to-end driver (the repo's headline experiment): the paper's §7
//! evaluation day at full pipeline depth.
//!
//! 80 microservice databases → Debezium-sim CDC → Kafka-sim topic → METL
//! (DMM / Alg 6, cache, state-i sync) → CDM topic → DW + ML sinks, with
//! 1168 CDC events and 3 mid-run schema-change storms (each triggering
//! Alg 5 + cache eviction, the paper's latency-spike mechanism), followed
//! by a store-restart restore and an XLA bulk initial load.
//!
//! Run with: `cargo run --release --example pipeline_e2e`
//! Results recorded in EXPERIMENTS.md.

use metl::config::PipelineConfig;
use metl::coordinator::batcher::InitialLoader;
use metl::coordinator::pipeline::Pipeline;
use metl::matrix::compaction::CompactionStats;
use metl::sink::{AuditMirrorSink, DwSink, JsonlSink, MlSink};
use metl::source::Connector;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::message::StateI;
use metl::util::rng::Rng;
use metl::util::stats::{format_ns, Summary};
use metl::workload;

fn main() -> anyhow::Result<()> {
    let cfg = PipelineConfig::paper_day();
    println!(
        "== METL e2e: {} services, {} CDC events, {} schema changes ==",
        cfg.n_services, cfg.trace_events, cfg.schema_changes
    );

    // landscape + pre-existing data
    let mut land = workload::generate(&cfg);
    let mut rng = Rng::seed_from(cfg.seed);
    workload::populate(&mut land, 20, &mut rng);

    // compaction at this scale (fig 5 / §5.3 claims)
    let dpm = DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let dusb =
        DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    let stats =
        CompactionStats::measure(&land.matrix, &land.tree, &land.cdm, &dpm, &dusb);
    println!("\n-- compaction --\n{}", stats.row());

    // the pipeline with the hybrid store attached, wired through the
    // connector-API builder: explicit source + four sink backends, each
    // with its own consumer group over the CDM topic
    let store_dir = std::env::temp_dir().join("metl-e2e-store");
    let _ = std::fs::remove_dir_all(&store_dir);
    let jsonl_path = std::env::temp_dir().join("metl-e2e-cdm.jsonl");
    let _ = std::fs::remove_file(&jsonl_path);
    let pipeline = Pipeline::builder(cfg.clone())
        .landscape(land)
        .source(Connector::new("src"))
        .sink(DwSink::new())
        .sink(MlSink::new())
        .sink(JsonlSink::new().with_path(&jsonl_path))
        .sink(AuditMirrorSink::new(64))
        .store(&store_dir)
        .build()?;

    // day trace (paper: 1168 CDC events on 13 Feb 2022)
    let ops = workload::day_trace(&cfg, &mut rng);
    let report = pipeline.run_trace(&ops)?;

    println!("\n-- day trace --");
    println!(
        "events={} out_messages={} dead_letters={} dmm_updates={} wall={:?}",
        report.events,
        report.out_messages,
        report.dead_letters,
        report.dmm_updates,
        report.wall
    );
    let lat = pipeline.metrics.map_latency.summary();
    println!(
        "map latency: mean={} sigma={} p50={} p99={} (paper: 39ms ± 51ms on \
         Docker/JVM; shape-check: sigma/mean = {:.2} vs paper {:.2})",
        format_ns(lat.mean),
        format_ns(lat.std),
        format_ns(lat.p50),
        format_ns(lat.p99),
        lat.std / lat.mean,
        51.0 / 39.0
    );
    // the lower bracket: latency without cache eviction (§7's 10-20 ms claim
    // analogue) — measured as the p50 of the warm-cache majority
    let samples = pipeline.metrics.map_latency.samples();
    let warm: Vec<f64> = {
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted[..samples.len() * 9 / 10].to_vec()
    };
    let warm_summary = Summary::from(&warm);
    println!(
        "warm-cache bracket (lowest 90%): mean={} max={}",
        format_ns(warm_summary.mean),
        format_ns(warm_summary.max)
    );

    println!("\n-- sinks (one consumer group each) --");
    let (rows, upserts, dupes) = pipeline
        .with_sink("dw", |dw: &DwSink| {
            (dw.total_rows(), dw.total_upserts(), dw.total_duplicates())
        })
        .unwrap();
    println!("DW:    {rows} rows, {upserts} upserts, {dupes} duplicates (at-least-once)");
    let (observations, features) = pipeline
        .with_sink("ml", |ml: &MlSink| (ml.observations, ml.n_features()))
        .unwrap();
    println!("ML:    {observations} observations, {features} features tracked");
    let jsonl_lines = pipeline
        .with_sink("jsonl", |j: &JsonlSink| j.len())
        .unwrap();
    println!(
        "JSONL: {} lines appended to {}",
        jsonl_lines,
        jsonl_path.display()
    );
    let (mirrored, tombstones) = pipeline
        .with_sink("audit", |a: &AuditMirrorSink| (a.mirrored, a.tombstones))
        .unwrap();
    println!("audit: {mirrored} mirrored, {tombstones} tombstones ledgered");
    for handle in &pipeline.sinks {
        assert_eq!(handle.lag(), 0, "sink {} fully drained", handle.name());
    }
    assert_eq!(jsonl_lines as u64, pipeline.metrics.messages_out.get());
    // the JSONL file is the flushed mirror of the in-memory log
    let flushed = std::fs::read_to_string(&jsonl_path)?.lines().count();
    assert_eq!(flushed, jsonl_lines);

    println!("\n-- dashboard (fig 7) --\n{}", pipeline.dashboard());

    // restart path: restore the DMM from the Postgres-sim store (§6.2)
    let t0 = std::time::Instant::now();
    let restored = pipeline.restore_from_store()?;
    println!(
        "-- restart -- store restore: {} in {:?} (state {})",
        restored,
        t0.elapsed(),
        pipeline.dmm.snapshot().state.0
    );

    // initial load through the XLA bulk lane (reserve capacity, §6.4)
    let loader = InitialLoader::from_config(&pipeline.cfg);
    let t0 = std::time::Instant::now();
    let load = loader.initial_load(&pipeline, 0)?;
    println!(
        "-- initial load -- rows={} out={} bulk={} in {:?}",
        load.rows,
        load.out_messages,
        load.used_bulk,
        t0.elapsed()
    );

    assert_eq!(report.events as usize, cfg.trace_events);
    assert_eq!(report.dmm_updates as usize, cfg.schema_changes);
    assert_eq!(report.dead_letters, 0);
    println!("\npipeline_e2e OK");
    Ok(())
}
