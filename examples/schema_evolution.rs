//! Schema evolution walkthrough: the paper's §3.3 semi-automated workflow
//! and the figure-6 worked update example, end to end — registry rules,
//! the four Alg-5 trigger cases, notices, the inspection views, and the
//! online evolution lane applying a live change to a running pipeline.
//!
//! Run with: `cargo run --release --example schema_evolution`

use metl::cdm::{CdmType, CdmVersionNo};
use metl::coordinator::inspect;
use metl::matrix::fixtures::{fig6_matrix, fig6_trees};
use metl::matrix::update::{auto_update, ChangeCase, Notice};
use metl::prelude::*;
use metl::schema::EvolutionError;

fn main() -> anyhow::Result<()> {
    // ---- 1. The Apicurio-sim registry enforces evolution discipline ----
    println!("== registry rules (§3.3) ==");
    let registry = Registry::new(Compatibility::Backward, true);
    let s = registry.create_schema("payments.incoming", "src.payments.incoming");
    let f = |n: &str| (n.to_string(), ExtractType::Int64, true);
    registry.register_version(s, &[f("id"), f("value")]).unwrap();
    // single-attribute additions pass
    let (v2, diff) = registry
        .register_version(s, &[f("id"), f("value"), f("currency")])
        .unwrap();
    println!("v{} accepted, diff: +{:?}", v2.0, diff.added);
    // removals violate backward compatibility
    let err = registry.register_version(s, &[f("id")]).unwrap_err();
    println!("removal rejected: {err}");
    assert!(matches!(err, EvolutionError::RemovalForbidden { .. }));
    // two changes at once violate the single-change rule
    let err = registry
        .register_version(s, &[f("id"), f("value"), f("currency"), f("x"), f("y")])
        .unwrap_err();
    println!("double change rejected: {err}");

    // ---- 2. Figure 6: the two update events through Alg 5 --------------
    println!("\n== figure-6 worked example (Alg 5) ==");
    let (mut tree, mut cdm) = fig6_trees();
    let m = fig6_matrix(&tree, &cdm);
    let mut dpm = DpmSet::from_matrix(&m, &tree, &cdm, StateI(0))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("initial DPM: {} elements", dpm.n_elements());

    // event (1): new extracting version s1.v3 with a7 ≡ a4 ≡ a1
    let s1 = tree.schema_by_name("s1").unwrap();
    let v3 = tree.add_version(s1, &[("a1".into(), ExtractType::Int64, true)]);
    let report = auto_update(
        &mut dpm,
        &tree,
        &cdm,
        ChangeCase::AddedSchemaVersion { schema: s1, v: v3 },
        StateI(1),
    );
    println!(
        "event 1 (added s1.v3): +{} elements, {} notice(s)",
        report.elements_added,
        report.notices.len()
    );
    for n in &report.notices {
        match n {
            Notice::SmallerPermutation { old_rank, new_rank, .. } => println!(
                "  notice: copied block shrank {old_rank} -> {new_rank} \
                 (user should double-check, §5.4.2)"
            ),
            other => println!("  notice: {other:?}"),
        }
    }

    // event (2): new CDM version (c3≡c1, c4≡c2), old rows deleted (§5.4.3)
    let e1 = cdm.entity_by_name("s1cdm").unwrap();
    let w2 = cdm.add_version(
        e1,
        &[
            ("c1".into(), CdmType::Integer, "c3 ≡ c1".into()),
            ("c2".into(), CdmType::Integer, "c4 ≡ c2".into()),
        ],
    );
    let report = auto_update(
        &mut dpm,
        &tree,
        &cdm,
        ChangeCase::AddedCdmVersion { entity: e1, w: w2 },
        StateI(2),
    );
    println!(
        "event 2 (added CDM v2): +{} elements to new rows, -{} blocks of \
         the old version (red cleanup in fig 6)",
        report.elements_added, report.blocks_removed
    );
    assert!(dpm.row(e1, CdmVersionNo(1)).is_empty());

    // ---- 3. Inspection views (§6.3 UI queries) --------------------------
    println!("\n== inspection (UI sim, §6.3) ==");
    print!("{}", inspect::reverse_search(&dpm, &tree, &cdm, e1, w2));
    print!("{}", inspect::version_progression(&dpm, &tree, &cdm, s1));

    // ---- 4. A deletion storm (cases 1+2) --------------------------------
    println!("== deletion storm ==");
    let before = dpm.n_elements();
    let report = auto_update(
        &mut dpm,
        &tree,
        &cdm,
        ChangeCase::DeletedSchemaVersion { schema: s1, v: VersionNo(1) },
        StateI(3),
    );
    println!(
        "deleted s1.v1: -{} blocks, -{} elements (DPM {} -> {})",
        report.blocks_removed,
        report.elements_removed,
        before,
        dpm.n_elements()
    );

    // ---- 5. The online evolution lane on a live pipeline ----------------
    println!("\n== online evolution lane (live pipeline) ==");
    let p = Pipeline::new(metl::config::PipelineConfig::small())?;
    let schema = p.landscape.read().unwrap().dbs[0].tables[0].schema;
    let mut fields = {
        let land = p.landscape.read().unwrap();
        let latest = land.tree.latest_version(schema).unwrap();
        land.tree.field_list(schema, latest).unwrap()
    };
    fields.push(("observed_on_the_wire".into(), ExtractType::Varchar, true));
    // a Debezium-style DDL event arrives on the schema-change source...
    p.evolution
        .source()
        .publish_change(SchemaChangeEvent::add_version(schema, fields, 0));
    // ...and the lane validates + applies it: one epoch swap, targeted
    // cache eviction, zero interruption of the mapping lanes
    let outcomes = p.evolution.pump(&p);
    println!(
        "applied {} live change(s): epoch {}, state {}, update latency n={}",
        outcomes.iter().filter(|o| o.is_applied()).count(),
        p.metrics.dmm_epoch.get(),
        p.state.current().0,
        p.metrics.update_latency.count()
    );

    println!("\nschema_evolution OK");
    Ok(())
}
