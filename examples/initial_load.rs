//! Initial loads at scale (paper §3.4/§5.5/§6.4): the fallback moment
//! where METL's "reserve capacity" is spent — XLA bulk lane vs the Alg-6
//! lane for snapshot replays, and horizontal scaling 1→8 instances over
//! the partitioned CDC backlog.
//!
//! Run with: `cargo run --release --example initial_load`

use metl::config::PipelineConfig;
use metl::coordinator::batcher::InitialLoader;
use metl::coordinator::pipeline::Pipeline;
use metl::coordinator::scaler;
use metl::runtime::BulkRuntime;
use metl::util::rng::Rng;
use metl::workload::{self, DmlKind, TraceOp};

const ROWS: usize = 4000;

fn loaded_pipeline(cfg: &PipelineConfig) -> anyhow::Result<Pipeline> {
    let mut land = workload::generate(cfg);
    let mut rng = Rng::seed_from(cfg.seed ^ 0x10AD);
    workload::populate(&mut land, ROWS, &mut rng);
    // connector-API wiring: config-driven sinks (runtime.sinks) ride on
    // the builder; the landscape is pre-populated for the load
    Pipeline::builder(cfg.clone()).landscape(land).build()
}

fn main() -> anyhow::Result<()> {
    let mut cfg = PipelineConfig::small();
    cfg.partitions = 8;
    cfg.artifacts_dir = Some("artifacts".into());

    // ---- lane comparison: XLA bulk vs Alg 6 -----------------------------
    println!("== initial load: {} rows/table ==", ROWS);
    let runtime = BulkRuntime::try_load("artifacts");
    match &runtime {
        Some(rt) => println!(
            "bulk runtime loaded: {} variants on {}",
            rt.n_variants(),
            rt.platform
        ),
        None => println!("no artifacts — run `make artifacts` for the XLA lane"),
    }

    let p_bulk = loaded_pipeline(&cfg)?;
    let loader = InitialLoader { runtime };
    let t0 = std::time::Instant::now();
    let r_bulk = loader.initial_load(&p_bulk, 0)?;
    let bulk_wall = t0.elapsed();
    println!(
        "bulk lane:  {} rows -> {} messages (bulk={}) in {:?}",
        r_bulk.rows, r_bulk.out_messages, r_bulk.used_bulk, bulk_wall
    );

    let p_fall = loaded_pipeline(&cfg)?;
    let fallback = InitialLoader { runtime: None };
    let t0 = std::time::Instant::now();
    let r_fall = fallback.initial_load(&p_fall, 0)?;
    let fall_wall = t0.elapsed();
    println!(
        "alg-6 lane: {} rows -> {} messages (bulk={}) in {:?}",
        r_fall.rows, r_fall.out_messages, r_fall.used_bulk, fall_wall
    );
    assert_eq!(r_bulk.rows, r_fall.rows);
    assert_eq!(
        r_bulk.out_messages, r_fall.out_messages,
        "the two lanes must produce identical message counts"
    );

    // ---- horizontal scaling over a CDC backlog --------------------------
    println!("\n== horizontal scaling (stable state i, §5.5) ==");
    println!("{:>10} {:>12} {:>14}", "instances", "wall", "events/s");
    let mut base_eps = 0.0;
    for instances in [1usize, 2, 4, 8] {
        let p = loaded_pipeline(&cfg)?;
        // backlog: one update event per existing row across 4 services
        for service in 0..p.cfg.n_services {
            for _ in 0..1500 {
                p.resolve_op(&TraceOp::Dml { service, kind: DmlKind::Update })?;
            }
        }
        let report = scaler::run_scaled(&p, instances);
        let eps = report.throughput_eps();
        if instances == 1 {
            base_eps = eps;
        }
        println!(
            "{:>10} {:>12?} {:>14.0}  (x{:.2})",
            instances,
            report.wall,
            eps,
            eps / base_eps
        );
        assert_eq!(report.processed, (p.cfg.n_services * 1500) as u64);
    }
    println!("\ninitial_load OK");
    Ok(())
}
