//! Quickstart: the paper's figure-3/figure-5 worked example through the
//! public API — build the two metadata trees, the mapping matrix, both
//! DMM compactions, and map one Kafka message.
//!
//! Run with: `cargo run --release --example quickstart`

use std::sync::Arc;

use metl::cache::DcpmCache;
use metl::matrix::compaction::CompactionStats;
use metl::matrix::fixtures::{fig5_matrix, fig5_trees};
use metl::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. The dynamic network: extracting-schema tree ᵢD and CDM tree ᵢR.
    let (tree, cdm) = fig5_trees();
    println!(
        "domain tree: {} schemas, {} attribute ids",
        tree.n_schemas(),
        tree.n_attr_ids()
    );
    println!(
        "range tree:  {} entities, {} attribute ids",
        cdm.n_entities(),
        cdm.n_attr_ids()
    );

    // 2. The sparse mapping matrix ᵢM (figure 5's worked example).
    let matrix = fig5_matrix(&tree, &cdm);
    println!("matrix ones: {}", matrix.count_ones());

    // 3. Strategy 1 (Alg 2): the dense permutation-matrix set ᵢ𝔇𝔓𝔐.
    let dpm = DpmSet::from_matrix(&matrix, &tree, &cdm, StateI(0))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // 4. Strategy 2 (Alg 3): the unique-square-block set ᵢ𝔇𝔘𝔖𝔅.
    let dusb = DusbSet::from_matrix(&matrix, &tree, &cdm, StateI(0))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let stats = CompactionStats::measure(&matrix, &tree, &cdm, &dpm, &dusb);
    println!("{}", stats.row());
    println!(
        "fig 5 check: DPM stores {} elements (paper: 7), DUSB stores {} \
         (+{} special null; paper: 5 + 1)",
        dpm.n_elements(),
        dusb.n_elements(),
        dusb.n_special_nulls()
    );

    // 5. Map one incoming Kafka message with Alg 6.
    let s1 = tree.schema_by_name("s1").unwrap();
    let sv = tree.version(s1, VersionNo(1)).unwrap();
    let msg = InMessage {
        key: 32201,
        schema: s1,
        version: VersionNo(1),
        state: StateI(0),
        ts_us: 1_634_052_484_031_131,
        fields: vec![
            (sv.attrs[0], Json::Num(10.0)),          // a1
            (sv.attrs[2], Json::Str("EUR".into())),  // a3
        ],
    };
    let cache = Arc::new(DcpmCache::new(StateI(0)));
    let mapper = ParallelMapper::new(Arc::new(dpm), cache);
    let outs = mapper.map(&msg).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nincoming message maps to {} outgoing message(s):", outs.len());
    for out in &outs {
        println!(
            "  -> {} v{}: {}",
            cdm.entity(out.entity).name,
            out.version.0,
            metl::message::codec::encode_out(out, &cdm)
        );
    }
    assert_eq!(outs.len(), 2, "be1.v2 and be3.v1 receive data");
    println!("\nquickstart OK");
    Ok(())
}
