//! Bench: durable-store crash recovery, the numbers behind `BENCH_7.json`.
//!
//! Three axes of the log-structured store (see ARCHITECTURE.md §Store):
//!
//!   1. full recovery latency (segment read + bounded decompaction +
//!      Alg-5 WAL-tail replay) — the restart-to-first-mapping cost,
//!   2. WAL replay rate (records/s through Alg 5),
//!   3. single-schema point recovery through the sparse index, with the
//!      "<10% of total store bytes" acceptance bound enforced.
//!
//! Flags (after `cargo bench --bench recovery --`):
//!   --smoke           reduced iterations + small profile (CI shape check)
//!   --out PATH        artifact destination (default ../BENCH_7.json from
//!                     the crate root, i.e. the repo-root baseline)
//!   --validate PATH   validate an existing artifact's schema and exit

#[path = "harness.rs"]
mod harness;

use harness::{arg_value, has_flag, section, Artifact, Bench};
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::util::json::Json;
use metl::util::tmp::TestDir;
use metl::workload::{self, Landscape};

/// Metrics every `BENCH_7.json`-shaped artifact must carry (dotted paths
/// under `metrics`; shared by `--validate` and the CI bench-smoke job).
const REQUIRED: &[&str] = &[
    "recovery_ns.p50",
    "recovery_ns.p99",
    "wal_replayed",
    "wal_replay_per_s",
    "point_recovery.bytes_read",
    "point_recovery.store_bytes",
    "point_recovery.read_fraction",
    "point_recovery.read_ns.p50",
];

fn main() {
    if let Some(path) = arg_value("--validate") {
        match harness::validate_artifact_file(&path, "recovery", REQUIRED) {
            Ok(()) => {
                println!("{path}: valid recovery artifact");
                return;
            }
            Err(e) => {
                eprintln!("invalid recovery artifact: {e}");
                std::process::exit(1);
            }
        }
    }

    let smoke = has_flag("--smoke");
    let (mut cfg, wal_tail, iters) = if smoke {
        (PipelineConfig::small(), 4usize, 3usize)
    } else {
        (PipelineConfig::paper_day(), 16, 10)
    };
    // keep every change in the WAL tail: replay is what we are measuring
    cfg.store_segment_threshold = 10_000;
    let profile = if smoke { "small" } else { "paper_day" };
    let mut artifact = Artifact::new("recovery");
    artifact
        .meta("profile", Json::Str(profile.to_string()))
        .meta("smoke", Json::Bool(smoke))
        .meta("iters", Json::Num(iters as f64));

    // --- axis 1+2: full recovery + WAL replay rate -----------------------
    section(&format!(
        "full recovery: segment + {wal_tail}-record WAL tail ({profile})"
    ));
    let dir = TestDir::new("bench-recovery");
    let p = Pipeline::new(cfg.clone())
        .unwrap()
        .with_store(dir.path())
        .unwrap();
    for i in 0..wal_tail {
        p.apply_schema_change(i % cfg.n_services).unwrap();
    }
    let store = p.store.as_ref().unwrap();
    let bench = Bench::new(1, iters);
    // recovery mutates its landscape, so each timed run consumes a
    // pre-generated pristine one (generation stays outside the timing)
    let mut lands: Vec<Landscape> =
        (0..=iters).map(|_| workload::generate(&cfg)).collect();
    let rec = bench.run("recover (cold restart)", || {
        let mut land = lands.pop().expect("pre-generated landscape");
        let out = store.recover(&mut land).unwrap().unwrap();
        assert_eq!(out.replayed, wal_tail);
        out.dpm.n_elements()
    });
    let replay_per_s = wal_tail as f64 / (rec.mean / 1e9);
    println!("  WAL replay rate: {replay_per_s:.0} records/s");
    artifact.set_summary_ns("recovery_ns", &rec);
    artifact.set_num("wal_replayed", wal_tail as f64);
    artifact.set_num("wal_replay_per_s", replay_per_s);

    // --- axis 3: single-schema point recovery ----------------------------
    section("single-schema point recovery (sparse index)");
    let mut pcfg = PipelineConfig::small();
    pcfg.n_services = 24;
    pcfg.n_entities = 12;
    pcfg.store_segment_threshold = 10_000;
    let pdir = TestDir::new("bench-recovery-point");
    let pp = Pipeline::new(pcfg)
        .unwrap()
        .with_store(pdir.path())
        .unwrap();
    pp.apply_schema_change(0).unwrap();
    pp.apply_schema_change(1).unwrap();
    let pstore = pp.store.as_ref().unwrap();
    let schema = {
        let land = pp.landscape.read().unwrap();
        land.dbs[12].tables[0].schema
    };
    let pr = pstore.recover_schema(schema).unwrap().unwrap();
    let frac = pr.bytes_read as f64 / pr.store_bytes as f64;
    println!(
        "  region read: {}B of {}B ({:.1}% of the store)",
        pr.bytes_read,
        pr.store_bytes,
        frac * 100.0
    );
    // the acceptance bound, enforced on every run including smoke
    assert!(
        frac < 0.10,
        "point recovery read {:.1}% of the store (bound: 10%)",
        frac * 100.0
    );
    let ps = bench.run("recover_schema (point read)", || {
        pstore.recover_schema(schema).unwrap().unwrap().bytes_read
    });
    artifact.set(
        "point_recovery",
        Json::Obj(vec![
            ("bytes_read".to_string(), Json::Num(pr.bytes_read as f64)),
            ("store_bytes".to_string(), Json::Num(pr.store_bytes as f64)),
            ("read_fraction".to_string(), Json::Num(frac)),
            ("read_ns".to_string(), summary_obj(&ps)),
        ]),
    );

    // --- emit ------------------------------------------------------------
    let out =
        arg_value("--out").unwrap_or_else(|| "../BENCH_7.json".to_string());
    artifact.write(&out).unwrap();
    if let Err(e) = harness::validate_artifact_file(&out, "recovery", REQUIRED) {
        eprintln!("emitted artifact failed self-validation: {e}");
        std::process::exit(1);
    }
    println!("\nrecovery bench OK");
}

fn summary_obj(s: &metl::util::stats::Summary) -> Json {
    Json::Obj(vec![
        ("count".to_string(), Json::Num(s.count as f64)),
        ("mean".to_string(), Json::Num(s.mean)),
        ("std".to_string(), Json::Num(s.std)),
        ("p50".to_string(), Json::Num(s.p50)),
        ("p90".to_string(), Json::Num(s.p90)),
        ("p99".to_string(), Json::Num(s.p99)),
    ])
}
