//! Bench: §7 evaluation — per-CDC-event mapping latency over the measured
//! day (1168 events, DMM updates evicting the cache a few times), plus the
//! warm/evicted split behind the paper's "10-20 ms lower bracket" claim
//! and the Alg-1 vs Alg-6 per-message comparison.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use harness::{section, Artifact, Bench};
use metl::cache::DcpmCache;
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::mapper::baseline::BaselineMapper;
use metl::mapper::parallel::ParallelMapper;
use metl::matrix::dpm::DpmSet;
use metl::message::{InMessage, StateI};
use metl::util::rng::Rng;
use metl::util::stats::{format_ns, Summary};
use metl::workload;

fn main() {
    let mut artifact = Artifact::new("mapping_latency");
    section("§7 day trace: 1168 CDC events, 3 cache-evicting DMM updates");
    let cfg = PipelineConfig::paper_day();
    let mut rng = Rng::seed_from(cfg.seed);
    let mut land = workload::generate(&cfg);
    workload::populate(&mut land, 20, &mut rng);
    let ops = workload::day_trace(&cfg, &mut rng);
    let pipeline = Pipeline::from_landscape(cfg, land).unwrap();
    let report = pipeline.run_trace(&ops).unwrap();
    let s = pipeline.metrics.map_latency.summary();
    println!(
        "  events={} mean={} sigma={} p50={} p90={} p99={} max={}",
        report.events,
        format_ns(s.mean),
        format_ns(s.std),
        format_ns(s.p50),
        format_ns(s.p90),
        format_ns(s.p99),
        format_ns(s.max)
    );
    println!(
        "  paper: mean 39 ms, sigma 51 ms (Docker/JVM testbed); this \
         in-proc sim reproduces the SHAPE: warm mode + eviction tail"
    );
    // warm vs tail split
    let mut samples = pipeline.metrics.map_latency.samples();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let warm = Summary::from(&samples[..samples.len() * 9 / 10]);
    let tail = Summary::from(&samples[samples.len() * 9 / 10..]);
    println!(
        "  warm bracket (90%): mean={} | tail (10%): mean={} ({}x warm — \
         the paper's post-eviction spikes)",
        format_ns(warm.mean),
        format_ns(tail.mean),
        (tail.mean / warm.mean).round()
    );
    artifact.set_summary_ns("day_map_latency_ns", &s);
    artifact.set_num("warm_bracket_mean_ns", warm.mean);
    artifact.set_num("tail_bracket_mean_ns", tail.mean);

    section("single-message latency: Alg 1 (baseline) vs Alg 6 (DMM)");
    let cfg = PipelineConfig::paper_day();
    let land = workload::generate(&cfg);
    let dpm = Arc::new(
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap(),
    );
    let cache = Arc::new(DcpmCache::new(StateI(0)));
    let mapper = ParallelMapper::new(Arc::clone(&dpm), cache);
    let baseline =
        BaselineMapper::new(&land.matrix, &land.tree, &land.cdm, StateI(0));
    let mut rng = Rng::seed_from(9);
    let msgs: Vec<InMessage> = (0..200)
        .map(|k| {
            let s = land.tree.schemas().nth(k % 80).unwrap();
            let v = *s.versions.last().unwrap();
            let row = metl::source::random_row(&land.tree, s.id, v, k as u64, &mut rng, 0.25);
            let sv = land.tree.version(s.id, v).unwrap();
            InMessage {
                key: k as u64,
                schema: s.id,
                version: v,
                state: StateI(0),
                ts_us: 0,
                fields: sv
                    .attrs
                    .iter()
                    .copied()
                    .zip(row.values)
                    .collect(),
            }
        })
        .collect();
    let dense: Vec<InMessage> = msgs.iter().map(|m| m.to_dense()).collect();

    let bench = Bench::new(2, 8);
    let s1 = bench.run("Alg 1 sparse sequential (200 msgs)", || {
        msgs.iter()
            .map(|m| baseline.map(m).unwrap().len())
            .sum::<usize>()
    });
    let s6 = bench.run("Alg 6 dense DMM       (200 msgs)", || {
        dense
            .iter()
            .map(|m| mapper.map(m).unwrap().len())
            .sum::<usize>()
    });
    println!(
        "  speedup Alg6 over Alg1: {:.1}x (paper: the DMM enables the \
         near-real-time path)",
        s1.mean / s6.mean
    );
    assert!(
        s6.mean < s1.mean,
        "the dense DMM path must beat the sparse baseline"
    );
    artifact.set_summary_ns("alg1_batch_ns", &s1);
    artifact.set_summary_ns("alg6_batch_ns", &s6);
    artifact.set_num("alg6_over_alg1_speedup", s1.mean / s6.mean);
    artifact.write_default().unwrap();
    println!("\nmapping_latency bench OK");
}
