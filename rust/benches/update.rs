//! Bench: §3.5/§5.4/fig 6 — automated DMM updates. The paper's point:
//! a version addition touches up to ~100k raw matrix parameters
//! ("virtually impossible to update for a user"), but the set-based
//! Alg 5 performs work proportional only to the *stored* elements.

#[path = "harness.rs"]
mod harness;

use harness::{section, Artifact, Bench};
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::matrix::dpm::DpmSet;
use metl::matrix::update::{auto_update, ChangeCase};
use metl::message::StateI;
use metl::workload;

fn main() {
    let mut artifact = Artifact::new("update");
    section("raw diff size vs Alg-5 set operations (per version addition)");
    let mut cfg = PipelineConfig::eos_scale();
    cfg.n_services = 60;
    cfg.n_entities = 60;
    let mut land = workload::generate(&cfg);
    let dpm0 =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    // one schema version addition: raw parameter diff = new columns x all
    // live rows (the naive update surface the paper deems impossible)
    let schema = land.tree.schemas().next().unwrap().id;
    let live_rows: usize = land
        .cdm
        .entities()
        .flat_map(|e| {
            e.versions
                .iter()
                .map(|&w| land.cdm.version(e.id, w).unwrap().height())
        })
        .sum();
    let new_cols = cfg.attrs_per_schema + 1;
    println!(
        "  raw diff surface: {} new columns x {} live rows = {} parameters",
        new_cols,
        live_rows,
        new_cols * live_rows
    );

    let fields = workload::evolved_fields(&land.tree, schema);
    let v_new = land.tree.add_version(schema, &fields);
    let (nr, nc) = (land.cdm.n_attr_ids(), land.tree.n_attr_ids());
    land.matrix.grow(nr, nc);
    let mut dpm = dpm0.clone();
    let report = auto_update(
        &mut dpm,
        &land.tree,
        &land.cdm,
        ChangeCase::AddedSchemaVersion { schema, v: v_new },
        StateI(1),
    );
    println!(
        "  Alg 5 set ops: +{} elements in {} blocks ({} notices) — {}x \
         smaller than the raw surface",
        report.elements_added,
        report.blocks_added,
        report.notices.len(),
        (new_cols * live_rows) / report.diff_elements().max(1)
    );

    section("Alg 5 case timing (eos_scale- landscape)");
    let bench = Bench::new(3, 15);
    // case 3: added schema version
    let s_c3 = bench.run("case 3: added schema version (copy via ≡)", || {
        let mut d = dpm0.clone();
        auto_update(
            &mut d,
            &land.tree,
            &land.cdm,
            ChangeCase::AddedSchemaVersion { schema, v: v_new },
            StateI(1),
        )
        .elements_added
    });
    // case 1: deleted schema version
    let v1 = metl::schema::VersionNo(1);
    let s_c1 = bench.run("case 1: deleted schema version (drop column)", || {
        let mut d = dpm0.clone();
        auto_update(
            &mut d,
            &land.tree,
            &land.cdm,
            ChangeCase::DeletedSchemaVersion { schema, v: v1 },
            StateI(1),
        )
        .elements_removed
    });
    // case 4: added CDM version (+ §5.4.3 cleanup)
    let entity = land.cdm.entities().next().unwrap().id;
    let cdm_fields: Vec<(String, metl::cdm::CdmType, String)> = {
        let w = *land.cdm.versions_of(entity).last().unwrap();
        land.cdm
            .version(entity, w)
            .unwrap()
            .attrs
            .iter()
            .map(|&a| {
                let at = land.cdm.attr(a);
                (at.name.clone(), at.ty, at.description.clone())
            })
            .collect()
    };
    let w_new = land.cdm.add_version(entity, &cdm_fields);
    let (nr, nc) = (land.cdm.n_attr_ids(), land.tree.n_attr_ids());
    land.matrix.grow(nr, nc);
    let s_c4 = bench.run("case 4: added CDM version (+cleanup)", || {
        let mut d = dpm0.clone();
        auto_update(
            &mut d,
            &land.tree,
            &land.cdm,
            ChangeCase::AddedCdmVersion { entity, w: w_new },
            StateI(1),
        )
        .elements_added
    });
    // case 2: deleted CDM version
    let w1 = metl::cdm::CdmVersionNo(1);
    let s_c2 = bench.run("case 2: deleted CDM version (drop row)", || {
        let mut d = dpm0.clone();
        auto_update(
            &mut d,
            &land.tree,
            &land.cdm,
            ChangeCase::DeletedCdmVersion { entity, w: w1 },
            StateI(1),
        )
        .elements_removed
    });
    artifact.set_summary_ns("case3_added_schema_version_ns", &s_c3);
    artifact.set_summary_ns("case1_deleted_schema_version_ns", &s_c1);
    artifact.set_summary_ns("case4_added_cdm_version_ns", &s_c4);
    artifact.set_summary_ns("case2_deleted_cdm_version_ns", &s_c2);

    section("update-vs-recompute (the automation dividend)");
    let bench = Bench::new(2, 8);
    let su = bench.run("Alg 5 incremental update", || {
        let mut d = dpm0.clone();
        auto_update(
            &mut d,
            &land.tree,
            &land.cdm,
            ChangeCase::AddedSchemaVersion { schema, v: v_new },
            StateI(1),
        )
        .elements_added
    });
    let sr = bench.run("full recompute (Alg 2 from matrix)", || {
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(1))
            .unwrap()
            .n_elements()
    });
    println!(
        "  incremental update is {:.0}x faster than recompute",
        sr.mean / su.mean
    );
    artifact.set_summary_ns("alg5_update_ns", &su);
    artifact.set_summary_ns("recompute_ns", &sr);
    artifact.set_num("update_over_recompute_speedup", sr.mean / su.mean);

    section("full workflow (pipeline storm incl. store + cache eviction)");
    let cfg2 = PipelineConfig::paper_day();
    let pipeline = Pipeline::new(cfg2).unwrap();
    let bench = Bench::new(1, 5);
    let mut svc = 0usize;
    let s_wf = bench.run("apply_schema_change end-to-end", || {
        svc += 1;
        pipeline.apply_schema_change(svc % 80).unwrap().elements_added
    });
    artifact.set_summary_ns("apply_schema_change_ns", &s_wf);
    artifact.write_default().unwrap();
    println!("\nupdate bench OK");
}
