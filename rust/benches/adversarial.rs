//! Bench: adversarial workload engine, the numbers behind `BENCH_8.json`.
//!
//! Runs every [`Scenario`] end-to-end through the sharded pipeline via
//! the conformance [`ScenarioRunner`] (redelivery exercise off — this
//! measures steady-state throughput, not the crash seam) and records
//! events/s per scenario. The acceptance bound from the scenario
//! conformance work: the Zipfian hot-key/hot-schema axis stays within 3×
//! of the uniform baseline (skew must degrade gracefully, not collapse).
//!
//! Flags (after `cargo bench --bench adversarial --`):
//!   --smoke           reduced event count + small profile (CI shape check)
//!   --scenario NAME   run only this hostile scenario besides the
//!                     uniform + zipf required axes
//!   --out PATH        artifact destination (default ../BENCH_8.json from
//!                     the crate root, i.e. the repo-root baseline)
//!   --validate PATH   validate an existing artifact's schema and exit

#[path = "harness.rs"]
mod harness;

use harness::{arg_value, has_flag, section, Artifact};
use metl::config::PipelineConfig;
use metl::util::json::Json;
use metl::workload::adversarial::Scenario;
use metl::workload::scenario::{ScenarioOutcome, ScenarioRunner};

/// Metrics every `BENCH_8.json`-shaped artifact must carry (dotted paths
/// under `metrics`; shared by `--validate` and the CI bench-smoke job).
const REQUIRED: &[&str] = &["uniform_eps", "zipf_eps", "zipf_over_uniform"];

const SHARDS: usize = 4;

fn metric_key(s: Scenario) -> String {
    format!("{}_eps", s.name().replace('-', "_"))
}

fn run_scenario(cfg: &PipelineConfig, scenario: Scenario) -> ScenarioOutcome {
    let mut runner = ScenarioRunner::new(cfg.clone(), scenario);
    runner.exercise_redelivery = false;
    let runner = runner.shards(SHARDS);
    let (pipeline, outcome) = runner.run().unwrap();
    assert_eq!(
        outcome.events_in, outcome.published,
        "{scenario}: published records went unconsumed"
    );
    assert_eq!(
        pipeline.metrics.transformations.get() + outcome.dead_letters,
        outcome.events_in,
        "{scenario}: silent drop"
    );
    outcome
}

fn main() {
    if let Some(path) = arg_value("--validate") {
        match harness::validate_artifact_file(&path, "adversarial", REQUIRED) {
            Ok(()) => {
                println!("{path}: valid adversarial artifact");
                return;
            }
            Err(e) => {
                eprintln!("invalid adversarial artifact: {e}");
                std::process::exit(1);
            }
        }
    }

    let smoke = has_flag("--smoke");
    let mut cfg =
        if smoke { PipelineConfig::small() } else { PipelineConfig::paper_day() };
    cfg.trace_events = if smoke { 2_000 } else { 20_000 };
    let profile = if smoke { "small" } else { "paper_day" };
    let pinned = arg_value("--scenario").map(|name| {
        Scenario::from_name(&name).unwrap_or_else(|| {
            eprintln!(
                "unknown scenario {name:?}; known: {}",
                Scenario::ALL.map(|s| s.name()).join(", ")
            );
            std::process::exit(1);
        })
    });
    let mut artifact = Artifact::new("adversarial");
    artifact
        .meta("profile", Json::Str(profile.to_string()))
        .meta("smoke", Json::Bool(smoke))
        .meta("events", Json::Num(cfg.trace_events as f64))
        .meta("shards", Json::Num(SHARDS as f64));

    section(&format!(
        "adversarial scenarios: {} events, {SHARDS} shards ({profile})",
        cfg.trace_events
    ));
    println!(
        "  {:<18} {:>14} {:>10} {:>10} {:>8}",
        "scenario", "events/s", "published", "dlq", "vs unif"
    );

    // uniform + zipf always run: they anchor the required ratio axis
    let mut axis: Vec<Scenario> = vec![Scenario::Uniform, Scenario::Zipf];
    match pinned {
        Some(s) => {
            if !axis.contains(&s) {
                axis.push(s);
            }
        }
        None => axis.extend(
            Scenario::HOSTILE
                .iter()
                .copied()
                .filter(|s| *s != Scenario::Zipf),
        ),
    }

    let mut uniform_eps = 0.0;
    let mut zipf_eps = 0.0;
    for &scenario in &axis {
        let outcome = run_scenario(&cfg, scenario);
        let eps = outcome.report.throughput_eps();
        println!(
            "  {:<18} {:>14.0} {:>10} {:>10} {:>7.2}x",
            scenario.name(),
            eps,
            outcome.published,
            outcome.dead_letters,
            if uniform_eps > 0.0 { uniform_eps / eps } else { 1.0 }
        );
        match scenario {
            Scenario::Uniform => uniform_eps = eps,
            Scenario::Zipf => zipf_eps = eps,
            _ => {}
        }
        artifact.set_num(&metric_key(scenario), eps);
    }

    let ratio = uniform_eps / zipf_eps.max(1e-9);
    println!(
        "  zipf slowdown vs uniform: {ratio:.2}x (acceptance bound: < 3x)"
    );
    artifact.set_num("zipf_over_uniform", ratio);
    if !smoke {
        assert!(
            ratio < 3.0,
            "Zipfian skew degraded throughput {ratio:.2}x vs uniform (bound 3x)"
        );
    }

    // --- emit ------------------------------------------------------------
    let out =
        arg_value("--out").unwrap_or_else(|| "../BENCH_8.json".to_string());
    artifact.write(&out).unwrap();
    if let Err(e) =
        harness::validate_artifact_file(&out, "adversarial", REQUIRED)
    {
        eprintln!("emitted artifact failed self-validation: {e}");
        std::process::exit(1);
    }
    println!("\nadversarial bench OK");
}
