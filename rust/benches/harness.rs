//! Minimal bench harness shared by all bench binaries (criterion is not
//! available offline; see DESIGN.md §2). Prints one row per measurement:
//! mean ± σ with percentiles over `iters` timed runs after `warmup` runs.
//!
//! Every bench binary also emits a machine-readable JSON [`Artifact`]
//! (default `target/bench-artifacts/<bench>.json`, overridable with
//! `--out PATH`) so CI and the checked-in `BENCH_<n>.json` baselines can
//! be diffed without scraping stdout. See README §Benchmarks for the
//! schema.

#![allow(dead_code)] // each bench binary uses a subset of the harness

use metl::util::json::Json;
use metl::util::stats::{format_ns, Summary};
use std::time::Instant;

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters }
    }

    /// Time `f` and print a row. Returns the summary (ns).
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Summary {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let s = Summary::from(&samples);
        println!("  {name:<44} {}", s.row(format_ns));
        s
    }
}

/// Section header helper.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// The value following `flag` on the bench command line, if present
/// (cargo passes everything after `--` through to the bench binary).
pub fn arg_value(flag: &str) -> Option<String> {
    std::env::args().skip_while(|a| a != flag).nth(1)
}

/// Whether a bare `flag` is present on the bench command line.
pub fn has_flag(flag: &str) -> bool {
    std::env::args().any(|a| a == flag)
}

/// Machine-readable bench result, serialized as pretty JSON:
///
/// ```json
/// { "schema_version": 1, "bench": "<name>", "metrics": { ... } }
/// ```
///
/// Metric values are numbers, strings, or latency-summary objects
/// (`set_summary_ns`) with `count/mean/std/p50/p90/p99` in nanoseconds.
pub struct Artifact {
    name: String,
    meta: Vec<(String, Json)>,
    metrics: Vec<(String, Json)>,
}

impl Artifact {
    pub fn new(name: &str) -> Artifact {
        Artifact {
            name: name.to_string(),
            meta: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Attach a top-level metadata field (profile, smoke, iters, ...).
    pub fn meta(&mut self, key: &str, value: Json) -> &mut Self {
        self.meta.push((key.to_string(), value));
        self
    }

    /// Record one metric.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        self.metrics.push((key.to_string(), value));
        self
    }

    pub fn set_num(&mut self, key: &str, v: f64) -> &mut Self {
        self.set(key, Json::Num(v))
    }

    /// Record a latency [`Summary`] (nanoseconds) as a nested object.
    pub fn set_summary_ns(&mut self, key: &str, s: &Summary) -> &mut Self {
        self.set(key, summary_json(s))
    }

    pub fn to_json(&self) -> Json {
        let mut top = vec![
            ("schema_version".to_string(), Json::Num(1.0)),
            ("bench".to_string(), Json::Str(self.name.clone())),
        ];
        top.extend(self.meta.iter().cloned());
        top.push(("metrics".to_string(), Json::Obj(self.metrics.clone())));
        Json::Obj(top)
    }

    /// Write the artifact to `path` (creating parent dirs) and say so.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_pretty() + "\n")?;
        println!("  artifact -> {path}");
        Ok(())
    }

    /// Write to `--out PATH` if given, else the default
    /// `target/bench-artifacts/<bench>.json`.
    pub fn write_default(&self) -> std::io::Result<()> {
        let path = arg_value("--out")
            .unwrap_or_else(|| format!("target/bench-artifacts/{}.json", self.name));
        self.write(&path)
    }
}

fn summary_json(s: &Summary) -> Json {
    Json::Obj(vec![
        ("count".to_string(), Json::Num(s.count as f64)),
        ("mean".to_string(), Json::Num(s.mean)),
        ("std".to_string(), Json::Num(s.std)),
        ("p50".to_string(), Json::Num(s.p50)),
        ("p90".to_string(), Json::Num(s.p90)),
        ("p99".to_string(), Json::Num(s.p99)),
    ])
}

/// Validate an artifact file: well-formed JSON, `schema_version` 1, the
/// expected `bench` name, and every dotted path in `required` present
/// under `metrics` as a number. Returns the error text instead of
/// panicking so bench binaries can exit(1) with a readable message.
pub fn validate_artifact_file(
    path: &str,
    bench: &str,
    required: &[&str],
) -> Result<(), String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{path}: {e}"))?;
    let json = metl::util::json::parse(&text)
        .map_err(|e| format!("{path}: invalid JSON: {e}"))?;
    let get = |obj: &Json, key: &str| -> Option<Json> {
        match obj {
            Json::Obj(fields) => fields
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v.clone()),
            _ => None,
        }
    };
    match get(&json, "schema_version") {
        Some(Json::Num(v)) if v == 1.0 => {}
        other => return Err(format!("{path}: bad schema_version {other:?}")),
    }
    match get(&json, "bench") {
        Some(Json::Str(name)) if name == bench => {}
        other => {
            return Err(format!("{path}: bench != {bench:?} (got {other:?})"))
        }
    }
    let metrics = get(&json, "metrics")
        .ok_or_else(|| format!("{path}: missing metrics object"))?;
    for dotted in required {
        let mut cur = metrics.clone();
        for part in dotted.split('.') {
            cur = get(&cur, part).ok_or_else(|| {
                format!("{path}: missing metric {dotted}")
            })?;
        }
        match cur {
            Json::Num(v) if v.is_finite() => {}
            other => {
                return Err(format!(
                    "{path}: metric {dotted} is not a finite number ({other:?})"
                ))
            }
        }
    }
    Ok(())
}

/// Allow the harness file to compile standalone if cargo ever treats it as
/// a bench target root (it should not — it is `#[path]`-included).
#[allow(dead_code)]
fn main() {}
