//! Minimal bench harness shared by all bench binaries (criterion is not
//! available offline; see DESIGN.md §2). Prints one row per measurement:
//! mean ± σ with percentiles over `iters` timed runs after `warmup` runs.

#![allow(dead_code)] // each bench binary uses a subset of the harness

use metl::util::stats::{format_ns, Summary};
use std::time::Instant;

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters }
    }

    /// Time `f` and print a row. Returns the summary (ns).
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Summary {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let s = Summary::from(&samples);
        println!("  {name:<44} {}", s.row(format_ns));
        s
    }
}

/// Section header helper.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Allow the harness file to compile standalone if cargo ever treats it as
/// a bench target root (it should not — it is `#[path]`-included).
#[allow(dead_code)]
fn main() {}
