//! Bench: §5.3.3/§6.2 — the hybrid strategy's restore path. The stored
//! `ᵢ𝔇𝔘𝔖𝔅` must rebuild the in-memory `ᵢ𝔇𝔓𝔐` (Alg 4 then Alg 2)
//! fast enough for restarts and instance copies.

#[path = "harness.rs"]
mod harness;

use harness::{section, Artifact, Bench};
use metl::config::PipelineConfig;
use metl::matrix::decompact::recreate_dpm;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::message::StateI;
use metl::store::MatrixStore;
use metl::workload;

fn main() {
    let mut artifact = Artifact::new("decompact");
    for (name, cfg) in [
        ("paper_day", PipelineConfig::paper_day()),
        ("eos_scale-", {
            let mut c = PipelineConfig::eos_scale();
            c.n_services = 60;
            c.n_entities = 60;
            c
        }),
    ] {
        section(&format!("restore path @ {name}"));
        let land = workload::generate(&cfg);
        let dpm_direct =
            DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
                .unwrap();
        let dusb =
            DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
                .unwrap();
        println!(
            "  DPM {} elements | DUSB {} elements (+{} special nulls)",
            dpm_direct.n_elements(),
            dusb.n_elements(),
            dusb.n_special_nulls()
        );

        let bench = Bench::new(2, 10);
        let key = name.replace('-', "_");
        let s4 = bench.run("Alg 4: DUSB -> M", || {
            dusb.decompact(&land.tree, &land.cdm).count_ones()
        });
        let sv = bench.run("view: DUSB -> M -> DPM", || {
            recreate_dpm(&dusb, &land.tree, &land.cdm)
                .unwrap()
                .n_elements()
        });
        artifact.set_summary_ns(&format!("alg4_decompact_ns_{key}"), &s4);
        artifact.set_summary_ns(&format!("recreate_dpm_ns_{key}"), &sv);
        // correctness of the restore
        let restored = recreate_dpm(&dusb, &land.tree, &land.cdm).unwrap();
        assert!(dpm_direct.same_elements(&restored));

        // store round trip (segment write + manifest swap + parse)
        let dir = metl::util::tmp::TestDir::new(&format!("bench-store-{name}"));
        let store = MatrixStore::open(dir.path()).unwrap();
        let ss = bench.run("store: save DUSB segment", || {
            store.save_dusb(&dusb, &land.tree).unwrap()
        });
        let sl = bench.run("store: load + recreate DPM", || {
            store
                .view_recreate_dpm(&land.tree, &land.cdm)
                .unwrap()
                .unwrap()
                .n_elements()
        });
        artifact.set_summary_ns(&format!("store_save_ns_{key}"), &ss);
        artifact.set_summary_ns(&format!("store_load_ns_{key}"), &sl);
    }
    artifact.write_default().unwrap();
    println!("\ndecompact bench OK");
}
