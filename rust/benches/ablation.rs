//! Ablation bench for the design choices DESIGN.md calls out:
//!   (a) the ᵢ𝒟𝒞𝒫𝓜 column cache (§6.2) — on vs off (evict every event);
//!   (b) dense vs sparse message discipline (§5.5 removed the baseline's
//!       all-attributes-present rule);
//!   (c) block-parallel threshold of Alg 6 (thread fan-out vs tight loop);
//!   (d) hybrid storage: mapping straight from a decompacted-on-demand
//!       DUSB vs the resident DPM (why the hybrid keeps ᵢ𝔇𝔓𝔐 in memory).

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use harness::{section, Artifact, Bench};
use metl::cache::DcpmCache;
use metl::config::PipelineConfig;
use metl::mapper::parallel::ParallelMapper;
use metl::matrix::decompact::recreate_dpm;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::message::{InMessage, StateI};
use metl::util::rng::Rng;
use metl::workload;

fn messages(
    land: &workload::Landscape,
    cfg: &PipelineConfig,
    n: usize,
) -> Vec<InMessage> {
    let mut rng = Rng::seed_from(17);
    (0..n)
        .map(|k| {
            let s = land.tree.schemas().nth(k % cfg.n_services).unwrap();
            let v = *s.versions.last().unwrap();
            let sv = land.tree.version(s.id, v).unwrap();
            let row = metl::source::random_row(
                &land.tree, s.id, v, k as u64, &mut rng, 0.25,
            );
            InMessage {
                key: k as u64,
                schema: s.id,
                version: v,
                state: StateI(0),
                ts_us: 0,
                fields: sv.attrs.iter().copied().zip(row.values).collect(),
            }
        })
        .collect()
}

fn main() {
    let cfg = PipelineConfig::paper_day();
    let land = workload::generate(&cfg);
    let dpm = Arc::new(
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap(),
    );
    let msgs = messages(&land, &cfg, 2_000);
    let dense: Vec<InMessage> = msgs.iter().map(|m| m.to_dense()).collect();
    let bench = Bench::new(2, 10);
    let mut artifact = Artifact::new("ablation");

    section("(a) column cache on vs off (2000 msgs)");
    let cache = Arc::new(DcpmCache::new(StateI(0)));
    let mapper = ParallelMapper::new(Arc::clone(&dpm), Arc::clone(&cache));
    let warm = bench.run("cache on (warm)", || {
        dense.iter().map(|m| mapper.map(m).unwrap().len()).sum::<usize>()
    });
    let cold = bench.run("cache off (evict every message)", || {
        dense
            .iter()
            .map(|m| {
                cache.evict_all(StateI(0));
                mapper.map(m).unwrap().len()
            })
            .sum::<usize>()
    });
    println!(
        "  cache dividend: {:.1}x (the §7 eviction-spike mechanism)",
        cold.mean / warm.mean
    );
    artifact.set_summary_ns("cache_on_ns", &warm);
    artifact.set_summary_ns("cache_off_ns", &cold);
    artifact.set_num("cache_dividend", cold.mean / warm.mean);

    section("(b) dense vs sparse message discipline (2000 msgs)");
    let s_dense = bench.run("dense messages (§5.5 rule)", || {
        dense.iter().map(|m| mapper.map(m).unwrap().len()).sum::<usize>()
    });
    let s_sparse = bench.run("sparse messages (nulls included)", || {
        msgs.iter().map(|m| mapper.map(m).unwrap().len()).sum::<usize>()
    });
    println!(
        "  dense dividend: {:.2}x fewer field scans",
        s_sparse.mean / s_dense.mean
    );
    artifact.set_summary_ns("dense_msgs_ns", &s_dense);
    artifact.set_summary_ns("sparse_msgs_ns", &s_sparse);

    section("(c) Alg 6 block-parallel threshold");
    for threshold in [1usize, 4, usize::MAX] {
        let mut m2 = ParallelMapper::new(Arc::clone(&dpm), Arc::clone(&cache));
        m2.block_parallel_threshold = threshold;
        let (label, key) = match threshold {
            1 => ("always spawn (threshold 1)", "threshold_1"),
            4 => ("default (threshold 4)", "threshold_4"),
            _ => ("never spawn (sequential)", "threshold_seq"),
        };
        let s = bench.run(label, || {
            dense.iter().map(|m| m2.map(m).unwrap().len()).sum::<usize>()
        });
        artifact.set_summary_ns(&format!("block_parallel_{key}_ns"), &s);
    }

    section("(d) hybrid storage: resident DPM vs decompact-on-demand DUSB");
    let dusb =
        DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    let resident = bench.run("resident DPM (hybrid, §6.2)", || {
        dense
            .iter()
            .take(50)
            .map(|m| mapper.map(m).unwrap().len())
            .sum::<usize>()
    });
    let on_demand = bench.run("decompact DUSB per batch of 50", || {
        let d = Arc::new(recreate_dpm(&dusb, &land.tree, &land.cdm).unwrap());
        let c = Arc::new(DcpmCache::new(StateI(0)));
        let m2 = ParallelMapper::new(d, c);
        dense
            .iter()
            .take(50)
            .map(|m| m2.map(m).unwrap().len())
            .sum::<usize>()
    });
    println!(
        "  hybrid dividend: {:.0}x — why ᵢ𝔇𝔓𝔐 stays in memory and \
         ᵢ𝔇𝔘𝔖𝔅 is the storage form",
        on_demand.mean / resident.mean
    );
    artifact.set_summary_ns("resident_dpm_ns", &resident);
    artifact.set_summary_ns("decompact_on_demand_ns", &on_demand);
    artifact.set_num("hybrid_dividend", on_demand.mean / resident.mean);
    artifact.write_default().unwrap();
    println!("\nablation bench OK");
}
