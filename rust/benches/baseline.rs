//! Bench: the checked-in performance baseline behind `BENCH_6.json`.
//!
//! Measures the repo's four headline axes on one binary so regressions
//! are diffable against the committed artifact:
//!
//!   1. end-to-end throughput (events/s) through the Alg-6 lane,
//!   2. per-message mapping latency (p50/p99 ns),
//!   3. Alg-5 update latency under the targeted-eviction default,
//!   4. the native block-permutation kernel vs the scalar Alg-6 lane on
//!      identical message batches (the tentpole speedup).
//!
//! Flags (after `cargo bench --bench baseline --`):
//!   --smoke           reduced iterations + small profile (CI shape check)
//!   --out PATH        artifact destination (default ../BENCH_6.json from
//!                     the crate root, i.e. the repo-root baseline)
//!   --validate PATH   validate an existing artifact's schema and exit

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use harness::{arg_value, has_flag, section, Artifact, Bench};
use metl::cache::DcpmCache;
use metl::config::PipelineConfig;
use metl::coordinator::{pipeline::Pipeline, scaler};
use metl::mapper::kernel::KernelMode;
use metl::mapper::parallel::ParallelMapper;
use metl::matrix::dpm::DpmSet;
use metl::message::{InMessage, StateI};
use metl::util::json::Json;
use metl::util::rng::Rng;
use metl::util::stats::format_ns;
use metl::workload::{self, DmlKind, TraceOp};

/// Metrics every `BENCH_6.json`-shaped artifact must carry (dotted paths
/// under `metrics`; shared by `--validate` and the CI bench-smoke job).
const REQUIRED: &[&str] = &[
    "throughput_eps",
    "mapping_latency_ns.p50",
    "mapping_latency_ns.p99",
    "update_latency_ns.mean",
    "kernel.native_batch_ns.mean",
    "kernel.scalar_batch_ns.mean",
    "kernel.native_over_scalar_speedup",
];

fn main() {
    if let Some(path) = arg_value("--validate") {
        match harness::validate_artifact_file(&path, "baseline", REQUIRED) {
            Ok(()) => {
                println!("{path}: valid baseline artifact");
                return;
            }
            Err(e) => {
                eprintln!("invalid baseline artifact: {e}");
                std::process::exit(1);
            }
        }
    }

    let smoke = has_flag("--smoke");
    let (cfg, backlog, batch, iters) = if smoke {
        (PipelineConfig::small(), 2_000usize, 400usize, 3usize)
    } else {
        let mut cfg = PipelineConfig::paper_day();
        cfg.partitions = 16;
        (cfg, 40_000, 2_000, 10)
    };
    let profile = if smoke { "small" } else { "paper_day" };
    let mut artifact = Artifact::new("baseline");
    artifact
        .meta("profile", Json::Str(profile.to_string()))
        .meta("smoke", Json::Bool(smoke))
        .meta("iters", Json::Num(iters as f64));

    // --- axis 1+2: end-to-end throughput + mapping latency ---------------
    section(format!("throughput + mapping latency ({backlog} events)").as_str());
    let p = {
        let mut land = workload::generate(&cfg);
        let mut rng = Rng::seed_from(cfg.seed ^ 0xFEED);
        workload::populate(&mut land, 50, &mut rng);
        let p = Pipeline::from_landscape(cfg.clone(), land).unwrap();
        for i in 0..backlog {
            p.resolve_op(&TraceOp::Dml {
                service: i % cfg.n_services,
                kind: if i % 3 == 0 { DmlKind::Update } else { DmlKind::Insert },
            })
            .unwrap();
        }
        p
    };
    let t0 = std::time::Instant::now();
    let report = scaler::run_scaled(&p, 1);
    let eps = report.processed as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(report.processed as usize, backlog);
    assert_eq!(p.metrics.dead_letters.get(), 0);
    let map = p.metrics.map_latency.summary();
    println!(
        "  {eps:>10.0} events/s | map p50={} p99={}",
        format_ns(map.p50),
        format_ns(map.p99)
    );
    artifact.set_num("throughput_eps", eps);
    artifact.set_summary_ns("mapping_latency_ns", &map);

    // --- axis 3: Alg-5 update latency (targeted eviction default) ---------
    section("update latency (Alg-5 storms, targeted eviction)");
    let storms = if smoke { 3 } else { 8 };
    for i in 0..storms {
        p.apply_schema_change(i % cfg.n_services).unwrap();
    }
    let upd = p.metrics.update_latency.summary();
    println!(
        "  {} storms: mean={} p99={}",
        storms,
        format_ns(upd.mean),
        format_ns(upd.p99)
    );
    artifact.set_summary_ns("update_latency_ns", &upd);

    // --- axis 4: native kernel vs scalar Alg-6 lane -----------------------
    section(format!("native vs scalar kernel ({batch}-message batches)").as_str());
    let land = workload::generate(&cfg);
    let dpm = Arc::new(
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap(),
    );
    let native = ParallelMapper::with_threads(
        Arc::clone(&dpm),
        Arc::new(DcpmCache::new(StateI(0))),
        1,
    )
    .with_kernel(KernelMode::Native);
    let scalar = ParallelMapper::with_threads(
        Arc::clone(&dpm),
        Arc::new(DcpmCache::new(StateI(0))),
        1,
    )
    .with_kernel(KernelMode::Scalar);
    let mut rng = Rng::seed_from(3);
    let msgs: Vec<InMessage> = (0..batch)
        .map(|k| {
            let s = land.tree.schemas().nth(k % cfg.n_services).unwrap();
            let v = *s.versions.last().unwrap();
            let sv = land.tree.version(s.id, v).unwrap();
            let row = metl::source::random_row(
                &land.tree, s.id, v, k as u64, &mut rng, 0.25,
            );
            InMessage {
                key: k as u64,
                schema: s.id,
                version: v,
                state: StateI(0),
                ts_us: 0,
                fields: sv.attrs.iter().copied().zip(row.values).collect(),
            }
            .to_dense()
        })
        .collect();
    // identical outputs before timing anything
    for m in &msgs {
        assert_eq!(native.map(m), scalar.map(m), "kernel lanes diverged");
    }
    let bench = Bench::new(if smoke { 1 } else { 3 }, iters);
    let s_native = bench.run("native block-permutation kernel", || {
        msgs.iter()
            .map(|m| native.map(m).map(|o| o.len()).unwrap_or(0))
            .sum::<usize>()
    });
    let s_scalar = bench.run("scalar Alg-6 lane", || {
        msgs.iter()
            .map(|m| scalar.map(m).map(|o| o.len()).unwrap_or(0))
            .sum::<usize>()
    });
    let speedup = s_scalar.mean / s_native.mean.max(1.0);
    println!("  native speedup over scalar: {speedup:.2}x");
    artifact.set(
        "kernel",
        Json::Obj(vec![
            ("native_batch_ns".to_string(), summary_obj(&s_native)),
            ("scalar_batch_ns".to_string(), summary_obj(&s_scalar)),
            (
                "native_over_scalar_speedup".to_string(),
                Json::Num(speedup),
            ),
        ]),
    );
    if !smoke {
        // the tentpole claim, enforced on real runs (smoke runs are too
        // short to be noise-free on shared CI runners)
        assert!(
            speedup > 1.0,
            "native kernel no faster than scalar lane ({speedup:.2}x)"
        );
    }

    // --- emit ------------------------------------------------------------
    let out = arg_value("--out").unwrap_or_else(|| "../BENCH_6.json".to_string());
    artifact.write(&out).unwrap();
    if let Err(e) = harness::validate_artifact_file(&out, "baseline", REQUIRED) {
        eprintln!("emitted artifact failed self-validation: {e}");
        std::process::exit(1);
    }
    println!("\nbaseline bench OK");
}

fn summary_obj(s: &metl::util::stats::Summary) -> Json {
    Json::Obj(vec![
        ("count".to_string(), Json::Num(s.count as f64)),
        ("mean".to_string(), Json::Num(s.mean)),
        ("std".to_string(), Json::Num(s.std)),
        ("p50".to_string(), Json::Num(s.p50)),
        ("p90".to_string(), Json::Num(s.p90)),
        ("p99".to_string(), Json::Num(s.p99)),
    ])
}
