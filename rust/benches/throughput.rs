//! Bench: §5.5 parallelism — end-to-end pipeline throughput across the
//! three lanes (Alg 1 baseline, Alg 6 DMM, XLA bulk), horizontal scaling
//! 1→8 instances over the partitioned CDC backlog (the paper's
//! initial-load scale-out), the sharded mapping lane with epoch-swapped
//! DMM snapshots (`--shards N` pins one shard count; default sweeps 1/2/4
//! and races an Alg-5 update against the drain), egress fan-out drain
//! throughput at 1/2/4 registered sinks (`--sinks N` pins one count),
//! and the online evolution lane under a change storm (`--evolve N` pins
//! the storm size): mapping-throughput dip and update latency with
//! targeted vs full cache eviction. A final adversarial lane drives one
//! hostile workload through the conformance runner (`--scenario NAME`
//! pins it; default `zipf` — see `benches/adversarial.rs` for the full
//! per-scenario sweep behind `BENCH_8.json`).

#[path = "harness.rs"]
mod harness;

use harness::{section, Artifact};
use metl::cache::EvictMode;
use metl::config::PipelineConfig;
use metl::coordinator::batcher::InitialLoader;
use metl::coordinator::pipeline::Pipeline;
use metl::coordinator::{scaler, shard};
use metl::mapper::baseline::BaselineMapper;
use metl::message::{InMessage, StateI};
use metl::runtime::BulkRuntime;
use metl::util::rng::Rng;
use metl::util::stats::format_ns;
use metl::workload::adversarial::Scenario;
use metl::workload::scenario::ScenarioRunner;
use metl::workload::{self, DmlKind, TraceOp};

const BACKLOG: usize = 80_000;

fn backlog_pipeline(cfg: &PipelineConfig) -> Pipeline {
    let mut land = workload::generate(cfg);
    let mut rng = Rng::seed_from(cfg.seed ^ 0xFEED);
    workload::populate(&mut land, 50, &mut rng);
    let p = Pipeline::from_landscape(cfg.clone(), land).unwrap();
    for i in 0..BACKLOG {
        p.resolve_op(&TraceOp::Dml {
            service: i % cfg.n_services,
            kind: if i % 3 == 0 { DmlKind::Update } else { DmlKind::Insert },
        })
        .unwrap();
    }
    p
}

fn main() {
    let mut cfg = PipelineConfig::paper_day();
    cfg.partitions = 16;
    let mut artifact = Artifact::new("throughput");

    section(format!("lane throughput over {BACKLOG} events").as_str());
    // --- Alg 6 lane (the production path) --------------------------------
    let p = backlog_pipeline(&cfg);
    let t0 = std::time::Instant::now();
    let report = scaler::run_scaled(&p, 1);
    let alg6_eps = report.processed as f64 / t0.elapsed().as_secs_f64();
    println!(
        "  Alg 6 lane (1 instance):       {:>10.0} events/s ({} events, {:?})",
        alg6_eps,
        report.processed,
        report.wall
    );
    artifact.set_num("alg6_pipeline_eps", alg6_eps);

    // --- raw mapper comparison on identical messages ----------------------
    // (mapper-only, no broker/metrics/sink overhead on either side)
    let land = workload::generate(&cfg);
    let baseline =
        BaselineMapper::new(&land.matrix, &land.tree, &land.cdm, StateI(0));
    let dpm = std::sync::Arc::new(
        metl::matrix::dpm::DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap(),
    );
    let cache = std::sync::Arc::new(metl::cache::DcpmCache::new(StateI(0)));
    let fast = metl::mapper::parallel::ParallelMapper::new(dpm, cache);
    let mut rng = Rng::seed_from(3);
    let msgs: Vec<InMessage> = (0..2_000)
        .map(|k| {
            let s = land.tree.schemas().nth(k % cfg.n_services).unwrap();
            let v = *s.versions.last().unwrap();
            let sv = land.tree.version(s.id, v).unwrap();
            let row = metl::source::random_row(
                &land.tree, s.id, v, k as u64, &mut rng, 0.25,
            );
            InMessage {
                key: k as u64,
                schema: s.id,
                version: v,
                state: StateI(0),
                ts_us: 0,
                fields: sv.attrs.iter().copied().zip(row.values).collect(),
            }
        })
        .collect();
    let dense: Vec<InMessage> = msgs.iter().map(|m| m.to_dense()).collect();
    let t0 = std::time::Instant::now();
    let n: usize = msgs.iter().map(|m| baseline.map(m).unwrap().len()).sum();
    let alg1_eps = msgs.len() as f64 / t0.elapsed().as_secs_f64();
    println!(
        "  Alg 1 raw (sparse sequential): {:>10.0} events/s ({n} outputs incl. all-null)",
        alg1_eps
    );
    let t0 = std::time::Instant::now();
    let n6: usize = dense.iter().map(|m| fast.map(m).unwrap().len()).sum();
    let alg6_raw_eps = dense.len() as f64 / t0.elapsed().as_secs_f64();
    println!(
        "  Alg 6 raw (dense DMM):         {:>10.0} events/s ({n6} non-empty outputs)",
        alg6_raw_eps
    );
    println!(
        "  raw speedup Alg6/Alg1: {:.1}x | full pipeline overhead over raw \
         Alg6: {:.1}x",
        alg6_raw_eps / alg1_eps,
        alg6_raw_eps / alg6_eps
    );
    assert!(alg6_raw_eps > alg1_eps);
    artifact.set_num("alg1_raw_eps", alg1_eps);
    artifact.set_num("alg6_raw_eps", alg6_raw_eps);

    // --- XLA bulk lane -----------------------------------------------------
    match BulkRuntime::try_load("artifacts") {
        None => println!("  XLA bulk lane: skipped (run `make artifacts`)"),
        Some(rt) => {
            let mut land = workload::generate(&cfg);
            let mut rng = Rng::seed_from(11);
            workload::populate(&mut land, 4_000, &mut rng);
            let p = Pipeline::from_landscape(cfg.clone(), land).unwrap();
            let loader = InitialLoader { runtime: Some(rt) };
            let t0 = std::time::Instant::now();
            let load = loader.initial_load(&p, 0).unwrap();
            let eps = load.rows as f64 / t0.elapsed().as_secs_f64();
            println!(
                "  XLA bulk lane (initial load):  {:>10.0} rows/s   ({} rows, bulk={})",
                eps, load.rows, load.used_bulk
            );
        }
    }

    section("horizontal scaling (one consumer group, stable state i)");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "  testbed has {cores} core(s): wallclock speedup requires >1; on a \
         single core this validates partition splitting + semantics only \
         (see integration_pipeline::scaled_processing_equivalent_to_single)"
    );
    println!(
        "  {:>10} {:>14} {:>12} {:>8}",
        "instances", "events/s", "wall", "scale"
    );
    let mut base = 0.0;
    for instances in [1usize, 2, 4, 8] {
        let p = backlog_pipeline(&cfg);
        let report = scaler::run_scaled(&p, instances);
        let eps = report.throughput_eps();
        if instances == 1 {
            base = eps;
        }
        println!(
            "  {:>10} {:>14.0} {:>12?} {:>7.2}x",
            instances, eps, report.wall, eps / base
        );
        assert_eq!(report.processed as usize, BACKLOG);
        artifact.set_num(&format!("scaling_eps_x{instances}"), eps);
    }

    section("sharded mapping lane (schema shards, epoch-swapped snapshots)");
    let shard_axis: Vec<usize> = std::env::args()
        .skip_while(|a| a != "--shards")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .map(|n| vec![n])
        .unwrap_or_else(|| vec![1, 2, 4]);
    println!(
        "  {:>10} {:>14} {:>12} {:>8}",
        "shards", "events/s", "wall", "scale"
    );
    let mut shard_base = 0.0;
    for (i, &shards) in shard_axis.iter().enumerate() {
        let p = backlog_pipeline(&cfg);
        let report = shard::run_sharded_drain(&p, shards);
        let eps = report.throughput_eps();
        if i == 0 {
            shard_base = eps;
        }
        println!(
            "  {:>10} {:>14.0} {:>12?} {:>7.2}x",
            shards, eps, report.wall, eps / shard_base
        );
        assert_eq!(report.processed as usize, BACKLOG);
        assert_eq!(p.metrics.dead_letters.get(), 0);
        artifact.set_num(&format!("shard_eps_x{shards}"), eps);
    }

    // no-stall check: an Alg-5 update racing the sharded drain must leave
    // p99 mapping latency in the same regime as the steady-state run
    let steady = backlog_pipeline(&cfg);
    let _ = shard::run_sharded_drain(&steady, 4);
    let steady_p99 = steady.metrics.map_latency.summary().p99;
    let stormy = backlog_pipeline(&cfg);
    let report = std::thread::scope(|scope| {
        let handle = scope.spawn(|| shard::run_sharded_drain(&stormy, 4));
        for svc in 0..3 {
            let _ = stormy.apply_schema_change(svc);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        handle.join().unwrap()
    });
    let stormy_p99 = stormy.metrics.map_latency.summary().p99;
    println!(
        "  update-under-load: p99 {:.0}ns steady vs {:.0}ns with {} swaps \
         ({:.2}x), {} restamps",
        steady_p99,
        stormy_p99,
        stormy.metrics.dmm_updates.get(),
        stormy_p99 / steady_p99.max(1.0),
        stormy.metrics.sync_retries.get()
    );
    assert_eq!(report.processed as usize, BACKLOG);
    assert_eq!(stormy.metrics.dead_letters.get(), 0);
    // the acceptance bound: p99 under updates within 2x of steady state
    // (plus a 2ms absolute grace for scheduler noise on shared runners)
    assert!(
        stormy_p99 <= steady_p99 * 2.0 + 2_000_000.0,
        "Alg-5 update stalled the sharded lane: p99 {stormy_p99}ns vs steady {steady_p99}ns"
    );
    artifact.set_num("steady_map_p99_ns", steady_p99);
    artifact.set_num("update_under_load_map_p99_ns", stormy_p99);

    section("egress fan-out (per-sink consumer groups over the CDM topic)");
    let sink_axis: Vec<usize> = std::env::args()
        .skip_while(|a| a != "--sinks")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .map(|n| vec![n])
        .unwrap_or_else(|| vec![1, 2, 4]);
    const SINK_NAMES: [&str; 4] = ["dw", "ml", "jsonl", "audit"];
    println!(
        "  {:>10} {:>14} {:>12} {:>10}",
        "sinks", "records/s", "wall", "applied"
    );
    for &requested in &sink_axis {
        // sink names must be unique; the axis is capped at the four
        // built-in backends
        let n_sinks = requested.clamp(1, SINK_NAMES.len());
        let mut fan_cfg = cfg.clone();
        fan_cfg.sinks = SINK_NAMES
            .iter()
            .take(n_sinks)
            .map(|s| s.to_string())
            .collect();
        let p = backlog_pipeline(&fan_cfg);
        // fill the CDM topic once; each sink then drains its own group
        let mapped = scaler::run_scaled(&p, 1);
        assert_eq!(mapped.processed as usize, BACKLOG);
        let t0 = std::time::Instant::now();
        let applied = p.drain_sinks();
        let wall = t0.elapsed();
        let rps = applied as f64 / wall.as_secs_f64().max(1e-9);
        println!(
            "  {:>10} {:>14.0} {:>12?} {:>10}",
            n_sinks, rps, wall, applied
        );
        assert_eq!(
            applied as u64,
            p.out_topic.total_records() * n_sinks as u64,
            "every sink drains the whole CDM topic"
        );
        for handle in &p.sinks {
            assert_eq!(handle.lag(), 0, "sink {}", handle.name());
        }
    }

    section("online evolution (--evolve: change storm during sharded drain)");
    let storms: usize = std::env::args()
        .skip_while(|a| a != "--evolve")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    // storm-free baseline for the dip computation
    let calm = backlog_pipeline(&cfg);
    let calm_report = shard::run_sharded_drain(&calm, 4);
    let calm_eps = calm_report.throughput_eps();
    let calm_p99 = calm.metrics.map_latency.summary().p99;
    println!(
        "  storm of {storms} schema change(s) racing a 4-shard drain \
         (baseline {calm_eps:.0} events/s, p99 {})",
        format_ns(calm_p99)
    );
    println!(
        "  {:>10} {:>14} {:>8} {:>12} {:>14} {:>14}",
        "evict", "events/s", "dip", "map p99", "update mean", "update p99"
    );
    for mode in [EvictMode::Targeted, EvictMode::Full] {
        let mut storm_cfg = cfg.clone();
        storm_cfg.evict = mode;
        let p = backlog_pipeline(&storm_cfg);
        let report = std::thread::scope(|scope| {
            let handle = scope.spawn(|| shard::run_sharded_drain(&p, 4));
            for svc in 0..storms {
                p.apply_schema_change(svc % storm_cfg.n_services).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            handle.join().unwrap()
        });
        assert_eq!(report.processed as usize, BACKLOG);
        assert_eq!(p.metrics.dead_letters.get(), 0);
        assert_eq!(p.metrics.dmm_updates.get(), storms as u64);
        let eps = report.throughput_eps();
        let upd = p.metrics.update_latency.summary();
        println!(
            "  {:>10} {:>14.0} {:>7.2}x {:>12} {:>14} {:>14}",
            mode.to_string(),
            eps,
            calm_eps / eps.max(1e-9),
            format_ns(p.metrics.map_latency.summary().p99),
            format_ns(upd.mean),
            format_ns(upd.p99)
        );
        artifact.set_num(&format!("evolve_{mode}_eps"), eps);
        artifact.set_num(&format!("evolve_{mode}_update_mean_ns"), upd.mean);
    }
    println!(
        "  dip = baseline eps / storm eps (1.00x = no dip); targeted \
         eviction keeps unaffected columns warm, so its dip and map p99 \
         stay below the full-evict fallback"
    );

    section("adversarial scenario lane (--scenario NAME pins; default zipf)");
    let scenario_name =
        harness::arg_value("--scenario").unwrap_or_else(|| "zipf".to_string());
    let scenario = Scenario::from_name(&scenario_name).unwrap_or_else(|| {
        eprintln!(
            "unknown scenario {scenario_name:?}; known: {}",
            Scenario::ALL.map(|s| s.name()).join(", ")
        );
        std::process::exit(1);
    });
    let mut adv_cfg = cfg.clone();
    adv_cfg.trace_events = 20_000;
    let mut runner = ScenarioRunner::new(adv_cfg, scenario);
    runner.exercise_redelivery = false;
    let (p, outcome) = runner.shards(4).run().unwrap();
    let eps = outcome.report.throughput_eps();
    println!(
        "  {scenario}: {:>10.0} events/s over {} published records \
         ({} dead-lettered, 4 shards)",
        eps, outcome.published, outcome.dead_letters
    );
    assert_eq!(outcome.events_in, outcome.published);
    assert_eq!(
        p.metrics.transformations.get() + outcome.dead_letters,
        outcome.events_in
    );
    artifact.set_num(
        &format!("scenario_{}_eps", scenario_name.replace('-', "_")),
        eps,
    );

    artifact.write_default().unwrap();
    println!("\nthroughput bench OK");
}
