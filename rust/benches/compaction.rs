//! Bench: compaction (paper fig 5 worked example + §5.2/§5.3 ratio claims
//! + §3.5 matrix-scale estimates + §5.2 O(n) space per mapping).
//!
//! Regenerates, at increasing scales, the table behind the paper's
//! ">99% / >99.9%" compaction statements and times Algorithms 2 and 3.

#[path = "harness.rs"]
mod harness;

use harness::{section, Artifact, Bench};
use metl::config::PipelineConfig;
use metl::matrix::compaction::CompactionStats;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::matrix::fixtures::{fig5_matrix, fig5_trees};
use metl::message::StateI;
use metl::workload;

fn main() {
    let mut artifact = Artifact::new("compaction");
    section("fig 5 worked example (exact)");
    let (t, c) = fig5_trees();
    let m = fig5_matrix(&t, &c);
    let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
    let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
    println!(
        "  matrix 30 live elements -> DPM {} (paper: 7) | DUSB {} + {} \
         special null (paper: 5 + 1)",
        dpm.n_elements(),
        dusb.n_elements(),
        dusb.n_special_nulls()
    );
    assert_eq!(dpm.n_elements(), 7);
    assert_eq!((dusb.n_elements(), dusb.n_special_nulls()), (5, 1));
    artifact.set_num("fig5_dpm_elements", dpm.n_elements() as f64);
    artifact.set_num("fig5_dusb_elements", dusb.n_elements() as f64);

    section("compaction ratios across scales (paper: >99% / >99.9%)");
    println!(
        "  {:<14} {:>14} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "profile", "live elems", "ones", "DPM", "DUSB", "r_dpm%", "r_dusb%"
    );
    for (name, cfg) in profiles() {
        let land = workload::generate(&cfg);
        let dpm =
            DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
                .unwrap();
        let dusb =
            DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
                .unwrap();
        let s = CompactionStats::measure(
            &land.matrix, &land.tree, &land.cdm, &dpm, &dusb,
        );
        println!(
            "  {:<14} {:>14} {:>9} {:>9} {:>9} {:>10.4} {:>10.4}",
            name,
            s.matrix_elements,
            s.ones,
            s.dpm_elements,
            s.dusb_elements,
            s.dpm_ratio() * 100.0,
            s.dusb_ratio() * 100.0
        );
        let key = name.replace(['/', '-'], "_");
        artifact.set_num(&format!("dpm_ratio_{key}"), s.dpm_ratio());
        artifact.set_num(&format!("dusb_ratio_{key}"), s.dusb_ratio());
    }

    section("§3.5 scale estimate (10k attrs x 10 versions x 1k CDM rows)");
    // the paper's arithmetic: ~1e9 elements before the §5.1 CDM-version
    // rule, ~1e8 after; reproduce the bookkeeping on a tree at the paper's
    // full 10k-base-attribute scale (1000 tables x ~10 attrs)
    let mut cfg = PipelineConfig::eos_scale();
    cfg.n_services = 1000;
    let land = workload::generate(&cfg);
    let live_cols: usize = land
        .tree
        .schemas()
        .flat_map(|s| {
            s.versions
                .iter()
                .map(|&v| land.tree.version(s.id, v).unwrap().width())
        })
        .sum();
    let live_rows: usize = land
        .cdm
        .entities()
        .flat_map(|e| {
            e.versions
                .iter()
                .map(|&w| land.cdm.version(e.id, w).unwrap().height())
        })
        .sum();
    println!(
        "  live columns {} x live rows {} = {:.2e} elements (one CDM \
         version per entity, §5.1 applied)",
        live_cols,
        live_rows,
        live_cols as f64 * live_rows as f64
    );
    println!(
        "  without §5.1 (x10 CDM versions): {:.2e} — the paper's 1e9 bound",
        live_cols as f64 * live_rows as f64 * 10.0
    );

    section("algorithm timing (paper_day profile)");
    let cfg = PipelineConfig::paper_day();
    let land = workload::generate(&cfg);
    let bench = Bench::default();
    let s2 = bench.run("Alg 2: M -> DPM", || {
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap()
            .n_elements()
    });
    let s3 = bench.run("Alg 3: M -> DUSB", || {
        DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap()
            .n_elements()
    });
    artifact.set_summary_ns("alg2_build_ns", &s2);
    artifact.set_summary_ns("alg3_build_ns", &s3);

    section("§5.2 space per single mapping is O(n)");
    // space to execute one mapping = the column super-set size, linear in
    // realized mappings, independent of matrix area
    let dpm =
        DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .unwrap();
    let mut rows = Vec::new();
    for s in land.tree.schemas().take(5) {
        let v = *s.versions.last().unwrap();
        let col = dpm.column(s.id, v);
        let elements: usize = col.iter().map(|b| b.elements.len()).sum();
        rows.push(elements);
        println!(
            "  column {}v{}: {} blocks, {} elements resident",
            s.name,
            v.0,
            col.len(),
            elements
        );
    }
    let max = *rows.iter().max().unwrap();
    assert!(
        max <= cfg.attrs_per_schema * cfg.n_entities,
        "column space bounded by realized mappings, not matrix area"
    );
    artifact.write_default().unwrap();
    println!("\ncompaction bench OK");
}

fn profiles() -> Vec<(&'static str, PipelineConfig)> {
    let mut quarter = PipelineConfig::paper_day();
    quarter.n_services = 20;
    let mut eos_lite = PipelineConfig::eos_scale();
    eos_lite.n_services = 60;
    eos_lite.n_entities = 60;
    vec![
        ("small", PipelineConfig::small()),
        ("paper_day/4", quarter),
        ("paper_day", PipelineConfig::paper_day()),
        ("eos_scale-", eos_lite),
    ]
}
