//! Bench: broker contention — the segmented lock-free core against an
//! in-bench replica of the old mutex-log broker, under the same
//! contended workload: 4 producers racing a 4-member consumer group on
//! one topic. The mutex replica does exactly what the pre-segment broker
//! did on the hot path — one lock acquisition per produced record and a
//! lock + clone for every fetch — while the segmented side publishes
//! with one release-store per append and fetches `Arc`-shared slices
//! without taking any lock. The payload is a bare `u64` on both sides,
//! so the measured gap is lock traffic, not clone cost.
//!
//! A second section drives the full 4-shard pipeline with all four sink
//! backends registered, so the segmented core is also exercised in situ
//! (dispatcher + workers + egress groups all sharing segments).
//!
//! Flags (after `cargo bench --bench contention --`):
//!   --smoke           reduced record counts (CI shape check)
//!   --out PATH        artifact destination (default ../BENCH_10.json)
//!   --validate PATH   validate an artifact's schema (and, for non-smoke
//!                     artifacts, the speedup > 1 acceptance bound) and
//!                     exit

#[path = "harness.rs"]
mod harness;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use harness::{arg_value, has_flag, section, Artifact, Bench};
use metl::broker::{Broker, Consumer};
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::coordinator::shard;
use metl::util::json::{self, Json};
use metl::util::rng::Rng;
use metl::util::stats::Summary;
use metl::workload::{self, DmlKind, TraceOp};

/// Metrics every `BENCH_10.json`-shaped artifact must carry.
const REQUIRED: &[&str] = &[
    "broker.ring_ns.mean",
    "broker.mutex_ns.mean",
    "broker.ring_over_mutex_speedup",
    "pipeline.sharded_eps",
];

const PARTITIONS: usize = 8;
const PRODUCERS: usize = 4;
const MEMBERS: usize = 4;

fn validate(path: &str) -> Result<(), String> {
    harness::validate_artifact_file(path, "contention", REQUIRED)?;
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let smoke = doc
        .get("smoke")
        .and_then(Json::as_bool)
        .ok_or_else(|| format!("{path}: missing smoke flag"))?;
    let speedup = doc
        .get("metrics")
        .and_then(|m| m.get("broker"))
        .and_then(|b| b.get("ring_over_mutex_speedup"))
        .and_then(Json::as_f64)
        .ok_or_else(|| {
            format!("{path}: missing broker.ring_over_mutex_speedup")
        })?;
    // smoke runs are too short to be noise-free on shared CI runners;
    // the bound is enforced on real (checked-in) artifacts only
    if !smoke && speedup <= 1.0 {
        return Err(format!(
            "{path}: broker.ring_over_mutex_speedup {speedup:.4} <= 1"
        ));
    }
    Ok(())
}

/// The pre-segment broker's hot path, reduced to its essence: one
/// `Mutex<Vec<_>>` per partition, every produce takes the lock to push,
/// every fetch takes the lock to clone a range.
struct MutexTopic {
    partitions: Vec<Mutex<Vec<(u64, u64)>>>,
}

impl MutexTopic {
    fn new(n: usize) -> MutexTopic {
        MutexTopic {
            partitions: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    fn produce_to(&self, partition: usize, key: u64, value: u64) {
        self.partitions[partition].lock().unwrap().push((key, value));
    }

    fn fetch(
        &self,
        partition: usize,
        offset: usize,
        max: usize,
    ) -> Vec<(u64, u64)> {
        let log = self.partitions[partition].lock().unwrap();
        let end = log.len().min(offset + max);
        log[offset.min(end)..end].to_vec()
    }

    fn len(&self, partition: usize) -> usize {
        self.partitions[partition].lock().unwrap().len()
    }
}

/// One contended run over the segmented broker: 4 producers append
/// concurrently while a 4-member group polls shared batches until every
/// record is delivered.
fn ring_run(records_per_producer: usize) {
    let broker: Broker<u64> = Broker::new(PARTITIONS);
    let topic = broker.create_topic("bench", PARTITIONS);
    let done = AtomicBool::new(false);
    let consumed = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let done = &done;
        let consumed = &consumed;
        let mut producers = Vec::new();
        for prod in 0..PRODUCERS {
            let topic = topic.clone();
            producers.push(s.spawn(move || {
                for seq in 0..records_per_producer {
                    let key = (seq * PRODUCERS + prod) as u64;
                    let value = ((prod as u64) << 32) | seq as u64;
                    topic.produce_to(key as usize % PARTITIONS, key, value);
                }
            }));
        }
        for member in 0..MEMBERS {
            let mut c = Consumer::new(topic.clone(), member, MEMBERS);
            s.spawn(move || {
                let mut sum = 0u64;
                loop {
                    let batches = c.poll_shared(256);
                    if batches.is_empty() {
                        if done.load(Ordering::Acquire) && c.lag() == 0 {
                            break;
                        }
                        std::thread::yield_now();
                        continue;
                    }
                    let mut n = 0;
                    for b in &batches {
                        n += b.len();
                        for rec in b.iter() {
                            sum = sum.wrapping_add(rec.value);
                        }
                    }
                    c.commit();
                    consumed.fetch_add(n, Ordering::Relaxed);
                }
                std::hint::black_box(sum);
            });
        }
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
    });
    assert_eq!(
        consumed.load(Ordering::Relaxed),
        PRODUCERS * records_per_producer
    );
}

/// The identical workload over the mutex-log replica: same partition
/// assignment, same batch size, same termination protocol.
fn mutex_run(records_per_producer: usize) {
    let topic = MutexTopic::new(PARTITIONS);
    let done = AtomicBool::new(false);
    let consumed = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let topic = &topic;
        let done = &done;
        let consumed = &consumed;
        let mut producers = Vec::new();
        for prod in 0..PRODUCERS {
            producers.push(s.spawn(move || {
                for seq in 0..records_per_producer {
                    let key = (seq * PRODUCERS + prod) as u64;
                    let value = ((prod as u64) << 32) | seq as u64;
                    topic.produce_to(key as usize % PARTITIONS, key, value);
                }
            }));
        }
        for member in 0..MEMBERS {
            s.spawn(move || {
                let assigned: Vec<usize> = (0..PARTITIONS)
                    .filter(|p| p % MEMBERS == member)
                    .collect();
                let mut pos = vec![0usize; assigned.len()];
                let mut sum = 0u64;
                loop {
                    let mut n = 0;
                    for (i, &p) in assigned.iter().enumerate() {
                        let batch = topic.fetch(p, pos[i], 256);
                        pos[i] += batch.len();
                        n += batch.len();
                        for &(_, v) in &batch {
                            sum = sum.wrapping_add(v);
                        }
                    }
                    if n == 0 {
                        if done.load(Ordering::Acquire) {
                            let lag: usize = assigned
                                .iter()
                                .enumerate()
                                .map(|(i, &p)| topic.len(p) - pos[i])
                                .sum();
                            if lag == 0 {
                                break;
                            }
                        }
                        std::thread::yield_now();
                        continue;
                    }
                    consumed.fetch_add(n, Ordering::Relaxed);
                }
                std::hint::black_box(sum);
            });
        }
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
    });
    assert_eq!(
        consumed.load(Ordering::Relaxed),
        PRODUCERS * records_per_producer
    );
}

fn backlog_pipeline(cfg: &PipelineConfig, backlog: usize) -> Pipeline {
    let mut land = workload::generate(cfg);
    let mut rng = Rng::seed_from(cfg.seed ^ 0xC0DE);
    workload::populate(&mut land, 50, &mut rng);
    let p = Pipeline::from_landscape(cfg.clone(), land).unwrap();
    for i in 0..backlog {
        p.resolve_op(&TraceOp::Dml {
            service: i % cfg.n_services,
            kind: if i % 3 == 0 { DmlKind::Update } else { DmlKind::Insert },
        })
        .unwrap();
    }
    p
}

fn main() {
    if let Some(path) = arg_value("--validate") {
        match validate(&path) {
            Ok(()) => {
                println!("{path}: valid contention artifact");
                return;
            }
            Err(e) => {
                eprintln!("invalid contention artifact: {e}");
                std::process::exit(1);
            }
        }
    }

    let smoke = has_flag("--smoke");
    let (records, iters, backlog) = if smoke {
        (5_000usize, 3usize, 2_000usize)
    } else {
        (50_000, 8, 20_000)
    };
    let mut artifact = Artifact::new("contention");
    artifact
        .meta("profile", Json::Str(if smoke { "small" } else { "paper_day" }.to_string()))
        .meta("smoke", Json::Bool(smoke))
        .meta("iters", Json::Num(iters as f64));

    section(
        format!(
            "contended broker ({PRODUCERS} producers x {MEMBERS} members, \
             {} records)",
            PRODUCERS * records
        )
        .as_str(),
    );
    let bench = Bench::new(2, iters);
    let ring = bench.run("segmented ring", || ring_run(records));
    let mutex = bench.run("mutex log (old broker)", || mutex_run(records));
    let speedup = mutex.mean / ring.mean.max(1.0);
    println!("  ring over mutex: {speedup:.2}x");
    artifact.set(
        "broker",
        Json::Obj(vec![
            ("ring_ns".to_string(), summary_obj(&ring)),
            ("mutex_ns".to_string(), summary_obj(&mutex)),
            ("ring_over_mutex_speedup".to_string(), Json::Num(speedup)),
            (
                "records".to_string(),
                Json::Num((PRODUCERS * records) as f64),
            ),
        ]),
    );
    if !smoke {
        assert!(
            speedup > 1.0,
            "segmented broker no faster than the mutex log ({speedup:.4}x)"
        );
    }

    section("in-situ: 4-shard drain, all sink backends registered");
    let mut cfg = if smoke {
        PipelineConfig::small()
    } else {
        let mut cfg = PipelineConfig::paper_day();
        cfg.partitions = 16;
        cfg
    };
    cfg.sinks = ["dw", "ml", "jsonl", "audit"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let p = backlog_pipeline(&cfg, backlog);
    let t0 = Instant::now();
    let report = shard::run_sharded_drain(&p, 4);
    let applied = p.drain_sinks();
    let wall = t0.elapsed();
    assert_eq!(report.processed as usize, backlog);
    assert_eq!(p.metrics.dead_letters.get(), 0);
    assert_eq!(
        applied as u64,
        p.out_topic.total_records() * cfg.sinks.len() as u64,
        "every sink drains the whole CDM topic"
    );
    let eps = report.throughput_eps();
    let brk = &p.metrics.broker;
    println!(
        "  {eps:>10.0} events/s mapped; {applied} sink records in {wall:?}"
    );
    println!(
        "  broker: {} segments, {} produce batches, {} fetch batches, \
         {} arena bytes",
        brk.segments_allocated.get(),
        brk.produce_batches.get(),
        brk.fetch_batches.get(),
        brk.arena_bytes.get()
    );
    artifact.set(
        "pipeline",
        Json::Obj(vec![
            ("sharded_eps".to_string(), Json::Num(eps)),
            (
                "sink_records".to_string(),
                Json::Num(applied as f64),
            ),
            (
                "segments_allocated".to_string(),
                Json::Num(brk.segments_allocated.get() as f64),
            ),
            (
                "produce_batches".to_string(),
                Json::Num(brk.produce_batches.get() as f64),
            ),
            (
                "fetch_batches".to_string(),
                Json::Num(brk.fetch_batches.get() as f64),
            ),
            (
                "arena_bytes".to_string(),
                Json::Num(brk.arena_bytes.get() as f64),
            ),
        ]),
    );

    let out =
        arg_value("--out").unwrap_or_else(|| "../BENCH_10.json".to_string());
    artifact.write(&out).unwrap();
    if let Err(e) = validate(&out) {
        eprintln!("emitted artifact failed self-validation: {e}");
        std::process::exit(1);
    }
    println!("\ncontention bench OK");
}

fn summary_obj(s: &Summary) -> Json {
    Json::Obj(vec![
        ("count".to_string(), Json::Num(s.count as f64)),
        ("mean".to_string(), Json::Num(s.mean)),
        ("std".to_string(), Json::Num(s.std)),
        ("p50".to_string(), Json::Num(s.p50)),
        ("p90".to_string(), Json::Num(s.p90)),
        ("p99".to_string(), Json::Num(s.p99)),
    ])
}
