//! Bench: tracing overhead — the observability tentpole's cost contract.
//!
//! Runs the same pre-resolved CDC backlog through two pipelines that
//! differ only in `runtime.trace`, interleaving tracing-on and
//! tracing-off iterations so machine drift hits both sides equally, and
//! emits `trace.overhead_ratio` (on-mean / off-mean). The checked-in
//! `BENCH_9.json` pins the contract that spans are cheap enough to leave
//! on by default: ratio < 1.05.
//!
//! Flags (after `cargo bench --bench overhead --`):
//!   --smoke           reduced backlog + small profile (CI shape check)
//!   --out PATH        artifact destination (default ../BENCH_9.json)
//!   --validate PATH   validate an artifact's schema (and, for non-smoke
//!                     artifacts, the < 1.05 overhead bound) and exit

#[path = "harness.rs"]
mod harness;

use std::time::{Duration, Instant};

use harness::{arg_value, has_flag, section, Artifact};
use metl::broker::Consumer;
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::util::json::{self, Json};
use metl::util::rng::Rng;
use metl::util::stats::{format_ns, Summary};
use metl::workload::{self, DmlKind, TraceOp};

/// Metrics every `BENCH_9.json`-shaped artifact must carry.
const REQUIRED: &[&str] = &[
    "trace.on_ns.mean",
    "trace.off_ns.mean",
    "trace.overhead_ratio",
    "trace.spans_per_event",
];

/// The cost contract: tracing-on must stay within 5% of tracing-off.
const MAX_OVERHEAD: f64 = 1.05;

fn validate(path: &str) -> Result<(), String> {
    harness::validate_artifact_file(path, "overhead", REQUIRED)?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let smoke = doc
        .get("smoke")
        .and_then(Json::as_bool)
        .ok_or_else(|| format!("{path}: missing smoke flag"))?;
    let ratio = doc
        .get("metrics")
        .and_then(|m| m.get("trace"))
        .and_then(|t| t.get("overhead_ratio"))
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing trace.overhead_ratio"))?;
    // smoke runs are too short to be noise-free on shared CI runners;
    // the bound is enforced on real (checked-in) artifacts only
    if !smoke && ratio >= MAX_OVERHEAD {
        return Err(format!(
            "{path}: trace.overhead_ratio {ratio:.4} >= {MAX_OVERHEAD}"
        ));
    }
    Ok(())
}

/// Build a pipeline with `backlog` pre-resolved DML events on the CDC
/// topic, then time draining it end to end (consume → map → egress).
/// Construction and backlog resolution stay outside the timed region.
fn timed_drain(
    cfg_base: &PipelineConfig,
    trace_on: bool,
    backlog: usize,
) -> (Duration, Pipeline) {
    let mut cfg = cfg_base.clone();
    cfg.trace = trace_on;
    let mut land = workload::generate(&cfg);
    let mut rng = Rng::seed_from(cfg.seed ^ 0x0B5);
    workload::populate(&mut land, 50, &mut rng);
    let p = Pipeline::from_landscape(cfg.clone(), land).unwrap();
    for i in 0..backlog {
        p.resolve_op(&TraceOp::Dml {
            service: i % cfg.n_services,
            kind: if i % 3 == 0 { DmlKind::Update } else { DmlKind::Insert },
        })
        .unwrap();
    }
    let t0 = Instant::now();
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    loop {
        let batch = consumer.poll(256);
        if batch.is_empty() {
            break;
        }
        for (partition, rec) in &batch {
            p.process_event_from(*partition, rec.offset, &rec.value);
        }
        consumer.commit();
    }
    p.drain_sinks();
    let dt = t0.elapsed();
    assert_eq!(p.metrics.events_in.get() as usize, backlog);
    assert_eq!(p.metrics.dead_letters.get(), 0);
    if trace_on {
        assert_eq!(p.metrics.trace.traces.get() as usize, backlog);
        assert_eq!(p.metrics.trace.spans_dropped.get(), 0);
    } else {
        assert_eq!(p.tracer.span_count(), 0);
    }
    (dt, p)
}

fn main() {
    if let Some(path) = arg_value("--validate") {
        match validate(&path) {
            Ok(()) => {
                println!("{path}: valid overhead artifact");
                return;
            }
            Err(e) => {
                eprintln!("invalid overhead artifact: {e}");
                std::process::exit(1);
            }
        }
    }

    let smoke = has_flag("--smoke");
    let (cfg, backlog, iters) = if smoke {
        (PipelineConfig::small(), 2_000usize, 3usize)
    } else {
        let mut cfg = PipelineConfig::paper_day();
        cfg.partitions = 16;
        (cfg, 20_000, 8)
    };
    let profile = if smoke { "small" } else { "paper_day" };
    let mut artifact = Artifact::new("overhead");
    artifact
        .meta("profile", Json::Str(profile.to_string()))
        .meta("smoke", Json::Bool(smoke))
        .meta("iters", Json::Num(iters as f64));

    section(format!("tracing on vs off ({backlog} events, interleaved)").as_str());
    // warmup one pair, then interleave A/B so thermal and cache drift
    // land on both sides equally
    timed_drain(&cfg, true, backlog);
    timed_drain(&cfg, false, backlog);
    let mut on_ns = Vec::with_capacity(iters);
    let mut off_ns = Vec::with_capacity(iters);
    let mut spans_per_event = 0.0;
    for i in 0..iters {
        let (dt_on, p_on) = timed_drain(&cfg, true, backlog);
        let (dt_off, _) = timed_drain(&cfg, false, backlog);
        on_ns.push(dt_on.as_nanos() as f64);
        off_ns.push(dt_off.as_nanos() as f64);
        spans_per_event =
            p_on.metrics.trace.spans.get() as f64 / backlog as f64;
        println!(
            "  iter {i}: on={} off={} ({:.1} spans/event)",
            format_ns(dt_on.as_nanos() as f64),
            format_ns(dt_off.as_nanos() as f64),
            spans_per_event
        );
    }
    let s_on = Summary::from(&on_ns);
    let s_off = Summary::from(&off_ns);
    let ratio = s_on.mean / s_off.mean.max(1.0);
    println!(
        "  on mean={} off mean={} -> overhead {:.4}x",
        format_ns(s_on.mean),
        format_ns(s_off.mean),
        ratio
    );

    artifact.set(
        "trace",
        Json::Obj(vec![
            ("on_ns".to_string(), summary_obj(&s_on)),
            ("off_ns".to_string(), summary_obj(&s_off)),
            ("overhead_ratio".to_string(), Json::Num(ratio)),
            ("spans_per_event".to_string(), Json::Num(spans_per_event)),
        ]),
    );
    if !smoke {
        assert!(
            ratio < MAX_OVERHEAD,
            "tracing overhead {ratio:.4}x breaks the < {MAX_OVERHEAD} contract"
        );
    }

    let out = arg_value("--out").unwrap_or_else(|| "../BENCH_9.json".to_string());
    artifact.write(&out).unwrap();
    if let Err(e) = validate(&out) {
        eprintln!("emitted artifact failed self-validation: {e}");
        std::process::exit(1);
    }
    println!("\noverhead bench OK");
}

fn summary_obj(s: &Summary) -> Json {
    Json::Obj(vec![
        ("count".to_string(), Json::Num(s.count as f64)),
        ("mean".to_string(), Json::Num(s.mean)),
        ("std".to_string(), Json::Num(s.std)),
        ("p50".to_string(), Json::Num(s.p50)),
        ("p90".to_string(), Json::Num(s.p90)),
        ("p99".to_string(), Json::Num(s.p99)),
    ])
}
