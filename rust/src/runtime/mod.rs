//! PJRT runtime: loads the AOT-compiled JAX/Pallas bulk-mapping kernels
//! from `artifacts/` (HLO text, see python/compile/aot.py) and executes
//! them from the coordinator's bulk lane. Python never runs here — the
//! artifacts are self-contained XLA programs.
//!
//! The bulk lane exists for initial loads (paper §5.5/§6.4: horizontal
//! scaling and extra parallelism are "reserve capacity ... for initial
//! loads"): thousands of snapshot messages against one mapping block
//! amortize a single compiled executable far better than per-message set
//! lookups.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

// The build image has no native XLA/PJRT library; the stub mirrors the
// bindings' API and fails at client construction, so `try_load` yields
// None and the coordinator serves everything through the Alg-6 lane.
use crate::util::json::{parse, Json};
use crate::xla_stub as xla;

/// One compiled shape variant of the bulk_map kernel.
struct BulkVariant {
    batch: usize,
    p: usize,
    q: usize,
    /// "pallas" (the L1 tiled TPU schedule) or "jnp" (fused-dot layout,
    /// preferred on the CPU PJRT backend; see EXPERIMENTS.md §Perf L2).
    impl_name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU runtime with all loaded executables.
pub struct BulkRuntime {
    variants: Vec<BulkVariant>,
    pub platform: String,
    preferred_impl: String,
}

/// Result of mapping one message through one block on the bulk lane:
/// realized (q_local, p_local) pairs.
pub type BulkMapped = Vec<(usize, usize)>;

impl BulkRuntime {
    /// Load every bulk_map variant listed in `artifacts/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<BulkRuntime> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?} (run `make artifacts`)"))?;
        let manifest =
            parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let platform = client.platform_name();
        let mut variants = Vec::new();
        for entry in manifest
            .get("bulk_map")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing bulk_map"))?
        {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("variant missing file"))?;
            let num = |k: &str| -> Result<usize> {
                Ok(entry
                    .get(k)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("variant missing {k}"))?
                    as usize)
            };
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("compile bulk_map")?;
            variants.push(BulkVariant {
                batch: num("batch")?,
                p: num("p")?,
                q: num("q")?,
                impl_name: entry
                    .get("impl")
                    .and_then(Json::as_str)
                    .unwrap_or("pallas")
                    .to_string(),
                exe,
            });
        }
        if variants.is_empty() {
            bail!("manifest lists no bulk_map variants");
        }
        variants.sort_by_key(|v| v.batch);
        // impl choice: the fused-dot "jnp" layout wins on the CPU backend,
        // the pallas tile schedule on accelerators; METL_BULK_IMPL forces
        // one for A/B benches.
        let preferred_impl = std::env::var("METL_BULK_IMPL").unwrap_or_else(
            |_| {
                if platform == "cpu" { "jnp" } else { "pallas" }.to_string()
            },
        );
        let preferred_impl = if variants.iter().any(|v| v.impl_name == preferred_impl) {
            preferred_impl
        } else {
            variants[0].impl_name.clone()
        };
        Ok(BulkRuntime { variants, platform, preferred_impl })
    }

    /// The impl the chunk scheduler selects ("jnp" or "pallas").
    pub fn preferred_impl(&self) -> &str {
        &self.preferred_impl
    }

    /// Load if the artifacts exist; None otherwise (the coordinator then
    /// serves everything through the Alg 6 lane).
    pub fn try_load(dir: impl AsRef<Path>) -> Option<BulkRuntime> {
        BulkRuntime::load(dir).ok()
    }

    /// Maximum (p, q) block dimensions the compiled variants accept.
    pub fn block_dims(&self) -> (usize, usize) {
        let v = self.preferred();
        (v.p, v.q)
    }

    fn preferred(&self) -> &BulkVariant {
        self.variants
            .iter()
            .find(|v| v.impl_name == self.preferred_impl)
            .unwrap_or(&self.variants[0])
    }

    pub fn n_variants(&self) -> usize {
        self.variants.len()
    }

    /// Map a batch of messages through one mapping block.
    ///
    /// `elements`: the block's permutation elements in *local* coordinates
    /// (q_local < Q, p_local < P). `presence`: per message, the local
    /// column indices carrying non-null data objects. Returns, per
    /// message, the realized (q_local, p_local) pairs — the paper's
    /// mapping function evaluated as one MXU-shaped matmul.
    pub fn bulk_map_block(
        &self,
        elements: &[(usize, usize)],
        presence: &[Vec<usize>],
    ) -> Result<Vec<BulkMapped>> {
        let (pmax, qmax) = self.block_dims();
        for &(q, p) in elements {
            if q >= qmax || p >= pmax {
                bail!("block element ({q},{p}) exceeds compiled dims ({qmax},{pmax})");
            }
        }
        // m tensor: (Q, P) row-major — one literal reused across chunks
        let mut m_host = vec![0f32; qmax * pmax];
        for &(q, p) in elements {
            m_host[q * pmax + p] = 1.0;
        }
        let m_lit = xla::Literal::vec1(&m_host)
            .reshape(&[qmax as i64, pmax as i64])?;
        let mut out = Vec::with_capacity(presence.len());
        // chunk the batch over the best-fitting variant
        let mut start = 0;
        while start < presence.len() {
            let remaining = presence.len() - start;
            let variant = self
                .variants
                .iter()
                .find(|v| {
                    v.impl_name == self.preferred_impl && v.batch >= remaining
                })
                .or_else(|| {
                    self.variants
                        .iter()
                        .rev()
                        .find(|v| v.impl_name == self.preferred_impl)
                })
                .unwrap_or_else(|| self.variants.last().unwrap());
            let chunk = remaining.min(variant.batch);
            let mapped = self.execute_chunk(
                variant,
                &m_lit,
                &presence[start..start + chunk],
            )?;
            out.extend(mapped);
            start += chunk;
        }
        Ok(out)
    }

    fn execute_chunk(
        &self,
        variant: &BulkVariant,
        m_lit: &xla::Literal,
        presence: &[Vec<usize>],
    ) -> Result<Vec<BulkMapped>> {
        let (b, p, q) = (variant.batch, variant.p, variant.q);
        let mut x_host = vec![0f32; b * p];
        for (i, msg) in presence.iter().enumerate() {
            for &pi in msg {
                if pi >= p {
                    bail!("presence index {pi} exceeds compiled P={p}");
                }
                x_host[i * p + pi] = 1.0;
            }
        }
        let x_lit = xla::Literal::vec1(&x_host).reshape(&[b as i64, p as i64])?;
        let result = variant
            .exe
            .execute::<&xla::Literal>(&[m_lit, &x_lit])?[0][0]
            .to_literal_sync()?;
        let (presence_lit, src_lit) = result.to_tuple2()?;
        let pres: Vec<f32> = presence_lit.to_vec()?;
        let src: Vec<f32> = src_lit.to_vec()?;
        let mut out = Vec::with_capacity(presence.len());
        for (i, _) in presence.iter().enumerate() {
            let mut mapped = Vec::new();
            for qi in 0..q {
                let v = pres[i * q + qi];
                if v > 0.5 {
                    let pi = src[i * q + qi];
                    debug_assert!(pi >= 0.0);
                    mapped.push((qi, pi as usize));
                }
            }
            out.push(mapped);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_manifest_and_executes_identity_block() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = BulkRuntime::load(dir).unwrap();
        assert!(rt.n_variants() >= 1);
        let (p, q) = rt.block_dims();
        assert!(p >= 128 && q >= 128);
        // identity-ish block: q_local i <- p_local i for i in 0..10
        let elements: Vec<(usize, usize)> = (0..10).map(|i| (i, i)).collect();
        let presence = vec![
            vec![0, 1, 2],
            vec![],
            vec![9, 11], // 11 is unmapped
        ];
        let mapped = rt.bulk_map_block(&elements, &presence).unwrap();
        assert_eq!(mapped[0], vec![(0, 0), (1, 1), (2, 2)]);
        assert!(mapped[1].is_empty());
        assert_eq!(mapped[2], vec![(9, 9)]);
    }

    #[test]
    fn permuted_block_and_chunking() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = BulkRuntime::load(dir).unwrap();
        // shifted permutation: q = p + 3
        let elements: Vec<(usize, usize)> = (0..20).map(|i| (i + 3, i)).collect();
        // 600 messages forces chunking over the 256 variant
        let presence: Vec<Vec<usize>> =
            (0..600).map(|i| vec![i % 20]).collect();
        let mapped = rt.bulk_map_block(&elements, &presence).unwrap();
        assert_eq!(mapped.len(), 600);
        for (i, m) in mapped.iter().enumerate() {
            assert_eq!(m, &vec![((i % 20) + 3, i % 20)]);
        }
    }

    #[test]
    fn rejects_oversized_blocks() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = BulkRuntime::load(dir).unwrap();
        let (p, q) = rt.block_dims();
        assert!(rt.bulk_map_block(&[(q, 0)], &[vec![0]]).is_err());
        assert!(rt.bulk_map_block(&[(0, p)], &[vec![0]]).is_err());
        assert!(rt.bulk_map_block(&[(0, 0)], &[vec![p]]).is_err());
    }

    #[test]
    fn try_load_missing_dir_is_none() {
        assert!(BulkRuntime::try_load("/nonexistent/path").is_none());
    }
}
