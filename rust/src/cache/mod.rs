//! Caffeine-sim column cache (paper §6.2): "a cached function that reads
//! in the columns `ᵢ𝒟𝒞𝒫𝓜_v^o` ... into an efficient hashmap which makes
//! them accessible in O(1). We evict the cache every time a business
//! entity, schema or mapping is updated" — the eviction that produces the
//! §7 latency spikes.
//!
//! The spike is avoidable: an Alg-5 update touches a handful of mapping
//! columns while the rest of the `ᵢ𝔇𝔓𝔐` blocks are shared `Arc`s with the
//! previous snapshot, so every unaffected cached column is still correct.
//! [`DcpmCache::advance`] therefore supports **targeted eviction**
//! ([`EvictMode::Targeted`], the default): given the changed-column list
//! from the epoch journal ([`crate::coordinator::EpochDmm::affected_between`]),
//! only those columns drop and the warm remainder survives the state
//! transition. [`EvictMode::Full`] restores the paper's evict-everything
//! behaviour (the `--evict full` fallback).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::mapper::kernel::{ColumnPlan, PlanCache};
use crate::matrix::dpm::{DpmBlock, DpmSet};
use crate::message::StateI;
use crate::schema::{SchemaId, VersionNo};

/// A cached `ᵢ𝒟𝒞𝒫𝓜` column super-set. The `Arc` identity doubles as the
/// validity token for compiled kernel plans ([`PlanCache`]).
pub type Column = Arc<Vec<Arc<DpmBlock>>>;

/// Eviction policy applied on a state transition with a known diff
/// (`runtime.evict` config key / `--evict` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictMode {
    /// Drop only the mapping columns the update changed (default).
    #[default]
    Targeted,
    /// Drop every cached column on every update — the paper's §6.2
    /// behaviour, kept as a fallback and as the bench baseline.
    Full,
}

impl std::str::FromStr for EvictMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "targeted" => Ok(EvictMode::Targeted),
            "full" => Ok(EvictMode::Full),
            other => {
                Err(format!("unknown evict mode {other:?} (targeted|full)"))
            }
        }
    }
}

impl std::fmt::Display for EvictMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvictMode::Targeted => write!(f, "targeted"),
            EvictMode::Full => write!(f, "full"),
        }
    }
}

/// Cache statistics surfaced on the dashboard (fig 7 records "the storage
/// requirements of the Caffeine cache").
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    /// Full evictions (everything dropped).
    pub evictions: AtomicU64,
    /// Targeted evictions (only affected columns dropped).
    pub targeted_evictions: AtomicU64,
}

/// The `ᵢ𝒟𝒞𝒫𝓜` column cache.
pub struct DcpmCache {
    state: RwLock<StateI>,
    columns: RwLock<HashMap<(SchemaId, VersionNo), Column>>,
    mode: EvictMode,
    pub stats: CacheStats,
    /// Compiled kernel plans, same sharing scope as the columns (the
    /// pipeline shares one cache; each shard worker owns its own).
    pub plans: PlanCache,
}

impl DcpmCache {
    pub fn new(state: StateI) -> Self {
        Self::with_mode(state, EvictMode::default())
    }

    /// Construct with an explicit eviction mode (`PipelineConfig::evict`).
    pub fn with_mode(state: StateI, mode: EvictMode) -> Self {
        Self {
            state: RwLock::new(state),
            columns: RwLock::new(HashMap::new()),
            mode,
            stats: CacheStats::default(),
            plans: PlanCache::new(),
        }
    }

    pub fn state(&self) -> StateI {
        *self.state.read().unwrap()
    }

    pub fn mode(&self) -> EvictMode {
        self.mode
    }

    /// O(1) column lookup; populates from `dpm` on miss. A `dpm` whose
    /// state differs from the cache's triggers a defensive full eviction
    /// (the cache must never serve a stale configuration).
    pub fn column(
        &self,
        dpm: &DpmSet,
        schema: SchemaId,
        version: VersionNo,
    ) -> Column {
        if dpm.state != self.state() {
            self.evict_all(dpm.state);
        }
        if let Some(col) = self.columns.read().unwrap().get(&(schema, version))
        {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(col);
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        // build OUTSIDE the write lock (a large column must not stall
        // concurrent hits), then double-check on insert: racing builders
        // agree on the first inserted Arc and drop their duplicate.
        let built: Column = Arc::new(dpm.column(schema, version));
        let mut columns = self.columns.write().unwrap();
        let entry = columns.entry((schema, version)).or_insert(built);
        Arc::clone(entry)
    }

    /// Column lookup plus its compiled kernel plan (the native lane's
    /// entry point). The plan is validated by `Arc` identity against the
    /// served column, so any eviction that replaces the column — targeted
    /// or full — transparently recompiles it.
    pub fn plan(
        &self,
        dpm: &DpmSet,
        schema: SchemaId,
        version: VersionNo,
    ) -> (Column, Arc<ColumnPlan>) {
        let column = self.column(dpm, schema, version);
        let plan = self.plans.plan_for((schema, version), &column);
        (column, plan)
    }

    /// Evict everything and move to a new state (§6.2: on every update of
    /// a business entity, schema or mapping).
    pub fn evict_all(&self, new_state: StateI) {
        let mut columns = self.columns.write().unwrap();
        if !columns.is_empty() {
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        }
        columns.clear();
        self.plans.clear();
        *self.state.write().unwrap() = new_state;
    }

    /// Advance to `new_state` after an epoch swap. With a known
    /// changed-column list under [`EvictMode::Targeted`], only those
    /// columns drop and every other warm column survives; with an unknown
    /// diff (`None`) or under [`EvictMode::Full`] this degrades to
    /// [`DcpmCache::evict_all`] — always safe, never stale.
    ///
    /// The caller must not run this concurrently with lookups against the
    /// *previous* snapshot on the same cache (the pipeline upholds this:
    /// the single lane is sequential and every shard worker owns its
    /// cache and refreshes it itself).
    pub fn advance(
        &self,
        new_state: StateI,
        affected: Option<&[(SchemaId, VersionNo)]>,
    ) {
        let Some(keys) = affected else {
            return self.evict_all(new_state);
        };
        if self.mode == EvictMode::Full {
            return self.evict_all(new_state);
        }
        let mut columns = self.columns.write().unwrap();
        for key in keys {
            columns.remove(key);
            self.plans.remove(key);
        }
        self.stats.targeted_evictions.fetch_add(1, Ordering::Relaxed);
        *self.state.write().unwrap() = new_state;
    }

    /// Number of cached columns.
    pub fn len(&self) -> usize {
        self.columns.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (dashboard metric).
    pub fn approx_bytes(&self) -> usize {
        let columns = self.columns.read().unwrap();
        columns
            .values()
            .map(|col| {
                col.iter()
                    .map(|b| {
                        std::mem::size_of::<DpmBlock>()
                            + b.elements.len() * std::mem::size_of::<(u32, u32)>()
                    })
                    .sum::<usize>()
                    + std::mem::size_of::<Column>()
            })
            .sum()
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.stats.hits.load(Ordering::Relaxed) as f64;
        let m = self.stats.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    /// Compiled-plan cache `(hits, misses)` (exposition metric).
    pub fn plan_counts(&self) -> (u64, u64) {
        self.plans.stats.counts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};

    fn setup() -> (DpmSet, DcpmCache, SchemaId) {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        (dpm, DcpmCache::new(StateI(0)), s1)
    }

    #[test]
    fn hit_after_miss() {
        let (dpm, cache, s1) = setup();
        let c1 = cache.column(&dpm, s1, VersionNo(1));
        assert_eq!(c1.len(), 2);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 1);
        let c2 = cache.column(&dpm, s1, VersionNo(1));
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 1);
        assert!(Arc::ptr_eq(&c1, &c2));
        assert!(cache.hit_rate() > 0.49);
    }

    #[test]
    fn eviction_on_state_change() {
        let (mut dpm, cache, s1) = setup();
        cache.column(&dpm, s1, VersionNo(1));
        assert_eq!(cache.len(), 1);
        // DMM moves to state 1 (e.g. after Alg 5)
        dpm.state = StateI(1);
        let col = cache.column(&dpm, s1, VersionNo(1));
        assert_eq!(col.len(), 2);
        assert_eq!(cache.stats.evictions.load(Ordering::Relaxed), 1);
        assert_eq!(cache.state(), StateI(1));
        // re-populated under the new state
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn explicit_evict_resets() {
        let (dpm, cache, s1) = setup();
        cache.column(&dpm, s1, VersionNo(1));
        cache.column(&dpm, s1, VersionNo(2));
        assert_eq!(cache.len(), 2);
        assert!(cache.approx_bytes() > 0);
        cache.evict_all(StateI(2));
        assert!(cache.is_empty());
        assert_eq!(cache.state(), StateI(2));
    }

    #[test]
    fn empty_columns_are_cached_too() {
        let (dpm, cache, s1) = setup();
        let col = cache.column(&dpm, s1, VersionNo(99));
        assert!(col.is_empty());
        cache.column(&dpm, s1, VersionNo(99));
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn targeted_advance_drops_only_affected_columns() {
        let (mut dpm, cache, s1) = setup();
        let warm = cache.column(&dpm, s1, VersionNo(2));
        cache.column(&dpm, s1, VersionNo(1));
        assert_eq!(cache.len(), 2);
        // the update touched only (s1, v1)
        cache.advance(StateI(1), Some(&[(s1, VersionNo(1))]));
        assert_eq!(cache.state(), StateI(1));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats.targeted_evictions.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.evictions.load(Ordering::Relaxed), 0);
        // the unaffected column survives warm across the transition:
        // same Arc, served as a hit under the new state
        dpm.state = StateI(1);
        let hits_before = cache.stats.hits.load(Ordering::Relaxed);
        let still_warm = cache.column(&dpm, s1, VersionNo(2));
        assert!(Arc::ptr_eq(&warm, &still_warm));
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), hits_before + 1);
        // the affected column misses and rebuilds from the new snapshot
        let misses_before = cache.stats.misses.load(Ordering::Relaxed);
        cache.column(&dpm, s1, VersionNo(1));
        assert_eq!(
            cache.stats.misses.load(Ordering::Relaxed),
            misses_before + 1
        );
    }

    #[test]
    fn advance_without_diff_falls_back_to_full_eviction() {
        let (dpm, cache, s1) = setup();
        cache.column(&dpm, s1, VersionNo(1));
        cache.column(&dpm, s1, VersionNo(2));
        cache.advance(StateI(1), None);
        assert!(cache.is_empty());
        assert_eq!(cache.state(), StateI(1));
        assert_eq!(cache.stats.evictions.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.targeted_evictions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn full_mode_ignores_targeted_diffs() {
        let (dpm, _, s1) = setup();
        let cache = DcpmCache::with_mode(StateI(0), EvictMode::Full);
        assert_eq!(cache.mode(), EvictMode::Full);
        cache.column(&dpm, s1, VersionNo(1));
        cache.column(&dpm, s1, VersionNo(2));
        cache.advance(StateI(1), Some(&[(s1, VersionNo(1))]));
        // --evict=full: everything drops even though the diff was known
        assert!(cache.is_empty());
        assert_eq!(cache.stats.evictions.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.targeted_evictions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn kernel_plans_follow_targeted_eviction() {
        let (mut dpm, cache, s1) = setup();
        let (col_a, plan_a) = cache.plan(&dpm, s1, VersionNo(1));
        let (_, plan_b) = cache.plan(&dpm, s1, VersionNo(2));
        // warm lookup reuses the compiled plan
        let (_, plan_a2) = cache.plan(&dpm, s1, VersionNo(1));
        assert!(Arc::ptr_eq(&plan_a, &plan_a2));
        // epoch swap whose journal says only (s1, v1) changed
        cache.advance(StateI(1), Some(&[(s1, VersionNo(1))]));
        dpm.state = StateI(1);
        let (col_a2, plan_a3) = cache.plan(&dpm, s1, VersionNo(1));
        assert!(!Arc::ptr_eq(&col_a, &col_a2));
        assert!(!Arc::ptr_eq(&plan_a, &plan_a3), "stale plan must recompile");
        // the unaffected column keeps its plan across the swap
        let (_, plan_b2) = cache.plan(&dpm, s1, VersionNo(2));
        assert!(Arc::ptr_eq(&plan_b, &plan_b2));
    }

    #[test]
    fn full_eviction_clears_plans() {
        let (dpm, cache, s1) = setup();
        cache.plan(&dpm, s1, VersionNo(1));
        assert_eq!(cache.plans.len(), 1);
        cache.evict_all(StateI(1));
        assert!(cache.plans.is_empty());
    }

    #[test]
    fn evict_mode_parses() {
        assert_eq!("targeted".parse::<EvictMode>(), Ok(EvictMode::Targeted));
        assert_eq!("full".parse::<EvictMode>(), Ok(EvictMode::Full));
        assert!("caffeine".parse::<EvictMode>().is_err());
        assert_eq!(EvictMode::Targeted.to_string(), "targeted");
        assert_eq!(EvictMode::Full.to_string(), "full");
    }
}
