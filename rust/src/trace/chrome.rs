//! Chrome `trace_event` JSON export.
//!
//! Emits the "JSON Object Format" (`{"traceEvents": [...]}`) with
//! complete events (`"ph": "X"`, microsecond `ts`/`dur`), loadable in
//! `chrome://tracing` or Perfetto. Track layout: one `tid` per mapping
//! shard, a track per sink, and a control track for store/recovery spans.

use crate::util::json::Json;

use super::{Span, Stage, TraceCtx, Tracer, SINK_NONE};

/// `pid` for all pipeline tracks (single process).
const PID: u64 = 1;
/// `tid` base for per-sink egress tracks.
const TID_SINK_BASE: u64 = 1000;
/// `tid` for control-plane spans (store commit, recovery).
const TID_CONTROL: u64 = 900;

fn tid_for(ctx: &TraceCtx, span: &Span) -> u64 {
    match span.stage {
        Stage::Egress if span.sink != SINK_NONE => TID_SINK_BASE + span.sink as u64,
        Stage::StoreCommit | Stage::Recovery => TID_CONTROL,
        _ => ctx.shard as u64,
    }
}

/// Render buffered spans as a Chrome trace JSON document.
pub fn render(spans: &[(TraceCtx, Span)], tracer: &Tracer) -> String {
    let mut events = Vec::with_capacity(spans.len());
    for (ctx, span) in spans {
        let mut args = Json::obj();
        args.set("trace_id", Json::Num(ctx.trace_id as f64));
        args.set("partition", Json::Num(ctx.partition as f64));
        args.set("offset", Json::Num(ctx.offset as f64));
        args.set("schema", Json::Num(ctx.schema as f64));
        args.set("version", Json::Num(ctx.version as f64));
        args.set("epoch", Json::Num(ctx.epoch as f64));
        args.set("lane", Json::Str(ctx.lane.name().to_string()));
        args.set("ok", Json::Bool(span.ok));
        if let Some(name) = tracer.sink_name(span.sink) {
            args.set("sink", Json::Str(name));
        }
        let mut ev = Json::obj();
        ev.set("name", Json::Str(span.stage.name().to_string()));
        ev.set("cat", Json::Str("metl".to_string()));
        ev.set("ph", Json::Str("X".to_string()));
        // trace_event timestamps are microseconds
        ev.set("ts", Json::Num(span.ts_ns as f64 / 1_000.0));
        ev.set("dur", Json::Num(span.dur_ns as f64 / 1_000.0));
        ev.set("pid", Json::Num(PID as f64));
        ev.set("tid", Json::Num(tid_for(ctx, span) as f64));
        ev.set("args", args);
        events.push(ev);
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events));
    doc.set("displayTimeUnit", Json::Str("ns".to_string()));
    doc.to_pretty()
}

#[cfg(test)]
mod tests {
    use super::super::Tracer;
    use super::*;
    use crate::metrics::TraceMetrics;
    use crate::util::json;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn export_parses_and_has_complete_events() {
        let tr = Tracer::new(Arc::new(TraceMetrics::default()), true);
        let sink = tr.register_sink("dw");
        let mut t = tr.begin(1, 5);
        t.stamp_payload(2, 1);
        t.stamp_epoch(3);
        let t0 = Instant::now();
        t.span(Stage::Ingest, t0);
        t.span(Stage::Map, t0);
        tr.finish(t);
        tr.record_span(TraceCtx::default(), Stage::Egress, sink, Instant::now(), true);

        let text = tr.chrome_trace_json();
        let doc = json::parse(&text).expect("valid json");
        let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(events.len(), 3);
        for ev in events {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("dur").and_then(Json::as_f64).is_some());
            assert!(ev.get("pid").and_then(Json::as_u64).is_some());
            assert!(ev.get("tid").and_then(Json::as_u64).is_some());
        }
        // the egress span landed on the sink track with its name in args
        let egress = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("egress"))
            .unwrap();
        assert_eq!(egress.get("tid").and_then(Json::as_u64), Some(TID_SINK_BASE));
        assert_eq!(
            egress.get("args").unwrap().get("sink").and_then(Json::as_str),
            Some("dw")
        );
    }
}
