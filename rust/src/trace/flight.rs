//! Flight recorder: a bounded ring of the last N completed traces.
//!
//! When something goes wrong after the fact — a record dead-letters, a
//! sink flush fails, the store recovers from a crash — the ring is
//! rendered into a [`FlightDump`] so the operator (and the quarantined
//! record itself) gets the causal history leading up to the failure, not
//! just a counter bump.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::metrics::TraceMetrics;
use crate::util::stats::format_ns;

use super::{Span, TraceCtx, Tracer, MAX_EVENT_SPANS, SINK_NONE};

/// Default completed-trace ring capacity.
pub const DEFAULT_FLIGHT_CAP: usize = 256;

/// How many recent traces a non-dead-letter dump includes.
const DUMP_TRACES: usize = 16;

/// Bounded number of retained dumps (oldest evicted).
const MAX_DUMPS: usize = 64;

/// One finished trace held in the flight ring.
#[derive(Debug, Clone)]
pub struct CompletedTrace {
    pub ctx: TraceCtx,
    spans: [Span; MAX_EVENT_SPANS],
    n: u8,
    /// Dead-letter error, when the trace ended in quarantine.
    pub error: Option<String>,
}

impl CompletedTrace {
    pub(super) fn new(ctx: TraceCtx, spans: &[Span], error: Option<&str>) -> CompletedTrace {
        let mut arr = [Span::default(); MAX_EVENT_SPANS];
        let n = spans.len().min(MAX_EVENT_SPANS);
        arr[..n].copy_from_slice(&spans[..n]);
        CompletedTrace { ctx, spans: arr, n: n as u8, error: error.map(str::to_string) }
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans[..self.n as usize]
    }

    /// Timestamp of the trace's last span (ring ordering key).
    fn end_ts(&self) -> u64 {
        self.spans().iter().map(|s| s.ts_ns + s.dur_ns).max().unwrap_or(0)
    }

    /// Render the full span chain, e.g.:
    ///
    /// ```text
    /// trace=7 src=p2@17 schema=s3v99 epoch=4 shard=0 lane=native
    ///   ingest        1.20µs ok
    ///   map          39.00ms FAIL
    ///   error: unknown version v99
    /// ```
    pub fn render(&self, tracer: &Tracer) -> String {
        let mut out = self.ctx.render();
        out.push('\n');
        for s in self.spans() {
            let stage = if s.stage == super::Stage::Egress && s.sink != SINK_NONE {
                match tracer.sink_name(s.sink) {
                    Some(name) => format!("{}:{}", s.stage.name(), name),
                    None => s.stage.name().to_string(),
                }
            } else {
                s.stage.name().to_string()
            };
            out.push_str(&format!(
                "  {:<14} {:>10} {}\n",
                stage,
                format_ns(s.dur_ns as f64),
                if s.ok { "ok" } else { "FAIL" }
            ));
        }
        if let Some(err) = &self.error {
            out.push_str(&format!("  error: {err}\n"));
        }
        out
    }
}

/// One rendered flight-recorder dump.
#[derive(Debug, Clone)]
pub struct FlightDump {
    /// Why the dump was taken ("dead-letter: …", "sink dw flush error",
    /// "store-recovery").
    pub reason: String,
    /// Rendered traces, oldest first.
    pub traces: Vec<String>,
}

impl FlightDump {
    pub fn render(&self) -> String {
        let mut out = format!("=== flight dump: {} ({} traces) ===\n", self.reason, self.traces.len());
        for t in &self.traces {
            out.push_str(t);
        }
        out
    }
}

/// The ring itself. Sub-ring sharded by thread (same affinity scheme as
/// the span buffer) so the per-event `push` doesn't serialize workers;
/// dumps merge and re-order by end timestamp.
#[derive(Debug)]
pub(super) struct FlightRecorder {
    rings: Vec<Mutex<VecDeque<CompletedTrace>>>,
    cap_per_ring: usize,
    dumps: Mutex<VecDeque<FlightDump>>,
}

const SUB_RINGS: usize = 8;

impl FlightRecorder {
    pub(super) fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            rings: (0..SUB_RINGS).map(|_| Mutex::new(VecDeque::new())).collect(),
            cap_per_ring: (capacity / SUB_RINGS).max(1),
            dumps: Mutex::new(VecDeque::new()),
        }
    }

    pub(super) fn push(&self, t: CompletedTrace) {
        let id = std::thread::current().id();
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::hash::Hash::hash(&id, &mut h);
        let idx = std::hash::Hasher::finish(&h) as usize % self.rings.len();
        let mut ring = self.rings[idx].lock().unwrap();
        if ring.len() >= self.cap_per_ring {
            ring.pop_front();
        }
        ring.push_back(t);
    }

    /// Completed traces across all sub-rings, oldest first.
    pub(super) fn snapshot(&self) -> Vec<CompletedTrace> {
        let mut all: Vec<CompletedTrace> = Vec::new();
        for ring in &self.rings {
            all.extend(ring.lock().unwrap().iter().cloned());
        }
        all.sort_by_key(|t| t.end_ts());
        all
    }

    /// Render the most recent traces into a dump under `reason`.
    pub(super) fn dump_recent(
        &self,
        reason: &str,
        tracer: &Tracer,
        metrics: &TraceMetrics,
    ) -> Option<FlightDump> {
        let all = self.snapshot();
        let tail = all.iter().rev().take(DUMP_TRACES).rev();
        let traces: Vec<String> = tail.map(|t| t.render(tracer)).collect();
        Some(self.dump(reason, traces, metrics))
    }

    /// Record a pre-rendered dump (dead-letter path renders its one trace).
    pub(super) fn dump(
        &self,
        reason: &str,
        traces: Vec<String>,
        metrics: &TraceMetrics,
    ) -> FlightDump {
        let d = FlightDump { reason: reason.to_string(), traces };
        let mut dumps = self.dumps.lock().unwrap();
        if dumps.len() >= MAX_DUMPS {
            dumps.pop_front();
        }
        dumps.push_back(d.clone());
        metrics.flight_dumps.inc();
        d
    }

    pub(super) fn dumps(&self) -> Vec<FlightDump> {
        self.dumps.lock().unwrap().iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Stage, Tracer};
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn ring_is_bounded() {
        let tr = Tracer::with_capacity(Arc::new(TraceMetrics::default()), true, 1 << 12, 8);
        for i in 0..100 {
            let mut t = tr.begin(0, i);
            t.span(Stage::Map, Instant::now());
            tr.finish(t);
        }
        // single thread → one sub-ring of cap 8/8 = 1
        let snap = tr.flight_snapshot();
        assert!(!snap.is_empty() && snap.len() <= 8, "len={}", snap.len());
        // the retained trace is the most recent one
        assert_eq!(snap.last().unwrap().ctx.offset, 99);
    }

    #[test]
    fn dump_recent_renders_tail() {
        let tr = Tracer::with_capacity(Arc::new(TraceMetrics::default()), true, 1 << 12, 64);
        for i in 0..5 {
            let mut t = tr.begin(1, i);
            t.stamp_epoch(i);
            t.span(Stage::Map, Instant::now());
            tr.finish(t);
        }
        let dump = tr.dump_recent("sink dw flush error").unwrap();
        assert_eq!(dump.reason, "sink dw flush error");
        assert_eq!(dump.traces.len(), 5);
        assert!(dump.render().contains("flight dump"));
        assert!(dump.traces.iter().any(|t| t.contains("p1@4")));
        assert_eq!(tr.dumps().len(), 1);
    }
}
