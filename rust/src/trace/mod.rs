//! Span-based tracing and record provenance.
//!
//! Every CDC event processed by the pipeline carries a [`TraceCtx`] — its
//! trace id, source partition/offset, schema id + version, the DMM epoch
//! it was mapped under, the kernel lane, and the worker shard. Each stage
//! (ingest → map/kernel → evolution heal → egress per sink → store
//! commit) records a timed [`Span`] into a thread-sharded bounded
//! [`Tracer`] buffer, exportable as Chrome `trace_event` JSON
//! ([`Tracer::chrome_trace_json`]) for flamegraph viewing.
//!
//! On top sits the [`flight`] recorder: a bounded ring of the last N
//! completed traces, dumped automatically on dead-letter, sink flush
//! error, or store recovery — so every quarantined record ships with its
//! full causal history.
//!
//! Cost model: recording is on by default (`PipelineConfig::trace`), so
//! the hot path must stay cheap — [`EventTrace`] is a stack value with a
//! fixed-size span array (no per-event allocation), and the only
//! synchronization per event is one lock on a thread-affine buffer shard
//! plus one on a thread-affine flight sub-ring. `benches/overhead.rs`
//! gates the end-to-end overhead at < 5%.

pub mod chrome;
pub mod flight;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::mapper::kernel::KernelMode;
use crate::metrics::TraceMetrics;

pub use flight::{CompletedTrace, FlightDump};

/// Pipeline stage a span measures. Names are stable — they appear in
/// metric labels and Chrome trace output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Source consume + provenance stamping overhead.
    Ingest,
    /// DMM mapping (Alg 6 / native kernel), including sync retries.
    Map,
    /// In-band evolution heal (Alg-5 case 3) triggered by this event.
    Heal,
    /// One sink drain batch: apply + flush.
    Egress,
    /// Durable-store WAL commit of an evolution-lane update.
    StoreCommit,
    /// Store recovery replay at startup.
    Recovery,
}

impl Stage {
    pub const ALL: [Stage; 6] = [
        Stage::Ingest,
        Stage::Map,
        Stage::Heal,
        Stage::Egress,
        Stage::StoreCommit,
        Stage::Recovery,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Ingest => "ingest",
            Stage::Map => "map",
            Stage::Heal => "heal",
            Stage::Egress => "egress",
            Stage::StoreCommit => "store_commit",
            Stage::Recovery => "recovery",
        }
    }
}

/// Which execution lane mapped the event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum Lane {
    /// Scalar Alg-6 per-element mapping.
    Scalar,
    /// Native block-permutation kernel.
    Native,
    /// XLA/native bulk initial-load lane.
    Bulk,
    /// Control-plane work (evolution, store, recovery).
    #[default]
    Control,
}

impl Lane {
    pub fn name(self) -> &'static str {
        match self {
            Lane::Scalar => "scalar",
            Lane::Native => "native",
            Lane::Bulk => "bulk",
            Lane::Control => "control",
        }
    }
}

impl From<KernelMode> for Lane {
    fn from(k: KernelMode) -> Lane {
        match k {
            KernelMode::Native => Lane::Native,
            KernelMode::Scalar => Lane::Scalar,
        }
    }
}

/// Sink index meaning "no sink" on non-egress spans.
pub const SINK_NONE: u8 = u8::MAX;

/// Provenance carried by one traced event through the pipeline.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceCtx {
    /// Unique per-tracer trace id (0 = batch/control span, not an event).
    pub trace_id: u64,
    /// Source CDC topic partition the event was consumed from.
    pub partition: u32,
    /// Offset within that partition.
    pub offset: u64,
    /// Schema id of the mapping payload.
    pub schema: u32,
    /// Schema version of the mapping payload.
    pub version: u32,
    /// DMM epoch the event was (last) mapped under.
    pub epoch: u64,
    /// Worker shard of the sharded mapping lane (0 in the single lane).
    pub shard: u16,
    /// Execution lane.
    pub lane: Lane,
}

impl TraceCtx {
    /// Render the provenance half of a flight-recorder line.
    pub fn render(&self) -> String {
        format!(
            "trace={} src=p{}@{} schema=s{}v{} epoch={} shard={} lane={}",
            self.trace_id,
            self.partition,
            self.offset,
            self.schema,
            self.version,
            self.epoch,
            self.shard,
            self.lane.name()
        )
    }
}

/// One timed stage of a trace. Timestamps are nanoseconds relative to the
/// owning [`Tracer`]'s anchor instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub stage: Stage,
    /// Registered sink index for [`Stage::Egress`], else [`SINK_NONE`].
    pub sink: u8,
    pub ok: bool,
    pub ts_ns: u64,
    pub dur_ns: u64,
}

impl Default for Span {
    fn default() -> Self {
        Span { stage: Stage::Ingest, sink: SINK_NONE, ok: true, ts_ns: 0, dur_ns: 0 }
    }
}

/// Max spans retained per event trace (ingest + map + a few heal
/// retries); later spans are dropped and counted.
pub const MAX_EVENT_SPANS: usize = 6;

/// Per-event trace under construction: a stack value threaded through
/// `process_event` — no allocation, nothing shared until
/// [`Tracer::finish`].
#[derive(Debug, Clone)]
pub struct EventTrace {
    active: bool,
    anchor: Instant,
    ctx: TraceCtx,
    n: u8,
    overflow: u8,
    spans: [Span; MAX_EVENT_SPANS],
}

impl EventTrace {
    /// A no-op trace: every method returns immediately. Used when tracing
    /// is disabled and by untraced internal callers.
    pub fn inactive() -> EventTrace {
        EventTrace {
            active: false,
            anchor: Instant::now(),
            ctx: TraceCtx::default(),
            n: 0,
            overflow: 0,
            spans: [Span::default(); MAX_EVENT_SPANS],
        }
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }

    /// Stamp schema id + version from the mapping payload.
    pub fn stamp_payload(&mut self, schema: u32, version: u32) {
        self.ctx.schema = schema;
        self.ctx.version = version;
    }

    /// Stamp the DMM epoch the event is being mapped under (re-stamped
    /// after an in-band heal or worker epoch refresh).
    pub fn stamp_epoch(&mut self, epoch: u64) {
        self.ctx.epoch = epoch;
    }

    pub fn stamp_shard(&mut self, shard: u16) {
        self.ctx.shard = shard;
    }

    pub fn stamp_lane(&mut self, lane: Lane) {
        self.ctx.lane = lane;
    }

    /// Record a successful span covering `t0 → now`.
    pub fn span(&mut self, stage: Stage, t0: Instant) {
        self.push(stage, t0, true);
    }

    /// Record a failed span covering `t0 → now`.
    pub fn span_err(&mut self, stage: Stage, t0: Instant) {
        self.push(stage, t0, false);
    }

    fn push(&mut self, stage: Stage, t0: Instant, ok: bool) {
        if !self.active {
            return;
        }
        if (self.n as usize) >= MAX_EVENT_SPANS {
            self.overflow += 1;
            return;
        }
        let ts_ns = t0
            .checked_duration_since(self.anchor)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        self.spans[self.n as usize] = Span {
            stage,
            sink: SINK_NONE,
            ok,
            ts_ns,
            dur_ns: t0.elapsed().as_nanos() as u64,
        };
        self.n += 1;
    }

    /// Spans recorded so far.
    pub fn spans(&self) -> &[Span] {
        &self.spans[..self.n as usize]
    }
}

/// One shard of the span buffer: cache-line padded so hot worker threads
/// don't false-share.
#[derive(Debug, Default)]
#[repr(align(64))]
struct BufShard {
    inner: Mutex<Vec<(TraceCtx, Span)>>,
}

const BUF_SHARDS: usize = 16;

/// Default total span-buffer capacity across shards. At ~48 bytes per
/// slot this bounds the buffer to a few MiB; overflow is dropped and
/// counted in `TraceMetrics::spans_dropped` (surfaced by the scenario
/// conservation checks — never silent).
pub const DEFAULT_SPAN_CAPACITY: usize = 1 << 18;

/// The pipeline-wide trace collector: hands out [`EventTrace`]s, stores
/// completed spans in thread-sharded bounded buffers, and feeds the
/// [`flight`] recorder.
#[derive(Debug)]
pub struct Tracer {
    enabled: AtomicBool,
    anchor: Instant,
    next_id: AtomicU64,
    shards: Vec<BufShard>,
    cap_per_shard: usize,
    flight: flight::FlightRecorder,
    sink_names: RwLock<Vec<String>>,
    /// Shared with `PipelineMetrics::trace` so exposition sees live values.
    pub metrics: Arc<TraceMetrics>,
}

impl Tracer {
    pub fn new(metrics: Arc<TraceMetrics>, enabled: bool) -> Tracer {
        Tracer::with_capacity(metrics, enabled, DEFAULT_SPAN_CAPACITY, flight::DEFAULT_FLIGHT_CAP)
    }

    /// Tracer with explicit span-buffer and flight-ring bounds (tests).
    pub fn with_capacity(
        metrics: Arc<TraceMetrics>,
        enabled: bool,
        span_capacity: usize,
        flight_capacity: usize,
    ) -> Tracer {
        Tracer {
            enabled: AtomicBool::new(enabled),
            anchor: Instant::now(),
            next_id: AtomicU64::new(1),
            shards: (0..BUF_SHARDS).map(|_| BufShard::default()).collect(),
            cap_per_shard: (span_capacity / BUF_SHARDS).max(1),
            flight: flight::FlightRecorder::new(flight_capacity),
            sink_names: RwLock::new(Vec::new()),
            metrics,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Register a sink name, returning its stable index for egress spans.
    pub fn register_sink(&self, name: &str) -> u8 {
        let mut names = self.sink_names.write().unwrap();
        if let Some(i) = names.iter().position(|n| n == name) {
            return i as u8;
        }
        names.push(name.to_string());
        (names.len() - 1) as u8
    }

    /// Name of a registered sink index.
    pub fn sink_name(&self, idx: u8) -> Option<String> {
        self.sink_names.read().unwrap().get(idx as usize).cloned()
    }

    /// Begin tracing one consumed event. Near-free when disabled.
    pub fn begin(&self, partition: u32, offset: u64) -> EventTrace {
        if !self.enabled() {
            return EventTrace::inactive();
        }
        EventTrace {
            active: true,
            anchor: self.anchor,
            ctx: TraceCtx {
                trace_id: self.next_id.fetch_add(1, Ordering::Relaxed),
                partition,
                offset,
                ..TraceCtx::default()
            },
            n: 0,
            overflow: 0,
            spans: [Span::default(); MAX_EVENT_SPANS],
        }
    }

    /// Complete a trace: persist its spans and admit it to the flight ring.
    pub fn finish(&self, t: EventTrace) {
        self.finish_inner(t, None);
    }

    /// Complete a dead-lettered trace; returns the rendered flight dump
    /// (the record's full causal history) for attachment to the DLQ entry.
    pub fn finish_dead_letter(&self, t: EventTrace, error: &str) -> Option<String> {
        if !t.active {
            return None;
        }
        let completed = self.finish_inner(t, Some(error));
        let rendered = completed.as_ref().map(|c| c.render(self));
        if let Some(text) = &rendered {
            self.flight.dump(
                &format!("dead-letter: {error}"),
                vec![text.clone()],
                &self.metrics,
            );
        }
        rendered
    }

    fn finish_inner(&self, t: EventTrace, error: Option<&str>) -> Option<CompletedTrace> {
        if !t.active {
            return None;
        }
        self.push_spans(t.ctx, t.spans());
        if t.overflow > 0 {
            self.metrics.spans_dropped.add(t.overflow as u64);
        }
        self.metrics.traces.inc();
        let completed = CompletedTrace::new(t.ctx, t.spans(), error);
        self.flight.push(completed.clone());
        Some(completed)
    }

    /// Record a standalone span not tied to one event trace (egress drain
    /// batches, store commits, bulk-lane batches, recovery).
    pub fn record_span(&self, ctx: TraceCtx, stage: Stage, sink: u8, t0: Instant, ok: bool) {
        if !self.enabled() {
            return;
        }
        let ts_ns = t0
            .checked_duration_since(self.anchor)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let span = Span { stage, sink, ok, ts_ns, dur_ns: t0.elapsed().as_nanos() as u64 };
        self.push_spans(ctx, &[span]);
    }

    fn push_spans(&self, ctx: TraceCtx, spans: &[Span]) {
        if spans.is_empty() {
            return;
        }
        // thread-affine shard, same scheme as LatencyChannel
        let id = std::thread::current().id();
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::hash::Hash::hash(&id, &mut h);
        let idx = std::hash::Hasher::finish(&h) as usize % self.shards.len();
        let mut buf = self.shards[idx].inner.lock().unwrap();
        let room = self.cap_per_shard.saturating_sub(buf.len());
        let take = spans.len().min(room);
        buf.extend(spans[..take].iter().map(|s| (ctx, *s)));
        drop(buf);
        self.metrics.spans.add(take as u64);
        if take < spans.len() {
            self.metrics.spans_dropped.add((spans.len() - take) as u64);
        }
    }

    /// Dump the most recent completed traces (flight-recorder contents)
    /// under `reason` — called on sink flush error and store recovery.
    pub fn dump_recent(&self, reason: &str) -> Option<FlightDump> {
        if !self.enabled() {
            return None;
        }
        self.flight.dump_recent(reason, self, &self.metrics)
    }

    /// All flight dumps taken so far (bounded; oldest evicted first).
    pub fn dumps(&self) -> Vec<FlightDump> {
        self.flight.dumps()
    }

    /// Snapshot of the completed-trace ring, oldest first.
    pub fn flight_snapshot(&self) -> Vec<CompletedTrace> {
        self.flight.snapshot()
    }

    /// Spans currently buffered, ordered by start timestamp.
    pub fn spans(&self) -> Vec<(TraceCtx, Span)> {
        let mut all = Vec::new();
        for s in &self.shards {
            all.extend(s.inner.lock().unwrap().iter().copied());
        }
        all.sort_by_key(|(_, s)| s.ts_ns);
        all
    }

    /// Number of spans currently buffered.
    pub fn span_count(&self) -> usize {
        self.shards.iter().map(|s| s.inner.lock().unwrap().len()).sum()
    }

    /// Export buffered spans as Chrome `trace_event` JSON (load in
    /// `chrome://tracing` or Perfetto).
    pub fn chrome_trace_json(&self) -> String {
        chrome::render(&self.spans(), self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer(enabled: bool) -> Tracer {
        Tracer::new(Arc::new(TraceMetrics::default()), enabled)
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let tr = tracer(false);
        let mut t = tr.begin(0, 7);
        assert!(!t.is_active());
        t.span(Stage::Map, Instant::now());
        tr.finish(t);
        assert_eq!(tr.span_count(), 0);
        assert_eq!(tr.metrics.traces.get(), 0);
    }

    #[test]
    fn event_trace_carries_provenance() {
        let tr = tracer(true);
        let mut t = tr.begin(3, 41);
        t.stamp_payload(5, 2);
        t.stamp_epoch(9);
        t.stamp_shard(1);
        t.stamp_lane(Lane::Native);
        let t0 = Instant::now();
        t.span(Stage::Ingest, t0);
        t.span(Stage::Map, t0);
        let ctx = t.ctx();
        tr.finish(t);
        assert_eq!(ctx.partition, 3);
        assert_eq!(ctx.offset, 41);
        assert_eq!(ctx.schema, 5);
        assert_eq!(ctx.version, 2);
        assert_eq!(ctx.epoch, 9);
        assert_eq!(ctx.shard, 1);
        assert_eq!(ctx.lane, Lane::Native);
        assert_eq!(tr.span_count(), 2);
        assert_eq!(tr.metrics.spans.get(), 2);
        assert_eq!(tr.metrics.traces.get(), 1);
        let r = ctx.render();
        assert!(r.contains("p3@41"));
        assert!(r.contains("s5v2"));
        assert!(r.contains("epoch=9"));
    }

    #[test]
    fn span_overflow_is_counted_not_silent() {
        let tr = tracer(true);
        let mut t = tr.begin(0, 0);
        let t0 = Instant::now();
        for _ in 0..MAX_EVENT_SPANS + 3 {
            t.span(Stage::Map, t0);
        }
        tr.finish(t);
        assert_eq!(tr.span_count(), MAX_EVENT_SPANS);
        assert_eq!(tr.metrics.spans_dropped.get(), 3);
    }

    #[test]
    fn buffer_capacity_drops_are_counted() {
        let tr = Tracer::with_capacity(Arc::new(TraceMetrics::default()), true, 16, 4);
        // 16 total / 16 shards = 1 slot on this thread's shard
        for i in 0..5 {
            let mut t = tr.begin(0, i);
            t.span(Stage::Map, Instant::now());
            tr.finish(t);
        }
        assert_eq!(tr.metrics.traces.get(), 5);
        assert_eq!(tr.metrics.spans.get() + tr.metrics.spans_dropped.get(), 5);
        assert!(tr.metrics.spans_dropped.get() > 0);
    }

    #[test]
    fn dead_letter_dump_contains_chain() {
        let tr = tracer(true);
        let mut t = tr.begin(2, 17);
        t.stamp_payload(3, 99);
        t.stamp_epoch(4);
        let t0 = Instant::now();
        t.span(Stage::Ingest, t0);
        t.span_err(Stage::Map, t0);
        let dump = tr.finish_dead_letter(t, "unknown version v99").unwrap();
        assert!(dump.contains("p2@17"), "{dump}");
        assert!(dump.contains("epoch=4"), "{dump}");
        assert!(dump.contains("map"), "{dump}");
        assert!(dump.contains("FAIL"), "{dump}");
        let dumps = tr.dumps();
        assert_eq!(dumps.len(), 1);
        assert!(dumps[0].reason.contains("dead-letter"));
        assert_eq!(tr.metrics.flight_dumps.get(), 1);
    }

    #[test]
    fn sink_registry_is_stable() {
        let tr = tracer(true);
        assert_eq!(tr.register_sink("dw"), 0);
        assert_eq!(tr.register_sink("ml"), 1);
        assert_eq!(tr.register_sink("dw"), 0);
        assert_eq!(tr.sink_name(1).as_deref(), Some("ml"));
        assert_eq!(tr.sink_name(SINK_NONE), None);
    }

    #[test]
    fn standalone_spans_are_recorded() {
        let tr = tracer(true);
        let sink = tr.register_sink("dw");
        tr.record_span(TraceCtx::default(), Stage::Egress, sink, Instant::now(), true);
        tr.record_span(TraceCtx::default(), Stage::StoreCommit, SINK_NONE, Instant::now(), true);
        let spans = tr.spans();
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().any(|(_, s)| s.stage == Stage::Egress && s.sink == sink));
    }
}
