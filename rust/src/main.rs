//! metl — CLI launcher for the METL reproduction.
//!
//! Subcommands (hand-rolled arg parsing; no clap offline):
//!   run        simulate a day trace through the full pipeline (fig 1/§7)
//!   compact    build ᵢ𝔇𝔓𝔐/ᵢ𝔇𝔘𝔖𝔅 at a profile's scale, print ratios
//!   update     apply a schema-change storm, print Alg-5 reports
//!   inspect    UI-sim queries: reverse search + version progression
//!   bulk       run an initial load through the XLA bulk lane
//!   dashboard  run a short trace and print the fig-7 dashboard
//!   trace      run a short trace, export Chrome trace-event JSON +
//!              the Prometheus-style metric exposition

use anyhow::{bail, Context, Result};

use metl::config::PipelineConfig;
use metl::coordinator::batcher::InitialLoader;
use metl::coordinator::{inspect, pipeline::Pipeline, scaler};
use metl::matrix::compaction::CompactionStats;
use metl::matrix::dpm::DpmSet;
use metl::matrix::dusb::DusbSet;
use metl::message::StateI;
use metl::util::rng::Rng;
use metl::util::stats::format_ns;
use metl::workload;

fn usage() -> ! {
    eprintln!(
        "usage: metl <command> [--profile small|paper_day|eos_scale] [--config FILE]\n\
         \x20                   [--sinks dw,ml,jsonl,audit] [--evict targeted|full]\n\
         \x20                   [--kernel native|scalar] [--store DIR]\n\
         \x20                   [--trace on|off]\n\
         \n\
         commands:\n\
           run        [--instances N]   simulate a day trace end to end\n\
           compact                      compaction ratios at profile scale\n\
           update     [--storms N]      schema-change storms + Alg-5 reports\n\
           inspect    [--entity N | --schema N]\n\
           bulk       [--rows N]        initial load via the XLA bulk lane\n\
           dashboard                    short trace + fig-7 dashboard\n\
           trace      [--out FILE] [--events N]\n\
                                        short trace -> Chrome trace-event\n\
                                        JSON (default trace.json) + metric\n\
                                        exposition on stdout\n\
           csv-export [--out FILE]      export the DMM as mapping CSV\n\
           csv-import --file FILE       validate + import a mapping CSV\n\
           serve      [--seconds N] [--expose PATH|-]\n\
                                        run the pipeline as a daemon with\n\
                                        live traffic + periodic dashboards\n\
                                        (--expose also writes the metric\n\
                                        exposition each refresh)"
    );
    std::process::exit(2);
}

struct Args {
    command: String,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Args {
        let mut argv = std::env::args().skip(1);
        let Some(command) = argv.next() else { usage() };
        let mut flags = Vec::new();
        let rest: Vec<String> = argv.collect();
        let mut i = 0;
        while i < rest.len() {
            let flag = rest[i].trim_start_matches("--").to_string();
            let value = rest.get(i + 1).cloned().unwrap_or_default();
            flags.push((flag, value));
            i += 2;
        }
        Args { command, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(f, _)| f == name)
            .map(|(_, v)| v.as_str())
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("bad --{name}")),
        }
    }
}

fn load_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path}"))?;
        PipelineConfig::parse(&text)?
    } else {
        match args.get("profile") {
            None | Some("small") => PipelineConfig::small(),
            Some("paper_day") => PipelineConfig::paper_day(),
            Some("eos_scale") => PipelineConfig::eos_scale(),
            Some(other) => bail!("unknown profile {other}"),
        }
    };
    if let Some(list) = args.get("sinks") {
        cfg.sinks = metl::config::parse_string_list(list);
    }
    if let Some(mode) = args.get("evict") {
        cfg.evict = mode
            .parse::<metl::cache::EvictMode>()
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(mode) = args.get("kernel") {
        cfg.kernel = mode
            .parse::<metl::mapper::kernel::KernelMode>()
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(dir) = args.get("store") {
        cfg.store_dir =
            if dir.is_empty() { None } else { Some(dir.to_string()) };
    }
    if let Some(mode) = args.get("trace") {
        cfg.trace = match mode {
            "on" | "true" => true,
            "off" | "false" => false,
            other => bail!("bad --trace {other:?} (expected on|off)"),
        };
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cfg = load_config(&args)?;
    match args.command.as_str() {
        "run" => cmd_run(&args, cfg),
        "compact" => cmd_compact(cfg),
        "update" => cmd_update(&args, cfg),
        "inspect" => cmd_inspect(&args, cfg),
        "bulk" => cmd_bulk(&args, cfg),
        "dashboard" => cmd_dashboard(cfg),
        "trace" => cmd_trace(&args, cfg),
        "csv-export" => cmd_csv_export(&args, cfg),
        "csv-import" => cmd_csv_import(&args, cfg),
        "serve" => cmd_serve(&args, cfg),
        _ => usage(),
    }
}

/// Daemon mode: a producer loop feeds live DML (with occasional schema
/// changes), the consumer loop maps continuously, and the fig-7 dashboard
/// refreshes once per second — the long-running shape of the real METL
/// service, bounded by --seconds for scripted runs.
fn cmd_serve(args: &Args, cfg: PipelineConfig) -> Result<()> {
    use metl::broker::Consumer;
    use metl::workload::{DmlKind, TraceOp};
    let seconds = args.get_usize("seconds", 10)?;
    let pipeline = Pipeline::new(cfg)?;
    if pipeline.restore_from_store()? {
        println!(
            "restored DMM from store at state {}",
            pipeline.state.current().0
        );
    }
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(seconds as u64);
    let mut rng = Rng::seed_from(pipeline.cfg.seed ^ 0x5E21E);
    let mut consumer = Consumer::new(pipeline.cdc_topic.clone(), 0, 1);
    let mut last_dash = std::time::Instant::now();
    let mut tick = 0u64;
    println!("serving for {seconds}s (ctrl-c to stop)...");
    while std::time::Instant::now() < deadline {
        // produce a small burst of source traffic
        for _ in 0..1 + rng.gen_range(8) {
            let service = rng.gen_range(pipeline.cfg.n_services as u64) as usize;
            let roll = rng.f64();
            let kind = if roll < 0.7 {
                DmlKind::Insert
            } else if roll < 0.95 {
                DmlKind::Update
            } else {
                DmlKind::Delete
            };
            pipeline.resolve_op(&TraceOp::Dml { service, kind })?;
        }
        // rare schema change (the paper: a few times a day)
        tick += 1;
        if tick % 997 == 0 {
            let service = rng.gen_range(pipeline.cfg.n_services as u64) as usize;
            let _ = pipeline.apply_schema_change(service);
        }
        // drain wire-observed schema changes (the online evolution lane)
        pipeline.evolution.pump(&pipeline);
        // consume + map + sink (zero-copy segment views)
        loop {
            let batches = consumer.poll_shared(128);
            if batches.is_empty() {
                break;
            }
            for batch in &batches {
                for rec in batch.iter() {
                    pipeline.process_event_from(
                        batch.partition(),
                        rec.offset,
                        &rec.value,
                    );
                }
            }
            consumer.commit();
        }
        pipeline.drain_sinks();
        if last_dash.elapsed() >= std::time::Duration::from_secs(1) {
            println!("{}", pipeline.dashboard());
            if let Some(path) = args.get("expose") {
                write_exposition(&pipeline, path)?;
            }
            last_dash = std::time::Instant::now();
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    println!("{}", pipeline.dashboard());
    if let Some(path) = args.get("expose") {
        write_exposition(&pipeline, path)?;
    }
    println!(
        "served {} events, {} updates, dlq={}",
        pipeline.metrics.events_in.get(),
        pipeline.metrics.dmm_updates.get(),
        pipeline.dlq.len()
    );
    for handle in &pipeline.sinks {
        let stats = handle.stats();
        println!(
            "  sink {:<7} accepted={} duplicates={} dropped={} lag={} flush_errors={}",
            handle.name(),
            stats.applied,
            stats.duplicates,
            stats.dropped,
            handle.lag(),
            handle.metrics().flush_errors.get()
        );
    }
    Ok(())
}

/// Write (or print, for `-`) the Prometheus-style text exposition.
fn write_exposition(pipeline: &Pipeline, path: &str) -> Result<()> {
    let text = pipeline.expose_text();
    if path == "-" {
        print!("{text}");
    } else {
        std::fs::write(path, &text)
            .with_context(|| format!("write exposition {path}"))?;
    }
    Ok(())
}

/// Run a short day trace with tracing forced on and export every span as
/// Chrome `trace_event` JSON (open in `chrome://tracing` or Perfetto),
/// plus the metric exposition on stdout.
fn cmd_trace(args: &Args, cfg: PipelineConfig) -> Result<()> {
    let out = args.get("out").unwrap_or("trace.json");
    let events = args.get_usize("events", cfg.trace_events.min(300))?;
    let mut rng = Rng::seed_from(cfg.seed);
    let mut cfg = cfg;
    cfg.trace = true;
    cfg.trace_events = events;
    let ops = workload::day_trace(&cfg, &mut rng);
    let pipeline = Pipeline::new(cfg)?;
    pipeline.run_trace(&ops)?;
    std::fs::write(out, pipeline.tracer.chrome_trace_json())
        .with_context(|| format!("write trace {out}"))?;
    println!(
        "wrote {} spans from {} completed traces to {out}",
        pipeline.tracer.span_count(),
        pipeline.metrics.trace.traces.get(),
    );
    print!("{}", pipeline.expose_text());
    Ok(())
}

fn cmd_csv_export(args: &Args, cfg: PipelineConfig) -> Result<()> {
    let land = workload::generate(&cfg);
    let dpm = DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let csv = metl::matrix::csv_import::export_dpm(&dpm, &land.tree, &land.cdm);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &csv)?;
            println!("wrote {} mapping rows to {path}", dpm.n_elements());
        }
        None => print!("{csv}"),
    }
    Ok(())
}

fn cmd_csv_import(args: &Args, cfg: PipelineConfig) -> Result<()> {
    let path = args.get("file").context("csv-import needs --file FILE")?;
    let text = std::fs::read_to_string(path)?;
    let land = workload::generate(&cfg);
    let (dpm, report) = metl::matrix::csv_import::import_dpm(
        &text,
        &land.tree,
        &land.cdm,
        StateI(0),
    )?;
    println!(
        "imported {}/{} rows into {} blocks ({} elements)",
        report.imported,
        report.rows,
        dpm.n_blocks(),
        dpm.n_elements()
    );
    for (line, reason) in &report.rejected {
        println!("  rejected line {line}: {reason}");
    }
    Ok(())
}

fn cmd_run(args: &Args, cfg: PipelineConfig) -> Result<()> {
    let instances = args.get_usize("instances", 1)?;
    let mut rng = Rng::seed_from(cfg.seed);
    let ops = workload::day_trace(&cfg, &mut rng);
    let pipeline = Pipeline::new(cfg)?;
    if pipeline.restore_from_store()? {
        println!(
            "restored DMM from store at state {}",
            pipeline.state.current().0
        );
    }
    println!(
        "running {} trace ops on {} services ({} instances)...",
        ops.len(),
        pipeline.cfg.n_services,
        instances
    );
    if instances <= 1 {
        let report = pipeline.run_trace(&ops)?;
        println!(
            "events={} out={} dlq={} updates={} wall={:?}",
            report.events,
            report.out_messages,
            report.dead_letters,
            report.dmm_updates,
            report.wall
        );
    } else {
        for op in &ops {
            pipeline.resolve_op(op)?;
        }
        let report = scaler::run_scaled(&pipeline, instances);
        println!(
            "processed={} instances={} wall={:?} ({:.0} events/s)",
            report.processed,
            report.instances,
            report.wall,
            report.throughput_eps()
        );
    }
    println!("{}", pipeline.dashboard());
    Ok(())
}

fn cmd_compact(cfg: PipelineConfig) -> Result<()> {
    println!(
        "generating landscape: {} services x {} versions...",
        cfg.n_services, cfg.versions_per_schema
    );
    let land = workload::generate(&cfg);
    let dpm = DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let dusb =
        DusbSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    let stats = CompactionStats::measure(
        &land.matrix,
        &land.tree,
        &land.cdm,
        &dpm,
        &dusb,
    );
    println!("{}", stats.row());
    Ok(())
}

fn cmd_update(args: &Args, cfg: PipelineConfig) -> Result<()> {
    let storms = args.get_usize("storms", 3)?;
    let pipeline = Pipeline::new(cfg)?;
    for i in 0..storms {
        let service = i % pipeline.cfg.n_services;
        let t0 = std::time::Instant::now();
        let report = pipeline.apply_schema_change(service)?;
        println!(
            "storm {i}: svc{service} +{} blocks +{} elements -{} blocks \
             ({} notices) in {}",
            report.blocks_added,
            report.elements_added,
            report.blocks_removed,
            report.notices.len(),
            format_ns(t0.elapsed().as_nanos() as f64),
        );
    }
    println!("final state i = {}", pipeline.state.current().0);
    Ok(())
}

fn cmd_inspect(args: &Args, cfg: PipelineConfig) -> Result<()> {
    let land = workload::generate(&cfg);
    let dpm = DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(e) = args.get("entity") {
        let id = metl::cdm::EntityId(e.parse::<u32>().context("bad --entity")?);
        let w =
            *land.cdm.versions_of(id).last().context("entity has versions")?;
        println!(
            "{}",
            inspect::reverse_search(&dpm, &land.tree, &land.cdm, id, w)
        );
    } else if let Some(s) = args.get("schema") {
        let id =
            metl::schema::SchemaId(s.parse::<u32>().context("bad --schema")?);
        println!(
            "{}",
            inspect::version_progression(&dpm, &land.tree, &land.cdm, id)
        );
    } else {
        bail!("inspect needs --entity N or --schema N");
    }
    Ok(())
}

fn cmd_bulk(args: &Args, cfg: PipelineConfig) -> Result<()> {
    let rows = args.get_usize("rows", 2000)?;
    let mut land = workload::generate(&cfg);
    let mut rng = Rng::seed_from(cfg.seed ^ 0xB);
    workload::populate(&mut land, rows, &mut rng);
    let loader = InitialLoader::from_config(&cfg);
    let pipeline = Pipeline::from_landscape(cfg, land)?;
    println!(
        "bulk runtime: {}",
        loader
            .runtime
            .as_ref()
            .map(|r| format!(
                "loaded ({} variants, platform {})",
                r.n_variants(),
                r.platform
            ))
            .unwrap_or_else(|| "unavailable — Alg-6 fallback".into())
    );
    let t0 = std::time::Instant::now();
    let report = loader.initial_load(&pipeline, 0)?;
    println!(
        "initial load: {} rows -> {} messages, lane={} in {:?}",
        report.rows,
        report.out_messages,
        report.lane,
        t0.elapsed()
    );
    Ok(())
}

fn cmd_dashboard(cfg: PipelineConfig) -> Result<()> {
    let mut rng = Rng::seed_from(cfg.seed);
    let mut small = cfg;
    small.trace_events = small.trace_events.min(300);
    let ops = workload::day_trace(&small, &mut rng);
    let pipeline = Pipeline::new(small)?;
    pipeline.run_trace(&ops)?;
    println!("{}", pipeline.dashboard());
    let dmm = pipeline.dmm.snapshot();
    println!(
        "dmm: {} blocks, {} elements, state {}",
        dmm.n_blocks(),
        dmm.n_elements(),
        dmm.state.0
    );
    Ok(())
}
