//! Latency statistics — the measurement substrate for reproducing the
//! paper's §7 evaluation (mean 39 ms, σ 51 ms over 1168 CDC events) and for
//! the bench harness (no criterion offline).

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute from a sample; empty samples produce an all-zero summary.
    pub fn from(sample: &[f64]) -> Summary {
        if sample.is_empty() {
            return Summary {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let count = sample.len();
        let mean = sample.iter().sum::<f64>() / count as f64;
        // Bessel-corrected *sample* variance — the paper's §7 σ is a
        // sample statistic; a single observation has no spread.
        let var = if count < 2 {
            0.0
        } else {
            sample.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (count - 1) as f64
        };
        let mut sorted = sample.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }

    /// Render one table row: `mean ± std [p50 p90 p99] (min..max) n=count`,
    /// values formatted by `fmt` (e.g. `format_us`).
    pub fn row(&self, fmt: impl Fn(f64) -> String) -> String {
        format!(
            "{} ± {} [p50 {} p90 {} p99 {}] (min {} max {}) n={}",
            fmt(self.mean),
            fmt(self.std),
            fmt(self.p50),
            fmt(self.p90),
            fmt(self.p99),
            fmt(self.min),
            fmt(self.max),
            self.count
        )
    }
}

/// Nearest-rank percentile on a pre-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Format nanoseconds human-readably.
pub fn format_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// A latency recorder accumulating nanosecond observations.
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_ns: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.samples_ns.push(d.as_nanos() as f64);
    }

    pub fn record_ns(&mut self, ns: f64) {
        self.samples_ns.push(ns);
    }

    pub fn len(&self) -> usize {
        self.samples_ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_ns.is_empty()
    }

    pub fn summary(&self) -> Summary {
        Summary::from(&self.samples_ns)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples_ns
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_ns.extend_from_slice(&other.samples_ns);
    }
}

/// Log-scaled histogram (base-2 buckets) for dashboard rendering.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// counts[i] counts samples in [2^i, 2^(i+1)) ns.
    counts: Vec<u64>,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self { counts: vec![0; 64] }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ns(&mut self, ns: u64) {
        let bucket = 63 - ns.max(1).leading_zeros() as usize;
        self.counts[bucket] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Render non-empty buckets as ASCII bars.
    pub fn render(&self) -> String {
        let total = self.total().max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let lo = 1u64 << i;
            let bar_len = (c * 40 / total).max(1) as usize;
            out.push_str(&format!(
                "{:>10} | {:<40} {}\n",
                format_ns(lo as f64),
                "#".repeat(bar_len),
                c
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        // sample std: Σ(x-x̄)² = 10, / (n-1) = 2.5
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn std_is_bessel_corrected() {
        // known sample σ: [2, 4, 4, 4, 5, 5, 7, 9] has Σ(x-x̄)² = 32 over
        // n-1 = 7 → σ = sqrt(32/7)
        let s = Summary::from(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.std - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn single_observation_has_zero_std() {
        let s = Summary::from(&[39.0]);
        assert_eq!(s.count, 1);
        assert_eq!(s.mean, 39.0);
        assert_eq!(s.std, 0.0);
        assert!(s.std.is_finite());
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Summary::from(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_monotone() {
        let sample: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::from(&sample);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 500.0).abs() <= 1.0);
        assert!((s.p99 - 989.0).abs() <= 2.0);
    }

    #[test]
    fn format_ns_scales() {
        assert_eq!(format_ns(500.0), "500ns");
        assert_eq!(format_ns(1_500.0), "1.50µs");
        assert_eq!(format_ns(39_000_000.0), "39.00ms");
        assert_eq!(format_ns(2_000_000_000.0), "2.000s");
    }

    #[test]
    fn histogram_buckets() {
        let mut h = LogHistogram::new();
        h.record_ns(1);
        h.record_ns(1024);
        h.record_ns(1024);
        assert_eq!(h.total(), 3);
        let rendered = h.render();
        assert!(rendered.contains("1ns"));
        assert!(rendered.contains("1.02µs"));
    }
}
