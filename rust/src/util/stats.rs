//! Latency statistics — the measurement substrate for reproducing the
//! paper's §7 evaluation (mean 39 ms, σ 51 ms over 1168 CDC events) and for
//! the bench harness (no criterion offline).
//!
//! [`LatencyRecorder`] keeps exact count/mean/σ/min/max as running
//! aggregates plus a bounded, deterministically seeded reservoir for
//! percentiles, so a long `serve` run holds steady-state memory no matter
//! how many samples it records.

use crate::util::rng::Rng;

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute from a sample; empty samples produce an all-zero summary.
    pub fn from(sample: &[f64]) -> Summary {
        if sample.is_empty() {
            return Summary {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let count = sample.len();
        let mean = sample.iter().sum::<f64>() / count as f64;
        // Bessel-corrected *sample* variance — the paper's §7 σ is a
        // sample statistic; a single observation has no spread.
        let var = if count < 2 {
            0.0
        } else {
            sample.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (count - 1) as f64
        };
        let mut sorted = sample.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[count - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }

    /// Render one table row: `mean ± std [p50 p90 p99] (min..max) n=count`,
    /// values formatted by `fmt` (e.g. `format_us`).
    pub fn row(&self, fmt: impl Fn(f64) -> String) -> String {
        format!(
            "{} ± {} [p50 {} p90 {} p99 {}] (min {} max {}) n={}",
            fmt(self.mean),
            fmt(self.std),
            fmt(self.p50),
            fmt(self.p90),
            fmt(self.p99),
            fmt(self.min),
            fmt(self.max),
            self.count
        )
    }
}

/// Nearest-rank percentile on a pre-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Format nanoseconds human-readably.
pub fn format_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Default reservoir capacity: large enough that the paper's 1168-event
/// day trace is retained exactly, small enough to bound a week-long
/// `serve` run to a few tens of KiB per channel shard.
pub const RESERVOIR_CAP: usize = 4096;

/// A latency recorder accumulating nanosecond observations.
///
/// Count, mean, σ, min and max are exact running aggregates; percentiles
/// come from a bounded reservoir (Vitter's Algorithm R) driven by a
/// fixed-seed [`Rng`], so memory is bounded and results are reproducible
/// run-to-run for a given sample sequence.
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    count: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
    reservoir: Vec<f64>,
    cap: usize,
    rng: Rng,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::with_capacity(RESERVOIR_CAP)
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Recorder with a custom reservoir bound (`cap >= 1`).
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            count: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::new(),
            cap: cap.max(1),
            // fixed seed: determinism matters more than independence here
            rng: Rng::seed_from(0x5EED_CAFE),
        }
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as f64);
    }

    pub fn record_ns(&mut self, ns: f64) {
        self.count += 1;
        self.sum += ns;
        self.sumsq += ns * ns;
        self.min = self.min.min(ns);
        self.max = self.max.max(ns);
        if self.reservoir.len() < self.cap {
            self.reservoir.push(ns);
        } else {
            // Algorithm R: keep each of the `count` samples with equal
            // probability cap/count.
            let j = self.rng.gen_range(self.count) as usize;
            if j < self.cap {
                self.reservoir[j] = ns;
            }
        }
    }

    /// Total observations recorded (exact, not the reservoir size).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact count/mean/σ/min/max; percentiles estimated from the
    /// reservoir (exact while `len() <= cap`).
    pub fn summary(&self) -> Summary {
        if self.count == 0 {
            return Summary::from(&[]);
        }
        let mut s = Summary::from(&self.reservoir);
        let count = self.count as f64;
        let mean = self.sum / count;
        let var = if self.count < 2 {
            0.0
        } else {
            ((self.sumsq - self.sum * self.sum / count) / (count - 1.0)).max(0.0)
        };
        s.count = self.count as usize;
        s.mean = mean;
        s.std = var.sqrt();
        s.min = self.min;
        s.max = self.max;
        s
    }

    /// The retained reservoir sample (all samples while `len() <= cap`).
    pub fn samples(&self) -> &[f64] {
        &self.reservoir
    }

    /// Merge another recorder in: aggregates add exactly; the reservoirs
    /// concatenate and thin deterministically back to `cap`.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.count += other.count;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.reservoir.extend_from_slice(&other.reservoir);
        while self.reservoir.len() > self.cap {
            let j = self.rng.gen_range(self.reservoir.len() as u64) as usize;
            self.reservoir.swap_remove(j);
        }
    }
}

/// Log-scaled histogram (base-2 buckets) for dashboard rendering.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// counts[i] counts samples in [2^i, 2^(i+1)) ns.
    counts: Vec<u64>,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self { counts: vec![0; 64] }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ns(&mut self, ns: u64) {
        let bucket = 63 - ns.max(1).leading_zeros() as usize;
        self.counts[bucket] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Merge another histogram in (bucket-wise add) — lets
    /// `LatencyChannel::histogram()` combine shards without replaying
    /// samples.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// `(bucket_floor_ns, count)` for every non-empty bucket, low to high.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (1u64 << i, c))
            .collect()
    }

    /// Render non-empty buckets as ASCII bars.
    pub fn render(&self) -> String {
        let total = self.total().max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let lo = 1u64 << i;
            let bar_len = (c * 40 / total).max(1) as usize;
            out.push_str(&format!(
                "{:>10} | {:<40} {}\n",
                format_ns(lo as f64),
                "#".repeat(bar_len),
                c
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        // sample std: Σ(x-x̄)² = 10, / (n-1) = 2.5
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn std_is_bessel_corrected() {
        // known sample σ: [2, 4, 4, 4, 5, 5, 7, 9] has Σ(x-x̄)² = 32 over
        // n-1 = 7 → σ = sqrt(32/7)
        let s = Summary::from(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.std - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn single_observation_has_zero_std() {
        let s = Summary::from(&[39.0]);
        assert_eq!(s.count, 1);
        assert_eq!(s.mean, 39.0);
        assert_eq!(s.std, 0.0);
        assert!(s.std.is_finite());
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Summary::from(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_monotone() {
        let sample: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::from(&sample);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 500.0).abs() <= 1.0);
        assert!((s.p99 - 989.0).abs() <= 2.0);
    }

    #[test]
    fn format_ns_scales() {
        assert_eq!(format_ns(500.0), "500ns");
        assert_eq!(format_ns(1_500.0), "1.50µs");
        assert_eq!(format_ns(39_000_000.0), "39.00ms");
        assert_eq!(format_ns(2_000_000_000.0), "2.000s");
    }

    #[test]
    fn recorder_exact_aggregates_with_small_sample() {
        let mut r = LatencyRecorder::new();
        for v in [1.0, 2.0, 3.0] {
            r.record_ns(v);
        }
        let s = r.summary();
        assert_eq!(s.count, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(r.samples().len(), 3);
    }

    #[test]
    fn recorder_memory_is_bounded_and_aggregates_stay_exact() {
        let mut r = LatencyRecorder::with_capacity(64);
        let n = 10_000u64;
        for i in 0..n {
            r.record_ns(i as f64);
        }
        assert_eq!(r.len(), n as usize);
        assert_eq!(r.samples().len(), 64); // reservoir bounded
        let s = r.summary();
        assert_eq!(s.count, n as usize);
        // exact running mean of 0..n-1
        assert!((s.mean - (n - 1) as f64 / 2.0).abs() < 1e-6, "mean={}", s.mean);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, (n - 1) as f64);
        // reservoir percentiles are estimates but must stay in range and
        // roughly track the uniform distribution
        assert!(s.p50 > 0.2 * n as f64 && s.p50 < 0.8 * n as f64, "p50={}", s.p50);
    }

    #[test]
    fn recorder_is_deterministic() {
        let run = || {
            let mut r = LatencyRecorder::with_capacity(32);
            for i in 0..5_000 {
                r.record_ns((i * 7 % 997) as f64);
            }
            r.samples().to_vec()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn recorder_merge_adds_exactly_and_stays_bounded() {
        let mut a = LatencyRecorder::with_capacity(16);
        let mut b = LatencyRecorder::with_capacity(16);
        for i in 0..100 {
            a.record_ns(i as f64);
            b.record_ns((1000 + i) as f64);
        }
        a.merge(&b);
        let s = a.summary();
        assert_eq!(s.count, 200);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 1099.0);
        assert!((s.mean - (49.5 + 1049.5) / 2.0).abs() < 1e-9);
        assert!(a.samples().len() <= 16);
    }

    #[test]
    fn histogram_merge_matches_combined_recording() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut combined = LogHistogram::new();
        for ns in [1u64, 100, 1024, 1_000_000] {
            a.record_ns(ns);
            combined.record_ns(ns);
        }
        for ns in [1024u64, 7, 7, 1 << 40] {
            b.record_ns(ns);
            combined.record_ns(ns);
        }
        a.merge(&b);
        assert_eq!(a.total(), combined.total());
        assert_eq!(a.buckets(), combined.buckets());
        assert_eq!(a.render(), combined.render());
    }

    #[test]
    fn histogram_buckets() {
        let mut h = LogHistogram::new();
        h.record_ns(1);
        h.record_ns(1024);
        h.record_ns(1024);
        assert_eq!(h.total(), 3);
        let rendered = h.render();
        assert!(rendered.contains("1ns"));
        assert!(rendered.contains("1.02µs"));
    }
}
