//! From-scratch substrates the vendored-crate environment does not provide
//! (no serde/serde_json, no rand, no rayon, no criterion offline): a JSON
//! codec, deterministic PRNGs, latency statistics, and a thread pool.
//!
//! These are first-class parts of the reproduction: the paper's messages
//! *are* JSON (fig 2), its evaluation *is* latency statistics (§7), and its
//! mapping algorithm *is* thread-level parallelism (§5.5).

pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod tmp;

/// Monotonic id source used for message keys / event ids across the sim.
#[derive(Debug, Default)]
pub struct IdGen(std::sync::atomic::AtomicU64);

impl IdGen {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn next(&self) -> u64 {
        self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }
}
