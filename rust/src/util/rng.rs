//! Deterministic PRNGs for workload generation (no `rand` offline).
//!
//! SplitMix64 seeds xoshiro256** (Blackman/Vigna); both are the standard
//! public-domain constructions. Every workload in benches/examples is
//! seeded, so paper-figure regenerations are reproducible run-to-run.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        // SplitMix64 to expand the seed — recommended initialization.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; n must be > 0. Uses Lemire's multiply-shift.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential inter-arrival time with rate `lambda` (events/unit).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson sample (Knuth) — fine for the small lambdas in the traces.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Random sample of `k` distinct indices from `0..n`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut all: Vec<usize> = (0..n).collect();
        self.shuffle(&mut all);
        all.truncate(k.min(n));
        all
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> Option<&'a T> {
        if items.is_empty() {
            None
        } else {
            Some(&items[self.gen_range(items.len() as u64) as usize])
        }
    }
}

/// Precomputed Zipfian sampler over ranks `0..n` with exponent `s`:
/// rank r is drawn with probability proportional to `1/(r+1)^s` — the
/// hot-key/hot-schema skew of real CDC traffic (a handful of entities
/// take most of the writes). Exact inverse-CDF over the precomputed
/// cumulative weights, so sampling is O(log n) and fully deterministic
/// under a seeded [`Rng`].
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the sampler for `n` ranks (`n >= 1`) and exponent `s > 0`.
    pub fn new(n: usize, s: f64) -> Self {
        let n = n.max(1);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for r in 0..n {
            total += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(total);
        }
        for w in &mut cdf {
            *w /= total;
        }
        Zipf { cdf }
    }

    /// Number of ranks in the universe.
    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Draw one rank in `[0, n)`; rank 0 is the hottest.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // first rank whose cumulative weight exceeds u
        match self.cdf.binary_search_by(|w| w.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut rng = Rng::seed_from(7);
        for _ in 0..10_000 {
            assert!(rng.gen_range(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::seed_from(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close_to_lambda() {
        let mut rng = Rng::seed_from(11);
        let lambda = 4.0;
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(3);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from(5);
        let s = rng.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn zipf_is_skewed_toward_rank_zero() {
        let zipf = Zipf::new(16, 1.1);
        let mut rng = Rng::seed_from(21);
        let mut counts = [0u64; 16];
        for _ in 0..50_000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        // the head dominates and frequencies decay monotonically-ish
        assert!(counts[0] > counts[1]);
        assert!(counts[0] > 4 * counts[8], "head {counts:?}");
        assert_eq!(counts.iter().sum::<u64>(), 50_000);
    }

    #[test]
    fn zipf_stays_in_range_and_is_deterministic() {
        let zipf = Zipf::new(5, 1.3);
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..10_000 {
            let ra = zipf.sample(&mut a);
            assert!(ra < 5);
            assert_eq!(ra, zipf.sample(&mut b));
        }
        // degenerate universes stay safe
        let one = Zipf::new(1, 1.0);
        assert_eq!(one.sample(&mut a), 0);
        assert_eq!(Zipf::new(0, 1.0).n(), 1);
    }
}
