//! Deterministic PRNGs for workload generation (no `rand` offline).
//!
//! SplitMix64 seeds xoshiro256** (Blackman/Vigna); both are the standard
//! public-domain constructions. Every workload in benches/examples is
//! seeded, so paper-figure regenerations are reproducible run-to-run.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        // SplitMix64 to expand the seed — recommended initialization.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; n must be > 0. Uses Lemire's multiply-shift.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential inter-arrival time with rate `lambda` (events/unit).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson sample (Knuth) — fine for the small lambdas in the traces.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Random sample of `k` distinct indices from `0..n`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut all: Vec<usize> = (0..n).collect();
        self.shuffle(&mut all);
        all.truncate(k.min(n));
        all
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> Option<&'a T> {
        if items.is_empty() {
            None
        } else {
            Some(&items[self.gen_range(items.len() as u64) as usize])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut rng = Rng::seed_from(7);
        for _ in 0..10_000 {
            assert!(rng.gen_range(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::seed_from(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close_to_lambda() {
        let mut rng = Rng::seed_from(11);
        let lambda = 4.0;
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(3);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from(5);
        let s = rng.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
