//! A small fixed-size thread pool + scoped parallel-map helpers.
//!
//! This carries the paper's §5.5 parallelism: Alg 6 executes independent
//! mapping elements / blocks / messages concurrently, and the horizontal
//! scaler runs one coordinator instance per Kafka partition subset.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let receiver = Arc::clone(&receiver);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("metl-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = receiver.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::AcqRel);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { workers, sender: Some(sender), in_flight }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job for asynchronous execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        self.sender
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("worker alive");
    }

    /// Busy-wait (with yielding) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        while self.in_flight.load(Ordering::Acquire) != 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close channel, workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel map: split `items` into `n_threads` contiguous chunks and
/// apply `f` to each item, preserving order. Falls back to sequential for
/// tiny inputs where spawn overhead dominates (the same batching judgment
/// the paper makes when it reserves horizontal scaling for initial loads).
pub fn par_map<T: Sync, R: Send>(
    n_threads: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let n_threads = n_threads.max(1).min(items.len().max(1));
    if n_threads == 1 || items.len() < 2 {
        return items.iter().map(&f).collect();
    }
    let chunk = items.len().div_ceil(n_threads);
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let out_slots: Vec<&mut [Option<R>]> = out.chunks_mut(chunk).collect();
    thread::scope(|scope| {
        for (slot, in_chunk) in out_slots.into_iter().zip(items.chunks(chunk)) {
            let f = &f;
            scope.spawn(move || {
                for (o, item) in slot.iter_mut().zip(in_chunk) {
                    *o = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("filled")).collect()
}

/// Scoped parallel for-each over mutable chunks (used by the bulk lane to
/// fill tensor buffers in place).
pub fn par_chunks_mut<T: Send>(
    n_threads: usize,
    items: &mut [T],
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let n_threads = n_threads.max(1);
    if n_threads == 1 || items.len() < 2 {
        f(0, items);
        return;
    }
    let chunk = items.len().div_ceil(n_threads);
    thread::scope(|scope| {
        for (i, part) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(i * chunk, part));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_shutdown_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(8, &items, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_item() {
        assert_eq!(par_map(8, &[5u64], |x| x + 1), vec![6]);
        assert_eq!(par_map(8, &Vec::<u64>::new(), |x| x + 1), Vec::<u64>::new());
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0u64; 97];
        par_chunks_mut(4, &mut v, |base, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (base + i) as u64;
            }
        });
        assert_eq!(v, (0..97).collect::<Vec<u64>>());
    }
}
