//! Self-cleaning unique temp directories for tests and benches.
//!
//! The old pattern (`temp_dir()/metl-store-tests/{name}-{pid}`) leaked
//! directories on every run and collided when the OS reused a pid. A
//! [`TestDir`] is unique per *instantiation* (pid + monotonic counter +
//! wall-clock nanos) and removes itself on `Drop`, so parallel tests,
//! repeated runs and crash-injection sweeps never see each other's state.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// A uniquely named temp directory that is deleted when dropped.
#[derive(Debug)]
pub struct TestDir {
    path: PathBuf,
    keep: bool,
}

impl TestDir {
    /// Create `temp_dir()/metl-tests/{prefix}-{pid}-{nanos}-{n}`.
    pub fn new(prefix: &str) -> TestDir {
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join("metl-tests").join(format!(
            "{prefix}-{}-{nanos}-{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&path).expect("create test dir");
        TestDir { path, keep: false }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join(&self, p: impl AsRef<Path>) -> PathBuf {
        self.path.join(p)
    }

    /// Leave the directory on disk after drop (debugging a failed run).
    pub fn keep(mut self) -> Self {
        self.keep = true;
        self
    }
}

impl Drop for TestDir {
    fn drop(&mut self) {
        if !self.keep {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dirs_are_unique_and_cleaned_up() {
        let a = TestDir::new("x");
        let b = TestDir::new("x");
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir());
        std::fs::write(a.join("f"), "data").unwrap();
        let path = a.path().to_path_buf();
        drop(a);
        assert!(!path.exists());
        assert!(b.path().is_dir());
    }
}
