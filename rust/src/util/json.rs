//! Minimal, complete JSON value + parser + writer.
//!
//! Object member order is preserved (`Vec<(String, Json)>`, not a hash map):
//! Kafka/Debezium payloads (paper fig 2) are ordered field lists and the
//! mapping system addresses attributes positionally within a schema version.

use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/replace a member on an object; panics on non-objects
    /// (programming error, not data error).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(members) => {
                if let Some(slot) = members.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    members.push((key.to_string(), value));
                }
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => {
                members.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Pretty serialization (2-space indent) for artifacts / the store.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(members) => {
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    indent(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(members) if !members.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in members.iter().enumerate() {
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < members.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { at: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.keyword("null", Json::Null),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are out of scope for the sim's
                            // payloads; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            members.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(text).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn parses_debezium_like_envelope() {
        let text = r#"{"payload":{"before":null,"after":{"id":32201,
            "value":10.0,"currency":"EUR","time":1634052484031131},
            "source":{"connector":"postgresql","db":"payments",
            "table":"incoming"}}}"#;
        let v = parse(text).unwrap();
        let after = v.get("payload").unwrap().get("after").unwrap();
        assert_eq!(after.get("currency").unwrap().as_str(), Some("EUR"));
        assert!(v.get("payload").unwrap().get("before").unwrap().is_null());
        // order preserved
        if let Json::Obj(members) = after {
            assert_eq!(members[0].0, "id");
            assert_eq!(members[3].0, "time");
        } else {
            panic!("not an object");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn big_int64_timestamps_survive() {
        // Debezium micros timestamps are ~2^50; f64 holds them exactly.
        let v = parse("1634052484031131").unwrap();
        assert_eq!(v.as_u64(), Some(1634052484031131));
        assert_eq!(v.to_string(), "1634052484031131");
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\"1}", "tru", "1.2.3", "\"\\q\""] {
            assert!(parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn nested_pretty_parses_back() {
        let mut obj = Json::obj();
        obj.set("a", Json::Arr(vec![Json::Num(1.0), Json::Null]));
        obj.set("b", Json::obj());
        let pretty = obj.to_pretty();
        assert_eq!(parse(&pretty).unwrap(), obj);
    }

    #[test]
    fn set_replaces_in_place() {
        let mut obj = Json::obj();
        obj.set("k", Json::Num(1.0));
        obj.set("k", Json::Num(2.0));
        assert_eq!(obj.get("k").unwrap().as_f64(), Some(2.0));
        if let Json::Obj(members) = &obj {
            assert_eq!(members.len(), 1);
        }
    }
}
