//! # metl — a modern ETL pipeline with a dynamic mapping matrix
//!
//! Reproduction of Haase, Röseler & Seidel (2022): a streaming ETL
//! framework that extracts CDC events from a simulated microservice
//! landscape, transforms them to a canonical data model (CDM) through the
//! paper's **dynamic mapping matrix (DMM)**, and loads them to data-
//! warehouse and ML sinks — as a three-layer rust + JAX + Pallas system
//! (see DESIGN.md).
//!
//! Quick tour (see `examples/quickstart.rs`):
//!
//! - [`schema`] / [`cdm`] — the two metadata trees of the dynamic network.
//! - [`matrix`] — the mapping matrix `ᵢM`, its block partitioning, the two
//!   compaction strategies (Alg 2 → `ᵢ𝔇𝔓𝔐`, Alg 3 → `ᵢ𝔇𝔘𝔖𝔅`),
//!   decompaction (Alg 4), and automated updates (Alg 5).
//! - [`mapper`] — the baseline sequential mapper (Alg 1) and the parallel
//!   dense mapper (Alg 6).
//! - [`broker`] / [`source`] / [`sink`] — the Kafka simulation substrate
//!   and the pluggable connector API: [`source::SourceConnector`] for
//!   ingress, [`sink::SinkConnector`] for egress backends (DW, ML, JSONL
//!   lakehouse, audit mirror — and yours).
//! - [`coordinator`] — the METL app: pipeline wiring via
//!   [`coordinator::pipeline::PipelineBuilder`], per-sink consumer
//!   groups, state-i sync, the online schema-evolution lane
//!   ([`coordinator::evolution`]), error management, horizontal scaling,
//!   bulk lane.
//! - [`runtime`] — PJRT loader executing the AOT-compiled JAX/Pallas bulk
//!   mapping kernels from `artifacts/`.
//!
//! `ARCHITECTURE.md` at the repository root maps every paper section to
//! its module and documents the epoch lifecycle end to end.

pub mod broker;
pub mod cache;
pub mod cdm;
pub mod config;
pub mod coordinator;
pub mod mapper;
pub mod matrix;
pub mod message;
pub mod metrics;
pub mod runtime;
pub mod schema;
pub mod sink;
pub mod source;
pub mod store;
pub mod trace;
pub mod util;
pub mod workload;
pub mod xla_stub;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::broker::{Broker, Consumer, Topic};
    pub use crate::cache::EvictMode;
    pub use crate::cdm::{CdmAttrId, CdmTree, CdmType, CdmVersionNo, EntityId};
    pub use crate::coordinator::evolution::{
        ChangeOutcome, EvolutionController,
    };
    pub use crate::coordinator::pipeline::{Pipeline, PipelineBuilder};
    pub use crate::sink::{
        AuditMirrorSink, DwSink, JsonlSink, MlSink, SinkConnector, SinkStats,
    };
    pub use crate::source::{
        Connector, DdlQueue, SchemaChange, SchemaChangeEvent,
        SchemaChangeSource, SourceConnector, SourceStats,
    };
    pub use crate::mapper::{baseline::BaselineMapper, parallel::ParallelMapper};
    pub use crate::matrix::{
        dpm::DpmSet, dusb::DusbSet, BlockKey, MappingMatrix,
    };
    pub use crate::message::{
        cdc::{CdcEvent, CdcOp},
        InMessage, OutMessage, StateI,
    };
    pub use crate::schema::{
        AttrId, Compatibility, ExtractType, Registry, SchemaId, SchemaTree,
        VersionNo,
    };
    pub use crate::trace::{Stage, TraceCtx, Tracer};
    pub use crate::util::json::Json;
    pub use crate::util::rng::Rng;
    pub use crate::util::stats::Summary;
}
