//! Launcher configuration: simulation topology + runtime knobs, with the
//! paper-scale presets of §3.5, parseable from a simple `key = value` file
//! (TOML subset — sections flatten to `section.key`).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::cache::EvictMode;
use crate::mapper::kernel::KernelMode;
use crate::schema::Compatibility;
use crate::store::FsyncPolicy;

/// Full pipeline configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    /// Number of simulated microservices (paper: "more than 80").
    pub n_services: usize,
    /// Attributes per schema version (paper estimate: ~10, §3.5).
    pub attrs_per_schema: usize,
    /// Schema versions kept in parallel (paper estimate: ~10, §3.5).
    pub versions_per_schema: usize,
    /// Business entities in the CDM.
    pub n_entities: usize,
    /// Attributes per business entity version.
    pub attrs_per_entity: usize,
    /// Fraction of schema attributes mapped to the CDM (rest filtered).
    pub mapped_fraction: f64,
    /// Probability an optional attribute is null in generated rows.
    pub null_prob: f64,
    /// Broker partitions per topic.
    pub partitions: usize,
    /// Worker threads for the parallel mapper.
    pub threads: usize,
    /// Worker shards of the sharded mapping lane (0 = use
    /// `available_parallelism`).
    pub shards: usize,
    /// CDC events for a generated day trace (paper: 1168 on 2022-02-13).
    pub trace_events: usize,
    /// Schema-change storms per day trace (paper: "a few times a day").
    pub schema_changes: usize,
    /// Deterministic seed.
    pub seed: u64,
    /// Bulk lane batch threshold (messages buffered before XLA dispatch).
    pub bulk_threshold: usize,
    /// artifacts/ directory for the PJRT runtime (None disables the lane).
    pub artifacts_dir: Option<String>,
    /// Sink backends registered on the pipeline, each with its own
    /// consumer group over the CDM topic
    /// (`runtime.sinks = ["dw","ml","jsonl"]`; see `sink::from_config_name`).
    pub sinks: Vec<String>,
    /// Append path for the JSONL lakehouse sink (None = in-memory log).
    pub jsonl_path: Option<String>,
    /// Compatibility mode the online evolution lane validates schema
    /// changes against (`runtime.evolution.compatibility =
    /// "backward"|"forward"|"full"|"none"`; §3.3).
    pub evolution_compatibility: Compatibility,
    /// Enforce the §3.3 "one single changed attribute" rule per accepted
    /// change (`runtime.evolution.single_change`).
    pub evolution_single_change: bool,
    /// Cache-eviction policy on DMM updates (`runtime.evict` / `--evict`):
    /// targeted (default — only affected columns drop) or full (the
    /// paper's §6.2 evict-everything behaviour).
    pub evict: EvictMode,
    /// Mapping lane (`runtime.kernel` / `--kernel`): native (default —
    /// the block-permutation kernel with compiled column plans) or scalar
    /// (the per-element Alg-6 lane, kept as fallback and bench baseline).
    pub kernel: KernelMode,
    /// Durable matrix-store directory (`runtime.store.dir` / `--store`);
    /// None runs without persistence.
    pub store_dir: Option<String>,
    /// WAL records past the live segment before a fresh snapshot segment
    /// is written (`runtime.store.segment_threshold`).
    pub store_segment_threshold: u64,
    /// WAL fsync policy (`runtime.store.fsync = "always"|"never"`).
    pub store_fsync: FsyncPolicy,
    /// Restart-recovery time budget asserted by the crash tests/benches
    /// (`runtime.store.recovery_budget_ms`).
    pub store_recovery_budget_ms: u64,
    /// Span tracing + flight recorder (`runtime.trace` / `--trace`),
    /// on by default — the observability overhead budget is enforced by
    /// `benches/overhead.rs` (< 5%).
    pub trace: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self::small()
    }
}

impl PipelineConfig {
    /// Small smoke-test profile.
    pub fn small() -> Self {
        PipelineConfig {
            n_services: 4,
            attrs_per_schema: 6,
            versions_per_schema: 3,
            n_entities: 2,
            attrs_per_entity: 8,
            mapped_fraction: 0.6,
            null_prob: 0.2,
            partitions: 4,
            threads: 4,
            shards: 0,
            trace_events: 200,
            schema_changes: 2,
            seed: 42,
            bulk_threshold: 64,
            artifacts_dir: None,
            sinks: default_sinks(),
            jsonl_path: None,
            evolution_compatibility: Compatibility::Full,
            evolution_single_change: true,
            evict: EvictMode::Targeted,
            kernel: KernelMode::Native,
            store_dir: None,
            store_segment_threshold: 32,
            store_fsync: FsyncPolicy::Always,
            store_recovery_budget_ms: 5_000,
            trace: true,
        }
    }

    /// The paper's measured day (§7): 80 services, 1168 CDC events,
    /// a few DMM updates evicting the cache.
    pub fn paper_day() -> Self {
        PipelineConfig {
            n_services: 80,
            attrs_per_schema: 10,
            versions_per_schema: 10,
            n_entities: 12,
            attrs_per_entity: 12,
            mapped_fraction: 0.7,
            null_prob: 0.25,
            partitions: 8,
            threads: 8,
            shards: 0,
            trace_events: 1168,
            schema_changes: 3,
            seed: 20220213,
            bulk_threshold: 128,
            artifacts_dir: Some("artifacts".into()),
            sinks: default_sinks(),
            jsonl_path: None,
            evolution_compatibility: Compatibility::Full,
            evolution_single_change: true,
            evict: EvictMode::Targeted,
            kernel: KernelMode::Native,
            store_dir: None,
            store_segment_threshold: 32,
            store_fsync: FsyncPolicy::Always,
            store_recovery_budget_ms: 5_000,
            trace: true,
        }
    }

    /// §3.5 estimation scale: ~10k extracting attributes versioned ×10,
    /// >1k CDM attributes — the 10⁸-element matrix after the §5.1 rule.
    pub fn eos_scale() -> Self {
        PipelineConfig {
            n_services: 100,
            attrs_per_schema: 10,
            versions_per_schema: 10,
            n_entities: 100,
            attrs_per_entity: 10,
            mapped_fraction: 0.8,
            null_prob: 0.25,
            partitions: 16,
            threads: 8,
            shards: 0,
            trace_events: 10_000,
            schema_changes: 5,
            seed: 7,
            bulk_threshold: 256,
            artifacts_dir: Some("artifacts".into()),
            sinks: default_sinks(),
            jsonl_path: None,
            evolution_compatibility: Compatibility::Full,
            evolution_single_change: true,
            evict: EvictMode::Targeted,
            kernel: KernelMode::Native,
            store_dir: None,
            store_segment_threshold: 32,
            store_fsync: FsyncPolicy::Always,
            store_recovery_budget_ms: 5_000,
            trace: true,
        }
    }

    /// Parse from the TOML-subset text format.
    pub fn parse(text: &str) -> Result<Self> {
        let kv = parse_kv(text)?;
        let mut cfg = PipelineConfig::small();
        if let Some(profile) = kv.get("profile") {
            cfg = match profile.as_str() {
                "small" => PipelineConfig::small(),
                "paper_day" => PipelineConfig::paper_day(),
                "eos_scale" => PipelineConfig::eos_scale(),
                other => bail!("unknown profile {other:?}"),
            };
        }
        macro_rules! num {
            ($key:expr, $field:expr) => {
                if let Some(v) = kv.get($key) {
                    $field = v.parse().with_context(|| format!("bad {}", $key))?;
                }
            };
        }
        num!("sim.services", cfg.n_services);
        num!("sim.attrs_per_schema", cfg.attrs_per_schema);
        num!("sim.versions_per_schema", cfg.versions_per_schema);
        num!("sim.entities", cfg.n_entities);
        num!("sim.attrs_per_entity", cfg.attrs_per_entity);
        num!("sim.mapped_fraction", cfg.mapped_fraction);
        num!("sim.null_prob", cfg.null_prob);
        num!("sim.trace_events", cfg.trace_events);
        num!("sim.schema_changes", cfg.schema_changes);
        num!("sim.seed", cfg.seed);
        num!("runtime.partitions", cfg.partitions);
        num!("runtime.threads", cfg.threads);
        num!("runtime.shards", cfg.shards);
        num!("runtime.bulk_threshold", cfg.bulk_threshold);
        if let Some(v) = kv.get("runtime.artifacts_dir") {
            cfg.artifacts_dir =
                if v.is_empty() { None } else { Some(v.clone()) };
        }
        if let Some(v) = kv.get("runtime.sinks") {
            cfg.sinks = parse_string_list(v);
        }
        if let Some(v) = kv.get("runtime.jsonl_path") {
            cfg.jsonl_path =
                if v.is_empty() { None } else { Some(v.clone()) };
        }
        if let Some(v) = kv.get("runtime.evolution.compatibility") {
            cfg.evolution_compatibility =
                v.parse::<Compatibility>().map_err(|e| anyhow::anyhow!(e))?;
        }
        num!(
            "runtime.evolution.single_change",
            cfg.evolution_single_change
        );
        if let Some(v) = kv.get("runtime.evict") {
            cfg.evict =
                v.parse::<EvictMode>().map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(v) = kv.get("runtime.kernel") {
            cfg.kernel =
                v.parse::<KernelMode>().map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(v) = kv.get("runtime.store.dir") {
            cfg.store_dir = if v.is_empty() { None } else { Some(v.clone()) };
        }
        num!("runtime.store.segment_threshold", cfg.store_segment_threshold);
        if let Some(v) = kv.get("runtime.store.fsync") {
            cfg.store_fsync =
                v.parse::<FsyncPolicy>().map_err(|e| anyhow::anyhow!(e))?;
        }
        num!("runtime.store.recovery_budget_ms", cfg.store_recovery_budget_ms);
        num!("runtime.trace", cfg.trace);
        Ok(cfg)
    }
}

/// The paper's fig-1 consumers: data warehouse + ML platform.
fn default_sinks() -> Vec<String> {
    vec!["dw".to_string(), "ml".to_string()]
}

/// Parse a `["a", "b"]` (or bare `a, b`) list value into its items —
/// shared by the config file (`runtime.sinks`) and the `--sinks` CLI flag.
pub fn parse_string_list(v: &str) -> Vec<String> {
    v.trim()
        .trim_start_matches('[')
        .trim_end_matches(']')
        .split(',')
        .map(|item| item.trim().trim_matches('"').to_string())
        .filter(|item| !item.is_empty())
        .collect()
}

/// Parse `key = value` lines with `[section]` prefixes and `#` comments.
fn parse_kv(text: &str) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: malformed section {line:?}", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, v.trim().trim_matches('"').to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_overrides() {
        let text = r#"
            profile = "paper_day"  # base profile
            [sim]
            services = 10
            seed = 99
            [runtime]
            threads = 2
            shards = 3
            artifacts_dir = ""
        "#;
        let cfg = PipelineConfig::parse(text).unwrap();
        assert_eq!(cfg.n_services, 10);
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.artifacts_dir, None);
        // untouched fields come from paper_day
        assert_eq!(cfg.trace_events, 1168);
    }

    #[test]
    fn empty_text_is_small_profile() {
        assert_eq!(PipelineConfig::parse("").unwrap(), PipelineConfig::small());
    }

    #[test]
    fn rejects_garbage() {
        assert!(PipelineConfig::parse("[broken").is_err());
        assert!(PipelineConfig::parse("novalue").is_err());
        assert!(PipelineConfig::parse("profile = \"nope\"").is_err());
        assert!(PipelineConfig::parse("[sim]\nservices = abc").is_err());
    }

    #[test]
    fn default_profiles_register_paper_consumers() {
        assert_eq!(PipelineConfig::small().sinks, vec!["dw", "ml"]);
        assert_eq!(PipelineConfig::paper_day().jsonl_path, None);
    }

    #[test]
    fn parses_sink_lists() {
        let text = r#"
            [runtime]
            sinks = ["dw", "jsonl", "audit"]
            jsonl_path = "/tmp/cdm.jsonl"
        "#;
        let cfg = PipelineConfig::parse(text).unwrap();
        assert_eq!(cfg.sinks, vec!["dw", "jsonl", "audit"]);
        assert_eq!(cfg.jsonl_path.as_deref(), Some("/tmp/cdm.jsonl"));
        // bare comma lists work too (CLI-style)
        let cfg = PipelineConfig::parse("[runtime]\nsinks = ml,dw").unwrap();
        assert_eq!(cfg.sinks, vec!["ml", "dw"]);
        // an explicitly empty list disables all egress
        let cfg = PipelineConfig::parse("[runtime]\nsinks = []").unwrap();
        assert!(cfg.sinks.is_empty());
    }

    #[test]
    fn parses_evolution_knobs() {
        let text = r#"
            [runtime]
            evict = "full"
            [runtime.evolution]
            compatibility = "backward"
            single_change = false
        "#;
        let cfg = PipelineConfig::parse(text).unwrap();
        assert_eq!(cfg.evict, EvictMode::Full);
        assert_eq!(cfg.evolution_compatibility, Compatibility::Backward);
        assert!(!cfg.evolution_single_change);
        // defaults: targeted eviction under full compatibility
        let cfg = PipelineConfig::parse("").unwrap();
        assert_eq!(cfg.evict, EvictMode::Targeted);
        assert_eq!(cfg.evolution_compatibility, Compatibility::Full);
        assert!(cfg.evolution_single_change);
        // bad values are rejected
        assert!(PipelineConfig::parse("[runtime]\nevict = caffeine").is_err());
        assert!(PipelineConfig::parse(
            "[runtime.evolution]\ncompatibility = sideways"
        )
        .is_err());
    }

    #[test]
    fn parses_kernel_mode() {
        let cfg =
            PipelineConfig::parse("[runtime]\nkernel = \"scalar\"").unwrap();
        assert_eq!(cfg.kernel, KernelMode::Scalar);
        // default is the native kernel in every profile
        assert_eq!(PipelineConfig::small().kernel, KernelMode::Native);
        assert_eq!(PipelineConfig::paper_day().kernel, KernelMode::Native);
        assert_eq!(PipelineConfig::eos_scale().kernel, KernelMode::Native);
        assert!(PipelineConfig::parse("[runtime]\nkernel = pallas").is_err());
    }

    #[test]
    fn parses_store_knobs() {
        let text = r#"
            [runtime.store]
            dir = "state/store"
            segment_threshold = 8
            fsync = "never"
            recovery_budget_ms = 250
        "#;
        let cfg = PipelineConfig::parse(text).unwrap();
        assert_eq!(cfg.store_dir.as_deref(), Some("state/store"));
        assert_eq!(cfg.store_segment_threshold, 8);
        assert_eq!(cfg.store_fsync, FsyncPolicy::Never);
        assert_eq!(cfg.store_recovery_budget_ms, 250);
        // defaults: no store, durable fsync
        let cfg = PipelineConfig::parse("").unwrap();
        assert_eq!(cfg.store_dir, None);
        assert_eq!(cfg.store_fsync, FsyncPolicy::Always);
        assert!(
            PipelineConfig::parse("[runtime.store]\nfsync = maybe").is_err()
        );
    }

    #[test]
    fn parses_trace_knob() {
        // on by default in every profile (the overhead bench keeps it cheap)
        assert!(PipelineConfig::small().trace);
        assert!(PipelineConfig::paper_day().trace);
        assert!(PipelineConfig::eos_scale().trace);
        let cfg =
            PipelineConfig::parse("[runtime]\ntrace = false").unwrap();
        assert!(!cfg.trace);
        assert!(PipelineConfig::parse("[runtime]\ntrace = sorta").is_err());
    }

    #[test]
    fn paper_day_matches_section7() {
        let cfg = PipelineConfig::paper_day();
        assert_eq!(cfg.trace_events, 1168);
        assert_eq!(cfg.n_services, 80);
        assert!(cfg.schema_changes >= 2); // "a few times a day"
    }
}
