//! In-process Kafka-sim broker: topics, partitions, offsets, consumer
//! groups, at-least-once delivery, offset reset — now on a **lock-free
//! segmented log core**.
//!
//! Substitution for the paper's Kafka/Kafka-streams substrate (DESIGN.md
//! §2): what METL relies on is semantic — per-partition ordering, keyed
//! partitioning, committed offsets per consumer group, the ability to
//! reset offsets for a new initial load (§3.4), and at-least-once delivery
//! (§5.5: "the ETL pipeline with the DMM system ensures an 'at least once'
//! approach").
//!
//! # Segmented log core
//!
//! Each partition is a chain of fixed-capacity, append-only
//! [`Segment`]s (`Arc`-shared, immutable once published) plus one atomic
//! **committed end-offset** — the Kafka log-end-offset. The protocol:
//!
//! - **Append** (producers): a short per-partition writer mutex serializes
//!   appenders — exactly Kafka's per-partition log-append order — while
//!   they write records into uninitialized slots of the tail segment
//!   (allocating and linking a fresh segment when the tail fills). The
//!   batch becomes visible with **one release-store of the committed
//!   end-offset per touched partition**; nothing is visible mid-batch.
//! - **Fetch** (consumers): an acquire-load of the committed end-offset,
//!   then direct slot reads — **zero locks, zero clones**. [`fetch_shared`]
//!   returns [`SharedBatch`]es: `Arc`-shared views into the segments
//!   themselves, so N consumer groups (one per sink) read the same bytes.
//! - **Lag / end-offset**: a single wait-free atomic load per partition.
//!
//! Memory-ordering argument (documented in ARCHITECTURE.md §Broker): a
//! slot write and the tail `next`-link store are sequenced before the
//! writer's release-store of `committed`; a reader's acquire-load of
//! `committed` therefore happens-after every slot (and link) the loaded
//! watermark covers. Readers never read past the watermark, writers never
//! rewrite a published slot, and segments are append-only — so the
//! unsynchronized slot reads are race-free.
//!
//! [`fetch_shared`]: Topic::fetch_shared
//!
//! Two topics matter in the wired pipeline (`ARCHITECTURE.md`): the CDC
//! ingress topic consumed partition-parallel by the mapping lanes, and
//! the CDM egress topic where every registered sink runs its **own**
//! [`Consumer`] group ([`crate::coordinator::egress::SinkHandle`]) so a
//! stalled backend never blocks the others.

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::metrics::BrokerMetrics;

/// Records per segment. Small enough that hostile mini-topics exercise
/// chain growth, large enough that the per-segment overhead is noise.
pub const SEGMENT_RECORDS: usize = 256;

/// A record as stored in a partition log.
#[derive(Debug, Clone)]
pub struct Record<V> {
    pub offset: u64,
    pub key: u64,
    pub value: V,
}

/// One write-once slot of a segment. Initialization is published by the
/// partition's committed watermark, never read before it.
struct Slot<V>(UnsafeCell<MaybeUninit<Record<V>>>);

/// A fixed-capacity, append-only block of the partition log. Immutable
/// once its slots are covered by the committed watermark; shared by `Arc`
/// between the log and every in-flight [`SharedBatch`].
pub struct Segment<V> {
    /// Offset of slot 0.
    base: u64,
    /// Slots initialized so far — the drop authority (readers use the
    /// partition watermark instead, which never exceeds this).
    init: AtomicUsize,
    /// The successor segment, linked by the writer before any record
    /// beyond this segment publishes.
    next: OnceLock<Arc<Segment<V>>>,
    slots: Box<[Slot<V>]>,
}

// SAFETY: slots are plain data owned by the segment; cross-thread access
// is mediated by the committed-watermark release/acquire protocol (reads)
// and the writer mutex (writes), as argued in the module docs.
unsafe impl<V: Send> Send for Segment<V> {}
unsafe impl<V: Send + Sync> Sync for Segment<V> {}

impl<V> Segment<V> {
    fn new(base: u64, capacity: usize) -> Arc<Self> {
        let slots: Box<[Slot<V>]> = (0..capacity)
            .map(|_| Slot(UnsafeCell::new(MaybeUninit::uninit())))
            .collect();
        Arc::new(Segment { base, init: AtomicUsize::new(0), next: OnceLock::new(), slots })
    }

    fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// # Safety
    /// `idx` must be below the slots covered by an acquire-loaded
    /// committed watermark (or, for the writer, below its own fill).
    unsafe fn slot(&self, idx: usize) -> &Record<V> {
        (*self.slots[idx].0.get()).assume_init_ref()
    }

    /// # Safety
    /// Caller is the unique writer (holds the partition writer mutex) and
    /// `idx` is the first uninitialized slot.
    unsafe fn write(&self, idx: usize, rec: Record<V>) {
        (*self.slots[idx].0.get()).write(rec);
        // drop authority only — readers are gated by the watermark, and
        // Arc teardown gives Drop the necessary fences
        self.init.store(idx + 1, Ordering::Relaxed);
    }
}

impl<V> Drop for Segment<V> {
    fn drop(&mut self) {
        let n = *self.init.get_mut();
        for slot in &mut self.slots[..n] {
            unsafe { slot.0.get_mut().assume_init_drop() }
        }
    }
}

/// A zero-copy view of consecutive records inside one segment: the fetch
/// unit of the lock-free read path. Cloning is one `Arc` bump; the
/// records themselves are never copied out of the log.
pub struct SharedBatch<V> {
    partition: usize,
    seg: Arc<Segment<V>>,
    start: usize,
    len: usize,
}

impl<V> Clone for SharedBatch<V> {
    fn clone(&self) -> Self {
        Self {
            partition: self.partition,
            seg: Arc::clone(&self.seg),
            start: self.start,
            len: self.len,
        }
    }
}

impl<V> SharedBatch<V> {
    /// The partition these records live in.
    pub fn partition(&self) -> usize {
        self.partition
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Offset of the first record in the view.
    pub fn first_offset(&self) -> u64 {
        self.seg.base + self.start as u64
    }

    /// Record `i` of the view, by reference into the shared segment.
    pub fn get(&self, i: usize) -> &Record<V> {
        assert!(i < self.len, "batch index {i} out of {}", self.len);
        // SAFETY: construction bounds [start, start+len) by the committed
        // watermark observed with acquire ordering
        unsafe { self.seg.slot(self.start + i) }
    }

    pub fn iter(&self) -> impl Iterator<Item = &Record<V>> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }
}

/// One partition: the segment chain head, the atomic committed
/// end-offset readers race, and the writer-side tail cursor.
struct PartitionLog<V> {
    head: Arc<Segment<V>>,
    /// Log end offset — the single publish point (release-stored by
    /// writers, acquire-loaded by readers).
    committed: AtomicU64,
    /// Tail segment, owned by whoever holds the append lock.
    writer: Mutex<Arc<Segment<V>>>,
}

impl<V> PartitionLog<V> {
    fn new(capacity: usize) -> Self {
        let head = Segment::new(0, capacity);
        Self {
            committed: AtomicU64::new(0),
            writer: Mutex::new(Arc::clone(&head)),
            head,
        }
    }

    /// Wait-free log end offset.
    fn end(&self) -> u64 {
        self.committed.load(Ordering::Acquire)
    }

    /// Append a batch and publish it with one release-store. Returns the
    /// offset of the first appended record.
    fn append(
        &self,
        metrics: &BrokerMetrics,
        items: impl IntoIterator<Item = (u64, V)>,
    ) -> u64 {
        let mut tail = self.writer.lock().unwrap();
        // only writers store `committed`, and we hold the writer lock
        let first = self.committed.load(Ordering::Relaxed);
        let mut end = first;
        for (key, value) in items {
            let mut fill = (end - tail.base) as usize;
            if fill == tail.capacity() {
                let seg = Segment::new(end, tail.capacity());
                metrics.segments_allocated.inc();
                // link before any of its records can publish
                if tail.next.set(Arc::clone(&seg)).is_err() {
                    unreachable!("tail segment already linked");
                }
                *tail = seg;
                fill = 0;
            }
            // SAFETY: unique writer under the lock; `fill` is the first
            // uninitialized slot of the tail
            unsafe { tail.write(fill, Record { offset: end, key, value }) };
            end += 1;
        }
        if end != first {
            // the one atomic publish: everything above becomes visible
            self.committed.store(end, Ordering::Release);
        }
        first
    }

    /// Segment containing `offset`, walking from `hint` when it helps
    /// (sequential consumers pay O(1) amortized) or from the chain head.
    /// Returns `None` only for offsets past the published chain.
    fn seek(
        &self,
        hint: Option<&Arc<Segment<V>>>,
        offset: u64,
    ) -> Option<Arc<Segment<V>>> {
        let mut seg = match hint {
            Some(s) if s.base <= offset => Arc::clone(s),
            _ => Arc::clone(&self.head),
        };
        while offset >= seg.base + seg.capacity() as u64 {
            seg = Arc::clone(seg.next.get()?);
        }
        Some(seg)
    }
}

struct TopicInner<V> {
    partitions: Box<[PartitionLog<V>]>,
    metrics: Arc<BrokerMetrics>,
}

/// A named topic with a fixed partition count.
pub struct Topic<V> {
    inner: Arc<TopicInner<V>>,
}

impl<V> Clone for Topic<V> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<V> Topic<V> {
    fn new(partitions: usize) -> Self {
        Self::with_metrics(partitions, SEGMENT_RECORDS, Arc::default())
    }

    fn with_metrics(
        partitions: usize,
        capacity: usize,
        metrics: Arc<BrokerMetrics>,
    ) -> Self {
        let capacity = capacity.max(1);
        let partitions = partitions.max(1);
        metrics.segments_allocated.add(partitions as u64); // head segments
        Self {
            inner: Arc::new(TopicInner {
                partitions: (0..partitions)
                    .map(|_| PartitionLog::new(capacity))
                    .collect(),
                metrics,
            }),
        }
    }

    pub fn n_partitions(&self) -> usize {
        self.inner.partitions.len()
    }

    /// The broker-level counters this topic reports into.
    pub fn metrics(&self) -> &Arc<BrokerMetrics> {
        &self.inner.metrics
    }

    /// Keyed produce: records with the same key land on the same partition
    /// (ordering guarantee the DW upserts rely on).
    pub fn produce(&self, key: u64, value: V) -> (usize, u64) {
        let p = (fxhash(key) % self.inner.partitions.len() as u64) as usize;
        self.produce_to(p, key, value)
    }

    pub fn produce_to(&self, partition: usize, key: u64, value: V) -> (usize, u64) {
        let offset = self.inner.partitions[partition]
            .append(&self.inner.metrics, std::iter::once((key, value)));
        self.inner.metrics.produce_batches.inc();
        (partition, offset)
    }

    /// Keyed batch produce — the sharded lane's ordered commit: records
    /// are grouped by target partition first, then appended with **one
    /// atomic publish per touched partition**, preserving the input order
    /// within each partition (and therefore per key — a key maps to
    /// exactly one partition). Returns the number of records produced.
    pub fn produce_batch(
        &self,
        records: impl IntoIterator<Item = (u64, V)>,
    ) -> usize {
        let n_parts = self.inner.partitions.len();
        let mut by_partition: Vec<Vec<(u64, V)>> =
            (0..n_parts).map(|_| Vec::new()).collect();
        let mut n = 0;
        for (key, value) in records {
            let p = (fxhash(key) % n_parts as u64) as usize;
            by_partition[p].push((key, value));
            n += 1;
        }
        for (p, batch) in by_partition.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            self.inner.partitions[p].append(&self.inner.metrics, batch);
            self.inner.metrics.produce_batches.inc();
        }
        n
    }

    /// Zero-copy fetch: up to `max` records from `partition` starting at
    /// `offset`, as `Arc`-shared segment views. No locks are taken and no
    /// record is cloned — readers race only the committed watermark.
    pub fn fetch_shared(
        &self,
        partition: usize,
        offset: u64,
        max: usize,
    ) -> Vec<SharedBatch<V>> {
        let mut cursor = None;
        self.fetch_shared_with_cursor(partition, offset, max, &mut cursor)
    }

    /// [`Topic::fetch_shared`] with a caller-held segment cursor:
    /// sequential consumers pass the cursor back in so the seek is O(1)
    /// instead of a walk from the chain head.
    pub fn fetch_shared_with_cursor(
        &self,
        partition: usize,
        offset: u64,
        max: usize,
        cursor: &mut Option<Arc<Segment<V>>>,
    ) -> Vec<SharedBatch<V>> {
        let part = &self.inner.partitions[partition];
        let end = part.end();
        if offset >= end || max == 0 {
            return Vec::new();
        }
        let mut remaining = max.min((end - offset) as usize);
        let Some(mut seg) = part.seek(cursor.as_ref(), offset) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let mut off = offset;
        while remaining > 0 {
            let start = (off - seg.base) as usize;
            let in_seg = (seg.capacity() - start).min(remaining);
            out.push(SharedBatch {
                partition,
                seg: Arc::clone(&seg),
                start,
                len: in_seg,
            });
            remaining -= in_seg;
            off += in_seg as u64;
            if remaining > 0 {
                match seg.next.get() {
                    Some(next) => seg = Arc::clone(next),
                    None => break,
                }
            }
        }
        *cursor = Some(seg);
        self.inner.metrics.fetch_batches.add(out.len() as u64);
        out
    }

    /// End offset (= log length) of a partition: one wait-free atomic
    /// load — the autoscaler's lag loop and the metrics exposition hit
    /// this on every round, so it must never contend with producers.
    pub fn end_offset(&self, partition: usize) -> u64 {
        self.inner.partitions[partition].end()
    }

    /// Total records across partitions (wait-free, one load each).
    pub fn total_records(&self) -> u64 {
        (0..self.n_partitions()).map(|p| self.end_offset(p)).sum()
    }
}

impl<V: Clone> Topic<V> {
    /// Read up to `max` records from `partition` starting at `offset`,
    /// cloned out of the log. Compatibility surface for inspection paths
    /// and tests; the hot paths use [`Topic::fetch_shared`].
    pub fn fetch(&self, partition: usize, offset: u64, max: usize) -> Vec<Record<V>> {
        let batches = self.fetch_shared(partition, offset, max);
        let total = batches.iter().map(SharedBatch::len).sum();
        let mut out = Vec::with_capacity(total);
        for batch in &batches {
            out.extend(batch.iter().cloned());
        }
        out
    }
}

/// FNV-1a–style key hash for partitioning (stable across runs).
fn fxhash(key: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in key.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The broker: a namespace of topics.
pub struct Broker<V> {
    topics: RwLock<HashMap<String, Topic<V>>>,
    default_partitions: usize,
    metrics: Arc<BrokerMetrics>,
}

impl<V> Broker<V> {
    pub fn new(default_partitions: usize) -> Self {
        Self::with_metrics(default_partitions, Arc::default())
    }

    /// Broker whose topics report into `metrics` (the pipeline shares one
    /// [`BrokerMetrics`] across its CDC and CDM brokers).
    pub fn with_metrics(
        default_partitions: usize,
        metrics: Arc<BrokerMetrics>,
    ) -> Self {
        Self {
            topics: RwLock::new(HashMap::new()),
            default_partitions: default_partitions.max(1),
            metrics,
        }
    }

    pub fn create_topic(&self, name: &str, partitions: usize) -> Topic<V> {
        let mut topics = self.topics.write().unwrap();
        topics
            .entry(name.to_string())
            .or_insert_with(|| {
                Topic::with_metrics(
                    partitions,
                    SEGMENT_RECORDS,
                    Arc::clone(&self.metrics),
                )
            })
            .clone()
    }

    /// Get-or-create with the broker default partition count.
    pub fn topic(&self, name: &str) -> Topic<V> {
        if let Some(t) = self.topics.read().unwrap().get(name) {
            return t.clone();
        }
        self.create_topic(name, self.default_partitions)
    }

    pub fn topic_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.topics.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }
}

/// A consumer-group member over one topic: tracks committed offsets per
/// partition. Polling returns records past the committed offset; a poll
/// without a following `commit` re-delivers the same records next time —
/// that is the at-least-once contract.
///
/// Polls interleave **round-robin across assigned partitions** with an
/// evenly split budget, so a hot partition can delay — but never starve —
/// the others (the pre-segmented core drained the budget in assignment
/// order, which let a hot first partition starve the rest permanently).
pub struct Consumer<V> {
    topic: Topic<V>,
    /// Partitions assigned to this member.
    assignment: Vec<usize>,
    committed: Vec<u64>, // per assigned partition (indexed like assignment)
    position: Vec<u64>,  // fetch position (>= committed)
    /// Cached tail segment per assigned partition: sequential polls seek
    /// in O(1) instead of walking the chain from its head.
    cursors: Vec<Option<Arc<Segment<V>>>>,
    /// Rotating start index for the round-robin fairness sweep.
    rr: usize,
}

impl<V> Consumer<V> {
    /// Member `member_idx` of `group_size` consumers: round-robin partition
    /// assignment like Kafka's range assignor.
    pub fn new(topic: Topic<V>, member_idx: usize, group_size: usize) -> Self {
        let assignment: Vec<usize> = (0..topic.n_partitions())
            .filter(|p| p % group_size.max(1) == member_idx)
            .collect();
        let n = assignment.len();
        Self {
            topic,
            assignment,
            committed: vec![0; n],
            position: vec![0; n],
            cursors: vec![None; n],
            rr: 0,
        }
    }

    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// Zero-copy poll: up to `max` records across assigned partitions as
    /// `Arc`-shared segment views, interleaved fairly (see type docs).
    /// Advances the *position* (not the committed offset).
    pub fn poll_shared(&mut self, max: usize) -> Vec<SharedBatch<V>> {
        let n = self.assignment.len();
        if n == 0 || max == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut budget = max;
        // Fairness sweep: rotate the start partition every poll and split
        // the remaining budget evenly over the partitions left in the
        // round, redistributing whatever a drained partition didn't use.
        // Repeat while budget and backlog remain, so a quiet tail
        // partition still yields its records even when a hot one could
        // have consumed the whole budget.
        loop {
            let mut moved = false;
            for k in 0..n {
                if budget == 0 {
                    break;
                }
                let i = (self.rr + k) % n;
                let p = self.assignment[i];
                let avail = self.topic.end_offset(p).saturating_sub(self.position[i]);
                if avail == 0 {
                    continue;
                }
                let left = n - k;
                let quota = (budget.div_ceil(left)).max(1);
                let take = quota.min(avail.min(usize::MAX as u64) as usize);
                let batches = self.topic.fetch_shared_with_cursor(
                    p,
                    self.position[i],
                    take,
                    &mut self.cursors[i],
                );
                for batch in &batches {
                    self.position[i] = batch.first_offset() + batch.len() as u64;
                    budget -= batch.len();
                    moved = true;
                }
                out.extend(batches);
            }
            if budget == 0 || !moved {
                break;
            }
        }
        self.rr = (self.rr + 1) % n;
        out
    }

    /// Commit everything polled so far.
    pub fn commit(&mut self) {
        self.committed.copy_from_slice(&self.position);
    }

    /// Abandon uncommitted progress: next poll re-delivers (at-least-once).
    pub fn rewind_to_committed(&mut self) {
        self.position.copy_from_slice(&self.committed);
        self.cursors.iter_mut().for_each(|c| *c = None);
    }

    /// Reset offsets to zero — the paper's "set back Kafka-offsets and start
    /// new initial loads" fallback (§3.4).
    pub fn reset_to_beginning(&mut self) {
        self.committed.iter_mut().for_each(|o| *o = 0);
        self.position.iter_mut().for_each(|o| *o = 0);
        self.cursors.iter_mut().for_each(|c| *c = None);
    }

    /// Records remaining past the current position (lag). Wait-free: one
    /// atomic load per assigned partition, no locks anywhere on the path.
    pub fn lag(&self) -> u64 {
        self.assignment
            .iter()
            .enumerate()
            .map(|(i, &p)| self.topic.end_offset(p).saturating_sub(self.position[i]))
            .sum()
    }

    /// Committed offset per assigned partition, `(partition, offset)` —
    /// the group's durable progress (monotone between resets).
    pub fn committed_offsets(&self) -> Vec<(usize, u64)> {
        self.assignment
            .iter()
            .copied()
            .zip(self.committed.iter().copied())
            .collect()
    }

    /// Fetch position per assigned partition, `(partition, offset)`
    /// (always `>=` the committed offset).
    pub fn positions(&self) -> Vec<(usize, u64)> {
        self.assignment
            .iter()
            .copied()
            .zip(self.position.iter().copied())
            .collect()
    }
}

impl<V: Clone> Consumer<V> {
    /// Poll up to `max` records across assigned partitions, cloned out of
    /// the log — compatibility surface over [`Consumer::poll_shared`]
    /// (which the hot paths use directly).
    pub fn poll(&mut self, max: usize) -> Vec<(usize, Record<V>)> {
        let batches = self.poll_shared(max);
        let total: usize = batches.iter().map(SharedBatch::len).sum();
        let mut out = Vec::with_capacity(total);
        for batch in &batches {
            out.extend(batch.iter().map(|r| (batch.partition(), r.clone())));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produce_fetch_ordering_per_partition() {
        let t: Topic<u64> = Topic::new(1);
        for i in 0..10 {
            t.produce(1, i);
        }
        let recs = t.fetch(0, 0, 100);
        assert_eq!(recs.len(), 10);
        assert!(recs.windows(2).all(|w| w[0].offset + 1 == w[1].offset));
        assert_eq!(recs.iter().map(|r| r.value).collect::<Vec<_>>(),
                   (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn produce_batch_matches_single_produces() {
        let single: Topic<u64> = Topic::new(4);
        let batched: Topic<u64> = Topic::new(4);
        let records: Vec<(u64, u64)> =
            (0..40).map(|i| (i % 7, i)).collect();
        for &(k, v) in &records {
            single.produce(k, v);
        }
        assert_eq!(batched.produce_batch(records.clone()), 40);
        for p in 0..4 {
            let a: Vec<u64> =
                single.fetch(p, 0, 100).into_iter().map(|r| r.value).collect();
            let b: Vec<u64> =
                batched.fetch(p, 0, 100).into_iter().map(|r| r.value).collect();
            assert_eq!(a, b, "partition {p} order must match");
        }
    }

    #[test]
    fn keyed_produce_is_sticky() {
        let t: Topic<u64> = Topic::new(4);
        let (p1, _) = t.produce(42, 0);
        let (p2, _) = t.produce(42, 1);
        assert_eq!(p1, p2);
    }

    #[test]
    fn segment_chain_grows_and_preserves_order() {
        // capacity 8 forces the chain to grow every 8 records
        let t: Topic<u64> = Topic::with_metrics(1, 8, Arc::default());
        let n = 1000u64;
        t.produce_batch((0..n).map(|i| (1, i)));
        let recs = t.fetch(0, 0, usize::MAX);
        assert_eq!(recs.len(), n as usize);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.offset, i as u64);
            assert_eq!(r.value, i as u64);
        }
        // ceil(1000/8) = 125 segments, head included
        assert_eq!(t.metrics().segments_allocated.get(), 125);
        // random access mid-chain still works (offset reset paths)
        let mid = t.fetch(0, 500, 3);
        assert_eq!(
            mid.iter().map(|r| r.value).collect::<Vec<_>>(),
            vec![500, 501, 502]
        );
    }

    #[test]
    fn fetch_shared_is_zero_copy() {
        let t: Topic<u64> = Topic::new(1);
        for i in 0..10 {
            t.produce(1, i);
        }
        let a = t.fetch_shared(0, 0, 10);
        let b = t.fetch_shared(0, 0, 10);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].len(), 10);
        // both views alias the same slot memory: nothing was cloned
        assert!(std::ptr::eq(a[0].get(3), b[0].get(3)));
        assert_eq!(a[0].get(3).value, 3);
        assert_eq!(a[0].first_offset(), 0);
        assert_eq!(t.metrics().fetch_batches.get(), 2);
    }

    #[test]
    fn mid_batch_records_invisible_until_publish() {
        // produce_batch publishes once per touched partition: a reader
        // sees either none or all of a partition's sub-batch
        let t: Topic<u64> = Topic::new(1);
        t.produce_batch((0..50).map(|i| (1, i)));
        assert_eq!(t.end_offset(0), 50);
        assert_eq!(t.fetch(0, 0, usize::MAX).len(), 50);
    }

    #[test]
    fn consumer_group_partitions_disjoint_and_complete() {
        let t: Topic<u64> = Topic::new(8);
        let c0: Consumer<u64> = Consumer::new(t.clone(), 0, 3);
        let c1: Consumer<u64> = Consumer::new(t.clone(), 1, 3);
        let c2: Consumer<u64> = Consumer::new(t.clone(), 2, 3);
        let mut all: Vec<usize> = [c0.assignment(), c1.assignment(), c2.assignment()]
            .concat();
        all.sort();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn at_least_once_redelivery() {
        let t: Topic<u64> = Topic::new(1);
        t.produce(1, 100);
        t.produce(1, 101);
        let mut c = Consumer::new(t.clone(), 0, 1);
        let first = c.poll(10);
        assert_eq!(first.len(), 2);
        // crash before commit: rewind re-delivers everything
        c.rewind_to_committed();
        let again = c.poll(10);
        assert_eq!(again.len(), 2);
        c.commit();
        c.rewind_to_committed();
        assert!(c.poll(10).is_empty());
    }

    #[test]
    fn reset_to_beginning_replays() {
        let t: Topic<u64> = Topic::new(2);
        for i in 0..20 {
            t.produce(i, i);
        }
        let mut c = Consumer::new(t.clone(), 0, 1);
        c.poll(100);
        c.commit();
        assert_eq!(c.lag(), 0);
        c.reset_to_beginning();
        assert_eq!(c.poll(100).len(), 20);
    }

    #[test]
    fn broker_topic_reuse() {
        let b: Broker<u64> = Broker::new(4);
        let t1 = b.topic("fx.payments");
        t1.produce(1, 1);
        let t2 = b.topic("fx.payments");
        assert_eq!(t2.total_records(), 1);
        assert_eq!(b.topic_names(), vec!["fx.payments"]);
    }

    #[test]
    fn lag_counts_unread() {
        let t: Topic<u64> = Topic::new(1);
        for i in 0..5 {
            t.produce(1, i);
        }
        let mut c = Consumer::new(t.clone(), 0, 1);
        assert_eq!(c.lag(), 5);
        c.poll(2);
        assert_eq!(c.lag(), 3);
    }

    #[test]
    fn poll_interleaves_hot_and_cold_partitions() {
        // Regression: the pre-segmented Consumer drained its budget in
        // assignment order, so a hot partition 0 starved partition 1
        // forever. The fair sweep must deliver the cold partition's
        // records in the very first poll.
        let t: Topic<u64> = Topic::new(2);
        t.produce_batch((0..10_000u64).map(|i| (0, i))); // key 0 → one partition
        let hot = usize::from(t.end_offset(1) > 0);
        let cold = 1 - hot;
        // 5 records on the cold partition
        for i in 0..5 {
            t.produce_to(cold, 99, 20_000 + i);
        }
        let mut c = Consumer::new(t.clone(), 0, 1);
        let batch = c.poll(100);
        let cold_seen = batch
            .iter()
            .filter(|(p, _)| *p == cold)
            .count();
        assert_eq!(cold_seen, 5, "cold partition starved within one poll");
        // the hot partition still gets the lion's share of the budget
        assert!(batch.len() >= 100 - 5);
        // order within each partition is untouched by the interleave
        let hot_vals: Vec<u64> = batch
            .iter()
            .filter(|(p, _)| *p == hot)
            .map(|(_, r)| r.value)
            .collect();
        assert!(hot_vals.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn poll_shared_budget_is_respected() {
        let t: Topic<u64> = Topic::new(4);
        t.produce_batch((0..1000u64).map(|i| (i, i)));
        let mut c = Consumer::new(t.clone(), 0, 1);
        let batches = c.poll_shared(100);
        let total: usize = batches.iter().map(SharedBatch::len).sum();
        assert_eq!(total, 100);
        assert_eq!(c.lag(), 900);
        // drain the rest
        let mut seen = total;
        loop {
            let more: usize =
                c.poll_shared(256).iter().map(SharedBatch::len).sum();
            if more == 0 {
                break;
            }
            seen += more;
        }
        assert_eq!(seen, 1000);
    }

    #[test]
    fn lag_path_takes_no_locks() {
        // Hold the partition writer mutex (a stalled producer) and prove
        // the lag path still completes: end_offset/total_records/lag are
        // wait-free atomic loads, never lock acquisitions. If any of them
        // took the writer lock this would deadlock — the watchdog turns
        // that into a failure instead of a hang.
        let t: Topic<u64> = Topic::new(2);
        for i in 0..7 {
            t.produce_to(0, 1, i);
        }
        let _stalled_producer = t.inner.partitions[0].writer.lock().unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let t2 = t.clone();
        std::thread::spawn(move || {
            let c = Consumer::new(t2.clone(), 0, 1);
            tx.send((t2.end_offset(0), t2.total_records(), c.lag())).ok();
        });
        let (end, total, lag) = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("lag path blocked on a lock");
        assert_eq!(end, 7);
        assert_eq!(total, 7);
        assert_eq!(lag, 7);
    }

    #[test]
    fn values_drop_exactly_once() {
        // Arc payloads across segment boundaries: every record dropped
        // exactly once when the topic (and shared batches) go away.
        let payload = Arc::new(42u64);
        {
            let t: Topic<Arc<u64>> = Topic::with_metrics(2, 4, Arc::default());
            t.produce_batch((0..100).map(|i| (i, Arc::clone(&payload))));
            let held = t.fetch_shared(0, 0, usize::MAX);
            drop(t);
            // batches keep their segments (and payloads) alive
            assert!(held.iter().map(SharedBatch::len).sum::<usize>() > 0);
        }
        assert_eq!(Arc::strong_count(&payload), 1);
    }
}
