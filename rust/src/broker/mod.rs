//! In-process Kafka-sim broker: topics, partitions, offsets, consumer
//! groups, at-least-once delivery, offset reset.
//!
//! Substitution for the paper's Kafka/Kafka-streams substrate (DESIGN.md
//! §2): what METL relies on is semantic — per-partition ordering, keyed
//! partitioning, committed offsets per consumer group, the ability to
//! reset offsets for a new initial load (§3.4), and at-least-once delivery
//! (§5.5: "the ETL pipeline with the DMM system ensures an 'at least once'
//! approach").
//!
//! Two topics matter in the wired pipeline (`ARCHITECTURE.md`): the CDC
//! ingress topic consumed partition-parallel by the mapping lanes, and
//! the CDM egress topic where every registered sink runs its **own**
//! [`Consumer`] group ([`crate::coordinator::egress::SinkHandle`]) so a
//! stalled backend never blocks the others.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

/// A record as stored in a partition log.
#[derive(Debug, Clone)]
pub struct Record<V> {
    pub offset: u64,
    pub key: u64,
    pub value: V,
}

#[derive(Debug)]
struct Partition<V> {
    log: Vec<Record<V>>,
}

impl<V> Default for Partition<V> {
    fn default() -> Self {
        Self { log: Vec::new() }
    }
}

#[derive(Debug)]
struct TopicInner<V> {
    partitions: Vec<Mutex<Partition<V>>>,
}

/// A named topic with a fixed partition count.
pub struct Topic<V> {
    inner: Arc<TopicInner<V>>,
}

impl<V> Clone for Topic<V> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<V: Clone> Topic<V> {
    fn new(partitions: usize) -> Self {
        Self {
            inner: Arc::new(TopicInner {
                partitions: (0..partitions.max(1))
                    .map(|_| Mutex::new(Partition::default()))
                    .collect(),
            }),
        }
    }

    pub fn n_partitions(&self) -> usize {
        self.inner.partitions.len()
    }

    /// Keyed produce: records with the same key land on the same partition
    /// (ordering guarantee the DW upserts rely on).
    pub fn produce(&self, key: u64, value: V) -> (usize, u64) {
        let p = (fxhash(key) % self.inner.partitions.len() as u64) as usize;
        self.produce_to(p, key, value)
    }

    pub fn produce_to(&self, partition: usize, key: u64, value: V) -> (usize, u64) {
        let mut part = self.inner.partitions[partition].lock().unwrap();
        let offset = part.log.len() as u64;
        part.log.push(Record { offset, key, value });
        (partition, offset)
    }

    /// Keyed batch produce — the sharded lane's ordered commit: records
    /// are grouped by target partition first, then appended with one lock
    /// acquisition per touched partition, preserving the input order
    /// within each partition (and therefore per key). Returns the number
    /// of records produced.
    pub fn produce_batch(
        &self,
        records: impl IntoIterator<Item = (u64, V)>,
    ) -> usize {
        let n_parts = self.inner.partitions.len();
        let mut by_partition: Vec<Vec<(u64, V)>> =
            (0..n_parts).map(|_| Vec::new()).collect();
        let mut n = 0;
        for (key, value) in records {
            let p = (fxhash(key) % n_parts as u64) as usize;
            by_partition[p].push((key, value));
            n += 1;
        }
        for (p, batch) in by_partition.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let mut part = self.inner.partitions[p].lock().unwrap();
            for (key, value) in batch {
                let offset = part.log.len() as u64;
                part.log.push(Record { offset, key, value });
            }
        }
        n
    }

    /// Read up to `max` records from `partition` starting at `offset`.
    pub fn fetch(&self, partition: usize, offset: u64, max: usize) -> Vec<Record<V>> {
        let part = self.inner.partitions[partition].lock().unwrap();
        part.log
            .iter()
            .skip(offset as usize)
            .take(max)
            .cloned()
            .collect()
    }

    /// End offset (= log length) of a partition.
    pub fn end_offset(&self, partition: usize) -> u64 {
        self.inner.partitions[partition].lock().unwrap().log.len() as u64
    }

    pub fn total_records(&self) -> u64 {
        (0..self.n_partitions()).map(|p| self.end_offset(p)).sum()
    }
}

/// FNV-1a–style key hash for partitioning (stable across runs).
fn fxhash(key: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in key.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The broker: a namespace of topics.
pub struct Broker<V> {
    topics: RwLock<HashMap<String, Topic<V>>>,
    default_partitions: usize,
}

impl<V: Clone> Broker<V> {
    pub fn new(default_partitions: usize) -> Self {
        Self {
            topics: RwLock::new(HashMap::new()),
            default_partitions: default_partitions.max(1),
        }
    }

    pub fn create_topic(&self, name: &str, partitions: usize) -> Topic<V> {
        let mut topics = self.topics.write().unwrap();
        topics
            .entry(name.to_string())
            .or_insert_with(|| Topic::new(partitions))
            .clone()
    }

    /// Get-or-create with the broker default partition count.
    pub fn topic(&self, name: &str) -> Topic<V> {
        if let Some(t) = self.topics.read().unwrap().get(name) {
            return t.clone();
        }
        self.create_topic(name, self.default_partitions)
    }

    pub fn topic_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.topics.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }
}

/// A consumer-group member over one topic: tracks committed offsets per
/// partition. Polling returns records past the committed offset; a poll
/// without a following `commit` re-delivers the same records next time —
/// that is the at-least-once contract.
pub struct Consumer<V> {
    topic: Topic<V>,
    /// Partitions assigned to this member.
    assignment: Vec<usize>,
    committed: Vec<u64>, // per assigned partition (indexed like assignment)
    position: Vec<u64>,  // fetch position (>= committed)
}

impl<V: Clone> Consumer<V> {
    /// Member `member_idx` of `group_size` consumers: round-robin partition
    /// assignment like Kafka's range assignor.
    pub fn new(topic: Topic<V>, member_idx: usize, group_size: usize) -> Self {
        let assignment: Vec<usize> = (0..topic.n_partitions())
            .filter(|p| p % group_size.max(1) == member_idx)
            .collect();
        let n = assignment.len();
        Self { topic, assignment, committed: vec![0; n], position: vec![0; n] }
    }

    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// Poll up to `max` records across assigned partitions. Advances the
    /// *position* (not the committed offset).
    pub fn poll(&mut self, max: usize) -> Vec<(usize, Record<V>)> {
        let mut out = Vec::new();
        for (i, &p) in self.assignment.iter().enumerate() {
            if out.len() >= max {
                break;
            }
            let batch = self.topic.fetch(p, self.position[i], max - out.len());
            if let Some(last) = batch.last() {
                self.position[i] = last.offset + 1;
            }
            out.extend(batch.into_iter().map(|r| (p, r)));
        }
        out
    }

    /// Commit everything polled so far.
    pub fn commit(&mut self) {
        self.committed.copy_from_slice(&self.position);
    }

    /// Abandon uncommitted progress: next poll re-delivers (at-least-once).
    pub fn rewind_to_committed(&mut self) {
        self.position.copy_from_slice(&self.committed);
    }

    /// Reset offsets to zero — the paper's "set back Kafka-offsets and start
    /// new initial loads" fallback (§3.4).
    pub fn reset_to_beginning(&mut self) {
        self.committed.iter_mut().for_each(|o| *o = 0);
        self.position.iter_mut().for_each(|o| *o = 0);
    }

    /// Records remaining past the current position (lag).
    pub fn lag(&self) -> u64 {
        self.assignment
            .iter()
            .enumerate()
            .map(|(i, &p)| self.topic.end_offset(p).saturating_sub(self.position[i]))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produce_fetch_ordering_per_partition() {
        let t: Topic<u64> = Topic::new(1);
        for i in 0..10 {
            t.produce(1, i);
        }
        let recs = t.fetch(0, 0, 100);
        assert_eq!(recs.len(), 10);
        assert!(recs.windows(2).all(|w| w[0].offset + 1 == w[1].offset));
        assert_eq!(recs.iter().map(|r| r.value).collect::<Vec<_>>(),
                   (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn produce_batch_matches_single_produces() {
        let single: Topic<u64> = Topic::new(4);
        let batched: Topic<u64> = Topic::new(4);
        let records: Vec<(u64, u64)> =
            (0..40).map(|i| (i % 7, i)).collect();
        for &(k, v) in &records {
            single.produce(k, v);
        }
        assert_eq!(batched.produce_batch(records.clone()), 40);
        for p in 0..4 {
            let a: Vec<u64> =
                single.fetch(p, 0, 100).into_iter().map(|r| r.value).collect();
            let b: Vec<u64> =
                batched.fetch(p, 0, 100).into_iter().map(|r| r.value).collect();
            assert_eq!(a, b, "partition {p} order must match");
        }
    }

    #[test]
    fn keyed_produce_is_sticky() {
        let t: Topic<u64> = Topic::new(4);
        let (p1, _) = t.produce(42, 0);
        let (p2, _) = t.produce(42, 1);
        assert_eq!(p1, p2);
    }

    #[test]
    fn consumer_group_partitions_disjoint_and_complete() {
        let t: Topic<u64> = Topic::new(8);
        let c0: Consumer<u64> = Consumer::new(t.clone(), 0, 3);
        let c1: Consumer<u64> = Consumer::new(t.clone(), 1, 3);
        let c2: Consumer<u64> = Consumer::new(t.clone(), 2, 3);
        let mut all: Vec<usize> = [c0.assignment(), c1.assignment(), c2.assignment()]
            .concat();
        all.sort();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn at_least_once_redelivery() {
        let t: Topic<u64> = Topic::new(1);
        t.produce(1, 100);
        t.produce(1, 101);
        let mut c = Consumer::new(t.clone(), 0, 1);
        let first = c.poll(10);
        assert_eq!(first.len(), 2);
        // crash before commit: rewind re-delivers everything
        c.rewind_to_committed();
        let again = c.poll(10);
        assert_eq!(again.len(), 2);
        c.commit();
        c.rewind_to_committed();
        assert!(c.poll(10).is_empty());
    }

    #[test]
    fn reset_to_beginning_replays() {
        let t: Topic<u64> = Topic::new(2);
        for i in 0..20 {
            t.produce(i, i);
        }
        let mut c = Consumer::new(t.clone(), 0, 1);
        c.poll(100);
        c.commit();
        assert_eq!(c.lag(), 0);
        c.reset_to_beginning();
        assert_eq!(c.poll(100).len(), 20);
    }

    #[test]
    fn broker_topic_reuse() {
        let b: Broker<u64> = Broker::new(4);
        let t1 = b.topic("fx.payments");
        t1.produce(1, 1);
        let t2 = b.topic("fx.payments");
        assert_eq!(t2.total_records(), 1);
        assert_eq!(b.topic_names(), vec!["fx.payments"]);
    }

    #[test]
    fn lag_counts_unread() {
        let t: Topic<u64> = Topic::new(1);
        for i in 0..5 {
            t.produce(1, i);
        }
        let mut c = Consumer::new(t.clone(), 0, 1);
        assert_eq!(c.lag(), 5);
        c.poll(2);
        assert_eq!(c.lag(), 3);
    }
}
