//! Debezium-style CDC envelopes (paper §3.2, fig 2): a CDC event carries a
//! "before" and "after" payload plus source metadata; creation events have
//! an empty "before", deletions an empty "after".

use super::InMessage;

/// CDC operation kinds (Debezium op codes c/u/d, plus schema-change
/// notifications which the pipeline's control lane consumes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CdcOp {
    Create,
    Update,
    Delete,
    /// Snapshot read during an initial load (Debezium op "r").
    SnapshotRead,
}

impl CdcOp {
    pub fn code(self) -> &'static str {
        match self {
            CdcOp::Create => "c",
            CdcOp::Update => "u",
            CdcOp::Delete => "d",
            CdcOp::SnapshotRead => "r",
        }
    }

    pub fn from_code(code: &str) -> Option<CdcOp> {
        Some(match code {
            "c" => CdcOp::Create,
            "u" => CdcOp::Update,
            "d" => CdcOp::Delete,
            "r" => CdcOp::SnapshotRead,
            _ => return None,
        })
    }
}

/// Source block of the envelope (fig 2: connector/db/table).
#[derive(Debug, Clone, PartialEq)]
pub struct CdcSource {
    pub connector: String,
    pub db: String,
    pub table: String,
}

/// One CDC event as extracted by the connector.
#[derive(Debug, Clone, PartialEq)]
pub struct CdcEvent {
    pub op: CdcOp,
    /// Row image before the change; None for creates/snapshot reads.
    pub before: Option<InMessage>,
    /// Row image after the change; None for deletes.
    pub after: Option<InMessage>,
    pub source: CdcSource,
    /// Commit timestamp, µs.
    pub ts_us: u64,
}

impl CdcEvent {
    /// The payload METL maps: "after" for upserts, "before" for deletes
    /// (so the DW can tombstone by key).
    pub fn mapping_payload(&self) -> Option<&InMessage> {
        match self.op {
            CdcOp::Create | CdcOp::Update | CdcOp::SnapshotRead => {
                self.after.as_ref()
            }
            CdcOp::Delete => self.before.as_ref(),
        }
    }

    /// Envelope well-formedness per fig 2 semantics.
    pub fn is_well_formed(&self) -> bool {
        match self.op {
            CdcOp::Create | CdcOp::SnapshotRead => {
                self.before.is_none() && self.after.is_some()
            }
            CdcOp::Update => self.before.is_some() && self.after.is_some(),
            CdcOp::Delete => self.after.is_none() && self.before.is_some(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::message::StateI;
    use crate::schema::{AttrId, SchemaId, VersionNo};
    use crate::util::json::Json;

    fn row(key: u64) -> InMessage {
        InMessage {
            key,
            schema: SchemaId(0),
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 1,
            fields: vec![(AttrId(0), Json::Num(key as f64))],
        }
    }

    fn src() -> CdcSource {
        CdcSource {
            connector: "postgresql".into(),
            db: "payments".into(),
            table: "incoming".into(),
        }
    }

    #[test]
    fn op_codes_roundtrip() {
        for op in [CdcOp::Create, CdcOp::Update, CdcOp::Delete, CdcOp::SnapshotRead] {
            assert_eq!(CdcOp::from_code(op.code()), Some(op));
        }
        assert_eq!(CdcOp::from_code("x"), None);
    }

    #[test]
    fn create_has_empty_before() {
        let ev = CdcEvent {
            op: CdcOp::Create,
            before: None,
            after: Some(row(1)),
            source: src(),
            ts_us: 1,
        };
        assert!(ev.is_well_formed());
        assert_eq!(ev.mapping_payload().unwrap().key, 1);
    }

    #[test]
    fn delete_maps_before_image() {
        let ev = CdcEvent {
            op: CdcOp::Delete,
            before: Some(row(2)),
            after: None,
            source: src(),
            ts_us: 1,
        };
        assert!(ev.is_well_formed());
        assert_eq!(ev.mapping_payload().unwrap().key, 2);
    }

    #[test]
    fn malformed_envelopes_detected() {
        let ev = CdcEvent {
            op: CdcOp::Create,
            before: Some(row(1)),
            after: Some(row(1)),
            source: src(),
            ts_us: 1,
        };
        assert!(!ev.is_well_formed());
        let ev = CdcEvent {
            op: CdcOp::Update,
            before: None,
            after: Some(row(1)),
            source: src(),
            ts_us: 1,
        };
        assert!(!ev.is_well_formed());
    }
}
