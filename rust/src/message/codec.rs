//! JSON wire codec for messages and CDC envelopes (fig 2 shape).
//!
//! The wire format carries attribute *names* (like real Debezium payloads)
//! plus the schema coordinates (o, v, state). Decoding resolves names back
//! to `AttrId`s through the schema tree — exactly the lookup METL performs
//! when it links a Kafka message to the mapping network (§4.1: "once a
//! Kafka-message is linked to the mapping network...").

use anyhow::{anyhow, bail, Context, Result};

use super::cdc::{CdcEvent, CdcOp, CdcSource};
use super::{InMessage, OutMessage, StateI};
use crate::cdm::CdmTree;
use crate::schema::{SchemaId, SchemaTree, VersionNo};
use crate::util::json::{parse, Json};

/// Encode an incoming message payload as a JSON object in field order.
pub fn encode_in(msg: &InMessage, tree: &SchemaTree) -> Json {
    let mut payload = Json::obj();
    for (attr, value) in &msg.fields {
        payload.set(&tree.attr(*attr).name, value.clone());
    }
    let mut obj = Json::obj();
    obj.set("key", Json::Num(msg.key as f64));
    obj.set("schemaId", Json::Num(msg.schema.0 as f64));
    obj.set("version", Json::Num(msg.version.0 as f64));
    obj.set("state", Json::Num(msg.state.0 as f64));
    obj.set("ts_us", Json::Num(msg.ts_us as f64));
    obj.set("payload", payload);
    obj
}

/// Decode an incoming message; unknown attribute names are an error (the
/// message and the registry are out of sync — a §3.4 condition).
pub fn decode_in(text: &str, tree: &SchemaTree) -> Result<InMessage> {
    let v = parse(text).context("invalid message JSON")?;
    decode_in_json(&v, tree)
}

pub fn decode_in_json(v: &Json, tree: &SchemaTree) -> Result<InMessage> {
    let schema = SchemaId(
        v.get("schemaId")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("missing schemaId"))? as u32,
    );
    let version = VersionNo(
        v.get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("missing version"))? as u32,
    );
    let sv = tree
        .version(schema, version)
        .ok_or_else(|| anyhow!("unknown schema version {schema:?} v{}", version.0))?;
    let payload = v
        .get("payload")
        .ok_or_else(|| anyhow!("missing payload"))?;
    let members = match payload {
        Json::Obj(m) => m,
        _ => bail!("payload must be an object"),
    };
    let mut fields = Vec::with_capacity(members.len());
    for (name, value) in members {
        let attr = sv
            .attrs
            .iter()
            .copied()
            .find(|a| tree.attr(*a).name == *name)
            .ok_or_else(|| {
                anyhow!("attribute {name:?} not in schema {schema:?} v{}", version.0)
            })?;
        fields.push((attr, value.clone()));
    }
    Ok(InMessage {
        key: v.get("key").and_then(Json::as_u64).unwrap_or(0),
        schema,
        version,
        state: StateI(v.get("state").and_then(Json::as_u64).unwrap_or(0)),
        ts_us: v.get("ts_us").and_then(Json::as_u64).unwrap_or(0),
        fields,
    })
}

/// Encode an outgoing CDM message. CDM attributes additionally surface the
/// business description as the label (§3.1: "time" → "Time of the payment").
pub fn encode_out(msg: &OutMessage, cdm: &CdmTree) -> Json {
    let mut payload = Json::obj();
    for (attr, value) in &msg.fields {
        let a = cdm.attr(*attr);
        let label = if a.description.is_empty() { &a.name } else { &a.description };
        payload.set(label, value.clone());
    }
    let mut obj = Json::obj();
    obj.set("key", Json::Num(msg.key as f64));
    obj.set("entity", Json::Str(cdm.entity(msg.entity).name.clone()));
    obj.set("entityId", Json::Num(msg.entity.0 as f64));
    obj.set("version", Json::Num(msg.version.0 as f64));
    obj.set("state", Json::Num(msg.state.0 as f64));
    obj.set("ts_us", Json::Num(msg.ts_us as f64));
    obj.set("payload", payload);
    obj
}

/// Encode a full Debezium-style CDC envelope (fig 2).
pub fn encode_cdc(ev: &CdcEvent, tree: &SchemaTree) -> Json {
    let img = |m: &Option<InMessage>| match m {
        None => Json::Null,
        Some(msg) => encode_in(msg, tree),
    };
    let mut source = Json::obj();
    source.set("connector", Json::Str(ev.source.connector.clone()));
    source.set("db", Json::Str(ev.source.db.clone()));
    source.set("table", Json::Str(ev.source.table.clone()));
    let mut payload = Json::obj();
    payload.set("before", img(&ev.before));
    payload.set("after", img(&ev.after));
    payload.set("source", source);
    payload.set("op", Json::Str(ev.op.code().to_string()));
    payload.set("ts_us", Json::Num(ev.ts_us as f64));
    let mut obj = Json::obj();
    obj.set("payload", payload);
    obj
}

/// Decode a CDC envelope.
pub fn decode_cdc(text: &str, tree: &SchemaTree) -> Result<CdcEvent> {
    let v = parse(text).context("invalid CDC JSON")?;
    let payload = v.get("payload").ok_or_else(|| anyhow!("missing payload"))?;
    let op = payload
        .get("op")
        .and_then(Json::as_str)
        .and_then(CdcOp::from_code)
        .ok_or_else(|| anyhow!("missing/unknown op"))?;
    let img = |key: &str| -> Result<Option<InMessage>> {
        match payload.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => Ok(Some(decode_in_json(j, tree)?)),
        }
    };
    let source = payload
        .get("source")
        .ok_or_else(|| anyhow!("missing source"))?;
    let s = |k: &str| {
        source
            .get(k)
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string()
    };
    Ok(CdcEvent {
        op,
        before: img("before")?,
        after: img("after")?,
        source: CdcSource { connector: s("connector"), db: s("db"), table: s("table") },
        ts_us: payload.get("ts_us").and_then(Json::as_u64).unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::ExtractType;

    fn tree() -> (SchemaTree, SchemaId, VersionNo) {
        let mut t = SchemaTree::new();
        let s = t.add_schema("payments.incoming", "fx.payments.incoming");
        let v = t.add_version(
            s,
            &[
                ("id".into(), ExtractType::Int64, false),
                ("value".into(), ExtractType::Decimal, true),
                ("currency".into(), ExtractType::Varchar, true),
                ("time".into(), ExtractType::MicroTimestamp, true),
            ],
        );
        (t, s, v)
    }

    fn sample(t: &SchemaTree, s: SchemaId, v: VersionNo) -> InMessage {
        let sv = t.version(s, v).unwrap();
        InMessage {
            key: 32201,
            schema: s,
            version: v,
            state: StateI(1),
            ts_us: 1_634_052_484_031_131,
            fields: vec![
                (sv.attrs[0], Json::Num(32201.0)),
                (sv.attrs[1], Json::Num(10.0)),
                (sv.attrs[2], Json::Str("EUR".into())),
                (sv.attrs[3], Json::Null),
            ],
        }
    }

    #[test]
    fn in_message_roundtrip() {
        let (t, s, v) = tree();
        let msg = sample(&t, s, v);
        let text = encode_in(&msg, &t).to_string();
        let back = decode_in(&text, &t).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn unknown_attribute_is_sync_error() {
        let (t, s, v) = tree();
        let mut j = encode_in(&sample(&t, s, v), &t);
        let payload = match &mut j {
            Json::Obj(m) => m.iter_mut().find(|(k, _)| k == "payload").unwrap(),
            _ => unreachable!(),
        };
        payload.1.set("ghost_column", Json::Num(1.0));
        assert!(decode_in(&j.to_string(), &t).is_err());
    }

    #[test]
    fn unknown_version_is_sync_error() {
        let (t, s, v) = tree();
        let msg = InMessage { version: VersionNo(9), ..sample(&t, s, v) };
        let mut j = Json::obj();
        j.set("schemaId", Json::Num(msg.schema.0 as f64));
        j.set("version", Json::Num(9.0));
        j.set("payload", Json::obj());
        assert!(decode_in(&j.to_string(), &t).is_err());
    }

    #[test]
    fn cdc_envelope_roundtrip() {
        let (t, s, v) = tree();
        let ev = CdcEvent {
            op: CdcOp::Update,
            before: Some(sample(&t, s, v)),
            after: Some(sample(&t, s, v)),
            source: CdcSource {
                connector: "postgresql".into(),
                db: "payments".into(),
                table: "incoming".into(),
            },
            ts_us: 42,
        };
        let text = encode_cdc(&ev, &t).to_string();
        let back = decode_cdc(&text, &t).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn create_envelope_has_null_before() {
        let (t, s, v) = tree();
        let ev = CdcEvent {
            op: CdcOp::Create,
            before: None,
            after: Some(sample(&t, s, v)),
            source: CdcSource {
                connector: "postgresql".into(),
                db: "payments".into(),
                table: "incoming".into(),
            },
            ts_us: 42,
        };
        let j = encode_cdc(&ev, &t);
        assert!(j.get("payload").unwrap().get("before").unwrap().is_null());
        let back = decode_cdc(&j.to_string(), &t).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn out_message_uses_descriptions() {
        let mut cdm = CdmTree::new();
        let e = cdm.add_entity("Payment");
        let w = cdm.add_version(
            e,
            &[(
                "time".into(),
                crate::cdm::CdmType::Timestamp,
                "Time of the payment".into(),
            )],
        );
        let q = cdm.version(e, w).unwrap().attrs[0];
        let out = OutMessage {
            key: 1,
            entity: e,
            version: w,
            state: StateI(0),
            ts_us: 0,
            fields: vec![(q, Json::Num(1_634_052_484_031_131.0))],
        };
        let j = encode_out(&out, &cdm);
        assert!(j
            .get("payload")
            .unwrap()
            .get("Time of the payment")
            .is_some());
    }
}
