//! Schematized Kafka messages and Debezium-style CDC envelopes (paper §3.1,
//! fig 2), plus the JSON codec.
//!
//! Two payload disciplines exist in the paper:
//! - **sparse** (baseline system, §4.2): every attribute of the schema
//!   version is present, "null" objects included — `nad_p ∈ {0,1}` is
//!   explicit;
//! - **dense** (optimized system, §5.5): only non-"null" attributes are
//!   present, and empty-payload messages are never emitted.

pub mod cdc;
pub mod codec;

use crate::cdm::{CdmAttrId, CdmVersionNo, EntityId};
use crate::schema::{AttrId, SchemaId, VersionNo};
use crate::util::json::Json;

/// The mapping-system state `i` a message is pinned to (paper §3.4: every
/// core element inherits the state; components check sync and error out).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct StateI(pub u64);

/// An incoming schematized Kafka message `ᵢMIn_v^o`: pairs of extracting
/// attributes and data objects.
#[derive(Debug, Clone, PartialEq)]
pub struct InMessage {
    /// Partitioning key (row key of the source record).
    pub key: u64,
    pub schema: SchemaId,
    pub version: VersionNo,
    pub state: StateI,
    /// Event time (µs since epoch, Debezium-style).
    pub ts_us: u64,
    /// Attribute/data-object pairs. Sparse messages include `Json::Null`
    /// entries; dense messages omit them.
    pub fields: Vec<(AttrId, Json)>,
}

impl InMessage {
    /// `nad_p` of one attribute: number of data objects (0 or 1, §4.1).
    pub fn nad(&self, attr: AttrId) -> u8 {
        match self.fields.iter().find(|(a, _)| *a == attr) {
            Some((_, v)) if !v.is_null() => 1,
            _ => 0,
        }
    }

    /// The data object `ad_p`, if present and non-null.
    pub fn data_object(&self, attr: AttrId) -> Option<&Json> {
        self.fields
            .iter()
            .find(|(a, v)| *a == attr && !v.is_null())
            .map(|(_, v)| v)
    }

    /// Convert a sparse message to the dense discipline (§5.5): drop nulls.
    pub fn to_dense(&self) -> InMessage {
        InMessage {
            fields: self
                .fields
                .iter()
                .filter(|(_, v)| !v.is_null())
                .cloned()
                .collect(),
            ..self.clone()
        }
    }

    pub fn non_null_count(&self) -> usize {
        self.fields.iter().filter(|(_, v)| !v.is_null()).count()
    }
}

/// An outgoing CDM message `ᵢMOut_w^r`: pairs of CDM attributes and
/// relabelled data objects.
#[derive(Debug, Clone, PartialEq)]
pub struct OutMessage {
    pub key: u64,
    pub entity: EntityId,
    pub version: CdmVersionNo,
    pub state: StateI,
    pub ts_us: u64,
    pub fields: Vec<(CdmAttrId, Json)>,
}

impl OutMessage {
    pub fn ncd(&self, attr: CdmAttrId) -> u8 {
        match self.fields.iter().find(|(c, _)| *c == attr) {
            Some((_, v)) if !v.is_null() => 1,
            _ => 0,
        }
    }

    pub fn non_null_count(&self) -> usize {
        self.fields.iter().filter(|(_, v)| !v.is_null()).count()
    }

    /// Dense-discipline check (§5.5): no nulls, non-empty.
    pub fn is_dense_valid(&self) -> bool {
        !self.fields.is_empty() && self.fields.iter().all(|(_, v)| !v.is_null())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg() -> InMessage {
        InMessage {
            key: 7,
            schema: SchemaId(0),
            version: VersionNo(1),
            state: StateI(3),
            ts_us: 1_634_052_484_031_131,
            fields: vec![
                (AttrId(0), Json::Num(32201.0)),
                (AttrId(1), Json::Null),
                (AttrId(2), Json::Str("EUR".into())),
            ],
        }
    }

    #[test]
    fn nad_reflects_null_formalization() {
        let m = msg();
        // ad_p = "null" <-> nad_p = 0 (paper §4.1)
        assert_eq!(m.nad(AttrId(0)), 1);
        assert_eq!(m.nad(AttrId(1)), 0);
        assert_eq!(m.nad(AttrId(2)), 1);
        assert_eq!(m.nad(AttrId(99)), 0); // absent == implicit null
    }

    #[test]
    fn dense_conversion_drops_nulls_only() {
        let m = msg().to_dense();
        assert_eq!(m.fields.len(), 2);
        assert_eq!(m.non_null_count(), 2);
        assert_eq!(m.nad(AttrId(1)), 0);
        assert_eq!(m.data_object(AttrId(2)).unwrap().as_str(), Some("EUR"));
    }

    #[test]
    fn out_message_dense_validity() {
        let empty = OutMessage {
            key: 1,
            entity: EntityId(0),
            version: CdmVersionNo(1),
            state: StateI(0),
            ts_us: 0,
            fields: vec![],
        };
        assert!(!empty.is_dense_valid());
        let with_null = OutMessage {
            fields: vec![(CdmAttrId(0), Json::Null)],
            ..empty.clone()
        };
        assert!(!with_null.is_dense_valid());
        let ok = OutMessage {
            fields: vec![(CdmAttrId(0), Json::Num(1.0))],
            ..empty
        };
        assert!(ok.is_dense_valid());
        assert_eq!(ok.ncd(CdmAttrId(0)), 1);
    }
}
