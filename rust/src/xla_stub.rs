//! Offline stand-in for the `xla` (PJRT) bindings used by [`crate::runtime`].
//!
//! The build image carries no native XLA/PJRT library, so this module
//! mirrors the exact API surface `runtime/mod.rs` consumes and fails at the
//! client-construction boundary: [`PjRtClient::cpu`] returns an error,
//! which makes `BulkRuntime::try_load` yield `None` and routes every load
//! through the Alg-6 fallback lane. All artifact-gated tests already skip
//! when `artifacts/manifest.json` is absent, so the stub keeps the crate
//! compiling and the test suite green without the accelerator toolchain.
//! Swapping in the real bindings is a one-line change in `runtime/mod.rs`.

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: metl was built without native XLA bindings";

/// PJRT client handle. Construction always fails in the stub.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!("{UNAVAILABLE}")
    }
}

/// Parsed HLO module (never constructible offline).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        bail!("{UNAVAILABLE}: cannot parse HLO text")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled executable (never constructible offline).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!("{UNAVAILABLE}")
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!("{UNAVAILABLE}")
    }
}

/// Host-side literal. Constructible (the loader builds inputs before it
/// learns the client is unavailable); all read-back paths error.
#[derive(Debug, Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        bail!("{UNAVAILABLE}")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn literals_build_but_never_read_back() {
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_tuple2().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
