//! Immutable snapshot segments + the atomically swapped manifest.
//!
//! A segment (`seg-NNNNNN.mseg`) is an SSTable-style immutable file: the
//! full `ᵢ𝔇𝔘𝔖𝔅` at snapshot time, laid out as one independent JSON
//! region **per schema** (newline-terminated, byte offsets recorded in
//! the manifest's [`SparseIndex`]). Each region also records the
//! schema's **version set at snapshot time**, which bounds Alg-4 replay
//! during recovery (see `DusbSet::decompact_bounded`).
//!
//! The manifest (`MANIFEST.json`) names the live segment and the WAL
//! cursor (`wal_seq`) the segment covers. Both files are published with
//! the classic crash-safe dance: write `*.tmp` + fsync, then rename over
//! the final name. A crash between any two steps leaves either the old
//! manifest (pointing at the old, still-present segment) or the new one
//! — never a torn view. Superseded segments are garbage-collected only
//! *after* the new manifest rename.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::index::{IndexEntry, SparseIndex};
use super::io::StoreIo;
use crate::cdm::{CdmVersionNo, EntityId};
use crate::matrix::dusb::{usb_entries_from_json, usb_entries_to_json, DusbSet};
use crate::message::StateI;
use crate::metrics::StoreMetrics;
use crate::schema::{SchemaId, SchemaTree, VersionNo};
use crate::util::json::Json;

/// Manifest file name inside the store directory.
pub const MANIFEST_FILE: &str = "MANIFEST.json";
/// On-disk format version gate.
pub const FORMAT: u64 = 1;

/// The store's root metadata: which segment is live and how much of the
/// WAL it already covers.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Monotonic snapshot number; also the segment file's number.
    pub seq: u64,
    /// Live segment file name (relative to the store dir).
    pub segment: String,
    /// The state `i` the segment's DUSB was built at.
    pub state: StateI,
    /// Highest WAL `seq` folded into the segment; recovery replays
    /// records strictly after this cursor.
    pub wal_seq: u64,
    pub index: SparseIndex,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format", Json::Num(FORMAT as f64));
        j.set("seq", Json::Num(self.seq as f64));
        j.set("segment", Json::Str(self.segment.clone()));
        j.set("state", Json::Num(self.state.0 as f64));
        j.set("wal_seq", Json::Num(self.wal_seq as f64));
        j.set("index", self.index.to_json());
        j
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let num = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let format = num("format")?;
        if format != FORMAT {
            bail!("unsupported store format {format} (want {FORMAT})");
        }
        Ok(Manifest {
            seq: num("seq")?,
            segment: j
                .get("segment")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest missing segment"))?
                .to_string(),
            state: StateI(num("state")?),
            wal_seq: num("wal_seq")?,
            index: SparseIndex::from_json(
                j.get("index").ok_or_else(|| anyhow!("manifest missing index"))?,
            )?,
        })
    }
}

/// `seg-000042.mseg` for snapshot 42.
pub fn segment_file_name(seq: u64) -> String {
    format!("seg-{seq:06}.mseg")
}

/// Write a new snapshot segment + swap the manifest to it. Crash-safe at
/// every step; returns the published manifest.
pub fn write_segment(
    io: &Arc<dyn StoreIo>,
    dir: &Path,
    seq: u64,
    dusb: &DusbSet,
    tree: &SchemaTree,
    wal_seq: u64,
    metrics: &StoreMetrics,
) -> Result<Manifest> {
    // one region per registered schema — including schemas with no groups,
    // whose recorded (possibly empty) version set still bounds replay
    let mut schema_ids: Vec<SchemaId> = tree.schemas().map(|s| s.id).collect();
    schema_ids.sort();
    let mut bytes = Vec::new();
    let mut entries = Vec::with_capacity(schema_ids.len());
    for o in schema_ids {
        let mut region = Json::obj();
        region.set("o", Json::Num(o.0 as f64));
        region.set(
            "versions",
            Json::Arr(
                tree.versions_of(o)
                    .iter()
                    .map(|v| Json::Num(v.0 as f64))
                    .collect(),
            ),
        );
        let mut groups: Vec<_> =
            dusb.groups().filter(|((go, _, _), _)| *go == o).collect();
        groups.sort_by_key(|(k, _)| **k);
        region.set(
            "groups",
            Json::Arr(
                groups
                    .into_iter()
                    .map(|(&(_, r, w), seq_entries)| {
                        let mut g = Json::obj();
                        g.set("r", Json::Num(r.0 as f64));
                        g.set("w", Json::Num(w.0 as f64));
                        g.set("seq", usb_entries_to_json(seq_entries));
                        g
                    })
                    .collect(),
            ),
        );
        let mut region_bytes = region.to_string().into_bytes();
        region_bytes.push(b'\n');
        entries.push(IndexEntry {
            schema: o,
            offset: bytes.len() as u64,
            len: region_bytes.len() as u64,
        });
        bytes.extend_from_slice(&region_bytes);
    }

    let seg_name = segment_file_name(seq);
    let seg_tmp = dir.join(format!("{seg_name}.tmp"));
    io.write_file(&seg_tmp, &bytes)?;
    io.rename(&seg_tmp, &dir.join(&seg_name))?;

    let manifest = Manifest {
        seq,
        segment: seg_name,
        state: dusb.state,
        wal_seq,
        index: SparseIndex::new(entries),
    };
    let man_tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
    io.write_file(&man_tmp, manifest.to_json().to_pretty().as_bytes())?;
    io.rename(&man_tmp, &dir.join(MANIFEST_FILE))?;
    metrics.segments_live.set(1);
    Ok(manifest)
}

/// Load the current manifest; `None` when the store is empty.
pub fn load_manifest(io: &Arc<dyn StoreIo>, dir: &Path) -> Result<Option<Manifest>> {
    let Some(bytes) = io.read(&dir.join(MANIFEST_FILE))? else {
        return Ok(None);
    };
    let text = std::str::from_utf8(&bytes).context("manifest is not utf-8")?;
    let j = crate::util::json::parse(text)
        .map_err(|e| anyhow!("manifest parse error: {e:?}"))?;
    Ok(Some(Manifest::from_json(&j)?))
}

/// One parsed segment region: the schema's snapshot-time version set and
/// its DUSB groups.
pub struct Region {
    pub schema: SchemaId,
    pub versions: Vec<VersionNo>,
    pub groups: Vec<(
        (SchemaId, EntityId, CdmVersionNo),
        Vec<crate::matrix::dusb::UsbEntry>,
    )>,
}

fn parse_region(bytes: &[u8]) -> Result<Region> {
    let text = std::str::from_utf8(bytes).context("segment region is not utf-8")?;
    let j = crate::util::json::parse(text.trim_end())
        .map_err(|e| anyhow!("segment region parse error: {e:?}"))?;
    let o = SchemaId(
        j.get("o")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("region missing o"))? as u32,
    );
    let versions = j
        .get("versions")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("region missing versions"))?
        .iter()
        .map(|v| {
            v.as_u64()
                .map(|n| VersionNo(n as u32))
                .ok_or_else(|| anyhow!("bad version"))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut groups = Vec::new();
    for g in j
        .get("groups")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("region missing groups"))?
    {
        let num = |k: &str| {
            g.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("group missing {k}"))
        };
        let key = (o, EntityId(num("r")? as u32), CdmVersionNo(num("w")? as u32));
        let seq = usb_entries_from_json(
            g.get("seq").ok_or_else(|| anyhow!("group missing seq"))?,
        )?;
        groups.push((key, seq));
    }
    Ok(Region { schema: o, versions, groups })
}

/// Read the whole segment back: the DUSB (at `manifest.state`) and the
/// per-schema snapshot-time version sets.
pub fn read_full(
    io: &Arc<dyn StoreIo>,
    dir: &Path,
    manifest: &Manifest,
) -> Result<(DusbSet, HashMap<SchemaId, Vec<VersionNo>>)> {
    let path = dir.join(&manifest.segment);
    let bytes = io
        .read(&path)?
        .ok_or_else(|| anyhow!("manifest names missing segment {:?}", manifest.segment))?;
    if bytes.len() as u64 != manifest.index.total_bytes() {
        bail!(
            "segment {:?} is {}B but the index covers {}B",
            manifest.segment,
            bytes.len(),
            manifest.index.total_bytes()
        );
    }
    let mut dusb = DusbSet::new(manifest.state);
    let mut versions = HashMap::new();
    for e in manifest.index.entries() {
        let region =
            parse_region(&bytes[e.offset as usize..(e.offset + e.len) as usize])?;
        versions.insert(region.schema, region.versions);
        for (key, seq) in region.groups {
            dusb.insert_group(key, seq);
        }
    }
    Ok((dusb, versions))
}

/// Point-read exactly one schema's region through the sparse index.
/// Returns the parsed region plus the bytes read (`None` when the segment
/// has no region for `schema`) — the byte count backs the "<10% of store
/// bytes for single-schema recovery" acceptance check.
pub fn read_schema_region(
    io: &Arc<dyn StoreIo>,
    dir: &Path,
    manifest: &Manifest,
    schema: SchemaId,
) -> Result<Option<(Region, u64)>> {
    let Some(entry) = manifest.index.lookup(schema) else {
        return Ok(None);
    };
    let bytes = io.read_range(
        &dir.join(&manifest.segment),
        entry.offset,
        entry.len as usize,
    )?;
    Ok(Some((parse_region(&bytes)?, entry.len)))
}

/// Remove segment files superseded by `manifest` (plus orphaned `*.tmp`
/// from crashed publishes). Runs after the manifest swap; a crash halfway
/// just leaves garbage for the next GC.
pub fn gc(
    io: &Arc<dyn StoreIo>,
    dir: &Path,
    manifest: &Manifest,
    metrics: &StoreMetrics,
) -> Result<usize> {
    let mut removed = 0;
    for path in io.list(dir)? {
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        let stale_seg = name.starts_with("seg-")
            && name.ends_with(".mseg")
            && name != manifest.segment;
        let orphan_tmp = name.ends_with(".tmp");
        if stale_seg || orphan_tmp {
            io.remove_file(&path)?;
            removed += 1;
        }
    }
    metrics.segment_gc_total.add(removed as u64);
    metrics.segments_live.set(1);
    Ok(removed)
}

/// The store directory's segment files (live + not-yet-GCed).
pub fn list_segments(io: &Arc<dyn StoreIo>, dir: &Path) -> Result<Vec<PathBuf>> {
    Ok(io
        .list(dir)?
        .into_iter()
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("seg-") && n.ends_with(".mseg"))
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::store::io::RealIo;
    use crate::util::tmp::TestDir;

    fn fixture() -> (SchemaTree, crate::cdm::CdmTree, DusbSet) {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(3)).unwrap();
        (t, c, dusb)
    }

    #[test]
    fn segment_roundtrip_with_version_sets() {
        let dir = TestDir::new("seg-roundtrip");
        let io: Arc<dyn StoreIo> = Arc::new(RealIo::default());
        let m = StoreMetrics::default();
        let (t, c, dusb) = fixture();
        let manifest =
            write_segment(&io, dir.path(), 1, &dusb, &t, 5, &m).unwrap();
        assert_eq!(manifest.wal_seq, 5);
        assert_eq!(manifest.state, StateI(3));
        let loaded = load_manifest(&io, dir.path()).unwrap().unwrap();
        assert_eq!(loaded, manifest);
        let (back, versions) = read_full(&io, dir.path(), &loaded).unwrap();
        assert_eq!(back.state, StateI(3));
        assert_eq!(back.n_elements(), dusb.n_elements());
        assert_eq!(back.decompact(&t, &c), dusb.decompact(&t, &c));
        // every schema has a recorded version set, even group-less ones
        for s in t.schemas() {
            assert_eq!(versions[&s.id], s.versions);
        }
    }

    #[test]
    fn point_read_touches_only_one_region() {
        let dir = TestDir::new("seg-point");
        let io: Arc<dyn StoreIo> = Arc::new(RealIo::default());
        let m = StoreMetrics::default();
        let (t, _c, dusb) = fixture();
        let manifest =
            write_segment(&io, dir.path(), 1, &dusb, &t, 1, &m).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        let (region, bytes_read) =
            read_schema_region(&io, dir.path(), &manifest, s1)
                .unwrap()
                .unwrap();
        assert_eq!(region.schema, s1);
        assert!(!region.groups.is_empty());
        assert!(bytes_read < manifest.index.total_bytes());
        assert!(
            read_schema_region(&io, dir.path(), &manifest, SchemaId(999))
                .unwrap()
                .is_none()
        );
    }

    #[test]
    fn gc_removes_superseded_segments_and_tmp() {
        let dir = TestDir::new("seg-gc");
        let io: Arc<dyn StoreIo> = Arc::new(RealIo::default());
        let m = StoreMetrics::default();
        let (t, _c, dusb) = fixture();
        write_segment(&io, dir.path(), 1, &dusb, &t, 1, &m).unwrap();
        let manifest =
            write_segment(&io, dir.path(), 2, &dusb, &t, 2, &m).unwrap();
        io.write_file(&dir.join("seg-000009.mseg.tmp"), b"junk").unwrap();
        assert_eq!(list_segments(&io, dir.path()).unwrap().len(), 2);
        let removed = gc(&io, dir.path(), &manifest, &m).unwrap();
        assert_eq!(removed, 2); // old segment + orphan tmp
        let left = list_segments(&io, dir.path()).unwrap();
        assert_eq!(left.len(), 1);
        assert!(left[0].ends_with(segment_file_name(2)));
        assert_eq!(m.segment_gc_total.get(), 2);
        // the survivor still loads
        let (back, _) = read_full(&io, dir.path(), &manifest).unwrap();
        assert_eq!(back.n_elements(), dusb.n_elements());
    }
}
