//! The write-ahead log of the durable matrix store.
//!
//! Evolution-lane updates are committed here **before** the epoch
//! publishes (see `coordinator::evolution`): once [`Wal::commit`]
//! returns, the schema change survives any crash. Records are framed as
//!
//! ```text
//! [len: u32 LE][crc32(payload): u32 LE][payload: len bytes of JSON]
//! ```
//!
//! Replay scans frames from the start and **truncates at the first
//! corrupt frame** (short header, implausible length, checksum mismatch,
//! unparseable payload): everything before the tear is intact and
//! everything after it was never acknowledged, so dropping it loses no
//! committed update.
//!
//! The WAL keeps the **entire schema-change history** (records are never
//! garbage-collected — schema changes are "a few times a day", §3.3, so
//! the log stays tiny). Recovery needs the full history to rebuild the
//! registry tree deterministically on a cold start; the segment
//! manifest's `wal_seq` cursor decides which suffix is replayed through
//! Alg-5 (see `super::recovery`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::io::StoreIo;
use crate::message::StateI;
use crate::metrics::StoreMetrics;
use crate::schema::{ExtractType, SchemaId, VersionNo};
use crate::util::json::Json;

/// WAL file name inside the store directory.
pub const WAL_FILE: &str = "wal.log";

/// Frames larger than this are treated as corruption, not data (the
/// biggest real record is a field list of a few hundred bytes).
const MAX_FRAME: u32 = 1 << 24;

/// When the WAL fsyncs (`runtime.store.fsync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync on every committed update (the durability default).
    Always,
    /// Never fsync (benchmarks / throwaway sims; a crash may lose tail
    /// updates that were acked).
    Never,
}

impl std::str::FromStr for FsyncPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "never" => Ok(FsyncPolicy::Never),
            other => Err(format!(
                "unknown fsync policy {other:?} (expected always|never)"
            )),
        }
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Never => "never",
        })
    }
}

/// The schema-change operation a WAL record describes.
#[derive(Debug, Clone, PartialEq)]
pub enum WalOp {
    /// A new version with its full field list (registry add).
    Add { fields: Vec<(String, ExtractType, bool)> },
    /// A version retirement (Alg-5 case 1).
    Drop,
    /// An in-band Alg-5 case-3 patch of an already registered version.
    InBand,
}

impl WalOp {
    fn case_name(&self) -> &'static str {
        match self {
            WalOp::Add { .. } => "add",
            WalOp::Drop => "drop",
            WalOp::InBand => "in-band",
        }
    }
}

/// One committed evolution-lane update.
#[derive(Debug, Clone, PartialEq)]
pub struct WalRecord {
    /// Monotonic commit sequence number (1-based).
    pub seq: u64,
    /// The state `i` the update installed.
    pub state: StateI,
    pub schema: SchemaId,
    pub v: VersionNo,
    pub ts_us: u64,
    pub op: WalOp,
}

impl WalRecord {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seq", Json::Num(self.seq as f64));
        j.set("state", Json::Num(self.state.0 as f64));
        j.set("case", Json::Str(self.op.case_name().to_string()));
        j.set("o", Json::Num(self.schema.0 as f64));
        j.set("v", Json::Num(self.v.0 as f64));
        j.set("ts", Json::Num(self.ts_us as f64));
        if let WalOp::Add { fields } = &self.op {
            let arr = fields
                .iter()
                .map(|(name, ty, optional)| {
                    Json::Arr(vec![
                        Json::Str(name.clone()),
                        Json::Str(ty.wire_name().to_string()),
                        Json::Bool(*optional),
                    ])
                })
                .collect();
            j.set("fields", Json::Arr(arr));
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<WalRecord> {
        let num = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("wal record missing {k}"))
        };
        let case = j
            .get("case")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("wal record missing case"))?;
        let op = match case {
            "drop" => WalOp::Drop,
            "in-band" => WalOp::InBand,
            "add" => {
                let fields = j
                    .get("fields")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("add record missing fields"))?
                    .iter()
                    .map(|f| {
                        let f = f
                            .as_arr()
                            .ok_or_else(|| anyhow!("bad field entry"))?;
                        if f.len() != 3 {
                            bail!("bad field entry arity");
                        }
                        let name = f[0]
                            .as_str()
                            .ok_or_else(|| anyhow!("bad field name"))?;
                        let wire = f[1]
                            .as_str()
                            .ok_or_else(|| anyhow!("bad field type"))?;
                        let ty = ExtractType::from_wire_name(wire)
                            .ok_or_else(|| anyhow!("unknown type {wire:?}"))?;
                        let optional = f[2]
                            .as_bool()
                            .ok_or_else(|| anyhow!("bad field optional"))?;
                        Ok((name.to_string(), ty, optional))
                    })
                    .collect::<Result<Vec<_>>>()?;
                WalOp::Add { fields }
            }
            other => bail!("unknown wal case {other:?}"),
        };
        Ok(WalRecord {
            seq: num("seq")?,
            state: StateI(num("state")?),
            schema: SchemaId(num("o")? as u32),
            v: VersionNo(num("v")? as u32),
            ts_us: num("ts")?,
            op,
        })
    }
}

/// CRC-32 (IEEE, reflected) over `bytes` — hand-rolled, table-driven; the
/// vendor set has no checksum crate.
pub fn crc32(bytes: &[u8]) -> u32 {
    const TABLE: [u32; 256] = crc32_table();
    let mut crc: u32 = !0;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            k += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Encode one record as a length+checksum frame.
pub fn encode_frame(rec: &WalRecord) -> Vec<u8> {
    let payload = rec.to_json().to_string().into_bytes();
    let mut frame = Vec::with_capacity(8 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&crc32(&payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

/// Scan `bytes` for frames. Returns the decoded records, the byte offset
/// of the first corrupt frame (== `bytes.len()` when the log is clean),
/// and whether a tear was found.
pub fn decode_frames(bytes: &[u8]) -> (Vec<WalRecord>, usize, bool) {
    let mut records = Vec::new();
    let mut off = 0usize;
    while off < bytes.len() {
        let rest = &bytes[off..];
        if rest.len() < 8 {
            return (records, off, true);
        }
        let len = u32::from_le_bytes(rest[0..4].try_into().unwrap());
        let crc = u32::from_le_bytes(rest[4..8].try_into().unwrap());
        if len > MAX_FRAME || (len as usize) > rest.len() - 8 {
            return (records, off, true);
        }
        let payload = &rest[8..8 + len as usize];
        if crc32(payload) != crc {
            return (records, off, true);
        }
        let parsed = std::str::from_utf8(payload)
            .ok()
            .and_then(|text| crate::util::json::parse(text).ok())
            .and_then(|j| WalRecord::from_json(&j).ok());
        match parsed {
            Some(rec) => records.push(rec),
            None => return (records, off, true),
        }
        off += 8 + len as usize;
    }
    (records, off, false)
}

/// The open write-ahead log: an append cursor over [`StoreIo`].
#[derive(Debug)]
pub struct Wal {
    io: Arc<dyn StoreIo>,
    path: PathBuf,
    fsync: FsyncPolicy,
    next_seq: AtomicU64,
    metrics: Arc<StoreMetrics>,
}

impl Wal {
    /// Open (creating if absent) and replay the log. A corrupt tail is
    /// truncated away on open, so the append cursor always lands on a
    /// frame boundary. Returns the log plus the surviving records.
    pub fn open(
        io: Arc<dyn StoreIo>,
        path: PathBuf,
        fsync: FsyncPolicy,
        metrics: Arc<StoreMetrics>,
    ) -> Result<(Wal, Vec<WalRecord>)> {
        let bytes = io.read(&path)?.unwrap_or_default();
        let (records, good_len, torn) = decode_frames(&bytes);
        if torn {
            io.truncate(&path, good_len as u64)?;
        }
        let next_seq = records.last().map(|r| r.seq + 1).unwrap_or(1);
        // commit order must be strictly sequential — gaps or reordering
        // mean the file is not our WAL
        for (i, rec) in records.iter().enumerate() {
            if rec.seq != i as u64 + 1 {
                bail!(
                    "wal sequence corrupt: record {i} has seq {}",
                    rec.seq
                );
            }
        }
        let wal = Wal {
            io,
            path,
            fsync,
            next_seq: AtomicU64::new(next_seq),
            metrics,
        };
        Ok((wal, records))
    }

    /// The sequence number the next commit will get.
    pub fn next_seq(&self) -> u64 {
        self.next_seq.load(Ordering::Relaxed)
    }

    /// Append + (policy-dependent) fsync one record: **the commit point**.
    /// The caller passes `seq == next_seq()`; callers are serialized by
    /// the store's inner lock.
    pub fn commit(&self, rec: &WalRecord) -> Result<()> {
        debug_assert_eq!(rec.seq, self.next_seq());
        let frame = encode_frame(rec);
        self.io.append(&self.path, &frame)?;
        self.sync()?;
        // count only after the bytes are durable
        self.metrics.wal_bytes.add(frame.len() as u64);
        self.next_seq.store(rec.seq + 1, Ordering::Relaxed);
        Ok(())
    }

    /// Flush + fsync the append handle (no-op under `fsync = never`).
    pub fn sync(&self) -> Result<()> {
        if self.fsync == FsyncPolicy::Always {
            self.io.sync(&self.path)?;
            self.metrics.wal_fsyncs.inc();
        }
        Ok(())
    }

    /// Current WAL size in bytes.
    pub fn len_bytes(&self) -> Result<u64> {
        self.io.file_len(&self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::io::RealIo;
    use crate::util::tmp::TestDir;

    fn rec(seq: u64, op: WalOp) -> WalRecord {
        WalRecord {
            seq,
            state: StateI(seq),
            schema: SchemaId(3),
            v: VersionNo(4),
            ts_us: 1_700_000,
            op,
        }
    }

    fn add_op() -> WalOp {
        WalOp::Add {
            fields: vec![
                ("id".into(), ExtractType::Int64, false),
                ("when".into(), ExtractType::MicroTimestamp, true),
            ],
        }
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // standard IEEE check values
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn record_json_roundtrip() {
        for op in [add_op(), WalOp::Drop, WalOp::InBand] {
            let r = rec(7, op);
            let j = r.to_json();
            let back =
                WalRecord::from_json(&crate::util::json::parse(&j.to_string()).unwrap())
                    .unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn commit_and_replay() {
        let dir = TestDir::new("wal-replay");
        let io: Arc<dyn StoreIo> = Arc::new(RealIo::default());
        let m = Arc::new(StoreMetrics::default());
        let (wal, existing) = Wal::open(
            Arc::clone(&io),
            dir.join(WAL_FILE),
            FsyncPolicy::Always,
            Arc::clone(&m),
        )
        .unwrap();
        assert!(existing.is_empty());
        wal.commit(&rec(1, add_op())).unwrap();
        wal.commit(&rec(2, WalOp::Drop)).unwrap();
        assert_eq!(wal.next_seq(), 3);
        assert!(m.wal_bytes.get() > 0);
        assert_eq!(m.wal_fsyncs.get(), 2);
        let (wal2, records) = Wal::open(
            io,
            dir.join(WAL_FILE),
            FsyncPolicy::Always,
            Arc::new(StoreMetrics::default()),
        )
        .unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0], rec(1, add_op()));
        assert_eq!(wal2.next_seq(), 3);
    }

    #[test]
    fn corrupt_tail_is_truncated_clean_prefix_survives() {
        let dir = TestDir::new("wal-torn");
        let io: Arc<dyn StoreIo> = Arc::new(RealIo::default());
        let m = Arc::new(StoreMetrics::default());
        let path = dir.join(WAL_FILE);
        let (wal, _) = Wal::open(
            Arc::clone(&io),
            path.clone(),
            FsyncPolicy::Always,
            Arc::clone(&m),
        )
        .unwrap();
        wal.commit(&rec(1, add_op())).unwrap();
        let good_len = io.file_len(&path).unwrap();
        // a torn second frame: header + half the payload
        let frame = encode_frame(&rec(2, WalOp::Drop));
        io.append(&path, &frame[..frame.len() / 2]).unwrap();
        io.sync(&path).unwrap();
        drop(wal);
        let (wal2, records) = Wal::open(
            Arc::clone(&io),
            path.clone(),
            FsyncPolicy::Always,
            m,
        )
        .unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(io.file_len(&path).unwrap(), good_len);
        // the log keeps working after the repair
        wal2.commit(&rec(2, WalOp::InBand)).unwrap();
        let bytes = io.read(&path).unwrap().unwrap();
        let (records, _, torn) = decode_frames(&bytes);
        assert_eq!(records.len(), 2);
        assert!(!torn);
    }

    #[test]
    fn corrupt_checksum_stops_replay() {
        let mut bytes = encode_frame(&rec(1, WalOp::Drop));
        let tail = encode_frame(&rec(2, WalOp::Drop));
        bytes.extend_from_slice(&tail);
        // flip one payload byte of frame 2
        let flip = bytes.len() - 3;
        bytes[flip] ^= 0xFF;
        let (records, good, torn) = decode_frames(&bytes);
        assert_eq!(records.len(), 1);
        assert!(torn);
        assert_eq!(good, bytes.len() - tail.len());
    }
}
