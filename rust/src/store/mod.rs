//! The durable log-structured matrix store (paper §6.2, hardened): the
//! strongly-compacted `ᵢ𝔇𝔘𝔖𝔅` lives in immutable snapshot **segments**,
//! evolution-lane updates commit to a checksummed **WAL** *before* their
//! epoch publishes, and restart recovery replays the WAL tail through
//! Alg 5 on top of the latest segment — so an acknowledged schema change
//! survives a crash at any write point.
//!
//! Layout of a store directory:
//!
//! ```text
//! MANIFEST.json     the live segment + WAL cursor (atomic rename swap)
//! seg-000003.mseg   immutable DUSB snapshot, one region per schema
//! wal.log           length+crc32-framed schema-change records
//! update_log.jsonl  human-readable audit trail (not used for recovery)
//! ```
//!
//! Submodules: [`io`] (the injectable filesystem seam + fault injection),
//! [`wal`] (framing/replay), [`segment`] (snapshot + manifest swap + GC),
//! [`index`] (sparse per-schema regions), [`recovery`] (the replay
//! algorithm). [`MatrixStore`] is the facade the coordinator talks to —
//! the DLQ/error lane (`coordinator::recovery`) and the §6.2 view
//! (`view_recreate_dpm`) ride on it unchanged.

pub mod index;
pub mod io;
pub mod recovery;
pub mod segment;
pub mod wal;

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

pub use io::{FaultIo, FaultMode, RealIo, StoreIo};
pub use recovery::{RecoveryOutcome, SegmentBase};
pub use segment::Manifest;
pub use wal::{FsyncPolicy, WalOp, WalRecord};

use crate::cdm::CdmTree;
use crate::matrix::dpm::DpmSet;
use crate::matrix::dusb::DusbSet;
use crate::message::StateI;
use crate::metrics::StoreMetrics;
use crate::schema::{SchemaId, SchemaTree, VersionNo};
use crate::util::json::Json;
use crate::workload::Landscape;

/// Audit-log file name (JSONL, operator-facing; recovery never reads it).
pub const AUDIT_FILE: &str = "update_log.jsonl";

/// Store tuning knobs (`runtime.store.*` config keys).
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Write a fresh snapshot segment once this many WAL records have
    /// accumulated past the current manifest's cursor.
    pub segment_update_threshold: u64,
    /// WAL fsync policy (`runtime.store.fsync`).
    pub fsync: FsyncPolicy,
    /// Recovery-time budget asserted by tests/benches (`recovery_ms` must
    /// stay under this; the store itself only reports the gauge).
    pub recovery_budget_ms: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            segment_update_threshold: 32,
            fsync: FsyncPolicy::Always,
            recovery_budget_ms: 5_000,
        }
    }
}

/// Result of a single-schema point recovery (sparse-index path).
#[derive(Debug)]
pub struct PointRecovery {
    pub schema: SchemaId,
    /// Bytes actually read from the segment (one indexed region).
    pub bytes_read: u64,
    /// Total bytes the store holds on disk (segment + WAL + manifest +
    /// audit log) — the denominator of the "<10%" acceptance bound.
    pub store_bytes: u64,
    /// The schema's version set recorded at snapshot time.
    pub versions: Vec<VersionNo>,
    /// DUSB groups recovered for the schema.
    pub groups: usize,
}

#[derive(Debug)]
struct Inner {
    manifest: Option<Manifest>,
    /// Full WAL history, in commit order (the log is tiny: schema changes
    /// happen "a few times a day", §3.3).
    records: Vec<WalRecord>,
}

/// Directory-backed durable matrix store.
#[derive(Debug)]
pub struct MatrixStore {
    dir: PathBuf,
    cfg: StoreConfig,
    io: Arc<dyn StoreIo>,
    metrics: Arc<StoreMetrics>,
    wal: wal::Wal,
    inner: Mutex<Inner>,
}

impl MatrixStore {
    /// Open with defaults (real IO, fresh metrics) — the back-compat
    /// constructor for benches/tests.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        Self::open_with(
            dir,
            StoreConfig::default(),
            Arc::new(RealIo::default()),
            Arc::new(StoreMetrics::default()),
        )
    }

    /// Open (creating the directory), load the manifest and replay the
    /// WAL. A corrupt WAL tail is truncated here; a corrupt manifest or
    /// segment index fails loudly — those are rename-swapped atomically
    /// and must never be torn.
    pub fn open_with(
        dir: impl AsRef<Path>,
        cfg: StoreConfig,
        io: Arc<dyn StoreIo>,
        metrics: Arc<StoreMetrics>,
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create store dir {dir:?}"))?;
        let manifest = segment::load_manifest(&io, &dir)?;
        let (wal, records) = wal::Wal::open(
            Arc::clone(&io),
            dir.join(wal::WAL_FILE),
            cfg.fsync,
            Arc::clone(&metrics),
        )?;
        metrics.segments_live.set(manifest.is_some() as u64);
        Ok(Self {
            dir,
            cfg,
            io,
            metrics,
            wal,
            inner: Mutex::new(Inner { manifest, records }),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    /// The current manifest, if a snapshot was ever published.
    pub fn manifest(&self) -> Option<Manifest> {
        self.inner.lock().unwrap().manifest.clone()
    }

    /// The replayed/committed WAL history (commit order).
    pub fn wal_records(&self) -> Vec<WalRecord> {
        self.inner.lock().unwrap().records.clone()
    }

    /// Commit one evolution-lane update to the WAL — **the durability
    /// point**: once this returns, the change survives any crash. Called
    /// by the evolution lane *before* it mutates the tree or publishes
    /// the epoch. Returns the record's sequence number.
    pub fn commit_update(
        &self,
        state: StateI,
        schema: SchemaId,
        v: VersionNo,
        op: WalOp,
        ts_us: u64,
    ) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let seq = self.wal.next_seq();
        let rec = WalRecord { seq, state, schema, v, ts_us, op };
        self.wal.commit(&rec)?;
        inner.records.push(rec);
        Ok(seq)
    }

    /// Should the caller build + persist a fresh snapshot segment now?
    /// True once `segment_update_threshold` WAL records accumulated past
    /// the manifest's cursor (cheap — no DUSB is built to answer this).
    pub fn snapshot_due(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        let cursor = inner.manifest.as_ref().map(|m| m.wal_seq).unwrap_or(0);
        let pending =
            inner.records.iter().filter(|r| r.seq > cursor).count() as u64;
        pending >= self.cfg.segment_update_threshold
    }

    /// Persist `dusb` as a new immutable segment and atomically swap the
    /// manifest to it; superseded segments are GCed afterwards. The tree
    /// is needed to record each schema's version set at snapshot time
    /// (the replay bound of [`DusbSet::decompact_bounded`]).
    pub fn save_dusb(&self, dusb: &DusbSet, tree: &SchemaTree) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.manifest.as_ref().map(|m| m.seq + 1).unwrap_or(1);
        let wal_seq = self.wal.next_seq() - 1;
        let manifest = segment::write_segment(
            &self.io,
            &self.dir,
            seq,
            dusb,
            tree,
            wal_seq,
            &self.metrics,
        )?;
        segment::gc(&self.io, &self.dir, &manifest, &self.metrics)?;
        inner.manifest = Some(manifest);
        Ok(())
    }

    /// Load the snapshot DUSB from the live segment, if any.
    pub fn load_dusb(&self) -> Result<Option<DusbSet>> {
        let Some(manifest) = self.manifest() else { return Ok(None) };
        let (dusb, _) = segment::read_full(&self.io, &self.dir, &manifest)?;
        Ok(Some(dusb))
    }

    /// The "Postgres view" of §6.2: recreate the in-memory DPM from the
    /// stored DUSB (snapshot only — no WAL replay; restart recovery goes
    /// through [`MatrixStore::recover`]). Returns None when nothing is
    /// stored yet.
    pub fn view_recreate_dpm(
        &self,
        tree: &SchemaTree,
        cdm: &CdmTree,
    ) -> Result<Option<DpmSet>> {
        let Some(manifest) = self.manifest() else { return Ok(None) };
        let (dusb, versions) =
            segment::read_full(&self.io, &self.dir, &manifest)?;
        let matrix = dusb.decompact_bounded(tree, cdm, &versions);
        let dpm = DpmSet::from_matrix(&matrix, tree, cdm, dusb.state)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(Some(dpm))
    }

    /// Full crash-point recovery: segment base + WAL tail replay (see
    /// [`recovery::recover`]). Mutates `land` to the recovered
    /// configuration and reports `recovery_ms` / `replayed_updates`.
    pub fn recover(
        &self,
        land: &mut Landscape,
    ) -> Result<Option<RecoveryOutcome>> {
        let t0 = Instant::now();
        let (manifest, records) = {
            let inner = self.inner.lock().unwrap();
            (inner.manifest.clone(), inner.records.clone())
        };
        let base = match manifest {
            None => None,
            Some(m) => {
                let (dusb, versions) =
                    segment::read_full(&self.io, &self.dir, &m)?;
                Some(SegmentBase { dusb, versions, wal_seq: m.wal_seq })
            }
        };
        let outcome = recovery::recover(land, base, &records)?;
        if let Some(out) = &outcome {
            self.metrics.replayed_updates.add(out.replayed as u64);
        }
        self.metrics.recovery_ms.set(t0.elapsed().as_millis() as u64);
        Ok(outcome)
    }

    /// Single-schema point recovery through the sparse index: reads one
    /// segment region instead of the whole store. `None` when no snapshot
    /// exists or the segment has no region for `schema`.
    pub fn recover_schema(
        &self,
        schema: SchemaId,
    ) -> Result<Option<PointRecovery>> {
        let Some(manifest) = self.manifest() else { return Ok(None) };
        let Some((region, bytes_read)) = segment::read_schema_region(
            &self.io,
            &self.dir,
            &manifest,
            schema,
        )?
        else {
            return Ok(None);
        };
        Ok(Some(PointRecovery {
            schema,
            bytes_read,
            store_bytes: self.total_bytes()?,
            versions: region.versions,
            groups: region.groups.len(),
        }))
    }

    /// Total bytes the store occupies on disk.
    pub fn total_bytes(&self) -> Result<u64> {
        let mut total = self.io.file_len(&self.dir.join(segment::MANIFEST_FILE))?
            + self.io.file_len(&self.dir.join(wal::WAL_FILE))?
            + self.io.file_len(&self.dir.join(AUDIT_FILE))?;
        for seg in segment::list_segments(&self.io, &self.dir)? {
            total += self.io.file_len(&seg)?;
        }
        Ok(total)
    }

    /// Append one line to the operator audit log through the store's
    /// buffered append handle (one open handle, not one open per line);
    /// [`MatrixStore::sync`] makes it durable.
    pub fn log_update(&self, line: &Json) -> Result<()> {
        let mut bytes = line.to_string().into_bytes();
        bytes.push(b'\n');
        self.io.append(&self.dir.join(AUDIT_FILE), &bytes)
    }

    /// Flush + fsync the buffered append files (audit log; the WAL syncs
    /// at every commit under `fsync = always`).
    pub fn sync(&self) -> Result<()> {
        self.io.sync(&self.dir.join(AUDIT_FILE))?;
        self.wal.sync()
    }

    /// Read back the audit log.
    pub fn read_log(&self) -> Result<Vec<Json>> {
        let Some(bytes) = self.io.read(&self.dir.join(AUDIT_FILE))? else {
            return Ok(Vec::new());
        };
        String::from_utf8_lossy(&bytes)
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                crate::util::json::parse(l).map_err(|e| anyhow::anyhow!("{e}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::util::tmp::TestDir;

    fn fig5_dusb(state: StateI) -> (SchemaTree, CdmTree, DusbSet) {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, state).unwrap();
        (t, c, dusb)
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = TestDir::new("store-roundtrip");
        let (t, c, dusb) = fig5_dusb(StateI(4));
        let store = MatrixStore::open(dir.path()).unwrap();
        store.save_dusb(&dusb, &t).unwrap();
        let back = store.load_dusb().unwrap().unwrap();
        assert_eq!(back.state, StateI(4));
        assert_eq!(back.n_elements(), dusb.n_elements());
        assert_eq!(back.decompact(&t, &c), fig5_matrix(&t, &c));
        // reopening sees the same snapshot (manifest + segment on disk)
        let store2 = MatrixStore::open(dir.path()).unwrap();
        assert_eq!(store2.manifest().unwrap(), store.manifest().unwrap());
    }

    #[test]
    fn view_recreates_dpm() {
        use crate::matrix::dpm::DpmSet;
        let dir = TestDir::new("store-view");
        let (t, c, dusb) = fig5_dusb(StateI(2));
        let direct =
            DpmSet::from_matrix(&fig5_matrix(&t, &c), &t, &c, StateI(2))
                .unwrap();
        let store = MatrixStore::open(dir.path()).unwrap();
        store.save_dusb(&dusb, &t).unwrap();
        let restored = store.view_recreate_dpm(&t, &c).unwrap().unwrap();
        assert!(direct.same_elements(&restored));
        assert_eq!(restored.state, StateI(2));
    }

    #[test]
    fn empty_store_returns_none() {
        let dir = TestDir::new("store-empty");
        let (t, c) = fig5_trees();
        let store = MatrixStore::open(dir.path()).unwrap();
        assert!(store.manifest().is_none());
        assert!(store.load_dusb().unwrap().is_none());
        assert!(store.view_recreate_dpm(&t, &c).unwrap().is_none());
        assert!(store.recover_schema(SchemaId(0)).unwrap().is_none());
        assert_eq!(store.total_bytes().unwrap(), 0);
    }

    #[test]
    fn update_log_appends() {
        let dir = TestDir::new("store-log");
        let store = MatrixStore::open(dir.path()).unwrap();
        let mut e1 = Json::obj();
        e1.set("state", Json::Num(1.0));
        e1.set("case", Json::Str("added-schema-version".into()));
        store.log_update(&e1).unwrap();
        let mut e2 = Json::obj();
        e2.set("state", Json::Num(2.0));
        store.log_update(&e2).unwrap();
        store.sync().unwrap();
        let log = store.read_log().unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].get("state").unwrap().as_u64(), Some(1));
        assert_eq!(
            log[0].get("case").unwrap().as_str(),
            Some("added-schema-version")
        );
    }

    #[test]
    fn commit_update_survives_reopen() {
        let dir = TestDir::new("store-commit");
        let store = MatrixStore::open(dir.path()).unwrap();
        let seq = store
            .commit_update(
                StateI(1),
                SchemaId(0),
                VersionNo(4),
                WalOp::InBand,
                42,
            )
            .unwrap();
        assert_eq!(seq, 1);
        assert_eq!(store.wal_records().len(), 1);
        drop(store);
        let store2 = MatrixStore::open(dir.path()).unwrap();
        let records = store2.wal_records();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].v, VersionNo(4));
        assert_eq!(records[0].op, WalOp::InBand);
    }

    #[test]
    fn snapshot_due_follows_threshold_and_cursor() {
        let dir = TestDir::new("store-due");
        let cfg = StoreConfig { segment_update_threshold: 2, ..Default::default() };
        let store = MatrixStore::open_with(
            dir.path(),
            cfg,
            Arc::new(RealIo::default()),
            Arc::new(StoreMetrics::default()),
        )
        .unwrap();
        let (t, _c, dusb) = fig5_dusb(StateI(0));
        assert!(!store.snapshot_due());
        for i in 1..=2 {
            store
                .commit_update(
                    StateI(i),
                    SchemaId(0),
                    VersionNo(4),
                    WalOp::InBand,
                    i,
                )
                .unwrap();
        }
        assert!(store.snapshot_due());
        store.save_dusb(&dusb, &t).unwrap();
        // the snapshot advanced the cursor past both records
        assert!(!store.snapshot_due());
        assert_eq!(store.manifest().unwrap().wal_seq, 2);
    }
}
