//! Postgres-sim persistence of the hybrid strategy (paper §6.2): the
//! strongly-compacted `ᵢ𝔇𝔘𝔖𝔅` is the stored representation; the
//! in-memory `ᵢ𝔇𝔓𝔐` is recreated through the decompaction "view"
//! (Alg 4 + Alg 2). An append-only update log stands in for the WAL and
//! lets operators audit the state-i history.
//!
//! Writers: every change accepted by the evolution lane
//! ([`crate::coordinator::evolution`]) saves the new DUSB and appends an
//! audit line. Readers: the restart path
//! (`Pipeline::restore_from_store`) recreates the DPM through
//! [`MatrixStore::view_recreate_dpm`] and publishes it as a fresh epoch
//! (with an unknown diff, so caches fully evict once).

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cdm::CdmTree;
use crate::matrix::decompact::recreate_dpm;
use crate::matrix::dpm::DpmSet;
use crate::matrix::dusb::DusbSet;
use crate::schema::SchemaTree;

/// Directory-backed matrix store.
pub struct MatrixStore {
    dir: PathBuf,
}

impl MatrixStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("create store dir {dir:?}"))?;
        Ok(Self { dir })
    }

    fn dusb_path(&self) -> PathBuf {
        self.dir.join("dusb.json")
    }

    fn log_path(&self) -> PathBuf {
        self.dir.join("update_log.jsonl")
    }

    /// Persist the current `ᵢ𝔇𝔘𝔖𝔅` (atomic replace via temp file).
    pub fn save_dusb(&self, dusb: &DusbSet) -> Result<()> {
        let tmp = self.dir.join("dusb.json.tmp");
        fs::write(&tmp, dusb.to_json().to_pretty())
            .with_context(|| format!("write {tmp:?}"))?;
        fs::rename(&tmp, self.dusb_path()).context("atomic replace")?;
        Ok(())
    }

    /// Load the stored `ᵢ𝔇𝔘𝔖𝔅`, if any.
    pub fn load_dusb(&self) -> Result<Option<DusbSet>> {
        let path = self.dusb_path();
        if !path.exists() {
            return Ok(None);
        }
        let text = fs::read_to_string(&path)
            .with_context(|| format!("read {path:?}"))?;
        let json = crate::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(Some(DusbSet::from_json(&json)?))
    }

    /// The "Postgres view" of §6.2: recreate the in-memory DPM from the
    /// stored DUSB. Returns None when nothing is stored yet.
    pub fn view_recreate_dpm(
        &self,
        tree: &SchemaTree,
        cdm: &CdmTree,
    ) -> Result<Option<DpmSet>> {
        match self.load_dusb()? {
            None => Ok(None),
            Some(dusb) => {
                let dpm = recreate_dpm(&dusb, tree, cdm)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                Ok(Some(dpm))
            }
        }
    }

    /// Append one line to the update log (WAL-style audit trail).
    pub fn log_update(&self, line: &crate::util::json::Json) -> Result<()> {
        use std::io::Write;
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.log_path())?;
        writeln!(f, "{}", line.to_string())?;
        Ok(())
    }

    /// Read back the update log.
    pub fn read_log(&self) -> Result<Vec<crate::util::json::Json>> {
        let path = self.log_path();
        if !path.exists() {
            return Ok(Vec::new());
        }
        fs::read_to_string(&path)?
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                crate::util::json::parse(l).map_err(|e| anyhow::anyhow!("{e}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::message::StateI;
    use crate::util::json::Json;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("metl-store-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_roundtrip() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(4)).unwrap();
        let store = MatrixStore::open(tmpdir("roundtrip")).unwrap();
        store.save_dusb(&dusb).unwrap();
        let back = store.load_dusb().unwrap().unwrap();
        assert_eq!(back.state, StateI(4));
        assert_eq!(back.n_elements(), dusb.n_elements());
        assert_eq!(back.decompact(&t, &c), m);
    }

    #[test]
    fn view_recreates_dpm() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let direct = DpmSet::from_matrix(&m, &t, &c, StateI(2)).unwrap();
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(2)).unwrap();
        let store = MatrixStore::open(tmpdir("view")).unwrap();
        store.save_dusb(&dusb).unwrap();
        let restored = store.view_recreate_dpm(&t, &c).unwrap().unwrap();
        assert!(direct.same_elements(&restored));
        assert_eq!(restored.state, StateI(2));
    }

    #[test]
    fn empty_store_returns_none() {
        let (t, c) = fig5_trees();
        let store = MatrixStore::open(tmpdir("empty")).unwrap();
        assert!(store.load_dusb().unwrap().is_none());
        assert!(store.view_recreate_dpm(&t, &c).unwrap().is_none());
    }

    #[test]
    fn update_log_appends() {
        let store = MatrixStore::open(tmpdir("log")).unwrap();
        let mut e1 = Json::obj();
        e1.set("state", Json::Num(1.0));
        e1.set("case", Json::Str("added-schema-version".into()));
        store.log_update(&e1).unwrap();
        let mut e2 = Json::obj();
        e2.set("state", Json::Num(2.0));
        store.log_update(&e2).unwrap();
        let log = store.read_log().unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].get("state").unwrap().as_u64(), Some(1));
        assert_eq!(
            log[0].get("case").unwrap().as_str(),
            Some("added-schema-version")
        );
    }
}
