//! Sparse per-schema index over a snapshot segment.
//!
//! A segment file is a concatenation of independent per-schema JSON
//! regions (see `super::segment`). The index records one `(schema,
//! offset, len)` entry per region so single-schema point recovery can
//! `read_range` exactly one region instead of the whole file — the
//! "<10% of store bytes" acceptance bound rides on this.

use anyhow::{anyhow, Result};

use crate::schema::SchemaId;
use crate::util::json::Json;

/// One region of a segment file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexEntry {
    pub schema: SchemaId,
    pub offset: u64,
    pub len: u64,
}

/// The per-segment sparse index, persisted inside the manifest.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SparseIndex {
    entries: Vec<IndexEntry>,
}

impl SparseIndex {
    pub fn new(mut entries: Vec<IndexEntry>) -> SparseIndex {
        entries.sort_by_key(|e| e.offset);
        SparseIndex { entries }
    }

    pub fn entries(&self) -> &[IndexEntry] {
        &self.entries
    }

    /// The region holding `schema`, if the segment has one.
    pub fn lookup(&self, schema: SchemaId) -> Option<IndexEntry> {
        self.entries.iter().copied().find(|e| e.schema == schema)
    }

    /// Total bytes across all regions (== segment file size).
    pub fn total_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.len).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    let mut j = Json::obj();
                    j.set("schema", Json::Num(e.schema.0 as f64));
                    j.set("offset", Json::Num(e.offset as f64));
                    j.set("len", Json::Num(e.len as f64));
                    j
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<SparseIndex> {
        let arr = j.as_arr().ok_or_else(|| anyhow!("index is not an array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            let num = |k: &str| {
                e.get(k)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("index entry missing {k}"))
            };
            entries.push(IndexEntry {
                schema: SchemaId(num("schema")? as u32),
                offset: num("offset")?,
                len: num("len")?,
            });
        }
        Ok(SparseIndex::new(entries))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_and_totals() {
        let idx = SparseIndex::new(vec![
            IndexEntry { schema: SchemaId(2), offset: 40, len: 60 },
            IndexEntry { schema: SchemaId(1), offset: 0, len: 40 },
        ]);
        assert_eq!(idx.entries()[0].schema, SchemaId(1));
        assert_eq!(idx.lookup(SchemaId(2)).unwrap().offset, 40);
        assert!(idx.lookup(SchemaId(9)).is_none());
        assert_eq!(idx.total_bytes(), 100);
    }

    #[test]
    fn json_roundtrip() {
        let idx = SparseIndex::new(vec![
            IndexEntry { schema: SchemaId(1), offset: 0, len: 40 },
            IndexEntry { schema: SchemaId(2), offset: 40, len: 61 },
        ]);
        let j = crate::util::json::parse(&idx.to_json().to_string()).unwrap();
        assert_eq!(SparseIndex::from_json(&j).unwrap(), idx);
    }
}
