//! Crash-point recovery: segment snapshot + WAL tail → one fresh epoch.
//!
//! The sequence (see `MatrixStore::recover` for the orchestration):
//!
//! 1. **Registry replay** — every WAL record replays *idempotently*
//!    against the schema tree: a version the tree already holds is
//!    skipped (the in-process restore case), an absent one is registered
//!    exactly as the live lane did (the cold-restart case — the tree's
//!    deterministic `add_version` must reassign the recorded version
//!    number, which is asserted). Adds also migrate the bound source
//!    tables, drops retire the tree node.
//! 2. **Base DPM** — the segment's DUSB is decompacted **bounded to the
//!    version sets recorded at snapshot time** (see
//!    [`DusbSet::decompact_bounded`]) so trailing PM runs never bleed
//!    into WAL-era versions, then compacted to the DPM at the segment's
//!    state.
//! 3. **Alg-5 tail replay** — records with `seq > manifest.wal_seq` run
//!    through [`prepare_update`] in commit order, rebuilding exactly the
//!    column diffs the live lane produced. A record whose column is
//!    already non-empty in the base is skipped (idempotency for the
//!    in-process restore, where the live matrix already carried it into
//!    the snapshot).
//! 4. The final DPM's decompaction becomes the landscape's ground-truth
//!    matrix, and the affected-column list from step 3 drives targeted
//!    cache eviction in the caller — unaffected columns stay warm across
//!    a restore.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::wal::{WalOp, WalRecord};
use crate::matrix::dpm::DpmSet;
use crate::matrix::dusb::DusbSet;
use crate::matrix::update::{prepare_update, ChangeCase, UpdateReport};
use crate::message::StateI;
use crate::schema::{SchemaId, VersionNo};
use crate::workload::Landscape;

/// The segment side of a recovery: the snapshot DUSB, the per-schema
/// version sets recorded when it was written, and the WAL cursor it
/// covers.
pub struct SegmentBase {
    pub dusb: DusbSet,
    pub versions: HashMap<SchemaId, Vec<VersionNo>>,
    pub wal_seq: u64,
}

/// What a recovery produced.
pub struct RecoveryOutcome {
    /// The rebuilt `ᵢ𝔇𝔓𝔐`, ready to publish as one fresh epoch.
    pub dpm: DpmSet,
    /// The state the store had committed (== `dpm.state`).
    pub state: StateI,
    /// Mapping columns touched by the WAL tail — the targeted-eviction
    /// list for `DcpmCache::advance`.
    pub affected: Vec<(SchemaId, VersionNo)>,
    /// WAL records replayed through Alg 5 (past the segment cursor).
    pub replayed: usize,
    /// Alg-5 reports of the replayed records, in commit order.
    pub reports: Vec<UpdateReport>,
}

/// Rebuild the DMM from a segment base + the full WAL history, mutating
/// `land` (tree, tables, ground-truth matrix) to the recovered
/// configuration. `Ok(None)` means the store holds nothing to recover.
pub fn recover(
    land: &mut Landscape,
    base: Option<SegmentBase>,
    records: &[WalRecord],
) -> Result<Option<RecoveryOutcome>> {
    if base.is_none() && records.is_empty() {
        return Ok(None);
    }

    // 1. registry replay (idempotent, full history)
    for rec in records {
        match &rec.op {
            WalOp::Add { fields } => {
                if land.tree.version(rec.schema, rec.v).is_some() {
                    continue; // in-process restore: already registered
                }
                let assigned = land.tree.add_version(rec.schema, fields);
                if assigned != rec.v {
                    bail!(
                        "wal replay diverged: record {} registered v{} as v{}",
                        rec.seq,
                        rec.v.0,
                        assigned.0
                    );
                }
                let Landscape { tree, dbs, .. } = &mut *land;
                for db in dbs.iter_mut() {
                    for t in 0..db.tables.len() {
                        if db.tables[t].schema == rec.schema {
                            db.migrate_table(tree, t, rec.v);
                        }
                    }
                }
            }
            WalOp::Drop => {
                if land.tree.version(rec.schema, rec.v).is_some() {
                    land.tree.delete_version(rec.schema, rec.v);
                }
            }
            // in-band patches touch only the DMM; the version was already
            // registered when the record was committed
            WalOp::InBand => {}
        }
    }

    // 2. base DPM at the segment's state (or the pre-change landscape
    // matrix when no snapshot was ever written)
    let (mut dpm, wal_seq) = match &base {
        Some(seg) => {
            let matrix =
                seg.dusb.decompact_bounded(&land.tree, &land.cdm, &seg.versions);
            let dpm = DpmSet::from_matrix(
                &matrix,
                &land.tree,
                &land.cdm,
                seg.dusb.state,
            )
            .map_err(|e| anyhow::anyhow!("segment DUSB violates 1:1: {e}"))?;
            (dpm, seg.wal_seq)
        }
        None => {
            let mut matrix = land.matrix.clone();
            matrix.grow(land.cdm.n_attr_ids(), land.tree.n_attr_ids());
            let dpm = DpmSet::from_matrix(
                &matrix,
                &land.tree,
                &land.cdm,
                StateI(0),
            )
            .map_err(|e| anyhow::anyhow!("landscape matrix violates 1:1: {e}"))?;
            (dpm, 0)
        }
    };

    // 3. Alg-5 replay of the WAL tail
    let mut affected = Vec::new();
    let mut reports = Vec::new();
    let mut replayed = 0usize;
    for rec in records.iter().filter(|r| r.seq > wal_seq) {
        let case = match &rec.op {
            WalOp::Add { .. } | WalOp::InBand => {
                if !dpm.column(rec.schema, rec.v).is_empty() {
                    continue; // column already present in the base
                }
                ChangeCase::AddedSchemaVersion { schema: rec.schema, v: rec.v }
            }
            WalOp::Drop => {
                ChangeCase::DeletedSchemaVersion { schema: rec.schema, v: rec.v }
            }
        };
        let (next, report) =
            prepare_update(&dpm, &land.tree, &land.cdm, case, rec.state);
        dpm = next;
        reports.push(report);
        replayed += 1;
        if !affected.contains(&(rec.schema, rec.v)) {
            affected.push((rec.schema, rec.v));
        }
    }

    // 4. the recovered DPM is the new ground truth
    let state = records.last().map(|r| r.state).unwrap_or(dpm.state);
    dpm.state = state;
    land.matrix =
        dpm.decompact(land.cdm.n_attr_ids(), land.tree.n_attr_ids());

    Ok(Some(RecoveryOutcome { dpm, state, affected, replayed, reports }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::schema::ExtractType;
    use crate::workload;

    fn land() -> Landscape {
        workload::generate(&PipelineConfig::small())
    }

    fn add_record(
        seq: u64,
        land: &Landscape,
        service: usize,
    ) -> (WalRecord, Vec<(String, ExtractType, bool)>) {
        let schema = land.dbs[service].tables[0].schema;
        let mut fields = {
            let latest = land.tree.latest_version(schema).unwrap();
            land.tree.field_list(schema, latest).unwrap()
        };
        fields.push((format!("evolved_{seq}"), ExtractType::Varchar, true));
        let v = VersionNo(land.tree.latest_version(schema).unwrap().0 + 1);
        (
            WalRecord {
                seq,
                state: StateI(seq),
                schema,
                v,
                ts_us: seq * 1_000,
                op: WalOp::Add { fields: fields.clone() },
            },
            fields,
        )
    }

    #[test]
    fn empty_store_recovers_nothing() {
        let mut l = land();
        assert!(recover(&mut l, None, &[]).unwrap().is_none());
    }

    #[test]
    fn cold_replay_registers_versions_and_rebuilds_columns() {
        let mut l = land();
        let (rec, fields) = add_record(1, &l, 0);
        let out = recover(&mut l, None, &[rec.clone()]).unwrap().unwrap();
        assert_eq!(out.state, StateI(1));
        assert_eq!(out.replayed, 1);
        assert_eq!(out.affected, vec![(rec.schema, rec.v)]);
        // the version registered with the recorded field list...
        assert_eq!(l.tree.field_list(rec.schema, rec.v).unwrap(), fields);
        // ...the bound table migrated to it...
        assert_eq!(l.dbs[0].tables[0].live_version, rec.v);
        // ...and the DMM carries the copied column
        assert!(!out.dpm.column(rec.schema, rec.v).is_empty());
        // ground-truth matrix was rewritten to match
        assert_eq!(
            l.matrix,
            out.dpm.decompact(l.cdm.n_attr_ids(), l.tree.n_attr_ids())
        );
    }

    #[test]
    fn replay_is_idempotent_when_tree_already_evolved() {
        // in-process restore: the tree already has the version
        let mut l = land();
        let (rec, fields) = add_record(1, &l, 0);
        let v = l.tree.add_version(rec.schema, &fields);
        assert_eq!(v, rec.v);
        let n_attrs = l.tree.n_attr_ids();
        let out = recover(&mut l, None, &[rec.clone()]).unwrap().unwrap();
        // no duplicate registration
        assert_eq!(l.tree.n_attr_ids(), n_attrs);
        assert!(!out.dpm.column(rec.schema, rec.v).is_empty());
    }

    #[test]
    fn diverged_wal_fails_loudly() {
        let mut l = land();
        let (mut rec, _) = add_record(1, &l, 0);
        rec.v = VersionNo(rec.v.0 + 7); // recorded version can't be assigned
        let err = recover(&mut l, None, &[rec]).unwrap_err();
        assert!(err.to_string().contains("diverged"));
    }

    #[test]
    fn drop_record_retires_version_and_column() {
        let mut l = land();
        let schema = l.dbs[0].tables[0].schema;
        let drop = WalRecord {
            seq: 1,
            state: StateI(1),
            schema,
            v: VersionNo(1),
            ts_us: 1,
            op: WalOp::Drop,
        };
        let out = recover(&mut l, None, &[drop]).unwrap().unwrap();
        assert!(l.tree.version(schema, VersionNo(1)).is_none());
        assert!(out.dpm.column(schema, VersionNo(1)).is_empty());
        assert_eq!(out.state, StateI(1));
    }
}
