//! The store's filesystem seam: every byte the durable store writes goes
//! through a [`StoreIo`], so crash-point fault injection is a constructor
//! argument instead of a test-only build.
//!
//! [`RealIo`] is the production implementation. It keeps **one buffered
//! append handle per path** (the fix for `log_update` reopening its file
//! on every append) and exposes an explicit [`StoreIo::sync`] that
//! flushes the buffer and fsyncs — the WAL's commit point.
//!
//! [`FaultIo`] wraps `RealIo` and kills the "process" at the Nth mutating
//! operation: [`FaultMode::Power`] fails before the op touches disk,
//! [`FaultMode::Torn`] persists a prefix of the bytes first (a torn
//! write). After the injected crash every further mutation fails, exactly
//! like a dead process — tests then reopen the directory with a fresh
//! `RealIo` and assert recovery invariants.

use std::collections::HashMap;
use std::fmt::Debug;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Mutating + reading filesystem operations of the matrix store.
///
/// Mutations (`append`, `sync`, `write_file`, `rename`, `remove_file`,
/// `truncate`) are the crash points swept by the fault-injection harness;
/// reads are never faulted (a dead process does not read).
pub trait StoreIo: Send + Sync + Debug {
    /// Append bytes through the (kept-open, buffered) handle for `path`.
    fn append(&self, path: &Path, bytes: &[u8]) -> Result<()>;
    /// Flush the append buffer for `path` and fsync the file.
    fn sync(&self, path: &Path) -> Result<()>;
    /// Write a whole file (create/truncate), fsynced before returning.
    fn write_file(&self, path: &Path, bytes: &[u8]) -> Result<()>;
    /// Atomic rename (the manifest/segment publish step).
    fn rename(&self, from: &Path, to: &Path) -> Result<()>;
    fn remove_file(&self, path: &Path) -> Result<()>;
    /// Truncate `path` to `len` bytes (WAL corrupt-tail repair).
    fn truncate(&self, path: &Path, len: u64) -> Result<()>;

    /// Whole-file read; `None` when the file does not exist.
    fn read(&self, path: &Path) -> Result<Option<Vec<u8>>>;
    /// Read exactly `len` bytes at `off` (sparse-index region read).
    fn read_range(&self, path: &Path, off: u64, len: usize) -> Result<Vec<u8>>;
    /// Current file length; 0 when the file does not exist.
    fn file_len(&self, path: &Path) -> Result<u64>;
    /// Files (not directories) directly under `dir`.
    fn list(&self, dir: &Path) -> Result<Vec<PathBuf>>;
}

/// Production IO: buffered per-path append handles + plain std::fs.
#[derive(Debug, Default)]
pub struct RealIo {
    handles: Mutex<HashMap<PathBuf, BufWriter<File>>>,
}

impl RealIo {
    /// Flush (not fsync) the append buffer for `path` so reads observe
    /// appended bytes; drop the handle entirely when `close` is set
    /// (before rename/remove/truncate).
    fn settle(&self, path: &Path, close: bool) -> Result<()> {
        let mut handles = self.handles.lock().unwrap();
        if close {
            if let Some(mut w) = handles.remove(path) {
                w.flush().with_context(|| format!("flush {path:?}"))?;
            }
        } else if let Some(w) = handles.get_mut(path) {
            w.flush().with_context(|| format!("flush {path:?}"))?;
        }
        Ok(())
    }
}

impl StoreIo for RealIo {
    fn append(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        let mut handles = self.handles.lock().unwrap();
        if !handles.contains_key(path) {
            let file = OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .with_context(|| format!("open append {path:?}"))?;
            handles.insert(path.to_path_buf(), BufWriter::new(file));
        }
        let w = handles.get_mut(path).expect("just inserted");
        w.write_all(bytes).with_context(|| format!("append {path:?}"))
    }

    fn sync(&self, path: &Path) -> Result<()> {
        let mut handles = self.handles.lock().unwrap();
        if let Some(w) = handles.get_mut(path) {
            w.flush().with_context(|| format!("flush {path:?}"))?;
            w.get_ref()
                .sync_data()
                .with_context(|| format!("fsync {path:?}"))?;
        }
        Ok(())
    }

    fn write_file(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        self.settle(path, true)?;
        let mut f =
            File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(bytes).with_context(|| format!("write {path:?}"))?;
        f.sync_data().with_context(|| format!("fsync {path:?}"))
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        self.settle(from, true)?;
        self.settle(to, true)?;
        fs::rename(from, to).with_context(|| format!("rename {from:?} -> {to:?}"))
    }

    fn remove_file(&self, path: &Path) -> Result<()> {
        self.settle(path, true)?;
        fs::remove_file(path).with_context(|| format!("remove {path:?}"))
    }

    fn truncate(&self, path: &Path, len: u64) -> Result<()> {
        self.settle(path, true)?;
        let f = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("open truncate {path:?}"))?;
        f.set_len(len).with_context(|| format!("truncate {path:?}"))?;
        f.sync_data().with_context(|| format!("fsync {path:?}"))
    }

    fn read(&self, path: &Path) -> Result<Option<Vec<u8>>> {
        self.settle(path, false)?;
        match fs::read(path) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e).with_context(|| format!("read {path:?}")),
        }
    }

    fn read_range(&self, path: &Path, off: u64, len: usize) -> Result<Vec<u8>> {
        self.settle(path, false)?;
        let mut f =
            File::open(path).with_context(|| format!("open {path:?}"))?;
        f.seek(SeekFrom::Start(off))
            .with_context(|| format!("seek {path:?}@{off}"))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)
            .with_context(|| format!("read {len}B at {path:?}@{off}"))?;
        Ok(buf)
    }

    fn file_len(&self, path: &Path) -> Result<u64> {
        self.settle(path, false)?;
        match fs::metadata(path) {
            Ok(m) => Ok(m.len()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(0),
            Err(e) => Err(e).with_context(|| format!("stat {path:?}")),
        }
    }

    fn list(&self, dir: &Path) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for entry in
            fs::read_dir(dir).with_context(|| format!("read dir {dir:?}"))?
        {
            let entry = entry?;
            if entry.file_type()?.is_file() {
                out.push(entry.path());
            }
        }
        out.sort();
        Ok(out)
    }
}

/// How the injected crash interacts with the bytes of the crash op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// The op fails before touching disk (power cut between ops).
    Power,
    /// Half the op's bytes are persisted first (a torn write mid-op).
    Torn,
}

/// Fault-injecting wrapper: mutating op number `fail_at` (1-based) crashes
/// the store; everything after fails like a dead process.
#[derive(Debug)]
pub struct FaultIo {
    inner: RealIo,
    fail_at: u64,
    mode: FaultMode,
    ops: AtomicU64,
    crashed: AtomicBool,
}

impl FaultIo {
    pub fn new(fail_at: u64, mode: FaultMode) -> Self {
        Self {
            inner: RealIo::default(),
            fail_at,
            mode,
            ops: AtomicU64::new(0),
            crashed: AtomicBool::new(false),
        }
    }

    /// Counting mode: never crashes; [`FaultIo::ops_attempted`] after a
    /// full run gives the sweep's upper bound.
    pub fn counting() -> Self {
        Self::new(u64::MAX, FaultMode::Power)
    }

    /// Mutating ops attempted so far (including the crash op).
    pub fn ops_attempted(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn did_crash(&self) -> bool {
        self.crashed.load(Ordering::Relaxed)
    }

    /// Returns `true` when this op is the injected crash op.
    fn gate(&self) -> Result<bool> {
        if self.crashed.load(Ordering::Relaxed) {
            bail!("store io: process killed by fault injection");
        }
        let n = self.ops.fetch_add(1, Ordering::Relaxed) + 1;
        if n == self.fail_at {
            self.crashed.store(true, Ordering::Relaxed);
            return Ok(true);
        }
        Ok(false)
    }

    fn crash(&self, what: &str) -> anyhow::Error {
        anyhow::anyhow!("store io: injected crash during {what}")
    }
}

impl StoreIo for FaultIo {
    fn append(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        if self.gate()? {
            if self.mode == FaultMode::Torn && !bytes.is_empty() {
                // a torn append: a prefix reaches the file, the tail not.
                // flushing makes the prefix durable-visible like a page
                // that hit disk before the cut
                let half = &bytes[..bytes.len() / 2];
                let _ = self.inner.append(path, half);
                let _ = self.inner.sync(path);
            }
            return Err(self.crash("append"));
        }
        self.inner.append(path, bytes)
    }

    fn sync(&self, path: &Path) -> Result<()> {
        if self.gate()? {
            return Err(self.crash("fsync"));
        }
        self.inner.sync(path)
    }

    fn write_file(&self, path: &Path, bytes: &[u8]) -> Result<()> {
        if self.gate()? {
            if self.mode == FaultMode::Torn && !bytes.is_empty() {
                let _ = self.inner.write_file(path, &bytes[..bytes.len() / 2]);
            }
            return Err(self.crash("write_file"));
        }
        self.inner.write_file(path, bytes)
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        // rename is atomic: either it happened (crash after) or it did
        // not (crash before) — Torn degrades to Power here
        if self.gate()? {
            return Err(self.crash("rename"));
        }
        self.inner.rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> Result<()> {
        if self.gate()? {
            return Err(self.crash("remove_file"));
        }
        self.inner.remove_file(path)
    }

    fn truncate(&self, path: &Path, len: u64) -> Result<()> {
        if self.gate()? {
            return Err(self.crash("truncate"));
        }
        self.inner.truncate(path, len)
    }

    fn read(&self, path: &Path) -> Result<Option<Vec<u8>>> {
        self.inner.read(path)
    }

    fn read_range(&self, path: &Path, off: u64, len: usize) -> Result<Vec<u8>> {
        self.inner.read_range(path, off, len)
    }

    fn file_len(&self, path: &Path) -> Result<u64> {
        self.inner.file_len(path)
    }

    fn list(&self, dir: &Path) -> Result<Vec<PathBuf>> {
        self.inner.list(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TestDir;

    #[test]
    fn real_io_appends_through_one_handle() {
        let dir = TestDir::new("io-append");
        let io = RealIo::default();
        let path = dir.join("log");
        io.append(&path, b"one").unwrap();
        io.append(&path, b"two").unwrap();
        // reads flush the buffered handle first
        assert_eq!(io.read(&path).unwrap().unwrap(), b"onetwo");
        io.sync(&path).unwrap();
        assert_eq!(io.file_len(&path).unwrap(), 6);
        io.truncate(&path, 3).unwrap();
        assert_eq!(io.read(&path).unwrap().unwrap(), b"one");
        // the handle was dropped by truncate; appends reopen in append mode
        io.append(&path, b"!").unwrap();
        assert_eq!(io.read(&path).unwrap().unwrap(), b"one!");
    }

    #[test]
    fn fault_io_kills_at_nth_op_and_stays_dead() {
        let dir = TestDir::new("io-fault");
        let io = FaultIo::new(2, FaultMode::Power);
        let path = dir.join("f");
        io.append(&path, b"ok").unwrap();
        assert!(io.sync(&path).is_err()); // op 2: the crash
        assert!(io.did_crash());
        assert!(io.append(&path, b"no").is_err()); // dead process
        assert_eq!(io.ops_attempted(), 2);
    }

    #[test]
    fn torn_write_persists_a_prefix() {
        let dir = TestDir::new("io-torn");
        let io = FaultIo::new(1, FaultMode::Torn);
        let path = dir.join("f");
        assert!(io.write_file(&path, b"abcdef").is_err());
        let real = RealIo::default();
        assert_eq!(real.read(&path).unwrap().unwrap(), b"abc");
    }
}
