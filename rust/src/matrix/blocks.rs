//! Block partitioning of `ᵢM` and largest-permutation-matrix extraction
//! (paper §4.4 naming scheme, §5.3.1 step 2).
//!
//! A mapping block `MB` is the rectangle of one versioned extracting
//! schema × one versioned business entity. Sizing a block down to its
//! **largest permutation matrix** `PM` means discarding all-zero rows and
//! columns; under the paper's 1:1-mapping constraint (§4.5) the remaining
//! 1-elements *are* a permutation matrix. For unconstrained input (CSV
//! imports) we fall back to a greedy maximum matching and report the
//! dropped elements.

use std::ops::Range;

use super::{BlockKey, MappingMatrix};
use crate::cdm::CdmTree;
use crate::schema::SchemaTree;

/// The rectangle of a block within `ᵢM` (global row/col index ranges).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockExtent {
    pub rows: Range<usize>,
    pub cols: Range<usize>,
}

impl BlockExtent {
    pub fn area(&self) -> u64 {
        self.rows.len() as u64 * self.cols.len() as u64
    }
}

/// Resolve a block's rectangle from the two trees; `None` if either
/// versioned schema no longer exists.
pub fn block_extent(
    tree: &SchemaTree,
    cdm: &CdmTree,
    key: BlockKey,
) -> Option<BlockExtent> {
    let sv = tree.version(key.schema, key.v)?;
    let cv = cdm.version(key.entity, key.w)?;
    Some(BlockExtent {
        rows: cv.row_start()..cv.row_start() + cv.height(),
        cols: sv.col_start()..sv.col_start() + sv.width(),
    })
}

/// Enumerate every block key (live versions only) — the partition of `ᵢM`
/// into `ᵢ𝔐𝔅` (Alg 2 step 3 / baseline Alg 1).
pub fn all_block_keys(tree: &SchemaTree, cdm: &CdmTree) -> Vec<BlockKey> {
    let mut keys = Vec::new();
    for s in tree.schemas() {
        for &v in &s.versions {
            for e in cdm.entities() {
                for &w in &e.versions {
                    keys.push(BlockKey::new(s.id, v, e.id, w));
                }
            }
        }
    }
    keys
}

/// Is the block all-zero (`NB` at block granularity)?
pub fn is_null_block(m: &MappingMatrix, ext: &BlockExtent) -> bool {
    m.ones_in(ext.rows.clone(), ext.cols.clone()).is_empty()
}

/// Violation of the 1:1 mapping constraint (§4.5: "we restrain the blocks
/// to 1:1 attribute mappings").
#[derive(Debug, PartialEq)]
pub struct ConstraintViolation {
    pub kind: &'static str,
    pub index: usize,
    pub degree: usize,
}

impl std::fmt::Display for ConstraintViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "block violates 1:1 mapping: {} {} has {} ones",
            self.kind, self.index, self.degree
        )
    }
}

impl std::error::Error for ConstraintViolation {}

/// Extract the largest permutation matrix of a block as global (q, p)
/// element pairs. Errors if the block is not a valid 1:1 mapping.
pub fn largest_permutation(
    m: &MappingMatrix,
    ext: &BlockExtent,
) -> Result<Vec<(usize, usize)>, ConstraintViolation> {
    let ones = m.ones_in(ext.rows.clone(), ext.cols.clone());
    validate_one_to_one(&ones)?;
    Ok(ones)
}

fn validate_one_to_one(
    ones: &[(usize, usize)],
) -> Result<(), ConstraintViolation> {
    // ones are row-major sorted; row duplicates are adjacent.
    for pair in ones.windows(2) {
        if pair[0].0 == pair[1].0 {
            return Err(ConstraintViolation {
                kind: "row",
                index: pair[0].0,
                degree: 2,
            });
        }
    }
    let mut cols: Vec<usize> = ones.iter().map(|&(_, p)| p).collect();
    cols.sort_unstable();
    for pair in cols.windows(2) {
        if pair[0] == pair[1] {
            return Err(ConstraintViolation {
                kind: "column",
                index: pair[0],
                degree: 2,
            });
        }
    }
    Ok(())
}

/// Greedy maximal-matching fallback for unconstrained blocks (CSV import
/// path): keeps the first 1 per row whose column is still free. Returns
/// (kept, dropped_count).
pub fn largest_permutation_greedy(
    m: &MappingMatrix,
    ext: &BlockExtent,
) -> (Vec<(usize, usize)>, usize) {
    let ones = m.ones_in(ext.rows.clone(), ext.cols.clone());
    let mut used_rows = std::collections::HashSet::new();
    let mut used_cols = std::collections::HashSet::new();
    let mut kept = Vec::new();
    for (q, p) in &ones {
        if used_rows.contains(q) || used_cols.contains(p) {
            continue;
        }
        used_rows.insert(*q);
        used_cols.insert(*p);
        kept.push((*q, *p));
    }
    let dropped = ones.len() - kept.len();
    (kept, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};

    #[test]
    fn extents_are_contiguous_rectangles() {
        let (t, c) = fig5_trees();
        let s1 = t.schema_by_name("s1").unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        let key = BlockKey::new(
            s1,
            crate::schema::VersionNo(1),
            be1,
            crate::cdm::CdmVersionNo(2),
        );
        let ext = block_extent(&t, &c, key).unwrap();
        assert_eq!(ext.rows.len(), 2);
        assert_eq!(ext.cols.len(), 3);
        assert_eq!(ext.area(), 6);
    }

    #[test]
    fn all_block_keys_cover_live_versions() {
        let (t, c) = fig5_trees();
        // schemas: s1 (2 versions) + s2 (1) = 3 columns of blocks;
        // entities: be1 (2 versions) + be2 (1) + be3 (1) = 4 rows of blocks.
        assert_eq!(all_block_keys(&t, &c).len(), 3 * 4);
    }

    #[test]
    fn fig5_matrix_has_7_ones_over_30_live_elements() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        assert_eq!(m.count_ones(), 7);
        // live elements: cols of live versions (3+2+1=6) × rows of
        // be1.v2 + be2.v1 + be3.v1 (2+1+2=5) = 30 (the fig-5 "30 elements")
        let live_rows = 5;
        let live_cols = 6;
        assert_eq!(live_rows * live_cols, 30);
    }

    #[test]
    fn largest_permutation_extracts_ones() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let s1 = t.schema_by_name("s1").unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        let key = BlockKey::new(s1, crate::schema::VersionNo(1), be1, crate::cdm::CdmVersionNo(2));
        let ext = block_extent(&t, &c, key).unwrap();
        let pm = largest_permutation(&m, &ext).unwrap();
        assert_eq!(pm.len(), 2); // (c3,a1), (c4,a3)
    }

    #[test]
    fn null_block_detection() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let s2 = t.schema_by_name("s2").unwrap();
        let be3 = c.entity_by_name("be3").unwrap();
        let key = BlockKey::new(s2, crate::schema::VersionNo(1), be3, crate::cdm::CdmVersionNo(1));
        let ext = block_extent(&t, &c, key).unwrap();
        assert!(is_null_block(&m, &ext));
    }

    #[test]
    fn one_to_one_violations_detected() {
        let mut m = MappingMatrix::new(3, 3);
        m.set(0, 0, true);
        m.set(0, 1, true); // row degree 2
        let ext = BlockExtent { rows: 0..3, cols: 0..3 };
        let err = largest_permutation(&m, &ext).unwrap_err();
        assert_eq!(err.kind, "row");
        let mut m = MappingMatrix::new(3, 3);
        m.set(0, 1, true);
        m.set(2, 1, true); // col degree 2
        let err = largest_permutation(&m, &ext).unwrap_err();
        assert_eq!(err.kind, "column");
    }

    #[test]
    fn greedy_fallback_drops_conflicts() {
        let mut m = MappingMatrix::new(3, 3);
        m.set(0, 0, true);
        m.set(0, 1, true);
        m.set(1, 1, true);
        m.set(2, 2, true);
        let ext = BlockExtent { rows: 0..3, cols: 0..3 };
        let (kept, dropped) = largest_permutation_greedy(&m, &ext);
        assert_eq!(kept.len(), 3); // (0,0), (1,1), (2,2)
        assert_eq!(dropped, 1);
        validate_one_to_one(&kept).unwrap();
    }
}
