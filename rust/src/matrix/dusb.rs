//! `ᵢ𝔇𝔘𝔖𝔅` — the aggressive compaction strategy (paper §5.3.2,
//! Algorithm 3): version-super-blocks are swept in ascending version
//! order, consecutive equivalent square blocks are deduplicated, and only
//! *unique* square blocks survive — plus **special null blocks** that mark
//! where a permutation pattern ends (fig 5's single green 0). Null blocks
//! that would start a sequence ("non-saved special null blocks", red in
//! fig 5) are omitted entirely.
//!
//! Cross-version equivalence of square blocks is decided under the
//! attribute-equivalence relation `≡`: an element (q, p) is canonicalized
//! to (q, equiv_root(p)), so the v1 block {(c3,a1),(c4,a3)} and the v2
//! block {(c3,a4≡a1),(c4,a5≡a3)} compare equal and are stored once.

use std::collections::HashMap;

use super::blocks;
use super::{BlockKey, MappingMatrix};
use crate::cdm::{CdmAttrId, CdmTree, CdmVersionNo, EntityId};
use crate::message::StateI;
use crate::schema::{AttrId, SchemaId, SchemaTree, VersionNo};
use crate::util::json::Json;

/// Canonical square-block content: elements as (q, equiv-root of p),
/// sorted. The empty vec is *not* used — null blocks are a variant.
pub type CanonPm = Vec<(CdmAttrId, AttrId)>;

/// One stored unique square block.
#[derive(Debug, Clone, PartialEq)]
pub enum SquareBlock {
    /// A unique largest permutation matrix (canonical form).
    Pm(CanonPm),
    /// A special null block: the pattern ends at this version.
    Null,
}

/// One entry of a version-super-block sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct UsbEntry {
    /// Version the pattern starts at.
    pub v_from: VersionNo,
    pub block: SquareBlock,
}

/// The super-set `ᵢ𝔇𝔘𝔖𝔅`, grouped by version-super-block coordinate
/// (schema o, entity r, CDM version w).
#[derive(Debug, Clone, Default)]
pub struct DusbSet {
    pub state: StateI,
    groups: HashMap<(SchemaId, EntityId, CdmVersionNo), Vec<UsbEntry>>,
}

impl DusbSet {
    pub fn new(state: StateI) -> Self {
        Self { state, ..Default::default() }
    }

    /// **Algorithm 3**: transform `ᵢM` into `ᵢ𝔇𝔘𝔖𝔅`.
    pub fn from_matrix(
        m: &MappingMatrix,
        tree: &SchemaTree,
        cdm: &CdmTree,
        state: StateI,
    ) -> Result<DusbSet, blocks::ConstraintViolation> {
        let mut set = DusbSet::new(state);
        for s in tree.schemas() {
            for e in cdm.entities() {
                for &w in &e.versions {
                    let mut seq: Vec<UsbEntry> = Vec::new();
                    for &v in &s.versions {
                        let key = BlockKey::new(s.id, v, e.id, w);
                        let ext = blocks::block_extent(tree, cdm, key)
                            .expect("live block");
                        if blocks::is_null_block(m, &ext) {
                            // NB: store only if it terminates a PM run
                            if matches!(
                                seq.last(),
                                Some(UsbEntry { block: SquareBlock::Pm(_), .. })
                            ) {
                                seq.push(UsbEntry {
                                    v_from: v,
                                    block: SquareBlock::Null,
                                });
                            }
                            continue;
                        }
                        let pm = blocks::largest_permutation(m, &ext)?;
                        let canon = canonicalize(tree, &pm);
                        let is_dup = matches!(
                            seq.last(),
                            Some(UsbEntry { block: SquareBlock::Pm(prev), .. })
                                if *prev == canon
                        );
                        if !is_dup {
                            seq.push(UsbEntry {
                                v_from: v,
                                block: SquareBlock::Pm(canon),
                            });
                        }
                    }
                    if !seq.is_empty() {
                        set.groups.insert((s.id, e.id, w), seq);
                    }
                }
            }
        }
        Ok(set)
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Stored mapping elements (PM elements only — the fig-5 "5 elements").
    pub fn n_elements(&self) -> usize {
        self.groups
            .values()
            .flatten()
            .map(|e| match &e.block {
                SquareBlock::Pm(canon) => canon.len(),
                SquareBlock::Null => 0,
            })
            .sum()
    }

    /// Stored special null blocks (the fig-5 "special 6th element").
    pub fn n_special_nulls(&self) -> usize {
        self.groups
            .values()
            .flatten()
            .filter(|e| matches!(e.block, SquareBlock::Null))
            .count()
    }

    pub fn groups(
        &self,
    ) -> impl Iterator<Item = (&(SchemaId, EntityId, CdmVersionNo), &Vec<UsbEntry>)>
    {
        self.groups.iter()
    }

    pub fn group(
        &self,
        o: SchemaId,
        r: EntityId,
        w: CdmVersionNo,
    ) -> Option<&Vec<UsbEntry>> {
        self.groups.get(&(o, r, w))
    }

    /// Insert a group directly — used when reassembling a set from
    /// per-schema store-segment regions.
    pub fn insert_group(
        &mut self,
        key: (SchemaId, EntityId, CdmVersionNo),
        seq: Vec<UsbEntry>,
    ) {
        self.groups.insert(key, seq);
    }

    /// **Algorithm 4**: decompact to the full matrix. Each stored block is
    /// replayed over ascending versions until the next entry's version
    /// (reassigning elements through `≡`), the special null block stops a
    /// run, and leading nulls need no representation.
    pub fn decompact(&self, tree: &SchemaTree, cdm: &CdmTree) -> MappingMatrix {
        self.decompact_impl(tree, cdm, None)
    }

    /// Algorithm 4 restricted to the versions each schema had when this
    /// set was built. A trailing PM run normally extends through *all*
    /// later tree versions — correct live (the tree can't outrun the
    /// matrix), but wrong when replaying a snapshot against a tree that
    /// already holds versions registered *after* it: those columns belong
    /// to the WAL tail, not the snapshot. Store recovery passes the
    /// manifest's recorded version sets here so snapshot runs never bleed
    /// past them.
    pub fn decompact_bounded(
        &self,
        tree: &SchemaTree,
        cdm: &CdmTree,
        allowed: &HashMap<SchemaId, Vec<VersionNo>>,
    ) -> MappingMatrix {
        self.decompact_impl(tree, cdm, Some(allowed))
    }

    fn decompact_impl(
        &self,
        tree: &SchemaTree,
        cdm: &CdmTree,
        allowed: Option<&HashMap<SchemaId, Vec<VersionNo>>>,
    ) -> MappingMatrix {
        let mut m =
            MappingMatrix::new(cdm.n_attr_ids(), tree.n_attr_ids());
        for (&(o, _r, _w), seq) in &self.groups {
            let versions = tree.versions_of(o);
            for (idx, entry) in seq.iter().enumerate() {
                let v_end = seq.get(idx + 1).map(|e| e.v_from);
                let canon = match &entry.block {
                    SquareBlock::Pm(c) => c,
                    SquareBlock::Null => continue,
                };
                for &v in versions {
                    if v < entry.v_from || v_end.is_some_and(|ve| v >= ve) {
                        continue;
                    }
                    if let Some(bound) = allowed {
                        if !bound.get(&o).is_some_and(|vs| vs.contains(&v)) {
                            continue;
                        }
                    }
                    for &(q, root) in canon {
                        // the attribute of version v descending from `root`
                        if let Some(p) = tree.equivalent_in(root, o, v) {
                            m.set(q.index(), p.index(), true);
                        }
                    }
                }
            }
        }
        m
    }

    /// Serialize for the Postgres-sim store (ids are raw numbers).
    pub fn to_json(&self) -> Json {
        let mut groups: Vec<_> = self.groups.iter().collect();
        groups.sort_by_key(|(k, _)| **k);
        let mut arr = Vec::new();
        for (&(o, r, w), seq) in groups {
            let mut g = Json::obj();
            g.set("o", Json::Num(o.0 as f64));
            g.set("r", Json::Num(r.0 as f64));
            g.set("w", Json::Num(w.0 as f64));
            g.set("seq", usb_entries_to_json(seq));
            arr.push(g);
        }
        let mut root = Json::obj();
        root.set("state", Json::Num(self.state.0 as f64));
        root.set("groups", Json::Arr(arr));
        root
    }

    pub fn from_json(j: &Json) -> anyhow::Result<DusbSet> {
        use anyhow::{anyhow, Context};
        let state = StateI(j.get("state").and_then(Json::as_u64).unwrap_or(0));
        let mut set = DusbSet::new(state);
        let groups = j
            .get("groups")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing groups"))?;
        for g in groups {
            let num = |k: &str| -> anyhow::Result<u32> {
                Ok(g.get(k)
                    .and_then(Json::as_u64)
                    .with_context(|| format!("missing {k}"))? as u32)
            };
            let key = (
                SchemaId(num("o")?),
                EntityId(num("r")?),
                CdmVersionNo(num("w")?),
            );
            let seq = usb_entries_from_json(
                g.get("seq").ok_or_else(|| anyhow!("missing seq"))?,
            )?;
            set.groups.insert(key, seq);
        }
        Ok(set)
    }
}

/// Serialize one version-super-block entry sequence — shared between the
/// whole-set codec above and the store's per-schema segment regions.
pub fn usb_entries_to_json(seq: &[UsbEntry]) -> Json {
    Json::Arr(
        seq.iter()
            .map(|e| {
                let mut j = Json::obj();
                j.set("v", Json::Num(e.v_from.0 as f64));
                match &e.block {
                    SquareBlock::Null => j.set("null", Json::Bool(true)),
                    SquareBlock::Pm(canon) => {
                        let elems = canon
                            .iter()
                            .map(|(q, p)| {
                                Json::Arr(vec![
                                    Json::Num(q.0 as f64),
                                    Json::Num(p.0 as f64),
                                ])
                            })
                            .collect();
                        j.set("pm", Json::Arr(elems));
                    }
                }
                j
            })
            .collect(),
    )
}

/// Inverse of [`usb_entries_to_json`].
pub fn usb_entries_from_json(j: &Json) -> anyhow::Result<Vec<UsbEntry>> {
    use anyhow::anyhow;
    let mut seq = Vec::new();
    for e in j.as_arr().ok_or_else(|| anyhow!("seq is not an array"))? {
        let v = VersionNo(
            e.get("v")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("missing v"))? as u32,
        );
        let block = if e.get("null").and_then(Json::as_bool) == Some(true) {
            SquareBlock::Null
        } else {
            let pm = e
                .get("pm")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing pm"))?;
            SquareBlock::Pm(
                pm.iter()
                    .map(|pair| {
                        let pair =
                            pair.as_arr().ok_or_else(|| anyhow!("bad pair"))?;
                        if pair.len() != 2 {
                            return Err(anyhow!("bad pair arity"));
                        }
                        Ok((
                            CdmAttrId(
                                pair[0]
                                    .as_u64()
                                    .ok_or_else(|| anyhow!("bad q"))?
                                    as u32,
                            ),
                            AttrId(
                                pair[1]
                                    .as_u64()
                                    .ok_or_else(|| anyhow!("bad p"))?
                                    as u32,
                            ),
                        ))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            )
        };
        seq.push(UsbEntry { v_from: v, block });
    }
    Ok(seq)
}

/// Canonicalize a PM's elements: map each column through `equiv_root`.
fn canonicalize(tree: &SchemaTree, pm: &[(usize, usize)]) -> CanonPm {
    let mut canon: CanonPm = pm
        .iter()
        .map(|&(q, p)| {
            (CdmAttrId(q as u32), tree.equiv_root(AttrId(p as u32)))
        })
        .collect();
    canon.sort();
    canon
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::dpm::DpmSet;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};

    #[test]
    fn algorithm3_compacts_fig5_to_5_plus_special_null() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(1)).unwrap();
        // fig 5: "the aggressive algorithm 3 compacts the above matrix from
        // 30 to 5 elements with a special 6th element"
        assert_eq!(dusb.n_elements(), 5);
        assert_eq!(dusb.n_special_nulls(), 1);
    }

    #[test]
    fn equivalent_version_blocks_are_deduped() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        let seq = dusb.group(s1, be1, CdmVersionNo(2)).unwrap();
        // v1 and v2 blocks are ≡-equal: stored once, starting at v1
        assert_eq!(seq.len(), 1);
        assert_eq!(seq[0].v_from, VersionNo(1));
        assert!(matches!(&seq[0].block, SquareBlock::Pm(c2) if c2.len() == 2));
    }

    #[test]
    fn trailing_null_block_is_stored_leading_is_not() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        let be3 = c.entity_by_name("be3").unwrap();
        // be3 row block: PM at v1, all-zero at v2 → Null entry at v2
        let seq = dusb.group(s1, be3, CdmVersionNo(1)).unwrap();
        assert_eq!(seq.len(), 2);
        assert!(matches!(seq[1].block, SquareBlock::Null));
        assert_eq!(seq[1].v_from, VersionNo(2));
        // be2 never maps s1: no group at all (red non-saved null blocks)
        let be2 = c.entity_by_name("be2").unwrap();
        assert!(dusb.group(s1, be2, CdmVersionNo(1)).is_none());
    }

    #[test]
    fn algorithm4_decompacts_exactly() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let back = dusb.decompact(&t, &c);
        assert_eq!(back, m);
    }

    #[test]
    fn hybrid_restore_path_dusb_to_dpm() {
        // §6.2: recreate ᵢ𝔇𝔓𝔐 from ᵢ𝔇𝔘𝔖𝔅 via ᵢM
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm_direct = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let recreated =
            DpmSet::from_matrix(&dusb.decompact(&t, &c), &t, &c, StateI(0))
                .unwrap();
        assert!(dpm_direct.same_elements(&recreated));
    }

    #[test]
    fn json_roundtrip() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(7)).unwrap();
        let j = dusb.to_json();
        let parsed = crate::util::json::parse(&j.to_pretty()).unwrap();
        let back = DusbSet::from_json(&parsed).unwrap();
        assert_eq!(back.state, StateI(7));
        assert_eq!(back.n_elements(), dusb.n_elements());
        assert_eq!(back.n_special_nulls(), dusb.n_special_nulls());
        assert_eq!(back.decompact(&t, &c), m);
    }

    #[test]
    fn bounded_decompaction_does_not_bleed_into_later_versions() {
        let (mut t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        // record the version sets *before* evolving the tree
        let allowed: HashMap<SchemaId, Vec<VersionNo>> = t
            .schemas()
            .map(|s| (s.id, s.versions.clone()))
            .collect();
        // register a v3 of s1 descending from v2 — as a post-snapshot
        // WAL-era change would
        let s1 = t.schema_by_name("s1").unwrap();
        let fields = t.field_list(s1, VersionNo(2)).unwrap();
        let v3 = t.add_version(s1, &fields);
        // unbounded Alg-4 extends trailing PM runs into v3 (the bleed)...
        let bled = dusb.decompact(&t, &c);
        let v3_cols: Vec<_> = t
            .version(s1, v3)
            .unwrap()
            .attrs
            .iter()
            .map(|a| a.index())
            .collect();
        let bled_elems: usize = v3_cols
            .iter()
            .map(|&p| (0..c.n_attr_ids()).filter(|&q| bled.get(q, p)).count())
            .sum();
        assert!(bled_elems > 0, "fixture should exercise a trailing run");
        // ...bounded replay leaves the v3 block untouched
        let bounded = dusb.decompact_bounded(&t, &c, &allowed);
        for &p in &v3_cols {
            for q in 0..c.n_attr_ids() {
                assert!(!bounded.get(q, p));
            }
        }
        // and is identical to the unbounded result everywhere else
        for s in t.schemas() {
            for &v in &s.versions {
                if s.id == s1 && v == v3 {
                    continue;
                }
                for a in &t.version(s.id, v).unwrap().attrs {
                    for q in 0..c.n_attr_ids() {
                        assert_eq!(
                            bounded.get(q, a.index()),
                            bled.get(q, a.index())
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dusb_never_larger_than_dpm() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        assert!(dusb.n_elements() <= dpm.n_elements());
    }
}
