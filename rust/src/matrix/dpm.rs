//! `ᵢ𝔇𝔓𝔐` — the dense set of block-partitioned largest permutation
//! matrices: *the* dynamic mapping matrix of the balanced strategy
//! (paper §5.3.1, Algorithm 2).
//!
//! Each surviving block stores only its 1-elements as (q, p) pairs of
//! global attribute ids; null blocks are deleted entirely. Column
//! super-sets `ᵢ𝒟𝒞𝒫𝓜` (all blocks of one versioned extracting schema)
//! drive the per-message lookup of Alg 6; row super-sets `ᵢ𝒟ℛ𝒫𝓜` drive
//! the UI's reverse search (§6.3).

use std::collections::HashMap;
use std::sync::Arc;

use super::blocks::{self, BlockExtent, ConstraintViolation};
use super::{BlockKey, MappingMatrix};
use crate::cdm::{CdmAttrId, CdmTree, CdmVersionNo, EntityId};
use crate::message::StateI;
use crate::schema::{AttrId, SchemaId, SchemaTree, VersionNo};

/// One dense permutation-matrix block `ᵢ_ov DPM_rw`: only 1-elements.
#[derive(Debug, Clone, PartialEq)]
pub struct DpmBlock {
    pub key: BlockKey,
    /// (c_q, a_p) pairs, sorted by q. Linearly independent by the
    /// permutation property — each q and each p occurs at most once.
    pub elements: Vec<(CdmAttrId, AttrId)>,
}

impl DpmBlock {
    pub fn rank(&self) -> usize {
        self.elements.len()
    }
}

/// The super-super-set `ᵢ𝔇𝔓𝔐` with its column/row indexes.
#[derive(Debug, Clone, Default)]
pub struct DpmSet {
    pub state: StateI,
    blocks: HashMap<BlockKey, Arc<DpmBlock>>,
    by_col: HashMap<(SchemaId, VersionNo), Vec<BlockKey>>,
    by_row: HashMap<(EntityId, CdmVersionNo), Vec<BlockKey>>,
}

impl DpmSet {
    pub fn new(state: StateI) -> Self {
        Self { state, ..Default::default() }
    }

    /// **Algorithm 2**: transform `ᵢM` into `ᵢ𝔇𝔓𝔐`.
    ///
    /// Partition into blocks, skip null blocks, size each survivor down to
    /// its largest permutation matrix, block-partition into elements and
    /// keep only the 1s. Errors on 1:1-constraint violations.
    pub fn from_matrix(
        m: &MappingMatrix,
        tree: &SchemaTree,
        cdm: &CdmTree,
        state: StateI,
    ) -> Result<DpmSet, ConstraintViolation> {
        let mut set = DpmSet::new(state);
        for key in blocks::all_block_keys(tree, cdm) {
            let ext = blocks::block_extent(tree, cdm, key).expect("live block");
            if blocks::is_null_block(m, &ext) {
                continue; // null blocks are deleted (Alg 2 step 4)
            }
            let pm = blocks::largest_permutation(m, &ext)?;
            set.insert_block(DpmBlock {
                key,
                elements: pm
                    .into_iter()
                    .map(|(q, p)| (CdmAttrId(q as u32), AttrId(p as u32)))
                    .collect(),
            });
        }
        Ok(set)
    }

    pub fn insert_block(&mut self, block: DpmBlock) {
        let key = block.key;
        if self.blocks.insert(key, Arc::new(block)).is_none() {
            self.by_col.entry(key.col_key()).or_default().push(key);
            self.by_row.entry(key.row_key()).or_default().push(key);
        }
    }

    pub fn remove_block(&mut self, key: BlockKey) -> Option<Arc<DpmBlock>> {
        let removed = self.blocks.remove(&key)?;
        if let Some(v) = self.by_col.get_mut(&key.col_key()) {
            v.retain(|k| *k != key);
        }
        if let Some(v) = self.by_row.get_mut(&key.row_key()) {
            v.retain(|k| *k != key);
        }
        Some(removed)
    }

    pub fn block(&self, key: BlockKey) -> Option<&Arc<DpmBlock>> {
        self.blocks.get(&key)
    }

    pub fn blocks(&self) -> impl Iterator<Item = &Arc<DpmBlock>> {
        self.blocks.values()
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Total stored mapping elements (the compaction metric of fig 5).
    pub fn n_elements(&self) -> usize {
        self.blocks.values().map(|b| b.elements.len()).sum()
    }

    /// Column super-set `ᵢ𝒟𝒞𝒫𝓜_v^o`: the blocks mapping one incoming
    /// message — the Alg 6 lookup.
    pub fn column(&self, schema: SchemaId, v: VersionNo) -> Vec<Arc<DpmBlock>> {
        self.by_col
            .get(&(schema, v))
            .map(|keys| {
                let mut blocks: Vec<Arc<DpmBlock>> = keys
                    .iter()
                    .map(|k| Arc::clone(&self.blocks[k]))
                    .collect();
                blocks.sort_by_key(|b| b.key);
                blocks
            })
            .unwrap_or_default()
    }

    /// Row super-set `ᵢ𝒟ℛ𝒫𝓜_w^r`: the reverse search of §6.3 — which
    /// incoming schema versions feed one business-entity version.
    pub fn row(&self, entity: EntityId, w: CdmVersionNo) -> Vec<Arc<DpmBlock>> {
        self.by_row
            .get(&(entity, w))
            .map(|keys| {
                let mut blocks: Vec<Arc<DpmBlock>> = keys
                    .iter()
                    .map(|k| Arc::clone(&self.blocks[k]))
                    .collect();
                blocks.sort_by_key(|b| b.key);
                blocks
            })
            .unwrap_or_default()
    }

    /// All column keys present (used by update case 3 to locate the
    /// previous version's column super-set).
    pub fn column_keys(&self) -> Vec<(SchemaId, VersionNo)> {
        let mut keys: Vec<_> = self.by_col.keys().copied().collect();
        keys.sort();
        keys
    }

    pub fn row_keys(&self) -> Vec<(EntityId, CdmVersionNo)> {
        let mut keys: Vec<_> = self.by_row.keys().copied().collect();
        keys.sort();
        keys
    }

    /// Remove every block of a column super-set; returns removed keys
    /// (update case 1).
    pub fn remove_column(&mut self, schema: SchemaId, v: VersionNo) -> Vec<BlockKey> {
        let keys = self.by_col.remove(&(schema, v)).unwrap_or_default();
        for key in &keys {
            self.blocks.remove(key);
            if let Some(vv) = self.by_row.get_mut(&key.row_key()) {
                vv.retain(|k| k != key);
            }
        }
        keys
    }

    /// Remove every block of a row super-set (update case 2 / §5.4.3
    /// cleanup).
    pub fn remove_row(&mut self, entity: EntityId, w: CdmVersionNo) -> Vec<BlockKey> {
        let keys = self.by_row.remove(&(entity, w)).unwrap_or_default();
        for key in &keys {
            self.blocks.remove(key);
            if let Some(vv) = self.by_col.get_mut(&key.col_key()) {
                vv.retain(|k| k != key);
            }
        }
        keys
    }

    /// Rebuild the full matrix from this set (the simple §5.3.3 direction).
    pub fn decompact(&self, n_rows: usize, n_cols: usize) -> MappingMatrix {
        let mut m = MappingMatrix::new(n_rows, n_cols);
        for block in self.blocks.values() {
            for (q, p) in &block.elements {
                m.set(q.index(), p.index(), true);
            }
        }
        m
    }

    /// Check the permutation property across every block: within one block
    /// each `q` and each `p` occurs at most once (§4.5). Returns the first
    /// violating block key, if any — the invariant the property suite
    /// asserts after every Alg-5 update.
    pub fn verify_one_to_one(&self) -> Result<(), BlockKey> {
        for block in self.blocks.values() {
            let mut qs: Vec<u32> =
                block.elements.iter().map(|&(q, _)| q.0).collect();
            qs.sort_unstable();
            let mut ps: Vec<u32> =
                block.elements.iter().map(|&(_, p)| p.0).collect();
            ps.sort_unstable();
            if qs.windows(2).any(|w| w[0] == w[1])
                || ps.windows(2).any(|w| w[0] == w[1])
            {
                return Err(block.key);
            }
        }
        Ok(())
    }

    /// Structural equality ignoring state (used by restore tests).
    pub fn same_elements(&self, other: &DpmSet) -> bool {
        if self.blocks.len() != other.blocks.len() {
            return false;
        }
        self.blocks.iter().all(|(k, b)| {
            other.blocks.get(k).is_some_and(|ob| {
                let mut a = b.elements.clone();
                let mut c = ob.elements.clone();
                a.sort();
                c.sort();
                a == c
            })
        })
    }
}

/// Extent helper re-export for callers needing rectangles.
pub fn extent_of(
    tree: &SchemaTree,
    cdm: &CdmTree,
    key: BlockKey,
) -> Option<BlockExtent> {
    blocks::block_extent(tree, cdm, key)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};

    #[test]
    fn algorithm2_compacts_fig5_from_30_to_7() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(1)).unwrap();
        // fig 5: "the efficient standard algorithm 2 compacts the above
        // matrix from 30 to 7 elements"
        assert_eq!(dpm.n_elements(), 7);
        // blocks with at least one 1: (s1v1,be1v2), (s1v2,be1v2),
        // (s2v1,be2v1), (s1v1,be3v1) = 4  (+ null blocks deleted)
        assert_eq!(dpm.n_blocks(), 4);
    }

    #[test]
    fn column_superset_lookup() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        let col = dpm.column(s1, VersionNo(1));
        // s1.v1 feeds be1.v2 (2 elements) and be3.v1 (2 elements)
        assert_eq!(col.len(), 2);
        assert_eq!(col.iter().map(|b| b.rank()).sum::<usize>(), 4);
        // unknown column is empty
        assert!(dpm.column(s1, VersionNo(9)).is_empty());
    }

    #[test]
    fn row_superset_reverse_search() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        let row = dpm.row(be1, CdmVersionNo(2));
        // be1.v2 is fed by s1.v1 and s1.v2
        assert_eq!(row.len(), 2);
        let schemas: Vec<_> = row.iter().map(|b| b.key.v).collect();
        assert_eq!(schemas, vec![VersionNo(1), VersionNo(2)]);
    }

    #[test]
    fn decompact_roundtrips_exactly() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let back = dpm.decompact(m.n_rows(), m.n_cols());
        assert_eq!(back, m);
    }

    #[test]
    fn remove_column_updates_indexes() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let mut dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        let removed = dpm.remove_column(s1, VersionNo(1));
        assert_eq!(removed.len(), 2);
        assert!(dpm.column(s1, VersionNo(1)).is_empty());
        let be3 = c.entity_by_name("be3").unwrap();
        assert!(dpm.row(be3, CdmVersionNo(1)).is_empty());
        // s1.v2 block survives
        assert_eq!(dpm.column(s1, VersionNo(2)).len(), 1);
        assert_eq!(dpm.n_elements(), 3);
    }

    #[test]
    fn remove_row_updates_indexes() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let mut dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        let removed = dpm.remove_row(be1, CdmVersionNo(2));
        assert_eq!(removed.len(), 2);
        assert_eq!(dpm.n_elements(), 3);
        let s1 = t.schema_by_name("s1").unwrap();
        // s1.v1 still feeds be3.v1
        assert_eq!(dpm.column(s1, VersionNo(1)).len(), 1);
    }

    #[test]
    fn same_elements_ignores_order() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let a = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let b = DpmSet::from_matrix(&m, &t, &c, StateI(5)).unwrap();
        assert!(a.same_elements(&b));
        let mut c2 = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let key = *c2.column_keys().first().unwrap();
        c2.remove_column(key.0, key.1);
        assert!(!a.same_elements(&c2));
    }
}
