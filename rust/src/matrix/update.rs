//! **Algorithm 5** — automated updates of the DMM (paper §5.4): transition
//! `ᵢ𝔇𝔓𝔐 → ᵢ₊₁𝔇𝔓𝔐` in response to the four external triggers, working
//! on sets only (never rebuilding the full matrix).
//!
//! - case 1: deleted extracting version `ᵢD_v^o` → drop the column set;
//! - case 2: deleted CDM version `ᵢR_w^r` → drop the row set;
//! - case 3: added extracting version `ᵢ₊₁D_{v+1}^o` → copy known values
//!   along attribute equivalences from the previous version's column set;
//! - case 4: added CDM version `ᵢ₊₁R_{w+1}^r` → same on row level, then
//!   delete the previous CDM version's rows (§5.4.3 cleanup rule: one
//!   business-entity version only).
//!
//! Copies that cannot reassign every element produce **notices** ("inform
//! the user about newly created smaller permutation matrices", fig 6) —
//! the semi-automated part of the workflow (§5.4.2).

use super::dpm::{DpmBlock, DpmSet};
use super::BlockKey;
use crate::cdm::{CdmTree, CdmVersionNo, EntityId};
use crate::message::StateI;
use crate::schema::{SchemaId, SchemaTree, VersionNo};

/// The four update triggers of §3.5 / Alg 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChangeCase {
    DeletedSchemaVersion { schema: SchemaId, v: VersionNo },
    DeletedCdmVersion { entity: EntityId, w: CdmVersionNo },
    AddedSchemaVersion { schema: SchemaId, v: VersionNo },
    AddedCdmVersion { entity: EntityId, w: CdmVersionNo },
}

/// User-facing notice emitted by an automated update (§5.4.2).
#[derive(Debug, Clone, PartialEq)]
pub enum Notice {
    /// The copied block is smaller than its source — a mapped attribute
    /// was deleted; the user should double-check the new block.
    SmallerPermutation { block: BlockKey, old_rank: usize, new_rank: usize },
    /// The copy produced no elements at all (new null block).
    EmptyBlock { source: BlockKey },
    /// Case 3/4 found no previous version to copy from: the user must
    /// initialize the block manually (UI / CSV path, §5.4.2).
    NeedsManualInit { schema: Option<SchemaId>, entity: Option<EntityId> },
}

/// Outcome of one automated update.
#[derive(Debug, Clone, Default)]
pub struct UpdateReport {
    pub blocks_added: usize,
    pub blocks_removed: usize,
    pub elements_added: usize,
    pub elements_removed: usize,
    pub notices: Vec<Notice>,
}

impl UpdateReport {
    /// Size of the diff-set handled automatically (§3.5: "up to 100.000
    /// elements ... virtually impossible to update for a user").
    pub fn diff_elements(&self) -> usize {
        self.elements_added + self.elements_removed
    }
}

/// Apply Algorithm 5 to `dpm`, advancing its state to `new_state`.
pub fn auto_update(
    dpm: &mut DpmSet,
    tree: &SchemaTree,
    cdm: &CdmTree,
    change: ChangeCase,
    new_state: StateI,
) -> UpdateReport {
    let mut report = UpdateReport::default();
    match change {
        // case 1
        ChangeCase::DeletedSchemaVersion { schema, v } => {
            remove_counted(dpm, &mut report, |d| d.remove_column(schema, v));
        }
        // case 2
        ChangeCase::DeletedCdmVersion { entity, w } => {
            remove_counted(dpm, &mut report, |d| d.remove_row(entity, w));
        }
        // case 3
        ChangeCase::AddedSchemaVersion { schema, v } => {
            let Some(prev) = case3_source(dpm, schema, v) else {
                report.notices.push(Notice::NeedsManualInit {
                    schema: Some(schema),
                    entity: None,
                });
                dpm.state = new_state;
                return report;
            };
            for block in dpm.column(schema, prev) {
                let mut elements = Vec::with_capacity(block.elements.len());
                for &(q, p) in &block.elements {
                    if let Some(p2) = tree.equivalent_in(p, schema, v) {
                        elements.push((q, p2));
                    }
                }
                let new_key = BlockKey::new(schema, v, block.key.entity, block.key.w);
                if elements.is_empty() {
                    report.notices.push(Notice::EmptyBlock { source: block.key });
                    continue;
                }
                if elements.len() < block.elements.len() {
                    report.notices.push(Notice::SmallerPermutation {
                        block: new_key,
                        old_rank: block.elements.len(),
                        new_rank: elements.len(),
                    });
                }
                report.blocks_added += 1;
                report.elements_added += elements.len();
                dpm.insert_block(DpmBlock { key: new_key, elements });
            }
        }
        // case 4
        ChangeCase::AddedCdmVersion { entity, w } => {
            let prev = dpm
                .row_keys()
                .into_iter()
                .filter(|(e, pw)| *e == entity && *pw < w)
                .map(|(_, pw)| pw)
                .max();
            let Some(prev) = prev else {
                report.notices.push(Notice::NeedsManualInit {
                    schema: None,
                    entity: Some(entity),
                });
                dpm.state = new_state;
                return report;
            };
            for block in dpm.row(entity, prev) {
                let mut elements = Vec::with_capacity(block.elements.len());
                for &(q, p) in &block.elements {
                    if let Some(q2) = cdm.equivalent_in(q, entity, w) {
                        elements.push((q2, p));
                    }
                }
                let new_key =
                    BlockKey::new(block.key.schema, block.key.v, entity, w);
                if elements.is_empty() {
                    report.notices.push(Notice::EmptyBlock { source: block.key });
                    continue;
                }
                if elements.len() < block.elements.len() {
                    report.notices.push(Notice::SmallerPermutation {
                        block: new_key,
                        old_rank: block.elements.len(),
                        new_rank: elements.len(),
                    });
                }
                report.blocks_added += 1;
                report.elements_added += elements.len();
                dpm.insert_block(DpmBlock { key: new_key, elements });
            }
            // §5.4.3 cleanup: delete the previous CDM version's rows
            remove_counted(dpm, &mut report, |d| d.remove_row(entity, prev));
        }
    }
    dpm.state = new_state;
    report
}

/// The column set Alg-5 case 3 copies from when version `v` of `schema`
/// is added: the latest earlier version with a column in `dpm`. Shared
/// between [`auto_update`] and the in-band patchability screen of the
/// evolution lane, so the two can never disagree on the copy source.
pub fn case3_source(
    dpm: &DpmSet,
    schema: SchemaId,
    v: VersionNo,
) -> Option<VersionNo> {
    dpm.column_keys()
        .into_iter()
        .filter(|(s, pv)| *s == schema && *pv < v)
        .map(|(_, pv)| pv)
        .max()
}

/// Epoch-swap variant of [`auto_update`]: build `ᵢ₊₁𝔇𝔓𝔐` off to the side
/// from an immutable snapshot. The live set keeps serving Alg 6 unchanged
/// while this runs; the caller publishes the returned set with a single
/// pointer swap (see `coordinator::state::EpochDmm`), so schema-change
/// storms never stall in-flight mapping.
///
/// ```
/// use metl::matrix::dpm::DpmSet;
/// use metl::matrix::fixtures::{fig6_matrix, fig6_trees};
/// use metl::matrix::update::{prepare_update, ChangeCase};
/// use metl::message::StateI;
/// use metl::schema::ExtractType;
///
/// let (mut tree, cdm) = fig6_trees();
/// let matrix = fig6_matrix(&tree, &cdm);
/// let live = DpmSet::from_matrix(&matrix, &tree, &cdm, StateI(0)).unwrap();
/// // figure-6 event (1): a new extracting version s1.v3 (a7 ≡ a4 ≡ a1)
/// let s1 = tree.schema_by_name("s1").unwrap();
/// let v3 = tree.add_version(s1, &[("a1".into(), ExtractType::Int64, true)]);
/// let (next, report) = prepare_update(
///     &live,
///     &tree,
///     &cdm,
///     ChangeCase::AddedSchemaVersion { schema: s1, v: v3 },
///     StateI(1),
/// );
/// // the live snapshot is untouched; the successor carries the new column
/// assert_eq!(live.state, StateI(0));
/// assert_eq!(next.state, StateI(1));
/// assert_eq!(report.blocks_added, 1);
/// assert_eq!(next.column(s1, v3).len(), 1);
/// ```
pub fn prepare_update(
    current: &DpmSet,
    tree: &SchemaTree,
    cdm: &CdmTree,
    change: ChangeCase,
    new_state: StateI,
) -> (DpmSet, UpdateReport) {
    let mut next = current.clone();
    let report = auto_update(&mut next, tree, cdm, change, new_state);
    (next, report)
}

fn remove_counted(
    dpm: &mut DpmSet,
    report: &mut UpdateReport,
    f: impl FnOnce(&mut DpmSet) -> Vec<BlockKey>,
) {
    // count elements before removal
    let snapshot: Vec<(BlockKey, usize)> = dpm
        .blocks()
        .map(|b| (b.key, b.elements.len()))
        .collect();
    let removed = f(dpm);
    for key in &removed {
        if let Some((_, n)) = snapshot.iter().find(|(k, _)| k == key) {
            report.elements_removed += n;
        }
    }
    report.blocks_removed += removed.len();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::dpm::DpmSet;
    use crate::matrix::fixtures::{fig6_matrix, fig6_trees};
    use crate::schema::ExtractType;

    fn setup() -> (crate::schema::SchemaTree, CdmTree, DpmSet) {
        let (t, c) = fig6_trees();
        let m = fig6_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        (t, c, dpm)
    }

    use crate::cdm::{CdmTree, CdmType};

    /// Figure-6 event (1): adding extracting version s1.v3 with a7≡a4≡a1.
    #[test]
    fn fig6_event1_add_schema_version_copies_equivalences() {
        let (mut t, c, mut dpm) = setup();
        let s1 = t.schema_by_name("s1").unwrap();
        let before = dpm.n_elements();
        // v3 has only attribute a1-lineage (displayed a7≡a4)
        let v3 = t.add_version(s1, &[("a1".into(), ExtractType::Int64, true)]);
        let report = auto_update(
            &mut dpm,
            &t,
            &c,
            ChangeCase::AddedSchemaVersion { schema: s1, v: v3 },
            StateI(1),
        );
        // fig 6 column s1.v3: c1=1 (copied via ≡), c2=0, c6=0, c7=0...
        // source column v2 had blocks: (s1cdm: c1<-a4, c2<-a6) — c2's a6
        // has no descendant in v3 → smaller PM notice.
        assert_eq!(report.blocks_added, 1);
        assert_eq!(report.elements_added, 1);
        assert!(report
            .notices
            .iter()
            .any(|n| matches!(n, Notice::SmallerPermutation { new_rank: 1, old_rank: 2, .. })));
        assert_eq!(dpm.n_elements(), before + 1);
        assert_eq!(dpm.state, StateI(1));
        // the new column maps c1 <- a7
        let col = dpm.column(s1, v3);
        assert_eq!(col.len(), 1);
        let e1 = c.entity_by_name("s1cdm").unwrap();
        assert_eq!(col[0].key.entity, e1);
    }

    /// Figure-6 event (2): adding CDM version v2 (c3≡c1, c4≡c2), then
    /// deleting the old CDM version's rows (red in the figure).
    #[test]
    fn fig6_event2_add_cdm_version_copies_rows_then_deletes_old() {
        let (t, mut c, mut dpm) = setup();
        let e1 = c.entity_by_name("s1cdm").unwrap();
        let old_row_elements: usize = dpm
            .row(e1, CdmVersionNo(1))
            .iter()
            .map(|b| b.rank())
            .sum();
        assert_eq!(old_row_elements, 4);
        let w2 = c.add_version(
            e1,
            &[
                ("c1".into(), CdmType::Integer, String::new()),
                ("c2".into(), CdmType::Integer, String::new()),
            ],
        );
        let report = auto_update(
            &mut dpm,
            &t,
            &c,
            ChangeCase::AddedCdmVersion { entity: e1, w: w2 },
            StateI(2),
        );
        // both v1-column and v2-column blocks copied to the new rows
        assert_eq!(report.blocks_added, 2);
        assert_eq!(report.elements_added, 4);
        // cleanup removed the old version's two blocks
        assert_eq!(report.blocks_removed, 2);
        assert_eq!(report.elements_removed, 4);
        assert!(dpm.row(e1, CdmVersionNo(1)).is_empty());
        let new_rows: usize =
            dpm.row(e1, w2).iter().map(|b| b.rank()).sum();
        assert_eq!(new_rows, 4);
        // other entity untouched
        let e2 = c.entity_by_name("s2cdm").unwrap();
        assert_eq!(dpm.row(e2, CdmVersionNo(1)).len(), 1);
    }

    #[test]
    fn case1_deletes_column_sets() {
        let (t, c, mut dpm) = setup();
        let s1 = t.schema_by_name("s1").unwrap();
        let report = auto_update(
            &mut dpm,
            &t,
            &c,
            ChangeCase::DeletedSchemaVersion { schema: s1, v: VersionNo(1) },
            StateI(1),
        );
        assert_eq!(report.blocks_removed, 2); // s1cdm + s2cdm blocks at v1
        assert_eq!(report.elements_removed, 4);
        assert!(dpm.column(s1, VersionNo(1)).is_empty());
        assert_eq!(dpm.n_elements(), 2); // v2 column survives
    }

    #[test]
    fn case2_deletes_row_sets() {
        let (t, c, mut dpm) = setup();
        let e2 = c.entity_by_name("s2cdm").unwrap();
        let report = auto_update(
            &mut dpm,
            &t,
            &c,
            ChangeCase::DeletedCdmVersion { entity: e2, w: CdmVersionNo(1) },
            StateI(1),
        );
        assert_eq!(report.blocks_removed, 1);
        assert_eq!(report.elements_removed, 2);
        assert!(dpm.row(e2, CdmVersionNo(1)).is_empty());
    }

    #[test]
    fn first_version_needs_manual_init() {
        let (mut t, c, mut dpm) = setup();
        let s9 = t.add_schema("s9", "t.s9");
        let v1 = t.add_version(s9, &[("x".into(), ExtractType::Int64, true)]);
        let report = auto_update(
            &mut dpm,
            &t,
            &c,
            ChangeCase::AddedSchemaVersion { schema: s9, v: v1 },
            StateI(1),
        );
        assert!(matches!(
            report.notices[0],
            Notice::NeedsManualInit { schema: Some(_), .. }
        ));
        assert_eq!(report.blocks_added, 0);
    }

    /// Update path must equal recompute-from-scratch on the ground-truth
    /// matrix (the invariant behind "automated updates").
    #[test]
    fn update_equals_recompute_for_fig6_event1() {
        let (mut t, c, mut dpm) = setup();
        let s1 = t.schema_by_name("s1").unwrap();
        let v3 = t.add_version(s1, &[("a1".into(), ExtractType::Int64, true)]);
        auto_update(
            &mut dpm,
            &t,
            &c,
            ChangeCase::AddedSchemaVersion { schema: s1, v: v3 },
            StateI(1),
        );
        // ground truth: extend the full matrix the same way (copy values
        // for equivalent attributes), then recompact
        let mut m = fig6_matrix(&t, &c);
        m.grow(c.n_attr_ids(), t.n_attr_ids());
        let v2 = VersionNo(2);
        let sv2 = t.version(s1, v2).unwrap().clone();
        let sv3 = t.version(s1, v3).unwrap().clone();
        for q in 0..m.n_rows() {
            for (i, &p2) in sv2.attrs.iter().enumerate() {
                let _ = i;
                if m.get(q, p2.index()) {
                    if let Some(p3) = t.equivalent_in(p2, s1, v3) {
                        let _ = &sv3;
                        m.set(q, p3.index(), true);
                    }
                }
            }
        }
        let recomputed = DpmSet::from_matrix(&m, &t, &c, StateI(1)).unwrap();
        assert!(dpm.same_elements(&recomputed));
    }
}
