//! Worked-example fixtures from the paper's figures, reused by unit tests,
//! integration tests (`cargo test figure5` / `figure6`) and benches.

use super::MappingMatrix;
use crate::cdm::{CdmTree, CdmType, CdmVersionNo};
use crate::schema::{ExtractType, SchemaTree, VersionNo};

fn cdm_f(name: &str) -> (String, CdmType, String) {
    (name.to_string(), CdmType::Integer, String::new())
}

fn ext_f(name: &str) -> (String, ExtractType, bool) {
    (name.to_string(), ExtractType::Int64, true)
}

/// Figure-3/figure-5 trees: schema s1 (v1: a1,a2,a3; v2: a4≡a1, a5≡a3),
/// s2 (v1: a6); entities be1 (v1: c1,c2; v2: c3,c4), be2 (v1: c5),
/// be3 (v1: c6,c7).
pub fn fig5_trees() -> (SchemaTree, CdmTree) {
    let mut t = SchemaTree::new();
    let s1 = t.add_schema("s1", "t.s1");
    t.add_version(s1, &[ext_f("a1"), ext_f("a2"), ext_f("a3")]);
    // v2 drops a2; a1→a4, a3→a5 via equivalences
    t.add_version(s1, &[ext_f("a1"), ext_f("a3")]);
    let s2 = t.add_schema("s2", "t.s2");
    t.add_version(s2, &[ext_f("a6")]);

    let mut c = CdmTree::new();
    let be1 = c.add_entity("be1");
    c.add_version(be1, &[cdm_f("c1"), cdm_f("c2")]);
    c.add_version(be1, &[cdm_f("c3"), cdm_f("c4")]);
    let be2 = c.add_entity("be2");
    c.add_version(be2, &[cdm_f("c5")]);
    let be3 = c.add_entity("be3");
    c.add_version(be3, &[cdm_f("c6"), cdm_f("c7")]);
    (t, c)
}

/// The exact figure-5 matrix over the fig5 trees. Only be1.v2 is live for
/// be1 (v1 deleted per §5.1's rule); 30 live elements, 7 ones.
pub fn fig5_matrix(t: &SchemaTree, c: &CdmTree) -> MappingMatrix {
    let mut m = MappingMatrix::new(c.n_attr_ids(), t.n_attr_ids());
    let s1 = t.schema_by_name("s1").unwrap();
    let s2 = t.schema_by_name("s2").unwrap();
    let (v1, v2) = (VersionNo(1), VersionNo(2));
    let a = |s, v, i: usize| t.version(s, v).unwrap().attrs[i].index();
    let be1 = c.entity_by_name("be1").unwrap();
    let be2 = c.entity_by_name("be2").unwrap();
    let be3 = c.entity_by_name("be3").unwrap();
    let (w1, w2) = (CdmVersionNo(1), CdmVersionNo(2));
    let q = |e, w, i: usize| c.version(e, w).unwrap().attrs[i].index();
    m.set(q(be1, w2, 0), a(s1, v1, 0), true); // c3 <- a1
    m.set(q(be1, w2, 0), a(s1, v2, 0), true); // c3 <- a4 (≡a1)
    m.set(q(be1, w2, 1), a(s1, v1, 2), true); // c4 <- a3
    m.set(q(be1, w2, 1), a(s1, v2, 1), true); // c4 <- a5 (≡a3)
    m.set(q(be2, w1, 0), a(s2, v1, 0), true); // c5 <- a6
    m.set(q(be3, w1, 0), a(s1, v1, 1), true); // c6 <- a2
    m.set(q(be3, w1, 1), a(s1, v1, 0), true); // c7 <- a1
    m
}

/// Delete be1.v1 from the fig5 CDM tree (the figure shows be1.v2 live
/// only — §5.1: outdated CDM versions are deleted from the matrix).
pub fn fig5_drop_old_cdm(c: &mut CdmTree) {
    let be1 = c.entity_by_name("be1").unwrap();
    c.delete_version(be1, CdmVersionNo(1));
}

/// Figure-6 trees: schema s1 v1 (a1,a2,a3) and v2 (a4≡a1, a5, a6≡a2);
/// CDM entities s1' (v1: c1,c2) and s2' (v1: c6,c7). The update events of
/// fig 6 — adding s1.v3 (a7≡a4) and CDM v2 (c3≡c1, c4≡c2) — are applied
/// by the test through Alg 5.
pub fn fig6_trees() -> (SchemaTree, CdmTree) {
    let mut t = SchemaTree::new();
    let s1 = t.add_schema("s1", "t.s1");
    t.add_version(s1, &[ext_f("a1"), ext_f("a2"), ext_f("a3")]);
    // v2: a4≡a1, a5 (new), a6≡a2 — figure's header row
    t.add_version(s1, &[ext_f("a1"), ext_f("a5"), ext_f("a2")]);
    let mut c = CdmTree::new();
    let e1 = c.add_entity("s1cdm");
    c.add_version(e1, &[cdm_f("c1"), cdm_f("c2")]);
    let e2 = c.add_entity("s2cdm");
    c.add_version(e2, &[cdm_f("c6"), cdm_f("c7")]);
    (t, c)
}

/// The figure-6 starting matrix (states before the two update events):
/// rows s1cdm.v1 {c1,c2} and s2cdm.v1 {c6,c7}; columns s1.v1 {a1,a2,a3},
/// s1.v2 {a4≡a1, a5, a6≡a2}.
pub fn fig6_matrix(t: &SchemaTree, c: &CdmTree) -> MappingMatrix {
    let mut m = MappingMatrix::new(c.n_attr_ids(), t.n_attr_ids());
    let s1 = t.schema_by_name("s1").unwrap();
    let (v1, v2) = (VersionNo(1), VersionNo(2));
    let a = |v, i: usize| t.version(s1, v).unwrap().attrs[i].index();
    let e1 = c.entity_by_name("s1cdm").unwrap();
    let e2 = c.entity_by_name("s2cdm").unwrap();
    let w1 = CdmVersionNo(1);
    let q = |e, i: usize| c.version(e, w1).unwrap().attrs[i].index();
    m.set(q(e1, 0), a(v1, 0), true); // c1 <- a1
    m.set(q(e1, 0), a(v2, 0), true); // c1 <- a4 (≡a1)
    m.set(q(e1, 1), a(v1, 2), true); // c2 <- a3
    m.set(q(e1, 1), a(v2, 2), true); // c2 <- a6 (figure: c2 maps a3 and a6≡a2)
    m.set(q(e2, 0), a(v1, 1), true); // c6 <- a2
    m.set(q(e2, 1), a(v1, 0), true); // c7 <- a1
    m
}
