//! Compaction accounting — the paper's headline ">99% / >99.9%" claims
//! (§5.2/§5.3, fig 5) measured over any matrix + tree pair.

use super::blocks;
use super::dpm::DpmSet;
use super::dusb::DusbSet;
use super::MappingMatrix;
use crate::cdm::CdmTree;
use crate::schema::SchemaTree;

/// Element counts before/after both compaction strategies.
#[derive(Debug, Clone)]
pub struct CompactionStats {
    /// Live parameter elements of `ᵢM` (sum of live block areas — the
    /// paper's matrix-size figure; dead id ranges don't count).
    pub matrix_elements: u64,
    /// Number of mapping blocks in the partition `ᵢ𝔐𝔅`.
    pub total_blocks: usize,
    /// Blocks with at least one 1.
    pub nonnull_blocks: usize,
    /// 1-elements of `ᵢM`.
    pub ones: u64,
    /// Elements stored by strategy 1 (`ᵢ𝔇𝔓𝔐`).
    pub dpm_elements: usize,
    /// Elements stored by strategy 2 (`ᵢ𝔇𝔘𝔖𝔅`).
    pub dusb_elements: usize,
    /// Special null blocks stored by strategy 2.
    pub dusb_special_nulls: usize,
}

impl CompactionStats {
    pub fn measure(
        m: &MappingMatrix,
        tree: &SchemaTree,
        cdm: &CdmTree,
        dpm: &DpmSet,
        dusb: &DusbSet,
    ) -> CompactionStats {
        let mut matrix_elements = 0u64;
        let mut total_blocks = 0usize;
        let mut nonnull_blocks = 0usize;
        for key in blocks::all_block_keys(tree, cdm) {
            // §5.1: outdated CDM versions are deleted from the matrix (the
            // tree keeps recording them) — their extents are dead and must
            // not inflate the live-element denominator (fig 5 counts 30,
            // not 42, for the worked example).
            if Some(key.w) != cdm.latest_version(key.entity) {
                continue;
            }
            let ext = blocks::block_extent(tree, cdm, key).expect("live");
            matrix_elements += ext.area();
            total_blocks += 1;
            if !blocks::is_null_block(m, &ext) {
                nonnull_blocks += 1;
            }
        }
        CompactionStats {
            matrix_elements,
            total_blocks,
            nonnull_blocks,
            ones: m.count_ones(),
            dpm_elements: dpm.n_elements(),
            dusb_elements: dusb.n_elements(),
            dusb_special_nulls: dusb.n_special_nulls(),
        }
    }

    /// Compaction ratio of strategy 1: fraction of live matrix elements
    /// *not* stored (fig 5: >99%).
    pub fn dpm_ratio(&self) -> f64 {
        1.0 - self.dpm_elements as f64 / self.matrix_elements.max(1) as f64
    }

    /// Compaction ratio of strategy 2 (special nulls counted as stored
    /// objects — they occupy a row in the store).
    pub fn dusb_ratio(&self) -> f64 {
        1.0 - (self.dusb_elements + self.dusb_special_nulls) as f64
            / self.matrix_elements.max(1) as f64
    }

    /// Null-block deletion alone (the "already compacts by 99%" step).
    pub fn null_block_ratio(&self) -> f64 {
        1.0 - self.nonnull_blocks as f64 / self.total_blocks.max(1) as f64
    }

    /// One table row for the bench harness.
    pub fn row(&self) -> String {
        format!(
            "|M|={:<12} blocks={:<8} nonnull={:<6} ones={:<8} DPM={:<8} DUSB={:<6}(+{} null) r_dpm={:.4}% r_dusb={:.4}%",
            self.matrix_elements,
            self.total_blocks,
            self.nonnull_blocks,
            self.ones,
            self.dpm_elements,
            self.dusb_elements,
            self.dusb_special_nulls,
            self.dpm_ratio() * 100.0,
            self.dusb_ratio() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::message::StateI;

    #[test]
    fn fig5_stats_match_paper_worked_example() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let stats = CompactionStats::measure(&m, &t, &c, &dpm, &dusb);
        // fig 5's live view exactly: 5 live rows (be1.v2, be2.v1, be3.v1;
        // the stale be1.v1 rows are dead per §5.1) × 6 columns = 30
        assert_eq!(stats.matrix_elements, 30);
        // 3 schema versions × 3 live entity versions
        assert_eq!(stats.total_blocks, 9);
        assert_eq!(stats.nonnull_blocks, 4);
        assert_eq!(stats.ones, 7);
        assert_eq!(stats.dpm_elements, 7);
        assert_eq!(stats.dusb_elements, 5);
        assert_eq!(stats.dusb_special_nulls, 1);
        // strategy 1 stores 7 of 30 → ratio 23/30; strategy 2 stores
        // 5 + 1 of 30 → ratio 0.80 (tiny example; scale benches hit >99%)
        assert!((stats.dpm_ratio() - 23.0 / 30.0).abs() < 1e-12);
        assert!((stats.dusb_ratio() - 0.80).abs() < 1e-12);
        assert!(stats.dusb_ratio() >= stats.dpm_ratio());
    }

    #[test]
    fn dead_cdm_version_extents_do_not_inflate_the_denominator() {
        use crate::matrix::fixtures::fig5_drop_old_cdm;
        let (t, mut c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let before = {
            let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
            let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
            CompactionStats::measure(&m, &t, &c, &dpm, &dusb)
        };
        // physically deleting be1.v1 must not change the live accounting —
        // the measure already excluded it
        fig5_drop_old_cdm(&mut c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let after = CompactionStats::measure(&m, &t, &c, &dpm, &dusb);
        assert_eq!(before.matrix_elements, after.matrix_elements);
        assert_eq!(before.total_blocks, after.total_blocks);
        assert_eq!(before.nonnull_blocks, after.nonnull_blocks);
        assert_eq!(before.dpm_ratio(), after.dpm_ratio());
    }
}
