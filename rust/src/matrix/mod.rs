//! The mapping matrix `ᵢM` and the dynamic mapping matrix (DMM) — the
//! paper's primary contribution (§4–§5).
//!
//! `ᵢM` is the `ᵢm × ᵢn` 0/1 parameter matrix over all CDM attributes
//! (rows, `q`) × all extracting attributes (columns, `p`); figure 3. It is
//! block-scoped by versioned schemata: block `ᵢMB` = (schema o, version v)
//! × (entity r, CDM version w) covers a contiguous rectangle because each
//! versioned schema owns a contiguous id range.
//!
//! Note on orientation: §4.3's prose swaps `m`/`n` relative to figure 3;
//! we follow the *figures* (and the `m_qp` index order): rows are CDM
//! attributes `c_q`, columns are extracting attributes `a_p`, and the
//! estimated row:column ratio is 1:100 (§5.2).
//!
//! The full paper-section → module map and the epoch lifecycle around
//! these sets live in `ARCHITECTURE.md` at the repository root.

pub mod blocks;
pub mod compaction;
pub mod csv_import;
pub mod decompact;
pub mod dpm;
pub mod dusb;
pub mod fixtures;
pub mod update;

use crate::cdm::{CdmVersionNo, EntityId};
use crate::schema::{SchemaId, VersionNo};

/// Identity of one mapping block `ᵢ_ov MB_rw`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockKey {
    pub schema: SchemaId,
    pub v: VersionNo,
    pub entity: EntityId,
    pub w: CdmVersionNo,
}

impl BlockKey {
    pub fn new(
        schema: SchemaId,
        v: VersionNo,
        entity: EntityId,
        w: CdmVersionNo,
    ) -> Self {
        Self { schema, v, entity, w }
    }

    /// The column super-block coordinate (paper: `𝒞` — all blocks of one
    /// versioned extracting schema).
    pub fn col_key(&self) -> (SchemaId, VersionNo) {
        (self.schema, self.v)
    }

    /// The row super-block coordinate (`ℛ`).
    pub fn row_key(&self) -> (EntityId, CdmVersionNo) {
        (self.entity, self.w)
    }

    /// The version super-block coordinate (`𝒱` — all versions of schema o
    /// against one versioned entity; the unit of Alg 3).
    pub fn version_key(&self) -> (SchemaId, EntityId, CdmVersionNo) {
        (self.schema, self.entity, self.w)
    }
}

/// The full sparse parameter matrix `ᵢM` as a row-major bitmap.
///
/// At the paper's estimated scale (§3.5: up to 10⁹ elements before the
/// §5.1 CDM-version rule) this is a 125 MB bitset — cheap enough to hold
/// as ground truth while the DMM sets do the real work.
#[derive(Debug, Clone, PartialEq)]
pub struct MappingMatrix {
    n_rows: usize,
    n_cols: usize,
    words_per_row: usize,
    bits: Vec<u64>,
}

impl MappingMatrix {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        let words_per_row = n_cols.div_ceil(64);
        Self {
            n_rows,
            n_cols,
            words_per_row,
            bits: vec![0; n_rows * words_per_row],
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Total parameter count `ᵢm × ᵢn` (the paper's "number of elements").
    pub fn n_elements(&self) -> u64 {
        self.n_rows as u64 * self.n_cols as u64
    }

    #[inline]
    pub fn get(&self, q: usize, p: usize) -> bool {
        debug_assert!(q < self.n_rows && p < self.n_cols);
        let word = self.bits[q * self.words_per_row + p / 64];
        (word >> (p % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, q: usize, p: usize, value: bool) {
        debug_assert!(q < self.n_rows && p < self.n_cols, "({q},{p}) out of ({}x{})", self.n_rows, self.n_cols);
        let word = &mut self.bits[q * self.words_per_row + p / 64];
        if value {
            *word |= 1 << (p % 64);
        } else {
            *word &= !(1 << (p % 64));
        }
    }

    /// Number of 1-elements in the whole matrix.
    pub fn count_ones(&self) -> u64 {
        self.bits.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Number of 1-elements within a rectangle.
    pub fn count_ones_in(
        &self,
        rows: std::ops::Range<usize>,
        cols: std::ops::Range<usize>,
    ) -> u64 {
        let mut n = 0;
        for q in rows {
            for p in cols.clone() {
                n += self.get(q, p) as u64;
            }
        }
        n
    }

    /// Iterate 1-elements of a rectangle as (q, p), row-major. Word-skips
    /// empty 64-column runs, so null blocks cost ~cols/64 loads per row.
    pub fn ones_in(
        &self,
        rows: std::ops::Range<usize>,
        cols: std::ops::Range<usize>,
    ) -> Vec<(usize, usize)> {
        assert!(
            rows.end <= self.n_rows && cols.end <= self.n_cols,
            "block ({rows:?},{cols:?}) outside matrix {}x{} — grow() after tree changes",
            self.n_rows,
            self.n_cols
        );
        let mut out = Vec::new();
        for q in rows {
            let row_base = q * self.words_per_row;
            let w_start = cols.start / 64;
            let w_end = (cols.end + 63) / 64;
            for wi in w_start..w_end.min(self.words_per_row) {
                let mut word = self.bits[row_base + wi];
                if word == 0 {
                    continue;
                }
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    let p = wi * 64 + bit;
                    if p >= cols.start && p < cols.end {
                        out.push((q, p));
                    }
                    word &= word - 1;
                }
            }
        }
        out
    }

    /// Grow to at least (n_rows, n_cols), preserving content. Used when
    /// version additions extend the trees (fig 6's yellow column blocks).
    pub fn grow(&mut self, n_rows: usize, n_cols: usize) {
        let n_rows = n_rows.max(self.n_rows);
        let n_cols = n_cols.max(self.n_cols);
        if n_rows == self.n_rows && n_cols == self.n_cols {
            return;
        }
        let mut next = MappingMatrix::new(n_rows, n_cols);
        for q in 0..self.n_rows {
            for wi in 0..self.words_per_row {
                let word = self.bits[q * self.words_per_row + wi];
                if word == 0 {
                    continue;
                }
                // same word layout prefix when words_per_row unchanged
                next.bits[q * next.words_per_row + wi] |= word;
            }
        }
        *self = next;
    }

    /// Zero out a rectangle (version deletions).
    pub fn clear_block(
        &mut self,
        rows: std::ops::Range<usize>,
        cols: std::ops::Range<usize>,
    ) {
        for q in rows {
            for p in cols.clone() {
                self.set(q, p, false);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count() {
        let mut m = MappingMatrix::new(5, 200);
        assert_eq!(m.count_ones(), 0);
        m.set(0, 0, true);
        m.set(4, 199, true);
        m.set(2, 64, true);
        assert!(m.get(0, 0) && m.get(4, 199) && m.get(2, 64));
        assert!(!m.get(1, 1));
        assert_eq!(m.count_ones(), 3);
        m.set(2, 64, false);
        assert_eq!(m.count_ones(), 2);
    }

    #[test]
    fn ones_in_respects_rectangle() {
        let mut m = MappingMatrix::new(10, 300);
        m.set(1, 10, true);
        m.set(1, 100, true);
        m.set(5, 10, true);
        m.set(9, 299, true);
        assert_eq!(m.ones_in(0..10, 0..300).len(), 4);
        assert_eq!(m.ones_in(0..2, 0..64), vec![(1, 10)]);
        assert_eq!(m.ones_in(1..2, 90..110), vec![(1, 100)]);
        assert_eq!(m.ones_in(6..9, 0..300), vec![]);
    }

    #[test]
    fn word_boundary_columns() {
        let mut m = MappingMatrix::new(2, 130);
        for p in [63, 64, 127, 128, 129] {
            m.set(1, p, true);
        }
        assert_eq!(
            m.ones_in(1..2, 63..130),
            vec![(1, 63), (1, 64), (1, 127), (1, 128), (1, 129)]
        );
        assert_eq!(m.ones_in(1..2, 64..128).len(), 2);
    }

    #[test]
    fn grow_preserves_content() {
        let mut m = MappingMatrix::new(3, 70);
        m.set(2, 69, true);
        m.set(0, 0, true);
        m.grow(5, 200);
        assert_eq!(m.n_rows(), 5);
        assert_eq!(m.n_cols(), 200);
        assert!(m.get(2, 69) && m.get(0, 0));
        assert_eq!(m.count_ones(), 2);
        // shrink requests are no-ops
        m.grow(1, 1);
        assert_eq!(m.n_rows(), 5);
    }

    #[test]
    fn clear_block_zeroes_rectangle() {
        let mut m = MappingMatrix::new(4, 100);
        for q in 0..4 {
            for p in 0..100 {
                m.set(q, p, true);
            }
        }
        m.clear_block(1..3, 10..20);
        assert_eq!(m.count_ones(), 400 - 20);
        assert!(!m.get(1, 10));
        assert!(m.get(0, 10) && m.get(3, 19) && m.get(1, 9));
    }

    #[test]
    fn block_key_coordinates() {
        let k = BlockKey::new(SchemaId(1), VersionNo(2), EntityId(3), CdmVersionNo(4));
        assert_eq!(k.col_key(), (SchemaId(1), VersionNo(2)));
        assert_eq!(k.row_key(), (EntityId(3), CdmVersionNo(4)));
        assert_eq!(k.version_key(), (SchemaId(1), EntityId(3), CdmVersionNo(4)));
    }
}
