//! CSV initialization/upload path (paper §5.3.1/§5.4.2: "the
//! initialisation can also be done via an upload of a CSV file", and the
//! UI "provides a good way to enforce the basic rule of the system (as
//! compared to CSV initialisation files)") — so the CSV lane must
//! validate the 1:1 rule itself and report what it had to drop.
//!
//! Format (header optional, `#` comments allowed):
//!
//! ```csv
//! schema,version,attribute,entity,cdm_version,cdm_attribute
//! payments.main,1,time,Payment,1,time_of_payment
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::dpm::{DpmBlock, DpmSet};
use super::BlockKey;
use crate::cdm::{CdmAttrId, CdmTree, CdmVersionNo};
use crate::message::StateI;
use crate::schema::{AttrId, SchemaTree, VersionNo};

/// One parsed CSV mapping row.
#[derive(Debug, Clone, PartialEq)]
pub struct CsvRow {
    pub schema: String,
    pub version: u32,
    pub attribute: String,
    pub entity: String,
    pub cdm_version: u32,
    pub cdm_attribute: String,
}

/// Import outcome: the built set plus everything the validator rejected.
#[derive(Debug)]
pub struct ImportReport {
    pub rows: usize,
    pub imported: usize,
    /// (line number, reason) for rows dropped by 1:1 enforcement or
    /// unresolvable names.
    pub rejected: Vec<(usize, String)>,
}

/// Parse CSV text into rows (no resolution yet).
pub fn parse_csv(text: &str) -> Result<Vec<(usize, CsvRow)>> {
    let mut rows = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if lineno == 0 && fields.first() == Some(&"schema") {
            continue; // header
        }
        if fields.len() != 6 {
            bail!("line {}: expected 6 fields, got {}", lineno + 1, fields.len());
        }
        let num = |s: &str, what: &str| -> Result<u32> {
            s.parse()
                .with_context(|| format!("line {}: bad {what} {s:?}", lineno + 1))
        };
        rows.push((
            lineno + 1,
            CsvRow {
                schema: fields[0].to_string(),
                version: num(fields[1], "version")?,
                attribute: fields[2].to_string(),
                entity: fields[3].to_string(),
                cdm_version: num(fields[4], "cdm_version")?,
                cdm_attribute: fields[5].to_string(),
            },
        ));
    }
    Ok(rows)
}

/// Resolve rows against the trees and build an `ᵢ𝔇𝔓𝔐`, enforcing the
/// 1:1 rule per block: later rows that double-map a row or column within
/// one block are rejected (first-wins, like the UI would refuse them).
pub fn import_dpm(
    text: &str,
    tree: &SchemaTree,
    cdm: &CdmTree,
    state: StateI,
) -> Result<(DpmSet, ImportReport)> {
    let rows = parse_csv(text)?;
    let mut report =
        ImportReport { rows: rows.len(), imported: 0, rejected: Vec::new() };
    let mut blocks: HashMap<BlockKey, Vec<(CdmAttrId, AttrId)>> =
        HashMap::new();
    for (lineno, row) in rows {
        match resolve(&row, tree, cdm) {
            Err(reason) => report.rejected.push((lineno, reason)),
            Ok((key, q, p)) => {
                let elements = blocks.entry(key).or_default();
                if elements.iter().any(|&(eq, _)| eq == q) {
                    report.rejected.push((
                        lineno,
                        format!(
                            "1:1 violation: CDM attribute {:?} already mapped \
                             in this block",
                            row.cdm_attribute
                        ),
                    ));
                } else if elements.iter().any(|&(_, ep)| ep == p) {
                    report.rejected.push((
                        lineno,
                        format!(
                            "1:1 violation: attribute {:?} already mapped in \
                             this block",
                            row.attribute
                        ),
                    ));
                } else {
                    elements.push((q, p));
                    report.imported += 1;
                }
            }
        }
    }
    let mut dpm = DpmSet::new(state);
    for (key, mut elements) in blocks {
        elements.sort();
        dpm.insert_block(DpmBlock { key, elements });
    }
    Ok((dpm, report))
}

fn resolve(
    row: &CsvRow,
    tree: &SchemaTree,
    cdm: &CdmTree,
) -> std::result::Result<(BlockKey, CdmAttrId, AttrId), String> {
    let schema = tree
        .schema_by_name(&row.schema)
        .ok_or_else(|| format!("unknown schema {:?}", row.schema))?;
    let v = VersionNo(row.version);
    let sv = tree
        .version(schema, v)
        .ok_or_else(|| format!("unknown version {} of {:?}", row.version, row.schema))?;
    let p = sv
        .attrs
        .iter()
        .copied()
        .find(|a| tree.attr(*a).name == row.attribute)
        .ok_or_else(|| {
            format!("attribute {:?} not in {:?} v{}", row.attribute, row.schema, row.version)
        })?;
    let entity = cdm
        .entity_by_name(&row.entity)
        .ok_or_else(|| format!("unknown entity {:?}", row.entity))?;
    let w = CdmVersionNo(row.cdm_version);
    let cv = cdm
        .version(entity, w)
        .ok_or_else(|| format!("unknown CDM version {} of {:?}", row.cdm_version, row.entity))?;
    let q = cv
        .attrs
        .iter()
        .copied()
        .find(|a| cdm.attr(*a).name == row.cdm_attribute)
        .ok_or_else(|| {
            format!("CDM attribute {:?} not in {:?} v{}", row.cdm_attribute, row.entity, row.cdm_version)
        })?;
    Ok((BlockKey::new(schema, v, entity, w), q, p))
}

/// Export an `ᵢ𝔇𝔓𝔐` back to the CSV format (round-trip / backup lane).
pub fn export_dpm(dpm: &DpmSet, tree: &SchemaTree, cdm: &CdmTree) -> String {
    let mut out =
        String::from("schema,version,attribute,entity,cdm_version,cdm_attribute\n");
    let mut blocks: Vec<_> = dpm.blocks().collect();
    blocks.sort_by_key(|b| b.key);
    for block in blocks {
        let schema = tree.schema(block.key.schema);
        let entity = cdm.entity(block.key.entity);
        for &(q, p) in &block.elements {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                schema.name,
                block.key.v.0,
                tree.attr(p).name,
                entity.name,
                block.key.w.0,
                cdm.attr(q).name
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};

    #[test]
    fn parse_basic_csv() {
        let text = "schema,version,attribute,entity,cdm_version,cdm_attribute\n\
                    # comment\n\
                    s1,1,a1,be1,2,c3\n\
                    \n\
                    s1,1,a3,be1,2,c4\n";
        let rows = parse_csv(text).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].1.attribute, "a1");
        assert_eq!(rows[1].0, 5); // line number preserved
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse_csv("a,b,c\n").is_err());
        assert!(parse_csv("s1,x,a1,be1,2,c3\n").is_err());
    }

    #[test]
    fn import_builds_fig5_dpm() {
        let (t, c) = fig5_trees();
        let text = "\
            s1,1,a1,be1,2,c3\n\
            s1,1,a3,be1,2,c4\n\
            s1,2,a1,be1,2,c3\n\
            s1,2,a3,be1,2,c4\n\
            s2,1,a6,be2,1,c5\n\
            s1,1,a2,be3,1,c6\n\
            s1,1,a1,be3,1,c7\n";
        let (dpm, report) = import_dpm(text, &t, &c, StateI(0)).unwrap();
        assert_eq!(report.imported, 7);
        assert!(report.rejected.is_empty());
        // equals the fixture matrix compiled through Alg 2
        let m = fig5_matrix(&t, &c);
        let direct =
            crate::matrix::dpm::DpmSet::from_matrix(&m, &t, &c, StateI(0))
                .unwrap();
        assert!(dpm.same_elements(&direct));
    }

    #[test]
    fn import_enforces_one_to_one() {
        let (t, c) = fig5_trees();
        let text = "\
            s1,1,a1,be1,2,c3\n\
            s1,1,a2,be1,2,c3\n\
            s1,1,a1,be1,2,c4\n";
        let (dpm, report) = import_dpm(text, &t, &c, StateI(0)).unwrap();
        assert_eq!(report.imported, 1);
        assert_eq!(report.rejected.len(), 2);
        assert!(report.rejected[0].1.contains("1:1 violation"));
        assert_eq!(dpm.n_elements(), 1);
    }

    #[test]
    fn import_reports_unresolvable_names() {
        let (t, c) = fig5_trees();
        let text = "\
            ghost,1,a1,be1,2,c3\n\
            s1,9,a1,be1,2,c3\n\
            s1,1,zz,be1,2,c3\n\
            s1,1,a1,be9,1,c3\n\
            s1,1,a1,be1,2,zz\n";
        let (dpm, report) = import_dpm(text, &t, &c, StateI(0)).unwrap();
        assert_eq!(report.imported, 0);
        assert_eq!(report.rejected.len(), 5);
        assert_eq!(dpm.n_blocks(), 0);
    }

    #[test]
    fn export_import_roundtrip() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = crate::matrix::dpm::DpmSet::from_matrix(&m, &t, &c, StateI(0))
            .unwrap();
        let csv = export_dpm(&dpm, &t, &c);
        let (back, report) = import_dpm(&csv, &t, &c, StateI(0)).unwrap();
        assert!(report.rejected.is_empty());
        assert!(back.same_elements(&dpm));
    }
}
