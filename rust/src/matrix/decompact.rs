//! Decompaction paths (paper §5.3.3) and the hybrid restore pipeline
//! (§6.2): `ᵢ𝔇𝔘𝔖𝔅 → ᵢM → ᵢ𝔇𝔓𝔐`.
//!
//! The direct decompactions live on the sets themselves
//! ([`DpmSet::decompact`], [`DusbSet::decompact`]); this module provides
//! the composed restore used when the app restarts from the store or a
//! configuration is copied to another instance.

use super::blocks::ConstraintViolation;
use super::dpm::DpmSet;
use super::dusb::DusbSet;
use crate::cdm::CdmTree;
use crate::schema::SchemaTree;

/// Recreate the in-memory `ᵢ𝔇𝔓𝔐` from the stored `ᵢ𝔇𝔘𝔖𝔅` — the
/// "two algorithms" path of §6.2 (Alg 4 then Alg 2).
pub fn recreate_dpm(
    dusb: &DusbSet,
    tree: &SchemaTree,
    cdm: &CdmTree,
) -> Result<DpmSet, ConstraintViolation> {
    let m = dusb.decompact(tree, cdm);
    DpmSet::from_matrix(&m, tree, cdm, dusb.state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::message::StateI;

    #[test]
    fn restore_pipeline_matches_direct_build() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let direct = DpmSet::from_matrix(&m, &t, &c, StateI(3)).unwrap();
        let dusb = DusbSet::from_matrix(&m, &t, &c, StateI(3)).unwrap();
        let restored = recreate_dpm(&dusb, &t, &c).unwrap();
        assert!(direct.same_elements(&restored));
        assert_eq!(restored.state, StateI(3));
    }
}
