//! Pipeline metrics + the fig-7 dashboard: "we record the number of
//! transformations, the time they take and the storage requirements of the
//! Caffeine cache" (§7).
//!
//! Two machine-readable views sit next to the human dashboard:
//! [`PipelineMetrics::expose_text`] renders a Prometheus-style text
//! exposition with stable metric names (see ARCHITECTURE.md
//! §Observability for the full table) and [`PipelineMetrics::snapshot`]
//! the same data as a JSON document.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::util::json::Json;
use crate::util::stats::{format_ns, LatencyRecorder, LogHistogram, Summary};

/// A monotonically increasing counter, cache-line-padded so the hot-path
/// counters of [`PipelineMetrics`] don't false-share under horizontal
/// scaling (every event bumps three of them).
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value gauge (e.g. the published DMM epoch).
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Counters of one worker shard of the sharded mapping lane.
#[derive(Debug, Default)]
pub struct ShardCounter {
    /// CDC events this shard consumed.
    pub events: Counter,
    /// CDM messages this shard produced.
    pub out: Counter,
}

/// Per-shard counter registry. Shards register lazily so
/// [`PipelineMetrics`] stays `Default` while the shard count is a runtime
/// knob (`PipelineConfig::shards`).
#[derive(Debug, Default)]
pub struct ShardCounters {
    shards: RwLock<Vec<Arc<ShardCounter>>>,
}

impl ShardCounters {
    /// Counter handle for shard `idx`, growing the registry as needed.
    pub fn shard(&self, idx: usize) -> Arc<ShardCounter> {
        if let Some(c) = self.shards.read().unwrap().get(idx) {
            return Arc::clone(c);
        }
        let mut shards = self.shards.write().unwrap();
        while shards.len() <= idx {
            shards.push(Arc::new(ShardCounter::default()));
        }
        Arc::clone(&shards[idx])
    }

    /// Events consumed per shard, in shard order.
    pub fn events_per_shard(&self) -> Vec<u64> {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|c| c.events.get())
            .collect()
    }

    /// `(events, out)` per shard, in shard order.
    pub fn rows(&self) -> Vec<(u64, u64)> {
        self.shards
            .read()
            .unwrap()
            .iter()
            .map(|c| (c.events.get(), c.out.get()))
            .collect()
    }
}

/// Counters/gauges of one registered sink backend (its consumer group
/// over the CDM topic): records drained into it, sink-reported
/// duplicates/drops, current consumer lag.
#[derive(Debug, Default)]
pub struct SinkMetrics {
    /// Records delivered to the sink by its drain loop (at-least-once:
    /// includes redeliveries; the backend's own accepted count is
    /// `SinkStats::applied`).
    pub drained: Counter,
    /// At-least-once redeliveries the sink deduplicated (last snapshot).
    pub duplicates: Gauge,
    /// Records the sink intentionally skipped (last snapshot).
    pub dropped: Gauge,
    /// CDM-topic records not yet consumed by this sink's group.
    pub lag: Gauge,
    /// Failed flush attempts (buffered backends).
    pub flush_errors: Counter,
}

/// One dashboard row of a sink's metrics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SinkMetricsRow {
    pub name: String,
    pub drained: u64,
    pub duplicates: u64,
    pub dropped: u64,
    pub lag: u64,
    pub flush_errors: u64,
}

/// Per-sink metrics registry. Sinks register lazily at pipeline build so
/// [`PipelineMetrics`] stays `Default` while the sink set is a runtime
/// knob (`PipelineConfig::sinks` / `PipelineBuilder::sink`).
#[derive(Debug, Default)]
pub struct SinkMetricsRegistry {
    sinks: RwLock<Vec<(String, Arc<SinkMetrics>)>>,
}

impl SinkMetricsRegistry {
    /// Metrics handle for `name`, registering it on first use. Sinks
    /// sharing a name share a handle.
    pub fn register(&self, name: &str) -> Arc<SinkMetrics> {
        if let Some((_, m)) = self
            .sinks
            .read()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
        {
            return Arc::clone(m);
        }
        let mut sinks = self.sinks.write().unwrap();
        if let Some((_, m)) = sinks.iter().find(|(n, _)| n == name) {
            return Arc::clone(m);
        }
        let m = Arc::new(SinkMetrics::default());
        sinks.push((name.to_string(), Arc::clone(&m)));
        m
    }

    /// Dashboard rows in registration order.
    pub fn rows(&self) -> Vec<SinkMetricsRow> {
        self.sinks
            .read()
            .unwrap()
            .iter()
            .map(|(name, m)| SinkMetricsRow {
                name: name.clone(),
                drained: m.drained.get(),
                duplicates: m.duplicates.get(),
                dropped: m.dropped.get(),
                lag: m.lag.get(),
                flush_errors: m.flush_errors.get(),
            })
            .collect()
    }
}

/// Thread-safe latency channel (recorder + histogram), sharded to keep
/// scaled instances off each other's locks (perf: EXPERIMENTS.md §Perf —
/// a single Mutex here serialized the horizontally scaled pipeline).
#[derive(Debug)]
pub struct LatencyChannel {
    shards: Vec<Shard>,
}

#[derive(Debug, Default)]
#[repr(align(64))] // one cache line per shard
struct Shard {
    inner: Mutex<(LatencyRecorder, LogHistogram)>,
}

impl Default for LatencyChannel {
    fn default() -> Self {
        Self { shards: (0..16).map(|_| Shard::default()).collect() }
    }
}

impl LatencyChannel {
    fn shard(&self) -> &Shard {
        // cheap per-thread affinity: hash the thread id
        let id = std::thread::current().id();
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::hash::Hash::hash(&id, &mut h);
        let idx = std::hash::Hasher::finish(&h) as usize % self.shards.len();
        &self.shards[idx]
    }

    pub fn record(&self, d: std::time::Duration) {
        let mut g = self.shard().inner.lock().unwrap();
        g.0.record(d);
        g.1.record_ns(d.as_nanos() as u64);
    }

    fn merged(&self) -> LatencyRecorder {
        let mut all = LatencyRecorder::new();
        for s in &self.shards {
            all.merge(&s.inner.lock().unwrap().0);
        }
        all
    }

    pub fn summary(&self) -> Summary {
        self.merged().summary()
    }

    /// Shard histograms merged bucket-wise — no sample replay, so cost is
    /// O(shards × buckets) regardless of how much was recorded.
    pub fn merged_histogram(&self) -> LogHistogram {
        let mut merged = LogHistogram::new();
        for s in &self.shards {
            merged.merge(&s.inner.lock().unwrap().1);
        }
        merged
    }

    pub fn histogram(&self) -> String {
        self.merged_histogram().render()
    }

    pub fn count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.inner.lock().unwrap().0.len())
            .sum()
    }

    pub fn samples(&self) -> Vec<f64> {
        self.merged().samples().to_vec()
    }
}

/// Counters/gauges of the durable matrix store (WAL + segment snapshots):
/// shared by `Arc` between [`PipelineMetrics`] and the
/// `store::MatrixStore` so the dashboard sees live values.
#[derive(Debug, Default)]
pub struct StoreMetrics {
    /// Bytes appended to the write-ahead log (frames, incl. headers).
    pub wal_bytes: Counter,
    /// fsync calls issued on the WAL (one per committed update under the
    /// default `fsync = always` policy).
    pub wal_fsyncs: Counter,
    /// Segment files referenced by the live manifest (0 or 1 today; the
    /// gauge form survives a future multi-level store).
    pub segments_live: Gauge,
    /// Obsolete segment files garbage-collected after a manifest swap.
    pub segment_gc_total: Counter,
    /// Wall-clock duration of the last `restore_from_store` recovery, ms.
    pub recovery_ms: Gauge,
    /// WAL-tail records replayed through Alg-5 across all recoveries.
    pub replayed_updates: Counter,
}

/// Counters of the tracing subsystem itself: shared by `Arc` between
/// [`PipelineMetrics`] and the `trace::Tracer` so conservation checks and
/// exposition see live values.
#[derive(Debug, Default)]
pub struct TraceMetrics {
    /// Spans admitted to the span buffer.
    pub spans: Counter,
    /// Spans dropped on buffer/trace overflow — surfaced by the scenario
    /// conservation checks, never silent.
    pub spans_dropped: Counter,
    /// Event traces completed (one per consumed CDC event when tracing
    /// is enabled, dead-lettered events included).
    pub traces: Counter,
    /// Flight-recorder dumps taken (dead-letter, flush error, recovery).
    pub flight_dumps: Counter,
}

/// Counters of the segmented broker core: shared by `Arc` between
/// [`PipelineMetrics`] and every `broker::Topic` the pipeline creates
/// (CDC ingress and CDM egress report into the same instance).
#[derive(Debug, Default)]
pub struct BrokerMetrics {
    /// Log segments allocated across all topics/partitions (head segments
    /// included) — growth of the append-only chains.
    pub segments_allocated: Counter,
    /// Batch appends published (one per touched partition per
    /// `produce`/`produce_batch` call — each is one atomic publish).
    pub produce_batches: Counter,
    /// `SharedBatch` views handed out by the zero-copy fetch path.
    pub fetch_batches: Counter,
    /// Bytes sealed into arena-backed CDM record slabs (one slab per
    /// produced batch instead of one `Arc` allocation per record).
    pub arena_bytes: Counter,
}

/// Cache-side values the exposition needs but `PipelineMetrics` doesn't
/// own (they live in the `DcpmCache` / kernel `PlanCache`).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheView {
    /// Resident bytes of the DCPM cache (the paper's fig-7 storage axis).
    pub bytes: usize,
    /// DCPM column-cache hit rate in `[0, 1]`.
    pub hit_rate: f64,
    /// Native-kernel plan-cache hits.
    pub plan_hits: u64,
    /// Native-kernel plan-cache misses.
    pub plan_misses: u64,
}

/// All counters/latencies of one METL deployment.
#[derive(Debug, Default)]
pub struct PipelineMetrics {
    /// CDC events consumed from the source topics.
    pub events_in: Counter,
    /// Outgoing CDM messages produced.
    pub messages_out: Counter,
    /// Mapping operations (transformations) executed.
    pub transformations: Counter,
    /// Events routed to the dead-letter queue.
    pub dead_letters: Counter,
    /// State-sync retries (§3.4 out-of-sync, recovered).
    pub sync_retries: Counter,
    /// DMM updates applied (state transitions).
    pub dmm_updates: Counter,
    /// Schema-change events rejected as incompatible by the evolution
    /// lane (epoch and state untouched).
    pub rejected_changes: Counter,
    /// Schema-change events observed but not yet applied (the evolution
    /// lane's backlog — how far the published epoch lags the wire).
    pub epoch_lag: Gauge,
    /// Events served through the XLA bulk lane.
    pub bulk_events: Counter,
    /// Published DMM epoch (bumped on every snapshot swap).
    pub dmm_epoch: Gauge,
    /// Per-shard counters of the sharded mapping lane.
    pub shard: ShardCounters,
    /// Durable-store counters (WAL, segments, recovery).
    pub store: Arc<StoreMetrics>,
    /// Per-sink counters/gauges of the registered egress backends.
    pub sinks: SinkMetricsRegistry,
    /// Tracing-subsystem counters (span/trace/dump accounting).
    pub trace: Arc<TraceMetrics>,
    /// Segmented-broker counters (segment growth, batch I/O, arenas).
    pub broker: Arc<BrokerMetrics>,
    /// Per-event consume + provenance-stamp overhead.
    pub ingest_latency: LatencyChannel,
    /// Per-event full mapping latency (the §7 headline metric).
    pub map_latency: LatencyChannel,
    /// Per-drain-batch sink apply+flush latency.
    pub egress_latency: LatencyChannel,
    /// Per-commit durable-store WAL latency.
    pub store_latency: LatencyChannel,
    /// End-to-end latency source-commit → DW-visible.
    pub e2e_latency: LatencyChannel,
    /// Per-change evolution-lane latency: event consumed → new epoch live.
    pub update_latency: LatencyChannel,
}

/// The stage-latency channels exported with stable `stage=` labels.
const STAGE_CHANNELS: [&str; 6] =
    ["ingest", "map", "egress", "store", "update", "e2e"];

impl PipelineMetrics {
    /// The stage-latency channel registered under `name` (one of
    /// `ingest|map|egress|store|update|e2e`).
    fn stage_channel(&self, name: &str) -> &LatencyChannel {
        match name {
            "ingest" => &self.ingest_latency,
            "map" => &self.map_latency,
            "egress" => &self.egress_latency,
            "store" => &self.store_latency,
            "update" => &self.update_latency,
            "e2e" => &self.e2e_latency,
            other => panic!("unknown stage channel {other}"),
        }
    }

    /// Render the fig-7 style text dashboard.
    pub fn dashboard(&self, cache_bytes: usize, cache_hit_rate: f64) -> String {
        let s = self.map_latency.summary();
        let mut out = String::new();
        out.push_str("+---------------- METL dashboard ----------------+\n");
        out.push_str(&format!(
            "| transformations   {:>12}  out msgs {:>9} |\n",
            self.transformations.get(),
            self.messages_out.get()
        ));
        out.push_str(&format!(
            "| events in         {:>12}  bulk     {:>9} |\n",
            self.events_in.get(),
            self.bulk_events.get()
        ));
        out.push_str(&format!(
            "| dead letters      {:>12}  retries  {:>9} |\n",
            self.dead_letters.get(),
            self.sync_retries.get()
        ));
        out.push_str(&format!(
            "| dmm updates       {:>12}  epoch    {:>9} |\n",
            self.dmm_updates.get(),
            self.dmm_epoch.get()
        ));
        out.push_str(&format!(
            "| evo rejected      {:>12}  epoch lag{:>9} |\n",
            self.rejected_changes.get(),
            self.epoch_lag.get()
        ));
        let u = self.update_latency.summary();
        out.push_str(&format!(
            "| update latency    mean {:>9} p99 {:>9}    |\n",
            format_ns(u.mean),
            format_ns(u.p99)
        ));
        out.push_str(&format!(
            "| map latency  mean {:>9} sigma {:>9} n={:<6} |\n",
            format_ns(s.mean),
            format_ns(s.std),
            s.count
        ));
        out.push_str(&format!(
            "|              p50  {:>9} p99   {:>9}          |\n",
            format_ns(s.p50),
            format_ns(s.p99)
        ));
        out.push_str(&format!(
            "| cache    {:>8} bytes   hit-rate {:>6.2}%        |\n",
            cache_bytes,
            cache_hit_rate * 100.0
        ));
        out.push_str(&format!(
            "| wal bytes         {:>12}  fsyncs   {:>9} |\n",
            self.store.wal_bytes.get(),
            self.store.wal_fsyncs.get()
        ));
        out.push_str(&format!(
            "| segments live     {:>12}  gc total {:>9} |\n",
            self.store.segments_live.get(),
            self.store.segment_gc_total.get()
        ));
        out.push_str(&format!(
            "| recovery ms       {:>12}  replayed {:>9} |\n",
            self.store.recovery_ms.get(),
            self.store.replayed_updates.get()
        ));
        out.push_str(&format!(
            "| broker segs       {:>12}  arena B  {:>9} |\n",
            self.broker.segments_allocated.get(),
            self.broker.arena_bytes.get()
        ));
        let ing = self.ingest_latency.summary();
        let eg = self.egress_latency.summary();
        let st = self.store_latency.summary();
        out.push_str(&format!(
            "| stage p99  ingest {:>9} egress {:>9}       |\n",
            format_ns(ing.p99),
            format_ns(eg.p99)
        ));
        out.push_str(&format!(
            "|            store  {:>9}                       |\n",
            format_ns(st.p99)
        ));
        out.push_str(&format!(
            "| trace spans       {:>12}  dropped  {:>9} |\n",
            self.trace.spans.get(),
            self.trace.spans_dropped.get()
        ));
        out.push_str(&format!(
            "| trace completed   {:>12}  dumps    {:>9} |\n",
            self.trace.traces.get(),
            self.trace.flight_dumps.get()
        ));
        for row in self.sinks.rows() {
            out.push_str(&format!(
                "| sink {:<7} drained {:>9} dup {:>5} lag {:>5} |\n",
                row.name, row.drained, row.duplicates, row.lag
            ));
            if row.flush_errors > 0 {
                out.push_str(&format!(
                    "|      {:<7} FLUSH ERRORS {:>24} |\n",
                    row.name, row.flush_errors
                ));
            }
        }
        out.push_str("+------------------------------------------------+\n");
        out.push_str("map latency histogram:\n");
        out.push_str(&self.map_latency.histogram());
        out
    }

    /// Prometheus-style text exposition. Metric names are a stable
    /// contract (golden-tested; table in ARCHITECTURE.md §Observability):
    /// renaming one is a breaking change for scrapers.
    pub fn expose_text(&self, cache: &CacheView) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, v: u64| {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        };
        counter("metl_events_in_total", self.events_in.get());
        counter("metl_messages_out_total", self.messages_out.get());
        counter("metl_transformations_total", self.transformations.get());
        counter("metl_dead_letters_total", self.dead_letters.get());
        counter("metl_sync_retries_total", self.sync_retries.get());
        counter("metl_dmm_updates_total", self.dmm_updates.get());
        counter("metl_rejected_changes_total", self.rejected_changes.get());
        counter("metl_bulk_events_total", self.bulk_events.get());
        counter("metl_trace_spans_total", self.trace.spans.get());
        counter("metl_trace_spans_dropped_total", self.trace.spans_dropped.get());
        counter("metl_trace_traces_total", self.trace.traces.get());
        counter("metl_trace_flight_dumps_total", self.trace.flight_dumps.get());
        counter("metl_store_wal_bytes_total", self.store.wal_bytes.get());
        counter("metl_store_wal_fsyncs_total", self.store.wal_fsyncs.get());
        counter("metl_store_segment_gc_total", self.store.segment_gc_total.get());
        counter(
            "metl_store_replayed_updates_total",
            self.store.replayed_updates.get(),
        );
        counter("metl_plan_cache_hits_total", cache.plan_hits);
        counter("metl_plan_cache_misses_total", cache.plan_misses);
        counter(
            "metl_broker_segments_allocated_total",
            self.broker.segments_allocated.get(),
        );
        counter(
            "metl_broker_produce_batches_total",
            self.broker.produce_batches.get(),
        );
        counter(
            "metl_broker_fetch_batches_total",
            self.broker.fetch_batches.get(),
        );
        counter("metl_broker_arena_bytes_total", self.broker.arena_bytes.get());

        let mut gauge = |name: &str, v: f64| {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        };
        gauge("metl_dmm_epoch", self.dmm_epoch.get() as f64);
        gauge("metl_epoch_lag", self.epoch_lag.get() as f64);
        gauge("metl_store_segments_live", self.store.segments_live.get() as f64);
        gauge("metl_store_recovery_ms", self.store.recovery_ms.get() as f64);
        gauge("metl_cache_bytes", cache.bytes as f64);
        gauge("metl_cache_hit_rate", cache.hit_rate);

        out.push_str("# TYPE metl_shard_events_total counter\n");
        out.push_str("# TYPE metl_shard_out_total counter\n");
        for (i, (events, msgs)) in self.shard.rows().iter().enumerate() {
            out.push_str(&format!(
                "metl_shard_events_total{{shard=\"{i}\"}} {events}\n"
            ));
            out.push_str(&format!("metl_shard_out_total{{shard=\"{i}\"}} {msgs}\n"));
        }

        out.push_str("# TYPE metl_sink_drained_total counter\n");
        out.push_str("# TYPE metl_sink_flush_errors_total counter\n");
        out.push_str("# TYPE metl_sink_duplicates gauge\n");
        out.push_str("# TYPE metl_sink_dropped gauge\n");
        out.push_str("# TYPE metl_sink_lag gauge\n");
        for row in self.sinks.rows() {
            let n = &row.name;
            out.push_str(&format!(
                "metl_sink_drained_total{{sink=\"{n}\"}} {}\n",
                row.drained
            ));
            out.push_str(&format!(
                "metl_sink_flush_errors_total{{sink=\"{n}\"}} {}\n",
                row.flush_errors
            ));
            out.push_str(&format!(
                "metl_sink_duplicates{{sink=\"{n}\"}} {}\n",
                row.duplicates
            ));
            out.push_str(&format!(
                "metl_sink_dropped{{sink=\"{n}\"}} {}\n",
                row.dropped
            ));
            out.push_str(&format!("metl_sink_lag{{sink=\"{n}\"}} {}\n", row.lag));
        }

        out.push_str("# TYPE metl_stage_latency_ns summary\n");
        for stage in STAGE_CHANNELS {
            let s = self.stage_channel(stage).summary();
            for (q, v) in
                [("0.5", s.p50), ("0.9", s.p90), ("0.99", s.p99)]
            {
                out.push_str(&format!(
                    "metl_stage_latency_ns{{stage=\"{stage}\",quantile=\"{q}\"}} {v}\n"
                ));
            }
            out.push_str(&format!(
                "metl_stage_latency_ns_sum{{stage=\"{stage}\"}} {}\n",
                s.mean * s.count as f64
            ));
            out.push_str(&format!(
                "metl_stage_latency_ns_count{{stage=\"{stage}\"}} {}\n",
                s.count
            ));
        }
        out
    }

    /// The same data as [`PipelineMetrics::expose_text`], as one JSON
    /// document (for dashboards that want structure, and for tests).
    pub fn snapshot(&self, cache: &CacheView) -> Json {
        let mut counters = Json::obj();
        counters.set("events_in", Json::Num(self.events_in.get() as f64));
        counters.set("messages_out", Json::Num(self.messages_out.get() as f64));
        counters.set(
            "transformations",
            Json::Num(self.transformations.get() as f64),
        );
        counters.set("dead_letters", Json::Num(self.dead_letters.get() as f64));
        counters.set("sync_retries", Json::Num(self.sync_retries.get() as f64));
        counters.set("dmm_updates", Json::Num(self.dmm_updates.get() as f64));
        counters.set(
            "rejected_changes",
            Json::Num(self.rejected_changes.get() as f64),
        );
        counters.set("bulk_events", Json::Num(self.bulk_events.get() as f64));
        counters.set("dmm_epoch", Json::Num(self.dmm_epoch.get() as f64));
        counters.set("epoch_lag", Json::Num(self.epoch_lag.get() as f64));

        let mut trace = Json::obj();
        trace.set("spans", Json::Num(self.trace.spans.get() as f64));
        trace.set(
            "spans_dropped",
            Json::Num(self.trace.spans_dropped.get() as f64),
        );
        trace.set("traces", Json::Num(self.trace.traces.get() as f64));
        trace.set(
            "flight_dumps",
            Json::Num(self.trace.flight_dumps.get() as f64),
        );

        let mut store = Json::obj();
        store.set("wal_bytes", Json::Num(self.store.wal_bytes.get() as f64));
        store.set("wal_fsyncs", Json::Num(self.store.wal_fsyncs.get() as f64));
        store.set(
            "segments_live",
            Json::Num(self.store.segments_live.get() as f64),
        );
        store.set(
            "segment_gc_total",
            Json::Num(self.store.segment_gc_total.get() as f64),
        );
        store.set("recovery_ms", Json::Num(self.store.recovery_ms.get() as f64));
        store.set(
            "replayed_updates",
            Json::Num(self.store.replayed_updates.get() as f64),
        );

        let mut broker = Json::obj();
        broker.set(
            "segments_allocated",
            Json::Num(self.broker.segments_allocated.get() as f64),
        );
        broker.set(
            "produce_batches",
            Json::Num(self.broker.produce_batches.get() as f64),
        );
        broker.set(
            "fetch_batches",
            Json::Num(self.broker.fetch_batches.get() as f64),
        );
        broker.set(
            "arena_bytes",
            Json::Num(self.broker.arena_bytes.get() as f64),
        );

        let mut cache_obj = Json::obj();
        cache_obj.set("bytes", Json::Num(cache.bytes as f64));
        cache_obj.set("hit_rate", Json::Num(cache.hit_rate));
        cache_obj.set("plan_hits", Json::Num(cache.plan_hits as f64));
        cache_obj.set("plan_misses", Json::Num(cache.plan_misses as f64));

        let mut stages = Json::obj();
        for stage in STAGE_CHANNELS {
            let s = self.stage_channel(stage).summary();
            let mut o = Json::obj();
            o.set("count", Json::Num(s.count as f64));
            o.set("mean_ns", Json::Num(s.mean));
            o.set("std_ns", Json::Num(s.std));
            o.set("p50_ns", Json::Num(s.p50));
            o.set("p90_ns", Json::Num(s.p90));
            o.set("p99_ns", Json::Num(s.p99));
            o.set("max_ns", Json::Num(s.max));
            stages.set(stage, o);
        }

        let shards = Json::Arr(
            self.shard
                .rows()
                .iter()
                .map(|(events, msgs)| {
                    let mut o = Json::obj();
                    o.set("events", Json::Num(*events as f64));
                    o.set("out", Json::Num(*msgs as f64));
                    o
                })
                .collect(),
        );

        let sinks = Json::Arr(
            self.sinks
                .rows()
                .iter()
                .map(|row| {
                    let mut o = Json::obj();
                    o.set("name", Json::Str(row.name.clone()));
                    o.set("drained", Json::Num(row.drained as f64));
                    o.set("duplicates", Json::Num(row.duplicates as f64));
                    o.set("dropped", Json::Num(row.dropped as f64));
                    o.set("lag", Json::Num(row.lag as f64));
                    o.set("flush_errors", Json::Num(row.flush_errors as f64));
                    o
                })
                .collect(),
        );

        let mut doc = Json::obj();
        doc.set("counters", counters);
        doc.set("trace", trace);
        doc.set("store", store);
        doc.set("broker", broker);
        doc.set("cache", cache_obj);
        doc.set("stages", stages);
        doc.set("shards", shards);
        doc.set("sinks", sinks);
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_holds_last_value() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0);
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn shard_counters_register_lazily() {
        let s = ShardCounters::default();
        s.shard(2).events.add(5);
        s.shard(0).events.inc();
        // shard 1 was implicitly created at zero
        assert_eq!(s.events_per_shard(), vec![1, 0, 5]);
        // handles are stable
        let h = s.shard(2);
        h.out.add(4);
        assert_eq!(s.shard(2).out.get(), 4);
    }

    #[test]
    fn sink_registry_registers_once_and_reports_rows() {
        let m = PipelineMetrics::default();
        let dw = m.sinks.register("dw");
        dw.drained.add(7);
        dw.lag.set(2);
        // re-registration returns the same handle
        m.sinks.register("dw").drained.inc();
        m.sinks.register("ml").dropped.set(3);
        m.sinks.register("ml").flush_errors.inc();
        let rows = m.sinks.rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "dw");
        assert_eq!(rows[0].drained, 8);
        assert_eq!(rows[0].lag, 2);
        assert_eq!(rows[1].dropped, 3);
        assert_eq!(rows[1].flush_errors, 1);
        let dash = m.dashboard(0, 0.0);
        assert!(dash.contains("sink dw"));
        assert!(dash.contains("sink ml"));
        assert!(dash.contains("FLUSH ERRORS"));
    }

    #[test]
    fn latency_channel_summary() {
        let ch = LatencyChannel::default();
        for ms in [1u64, 2, 3] {
            ch.record(Duration::from_millis(ms));
        }
        let s = ch.summary();
        assert_eq!(s.count, 3);
        assert!((s.mean - 2e6).abs() < 1e3);
        assert_eq!(ch.count(), 3);
    }

    #[test]
    fn dashboard_renders() {
        let m = PipelineMetrics::default();
        m.events_in.add(1168);
        m.transformations.add(1168);
        m.map_latency.record(Duration::from_millis(39));
        m.rejected_changes.add(2);
        m.epoch_lag.set(4);
        m.update_latency.record(Duration::from_millis(7));
        let d = m.dashboard(1024, 0.97);
        assert!(d.contains("1168"));
        assert!(d.contains("39.00ms"));
        assert!(d.contains("97.00%"));
        assert!(d.contains("evo rejected"));
        assert!(d.contains("update latency"));
        assert!(d.contains("7.00ms"));
    }

    #[test]
    fn dashboard_has_store_rows() {
        let m = PipelineMetrics::default();
        m.store.wal_bytes.add(2048);
        m.store.wal_fsyncs.add(3);
        m.store.segments_live.set(1);
        m.store.segment_gc_total.add(2);
        m.store.recovery_ms.set(17);
        m.store.replayed_updates.add(5);
        let d = m.dashboard(0, 0.0);
        assert!(d.contains("wal bytes"));
        assert!(d.contains("2048"));
        assert!(d.contains("segments live"));
        assert!(d.contains("recovery ms"));
        assert!(d.contains("replayed"));
    }
}
