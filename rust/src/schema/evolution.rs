//! Schema-evolution rules (paper §3.3): the registry enforces versioning
//! discipline — forward/backward compatibility and the "one single changed
//! attribute" rule for semi-automated update workflows.

use super::attribute::ExtractType;

/// Compatibility mode of a schema subject (Avro/Apicurio-style, §3.3:
/// "one allows the deletions of attributes, the other one additions").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compatibility {
    /// New consumers read old data: additions only (with defaults/optional).
    Backward,
    /// Old consumers read new data: deletions only.
    Forward,
    /// Both: renames/retypes forbidden, additions must be optional.
    Full,
    /// No checking (used by tests and free-form sims).
    None,
}

impl std::str::FromStr for Compatibility {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "backward" => Ok(Compatibility::Backward),
            "forward" => Ok(Compatibility::Forward),
            "full" => Ok(Compatibility::Full),
            "none" => Ok(Compatibility::None),
            other => Err(format!(
                "unknown compatibility {other:?} (backward|forward|full|none)"
            )),
        }
    }
}

/// The diff between two consecutive schema versions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VersionDiff {
    pub added: Vec<String>,
    pub removed: Vec<String>,
    pub retyped: Vec<(String, ExtractType, ExtractType)>,
}

impl VersionDiff {
    /// Diff two full field lists `(name, type, optional)` by name: fields
    /// only in `next` are additions, fields only in `prev` are removals,
    /// and a shared name with a different type is a retype.
    ///
    /// ```
    /// use metl::schema::{ExtractType, VersionDiff};
    ///
    /// let prev = vec![("id".to_string(), ExtractType::Int64, false)];
    /// let next = vec![
    ///     ("id".to_string(), ExtractType::Int64, false),
    ///     ("currency".to_string(), ExtractType::Varchar, true),
    /// ];
    /// let diff = VersionDiff::compute(&prev, &next);
    /// assert_eq!(diff.added, vec!["currency".to_string()]);
    /// assert!(diff.removed.is_empty() && diff.retyped.is_empty());
    /// assert_eq!(diff.change_count(), 1);
    /// ```
    pub fn compute(
        prev: &[(String, ExtractType, bool)],
        next: &[(String, ExtractType, bool)],
    ) -> VersionDiff {
        let mut diff = VersionDiff::default();
        for (name, ty, _) in next {
            match prev.iter().find(|(n, _, _)| n == name) {
                None => diff.added.push(name.clone()),
                Some((_, pty, _)) if pty != ty => {
                    diff.retyped.push((name.clone(), *pty, *ty))
                }
                Some(_) => {}
            }
        }
        for (name, _, _) in prev {
            if !next.iter().any(|(n, _, _)| n == name) {
                diff.removed.push(name.clone());
            }
        }
        diff
    }

    /// Total number of changed attributes.
    pub fn change_count(&self) -> usize {
        self.added.len() + self.removed.len() + self.retyped.len()
    }

    pub fn is_empty(&self) -> bool {
        self.change_count() == 0
    }
}

#[derive(Debug, PartialEq)]
pub enum EvolutionError {
    RemovalForbidden { mode: &'static str, names: Vec<String> },
    AdditionForbidden { mode: &'static str, names: Vec<String> },
    RetypeForbidden(Vec<String>),
    AddedMustBeOptional(String),
    TooManyChanges(usize),
    NoChange,
}

impl std::fmt::Display for EvolutionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvolutionError::RemovalForbidden { mode, names } => write!(
                f,
                "compatibility {mode:?} forbids removing attributes: {names:?}"
            ),
            EvolutionError::AdditionForbidden { mode, names } => write!(
                f,
                "compatibility {mode:?} forbids adding attributes: {names:?}"
            ),
            EvolutionError::RetypeForbidden(names) => {
                write!(f, "type changes are forbidden: {names:?}")
            }
            EvolutionError::AddedMustBeOptional(name) => {
                write!(f, "added attribute {name:?} must be optional under this mode")
            }
            EvolutionError::TooManyChanges(n) => write!(
                f,
                "registry requires single-attribute changes (paper §3.3), got {n} changes"
            ),
            EvolutionError::NoChange => {
                write!(f, "new version is identical to the previous one")
            }
        }
    }
}

impl std::error::Error for EvolutionError {}

/// Validate an evolution step under `mode`. `single_change` additionally
/// enforces the paper's semi-automated workflow rule that a new version
/// "may only contain one single changed attribute".
pub fn validate(
    mode: Compatibility,
    prev: &[(String, ExtractType, bool)],
    next: &[(String, ExtractType, bool)],
    single_change: bool,
) -> Result<VersionDiff, EvolutionError> {
    let diff = VersionDiff::compute(prev, next);
    if mode == Compatibility::None {
        return Ok(diff);
    }
    if diff.is_empty() {
        return Err(EvolutionError::NoChange);
    }
    if !diff.retyped.is_empty() {
        return Err(EvolutionError::RetypeForbidden(
            diff.retyped.iter().map(|(n, _, _)| n.clone()).collect(),
        ));
    }
    match mode {
        Compatibility::Backward => {
            if !diff.removed.is_empty() {
                return Err(EvolutionError::RemovalForbidden {
                    mode: "backward",
                    names: diff.removed.clone(),
                });
            }
        }
        Compatibility::Forward => {
            if !diff.added.is_empty() {
                return Err(EvolutionError::AdditionForbidden {
                    mode: "forward",
                    names: diff.added.clone(),
                });
            }
        }
        Compatibility::Full => {
            for name in &diff.added {
                let (_, _, optional) = next
                    .iter()
                    .find(|(n, _, _)| n == name)
                    .expect("added attr in next");
                if !optional {
                    return Err(EvolutionError::AddedMustBeOptional(
                        name.clone(),
                    ));
                }
            }
        }
        Compatibility::None => unreachable!(),
    }
    if single_change && diff.change_count() > 1 {
        return Err(EvolutionError::TooManyChanges(diff.change_count()));
    }
    Ok(diff)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(name: &str, ty: ExtractType, opt: bool) -> (String, ExtractType, bool) {
        (name.to_string(), ty, opt)
    }

    #[test]
    fn diff_detects_everything() {
        let prev = vec![
            f("a", ExtractType::Int32, false),
            f("b", ExtractType::Varchar, false),
        ];
        let next = vec![
            f("a", ExtractType::Int64, false),
            f("c", ExtractType::Boolean, true),
        ];
        let d = VersionDiff::compute(&prev, &next);
        assert_eq!(d.added, vec!["c"]);
        assert_eq!(d.removed, vec!["b"]);
        assert_eq!(d.retyped.len(), 1);
        assert_eq!(d.change_count(), 3);
    }

    #[test]
    fn backward_allows_add_forbids_remove() {
        let prev = vec![f("a", ExtractType::Int32, false)];
        let add = vec![prev[0].clone(), f("b", ExtractType::Int32, true)];
        assert!(validate(Compatibility::Backward, &prev, &add, true).is_ok());
        let rem: Vec<_> = vec![];
        assert!(matches!(
            validate(Compatibility::Backward, &prev, &rem, true),
            Err(EvolutionError::RemovalForbidden { .. })
        ));
    }

    #[test]
    fn forward_allows_remove_forbids_add() {
        let prev = vec![
            f("a", ExtractType::Int32, false),
            f("b", ExtractType::Int32, false),
        ];
        let rem = vec![prev[0].clone()];
        assert!(validate(Compatibility::Forward, &prev, &rem, true).is_ok());
        let add = vec![
            prev[0].clone(),
            prev[1].clone(),
            f("c", ExtractType::Int32, true),
        ];
        assert!(matches!(
            validate(Compatibility::Forward, &prev, &add, true),
            Err(EvolutionError::AdditionForbidden { .. })
        ));
    }

    #[test]
    fn full_requires_optional_additions() {
        let prev = vec![f("a", ExtractType::Int32, false)];
        let bad = vec![prev[0].clone(), f("b", ExtractType::Int32, false)];
        assert!(matches!(
            validate(Compatibility::Full, &prev, &bad, true),
            Err(EvolutionError::AddedMustBeOptional(_))
        ));
        let good = vec![prev[0].clone(), f("b", ExtractType::Int32, true)];
        assert!(validate(Compatibility::Full, &prev, &good, true).is_ok());
    }

    #[test]
    fn single_change_rule() {
        let prev = vec![f("a", ExtractType::Int32, false)];
        let two = vec![
            prev[0].clone(),
            f("b", ExtractType::Int32, true),
            f("c", ExtractType::Int32, true),
        ];
        assert_eq!(
            validate(Compatibility::Backward, &prev, &two, true),
            Err(EvolutionError::TooManyChanges(2))
        );
        assert!(validate(Compatibility::Backward, &prev, &two, false).is_ok());
    }

    #[test]
    fn compatibility_parses_from_config_names() {
        assert_eq!("backward".parse(), Ok(Compatibility::Backward));
        assert_eq!("forward".parse(), Ok(Compatibility::Forward));
        assert_eq!("full".parse(), Ok(Compatibility::Full));
        assert_eq!("none".parse(), Ok(Compatibility::None));
        assert!("sideways".parse::<Compatibility>().is_err());
    }

    #[test]
    fn no_change_rejected() {
        let prev = vec![f("a", ExtractType::Int32, false)];
        assert_eq!(
            validate(Compatibility::Backward, &prev, &prev.clone(), true),
            Err(EvolutionError::NoChange)
        );
    }

    #[test]
    fn retype_rejected_under_checked_modes() {
        let prev = vec![f("a", ExtractType::Int32, false)];
        let next = vec![f("a", ExtractType::Varchar, false)];
        assert!(matches!(
            validate(Compatibility::Full, &prev, &next, true),
            Err(EvolutionError::RetypeForbidden(_))
        ));
        assert!(validate(Compatibility::None, &prev, &next, true).is_ok());
    }
}
