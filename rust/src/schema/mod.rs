//! The *domain* side of the paper's dynamic network: extracting schemata.
//!
//! The extracting-schema tree `ᵢD` (paper §4.1) has the root `ᵢd`, schema
//! nodes `s_o` (one per extracted table/event stream), versioned child
//! nodes `v_v`, and attribute leaves `a_p`. Every attribute carries a
//! **global column index** `p` into the mapping matrix `ᵢM`; each version
//! owns a contiguous column range so the matrix is block-scoped (fig 3).
//!
//! Versioning semantics follow §3.3: single-attribute-change evolution is
//! enforced by the registry, and attributes duplicated across versions are
//! linked by the equivalence relation `≡` (§5.4.1) that powers automated
//! matrix updates.

pub mod attribute;
pub mod evolution;
pub mod registry;
pub mod tree;

pub use attribute::{AttrId, Attribute, ExtractType};
pub use evolution::{Compatibility, EvolutionError, VersionDiff};
pub use registry::{Registry, RegistryEvent};
pub use tree::{SchemaId, SchemaTree, SchemaVersion, VersionNo};
