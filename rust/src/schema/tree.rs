//! The extracting-schema tree `ᵢD` (paper §4.1): root → schemata `s_o` →
//! versions `v_v` → attribute leaves `a_p`, plus the global attribute
//! arena that maps every `AttrId` (matrix column) back to its path
//! `ᵢd.s_o.v_v.a_p`.

use std::collections::HashMap;

use super::attribute::{AttrId, Attribute, ExtractType};

/// Id of one extracting schema `s_o` (one per source table / event type).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SchemaId(pub u32);

/// Version number `v` within a schema (1-based, ascending).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VersionNo(pub u32);

/// One versioned schema `ᵢD_v^o`: a block of attributes owning a contiguous
/// column range of the mapping matrix.
#[derive(Debug, Clone)]
pub struct SchemaVersion {
    pub schema: SchemaId,
    pub version: VersionNo,
    /// Global attribute ids, in field order. Contiguous ascending range.
    pub attrs: Vec<AttrId>,
}

impl SchemaVersion {
    /// First column index of this version's block in ᵢM.
    pub fn col_start(&self) -> usize {
        self.attrs.first().map(|a| a.index()).unwrap_or(0)
    }

    pub fn width(&self) -> usize {
        self.attrs.len()
    }

    /// Local position of a global attribute id within this version.
    pub fn local_of(&self, id: AttrId) -> Option<usize> {
        // attrs are contiguous ascending
        let start = self.attrs.first()?.0;
        if id.0 >= start && ((id.0 - start) as usize) < self.attrs.len() {
            Some((id.0 - start) as usize)
        } else {
            None
        }
    }
}

/// One schema node `s_o` with its version children.
#[derive(Debug, Clone)]
pub struct SchemaNode {
    pub id: SchemaId,
    pub name: String,
    /// Source topic the connector publishes this schema's events on.
    pub topic: String,
    /// Versions in ascending `v` order (may be sparse after deletions).
    pub versions: Vec<VersionNo>,
}

/// The full domain tree `ᵢD` plus the attribute arena.
#[derive(Debug, Default, Clone)]
pub struct SchemaTree {
    schemas: Vec<SchemaNode>,
    by_name: HashMap<String, SchemaId>,
    versions: HashMap<(SchemaId, VersionNo), SchemaVersion>,
    /// Arena of all attributes ever allocated, indexed by AttrId.
    attrs: Vec<Attribute>,
    /// AttrId -> (schema, version) owner.
    attr_owner: Vec<(SchemaId, VersionNo)>,
}

impl SchemaTree {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total number of matrix columns ever allocated (`|ᵢ𝒜|` upper bound;
    /// deleted versions keep their ids — the matrix tracks liveness).
    pub fn n_attr_ids(&self) -> usize {
        self.attrs.len()
    }

    pub fn n_schemas(&self) -> usize {
        self.schemas.len()
    }

    pub fn schemas(&self) -> impl Iterator<Item = &SchemaNode> {
        self.schemas.iter()
    }

    pub fn add_schema(&mut self, name: &str, topic: &str) -> SchemaId {
        debug_assert!(!self.by_name.contains_key(name), "duplicate schema {name}");
        let id = SchemaId(self.schemas.len() as u32);
        self.schemas.push(SchemaNode {
            id,
            name: name.to_string(),
            topic: topic.to_string(),
            versions: Vec::new(),
        });
        self.by_name.insert(name.to_string(), id);
        id
    }

    pub fn schema(&self, id: SchemaId) -> &SchemaNode {
        &self.schemas[id.0 as usize]
    }

    pub fn schema_by_name(&self, name: &str) -> Option<SchemaId> {
        self.by_name.get(name).copied()
    }

    /// Add a version with the given field definitions. Equivalence links to
    /// the previous version are resolved by (name, type) match. Returns the
    /// new version number.
    pub fn add_version(
        &mut self,
        schema: SchemaId,
        fields: &[(String, ExtractType, bool)],
    ) -> VersionNo {
        let prev = self.latest_version(schema);
        let v = VersionNo(prev.map(|p| p.0 + 1).unwrap_or(1));
        let prev_attrs: Vec<Attribute> = prev
            .map(|pv| {
                self.versions[&(schema, pv)]
                    .attrs
                    .iter()
                    .map(|a| self.attrs[a.index()].clone())
                    .collect()
            })
            .unwrap_or_default();
        let mut ids = Vec::with_capacity(fields.len());
        for (name, ty, optional) in fields {
            let id = AttrId(self.attrs.len() as u32);
            let equiv = prev_attrs
                .iter()
                .find(|a| &a.name == name && a.ty == *ty)
                .map(|a| a.id);
            self.attrs.push(Attribute {
                id,
                name: name.clone(),
                ty: *ty,
                optional: *optional,
                equiv,
            });
            self.attr_owner.push((schema, v));
            ids.push(id);
        }
        self.versions.insert(
            (schema, v),
            SchemaVersion { schema, version: v, attrs: ids },
        );
        self.schemas[schema.0 as usize].versions.push(v);
        v
    }

    /// Remove a version from the tree (its AttrIds remain allocated but
    /// unreachable — matching the paper's matrix shrink semantics where the
    /// DMM drops the column sets).
    pub fn delete_version(&mut self, schema: SchemaId, v: VersionNo) -> bool {
        if self.versions.remove(&(schema, v)).is_some() {
            self.schemas[schema.0 as usize].versions.retain(|x| *x != v);
            true
        } else {
            false
        }
    }

    pub fn latest_version(&self, schema: SchemaId) -> Option<VersionNo> {
        self.schemas[schema.0 as usize].versions.iter().max().copied()
    }

    pub fn version(&self, schema: SchemaId, v: VersionNo) -> Option<&SchemaVersion> {
        self.versions.get(&(schema, v))
    }

    pub fn versions_of(&self, schema: SchemaId) -> &[VersionNo] {
        &self.schemas[schema.0 as usize].versions
    }

    /// The `(name, type, optional)` field list of one registered version —
    /// the registry-facing shape used by evolution validation, change
    /// events and version registration.
    pub fn field_list(
        &self,
        schema: SchemaId,
        v: VersionNo,
    ) -> Option<Vec<(String, ExtractType, bool)>> {
        let sv = self.version(schema, v)?;
        Some(
            sv.attrs
                .iter()
                .map(|&a| {
                    let at = self.attr(a);
                    (at.name.clone(), at.ty, at.optional)
                })
                .collect(),
        )
    }

    pub fn attr(&self, id: AttrId) -> &Attribute {
        &self.attrs[id.index()]
    }

    /// Owner (schema, version) of an attribute id.
    pub fn owner_of(&self, id: AttrId) -> (SchemaId, VersionNo) {
        self.attr_owner[id.index()]
    }

    /// Follow the `≡` chain to the oldest ancestor — the canonical
    /// representative used to compare blocks across versions (DUSB) and to
    /// copy values on updates (Alg 5).
    pub fn equiv_root(&self, id: AttrId) -> AttrId {
        let mut cur = id;
        while let Some(prev) = self.attrs[cur.index()].equiv {
            cur = prev;
        }
        cur
    }

    /// Find the attribute in (schema, v2) equivalent to `id` (an attribute
    /// of an earlier version), if any: same equiv-root.
    pub fn equivalent_in(
        &self,
        id: AttrId,
        schema: SchemaId,
        v2: VersionNo,
    ) -> Option<AttrId> {
        let root = self.equiv_root(id);
        let sv = self.version(schema, v2)?;
        sv.attrs
            .iter()
            .copied()
            .find(|a| self.equiv_root(*a) == root)
    }

    /// Path string `d.s_o.v_v.a_p` (paper's short edge notation).
    pub fn path_of(&self, id: AttrId) -> String {
        let (s, v) = self.owner_of(id);
        format!(
            "d.{}.v{}.{}",
            self.schema(s).name,
            v.0,
            self.attr(id).name
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fields(names: &[&str]) -> Vec<(String, ExtractType, bool)> {
        names
            .iter()
            .map(|n| (n.to_string(), ExtractType::Int64, false))
            .collect()
    }

    #[test]
    fn versions_allocate_contiguous_fresh_ids() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("payments.incoming", "fx.payments.incoming");
        let v1 = t.add_version(s, &fields(&["id", "value", "time"]));
        let v2 = t.add_version(s, &fields(&["id", "value", "time", "currency"]));
        assert_eq!(v1, VersionNo(1));
        assert_eq!(v2, VersionNo(2));
        let sv1 = t.version(s, v1).unwrap();
        let sv2 = t.version(s, v2).unwrap();
        assert_eq!(sv1.attrs, vec![AttrId(0), AttrId(1), AttrId(2)]);
        assert_eq!(sv2.attrs.len(), 4);
        assert_eq!(sv2.col_start(), 3);
        // fresh ids, not reused
        assert_eq!(t.n_attr_ids(), 7);
    }

    #[test]
    fn equivalences_link_same_name_same_type() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("s1", "t1");
        t.add_version(s, &fields(&["a", "b"]));
        t.add_version(s, &fields(&["a", "b", "c"]));
        let v3 = t.add_version(s, &fields(&["a", "c"]));
        let sv3 = t.version(s, v3).unwrap();
        let a_v3 = sv3.attrs[0];
        // a chains v3 -> v2 -> v1
        assert_eq!(t.equiv_root(a_v3), AttrId(0));
        // c chains v3 -> v2 only
        let c_v3 = sv3.attrs[1];
        assert_eq!(t.equiv_root(c_v3), AttrId(4));
    }

    #[test]
    fn type_change_breaks_equivalence() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("s1", "t1");
        t.add_version(s, &[("a".into(), ExtractType::Int32, false)]);
        let v2 = t.add_version(s, &[("a".into(), ExtractType::Varchar, false)]);
        let a_v2 = t.version(s, v2).unwrap().attrs[0];
        assert_eq!(t.attr(a_v2).equiv, None);
    }

    #[test]
    fn equivalent_in_finds_descendant() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("s1", "t1");
        let v1 = t.add_version(s, &fields(&["a", "b"]));
        let v2 = t.add_version(s, &fields(&["b", "a"])); // reordered
        let a_v1 = t.version(s, v1).unwrap().attrs[0];
        let found = t.equivalent_in(a_v1, s, v2).unwrap();
        assert_eq!(t.attr(found).name, "a");
        assert_eq!(t.version(s, v2).unwrap().local_of(found), Some(1));
    }

    #[test]
    fn delete_version_removes_reachability() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("s1", "t1");
        let v1 = t.add_version(s, &fields(&["a"]));
        let _v2 = t.add_version(s, &fields(&["a", "b"]));
        assert!(t.delete_version(s, v1));
        assert!(t.version(s, v1).is_none());
        assert_eq!(t.versions_of(s), &[VersionNo(2)]);
        assert!(!t.delete_version(s, v1));
        // ids remain allocated
        assert_eq!(t.n_attr_ids(), 3);
    }

    #[test]
    fn local_of_rejects_foreign_ids() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("s1", "t1");
        let v1 = t.add_version(s, &fields(&["a", "b"]));
        let v2 = t.add_version(s, &fields(&["a", "b"]));
        let sv1 = t.version(s, v1).unwrap();
        let a_v2 = t.version(s, v2).unwrap().attrs[0];
        assert_eq!(sv1.local_of(a_v2), None);
    }

    #[test]
    fn field_list_round_trips_registration() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("s1", "t1");
        let fields = vec![
            ("a".to_string(), ExtractType::Int64, false),
            ("b".to_string(), ExtractType::Varchar, true),
        ];
        let v = t.add_version(s, &fields);
        assert_eq!(t.field_list(s, v), Some(fields));
        assert_eq!(t.field_list(s, VersionNo(9)), None);
    }

    #[test]
    fn path_notation() {
        let mut t = SchemaTree::new();
        let s = t.add_schema("payments", "fx.payments");
        let v = t.add_version(s, &fields(&["time"]));
        let a = t.version(s, v).unwrap().attrs[0];
        assert_eq!(t.path_of(a), "d.payments.v1.time");
    }
}
