//! Apicurio-sim schema registry (paper §3 pillar 2): the single source of
//! truth for extracting schemata, enforcing evolution rules and emitting
//! change events that trigger the semi-automated DMM update workflow.

use std::sync::{Mutex, RwLock};

use super::attribute::ExtractType;
use super::evolution::{self, Compatibility, EvolutionError, VersionDiff};
use super::tree::{SchemaId, SchemaTree, VersionNo};

/// A registry change event — the external trigger feeding Alg 5 (§3.5
/// defines exactly these triggers for the extraction side).
#[derive(Debug, Clone, PartialEq)]
pub enum RegistryEvent {
    SchemaCreated { schema: SchemaId },
    VersionAdded { schema: SchemaId, version: VersionNo, diff: VersionDiff },
    VersionDeleted { schema: SchemaId, version: VersionNo },
}

/// Thread-safe registry around the schema tree.
#[derive(Debug)]
pub struct Registry {
    tree: RwLock<SchemaTree>,
    compatibility: Compatibility,
    /// Enforce "one single changed attribute" per version (paper §3.3).
    single_change: bool,
    events: Mutex<Vec<RegistryEvent>>,
}

impl Registry {
    pub fn new(compatibility: Compatibility, single_change: bool) -> Self {
        Self {
            tree: RwLock::new(SchemaTree::new()),
            compatibility,
            single_change,
            events: Mutex::new(Vec::new()),
        }
    }

    /// Run a closure over the (read-locked) tree.
    pub fn read<R>(&self, f: impl FnOnce(&SchemaTree) -> R) -> R {
        f(&self.tree.read().unwrap())
    }

    /// Snapshot a clone of the tree (used by instances that must pin a
    /// consistent state i while the registry keeps evolving).
    pub fn snapshot(&self) -> SchemaTree {
        self.tree.read().unwrap().clone()
    }

    pub fn create_schema(&self, name: &str, topic: &str) -> SchemaId {
        let id = self.tree.write().unwrap().add_schema(name, topic);
        self.push(RegistryEvent::SchemaCreated { schema: id });
        id
    }

    /// Register a new version; validates evolution against the latest
    /// version under the registry's compatibility mode.
    pub fn register_version(
        &self,
        schema: SchemaId,
        fields: &[(String, ExtractType, bool)],
    ) -> Result<(VersionNo, VersionDiff), EvolutionError> {
        let mut tree = self.tree.write().unwrap();
        let prev_fields: Vec<(String, ExtractType, bool)> = tree
            .latest_version(schema)
            .and_then(|v| tree.field_list(schema, v))
            .unwrap_or_default();
        let diff = if prev_fields.is_empty() {
            // first version: no evolution check
            VersionDiff {
                added: fields.iter().map(|(n, _, _)| n.clone()).collect(),
                ..Default::default()
            }
        } else {
            evolution::validate(
                self.compatibility,
                &prev_fields,
                fields,
                self.single_change,
            )?
        };
        let v = tree.add_version(schema, fields);
        drop(tree);
        self.push(RegistryEvent::VersionAdded {
            schema,
            version: v,
            diff: diff.clone(),
        });
        Ok((v, diff))
    }

    pub fn delete_version(&self, schema: SchemaId, v: VersionNo) -> bool {
        let ok = self.tree.write().unwrap().delete_version(schema, v);
        if ok {
            self.push(RegistryEvent::VersionDeleted { schema, version: v });
        }
        ok
    }

    fn push(&self, ev: RegistryEvent) {
        self.events.lock().unwrap().push(ev);
    }

    /// Drain events recorded since the last drain (the pipeline's control
    /// loop consumes these to drive DMM updates + cache eviction).
    pub fn drain_events(&self) -> Vec<RegistryEvent> {
        std::mem::take(&mut self.events.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(name: &str) -> (String, ExtractType, bool) {
        (name.to_string(), ExtractType::Int64, true)
    }

    #[test]
    fn register_and_evolve() {
        let reg = Registry::new(Compatibility::Backward, true);
        let s = reg.create_schema("payments", "fx.payments");
        let (v1, _) = reg.register_version(s, &[f("id"), f("value")]).unwrap();
        let (v2, diff) = reg
            .register_version(s, &[f("id"), f("value"), f("currency")])
            .unwrap();
        assert_eq!((v1, v2), (VersionNo(1), VersionNo(2)));
        assert_eq!(diff.added, vec!["currency"]);
        let events = reg.drain_events();
        assert_eq!(events.len(), 3);
        assert!(matches!(events[2], RegistryEvent::VersionAdded { .. }));
        assert!(reg.drain_events().is_empty());
    }

    #[test]
    fn rejects_violating_evolution() {
        let reg = Registry::new(Compatibility::Backward, true);
        let s = reg.create_schema("s", "t");
        reg.register_version(s, &[f("a"), f("b")]).unwrap();
        // removal under backward compat
        let err = reg.register_version(s, &[f("a")]).unwrap_err();
        assert!(matches!(err, EvolutionError::RemovalForbidden { .. }));
        // two changes at once under single-change rule
        let err = reg
            .register_version(s, &[f("a"), f("b"), f("c"), f("d")])
            .unwrap_err();
        assert!(matches!(err, EvolutionError::TooManyChanges(2)));
        // tree unchanged by rejections
        reg.read(|t| assert_eq!(t.versions_of(s).len(), 1));
    }

    #[test]
    fn delete_emits_event() {
        let reg = Registry::new(Compatibility::None, false);
        let s = reg.create_schema("s", "t");
        let (v1, _) = reg.register_version(s, &[f("a")]).unwrap();
        reg.register_version(s, &[f("a"), f("b")]).unwrap();
        assert!(reg.delete_version(s, v1));
        assert!(!reg.delete_version(s, v1));
        let events = reg.drain_events();
        assert!(matches!(
            events.last().unwrap(),
            RegistryEvent::VersionDeleted { .. }
        ));
    }

    #[test]
    fn snapshot_is_isolated() {
        let reg = Registry::new(Compatibility::None, false);
        let s = reg.create_schema("s", "t");
        reg.register_version(s, &[f("a")]).unwrap();
        let snap = reg.snapshot();
        reg.register_version(s, &[f("a"), f("b")]).unwrap();
        assert_eq!(snap.versions_of(s).len(), 1);
        reg.read(|t| assert_eq!(t.versions_of(s).len(), 2));
    }
}
