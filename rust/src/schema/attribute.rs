//! Attributes (`a_p`) of extracting schemata and their physical types.

/// Global column index `p` of an attribute in the mapping matrix `ᵢM`.
/// Allocated once per (schema, version, position) — attributes duplicated
/// across versions get *fresh* ids linked by [`Attribute::equiv`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AttrId(pub u32);

impl AttrId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Physical types as produced by the Debezium-style connectors (fig 2:
/// "int32", "int64" with semantic names like io.debezium.time.Date, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExtractType {
    Int32,
    Int64,
    Float32,
    Float64,
    Boolean,
    Varchar,
    Bytes,
    /// io.debezium.time.Date — days since epoch as int32.
    DebeziumDate,
    /// io.debezium.time.MicroTimestamp — micros since epoch as int64.
    MicroTimestamp,
    Decimal,
    Uuid,
}

impl ExtractType {
    /// The wire-name as it appears in the extracting JSON schema.
    pub fn wire_name(self) -> &'static str {
        match self {
            ExtractType::Int32 => "int32",
            ExtractType::Int64 => "int64",
            ExtractType::Float32 => "float32",
            ExtractType::Float64 => "float64",
            ExtractType::Boolean => "boolean",
            ExtractType::Varchar => "string",
            ExtractType::Bytes => "bytes",
            ExtractType::DebeziumDate => "io.debezium.time.Date",
            ExtractType::MicroTimestamp => "io.debezium.time.MicroTimestamp",
            ExtractType::Decimal => "decimal",
            ExtractType::Uuid => "uuid",
        }
    }

    /// Inverse of [`ExtractType::wire_name`] — used when deserializing
    /// schema-change records from the store WAL.
    pub fn from_wire_name(name: &str) -> Option<ExtractType> {
        ExtractType::all().iter().copied().find(|t| t.wire_name() == name)
    }

    pub fn all() -> &'static [ExtractType] {
        &[
            ExtractType::Int32,
            ExtractType::Int64,
            ExtractType::Float32,
            ExtractType::Float64,
            ExtractType::Boolean,
            ExtractType::Varchar,
            ExtractType::Bytes,
            ExtractType::DebeziumDate,
            ExtractType::MicroTimestamp,
            ExtractType::Decimal,
            ExtractType::Uuid,
        ]
    }
}

/// One attribute leaf `a_p` of a versioned extracting schema.
#[derive(Debug, Clone)]
pub struct Attribute {
    pub id: AttrId,
    pub name: String,
    pub ty: ExtractType,
    pub optional: bool,
    /// Equivalence link `a_p ≡ a_p'` to the same-named attribute in the
    /// *previous* version of the same schema (paper §5.4.1). Chains back
    /// through all versions; `root` resolution follows it to the oldest
    /// ancestor.
    pub equiv: Option<AttrId>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_names_are_stable() {
        assert_eq!(ExtractType::Int32.wire_name(), "int32");
        assert_eq!(
            ExtractType::MicroTimestamp.wire_name(),
            "io.debezium.time.MicroTimestamp"
        );
        // all() covers every variant exactly once
        assert_eq!(ExtractType::all().len(), 11);
    }

    #[test]
    fn wire_names_round_trip() {
        for &t in ExtractType::all() {
            assert_eq!(ExtractType::from_wire_name(t.wire_name()), Some(t));
        }
        assert_eq!(ExtractType::from_wire_name("tinyint"), None);
    }
}
