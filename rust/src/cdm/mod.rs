//! The *range* side of the dynamic network: the canonical data model.
//!
//! The CDM tree `ᵢR` (paper §4.1) has root `ᵢr`, business-entity nodes
//! `be_r`, versioned children `v_w`, and CDM-attribute leaves `c_q`. CDM
//! attributes carry **generalized types** and business descriptions
//! ("time" → "Time of the payment", int32 → integer; §3.1), and own the
//! *row* indices `q` of the mapping matrix.
//!
//! Per §5.1's business rule, outdated CDM versions are deleted from the
//! matrix — the tree records them, the DMM drops their row sets.

use std::collections::HashMap;

use crate::schema::ExtractType;

/// Global row index `q` of a CDM attribute in the mapping matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CdmAttrId(pub u32);

impl CdmAttrId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Id of a business entity `be_r`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EntityId(pub u32);

/// CDM version number `w` (1-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CdmVersionNo(pub u32);

/// Generalized CDM data types (§3.1: "more general data types for sharing").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CdmType {
    Integer,
    Number,
    Boolean,
    Text,
    Date,
    Timestamp,
    Binary,
    Identifier,
}

impl CdmType {
    /// The type-generalization mapping applied during CDM design: every
    /// physical extracting type widens to one canonical type.
    pub fn generalize(ty: ExtractType) -> CdmType {
        match ty {
            ExtractType::Int32 | ExtractType::Int64 => CdmType::Integer,
            ExtractType::Float32
            | ExtractType::Float64
            | ExtractType::Decimal => CdmType::Number,
            ExtractType::Boolean => CdmType::Boolean,
            ExtractType::Varchar => CdmType::Text,
            ExtractType::Bytes => CdmType::Binary,
            ExtractType::DebeziumDate => CdmType::Date,
            ExtractType::MicroTimestamp => CdmType::Timestamp,
            ExtractType::Uuid => CdmType::Identifier,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CdmType::Integer => "integer",
            CdmType::Number => "number",
            CdmType::Boolean => "boolean",
            CdmType::Text => "text",
            CdmType::Date => "date",
            CdmType::Timestamp => "timestamp",
            CdmType::Binary => "binary",
            CdmType::Identifier => "identifier",
        }
    }
}

/// One CDM attribute leaf `c_q`.
#[derive(Debug, Clone)]
pub struct CdmAttribute {
    pub id: CdmAttrId,
    pub name: String,
    pub ty: CdmType,
    /// Business description, absent from extracting schemata (§3.1).
    pub description: String,
    /// `≡` link to the previous CDM version's attribute (Alg 5 case 4).
    pub equiv: Option<CdmAttrId>,
}

/// One versioned business entity `ᵢR_w^r`: a block of CDM attributes owning
/// a contiguous row range of the mapping matrix.
#[derive(Debug, Clone)]
pub struct CdmVersion {
    pub entity: EntityId,
    pub version: CdmVersionNo,
    pub attrs: Vec<CdmAttrId>,
}

impl CdmVersion {
    pub fn row_start(&self) -> usize {
        self.attrs.first().map(|a| a.index()).unwrap_or(0)
    }

    pub fn height(&self) -> usize {
        self.attrs.len()
    }

    pub fn local_of(&self, id: CdmAttrId) -> Option<usize> {
        let start = self.attrs.first()?.0;
        if id.0 >= start && ((id.0 - start) as usize) < self.attrs.len() {
            Some((id.0 - start) as usize)
        } else {
            None
        }
    }
}

/// A business entity node with version children.
#[derive(Debug, Clone)]
pub struct EntityNode {
    pub id: EntityId,
    pub name: String,
    /// Outgoing topic for mapped messages of this entity.
    pub topic: String,
    pub versions: Vec<CdmVersionNo>,
}

/// The CDM tree `ᵢR` plus its attribute arena.
#[derive(Debug, Default, Clone)]
pub struct CdmTree {
    entities: Vec<EntityNode>,
    by_name: HashMap<String, EntityId>,
    versions: HashMap<(EntityId, CdmVersionNo), CdmVersion>,
    attrs: Vec<CdmAttribute>,
    attr_owner: Vec<(EntityId, CdmVersionNo)>,
}

impl CdmTree {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn n_attr_ids(&self) -> usize {
        self.attrs.len()
    }

    pub fn n_entities(&self) -> usize {
        self.entities.len()
    }

    pub fn entities(&self) -> impl Iterator<Item = &EntityNode> {
        self.entities.iter()
    }

    pub fn add_entity(&mut self, name: &str) -> EntityId {
        debug_assert!(!self.by_name.contains_key(name));
        let id = EntityId(self.entities.len() as u32);
        self.entities.push(EntityNode {
            id,
            name: name.to_string(),
            topic: format!("cdm.{name}"),
            versions: Vec::new(),
        });
        self.by_name.insert(name.to_string(), id);
        id
    }

    pub fn entity(&self, id: EntityId) -> &EntityNode {
        &self.entities[id.0 as usize]
    }

    pub fn entity_by_name(&self, name: &str) -> Option<EntityId> {
        self.by_name.get(name).copied()
    }

    /// Add a CDM version; fields are (name, type, description). Equivalence
    /// links resolve by (name, type) against the previous version.
    pub fn add_version(
        &mut self,
        entity: EntityId,
        fields: &[(String, CdmType, String)],
    ) -> CdmVersionNo {
        let prev = self.latest_version(entity);
        let w = CdmVersionNo(prev.map(|p| p.0 + 1).unwrap_or(1));
        let prev_attrs: Vec<CdmAttribute> = prev
            .map(|pw| {
                self.versions[&(entity, pw)]
                    .attrs
                    .iter()
                    .map(|a| self.attrs[a.index()].clone())
                    .collect()
            })
            .unwrap_or_default();
        let mut ids = Vec::with_capacity(fields.len());
        for (name, ty, desc) in fields {
            let id = CdmAttrId(self.attrs.len() as u32);
            let equiv = prev_attrs
                .iter()
                .find(|a| &a.name == name && a.ty == *ty)
                .map(|a| a.id);
            self.attrs.push(CdmAttribute {
                id,
                name: name.clone(),
                ty: *ty,
                description: desc.clone(),
                equiv,
            });
            self.attr_owner.push((entity, w));
            ids.push(id);
        }
        self.versions
            .insert((entity, w), CdmVersion { entity, version: w, attrs: ids });
        self.entities[entity.0 as usize].versions.push(w);
        w
    }

    /// Test-only corruption: remove a version's *definition* while keeping
    /// it listed on the entity — a torn §5.1 delete, unreachable through
    /// the public API (`delete_version` updates both sides). Lets tests
    /// prove the mapping path surfaces `DeadCdmVersion` instead of
    /// panicking.
    #[cfg(test)]
    pub(crate) fn drop_version_definition(
        &mut self,
        entity: EntityId,
        w: CdmVersionNo,
    ) {
        self.versions.remove(&(entity, w));
    }

    pub fn delete_version(&mut self, entity: EntityId, w: CdmVersionNo) -> bool {
        if self.versions.remove(&(entity, w)).is_some() {
            self.entities[entity.0 as usize].versions.retain(|x| *x != w);
            true
        } else {
            false
        }
    }

    pub fn latest_version(&self, entity: EntityId) -> Option<CdmVersionNo> {
        self.entities[entity.0 as usize].versions.iter().max().copied()
    }

    pub fn version(
        &self,
        entity: EntityId,
        w: CdmVersionNo,
    ) -> Option<&CdmVersion> {
        self.versions.get(&(entity, w))
    }

    pub fn versions_of(&self, entity: EntityId) -> &[CdmVersionNo] {
        &self.entities[entity.0 as usize].versions
    }

    pub fn attr(&self, id: CdmAttrId) -> &CdmAttribute {
        &self.attrs[id.index()]
    }

    pub fn owner_of(&self, id: CdmAttrId) -> (EntityId, CdmVersionNo) {
        self.attr_owner[id.index()]
    }

    pub fn equiv_root(&self, id: CdmAttrId) -> CdmAttrId {
        let mut cur = id;
        while let Some(prev) = self.attrs[cur.index()].equiv {
            cur = prev;
        }
        cur
    }

    pub fn equivalent_in(
        &self,
        id: CdmAttrId,
        entity: EntityId,
        w2: CdmVersionNo,
    ) -> Option<CdmAttrId> {
        let root = self.equiv_root(id);
        let cv = self.version(entity, w2)?;
        cv.attrs.iter().copied().find(|a| self.equiv_root(*a) == root)
    }

    /// Path string `r.be_r.v_w.c_q`.
    pub fn path_of(&self, id: CdmAttrId) -> String {
        let (e, w) = self.owner_of(id);
        format!("r.{}.v{}.{}", self.entity(e).name, w.0, self.attr(id).name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(name: &str, desc: &str) -> (String, CdmType, String) {
        (name.to_string(), CdmType::Integer, desc.to_string())
    }

    #[test]
    fn type_generalization_table() {
        assert_eq!(CdmType::generalize(ExtractType::Int32), CdmType::Integer);
        assert_eq!(CdmType::generalize(ExtractType::Int64), CdmType::Integer);
        assert_eq!(CdmType::generalize(ExtractType::Decimal), CdmType::Number);
        assert_eq!(
            CdmType::generalize(ExtractType::MicroTimestamp),
            CdmType::Timestamp
        );
        assert_eq!(CdmType::generalize(ExtractType::Uuid), CdmType::Identifier);
    }

    #[test]
    fn entity_versions_and_rows() {
        let mut c = CdmTree::new();
        let e = c.add_entity("Payment");
        let w1 = c.add_version(e, &[f("amount", "Payment amount"), f("time", "Time of the payment")]);
        let w2 = c.add_version(e, &[f("amount", "Payment amount"), f("time", "Time of the payment"), f("currency", "ISO currency")]);
        assert_eq!((w1, w2), (CdmVersionNo(1), CdmVersionNo(2)));
        let cv2 = c.version(e, w2).unwrap();
        assert_eq!(cv2.row_start(), 2);
        assert_eq!(cv2.height(), 3);
        // equivalences link across versions
        let time_w2 = cv2.attrs[1];
        assert_eq!(c.equiv_root(time_w2), CdmAttrId(1));
    }

    #[test]
    fn delete_version_per_section_5_1() {
        let mut c = CdmTree::new();
        let e = c.add_entity("Payment");
        let w1 = c.add_version(e, &[f("a", "")]);
        c.add_version(e, &[f("a", "")]);
        assert!(c.delete_version(e, w1));
        assert_eq!(c.versions_of(e), &[CdmVersionNo(2)]);
    }

    #[test]
    fn descriptions_present() {
        let mut c = CdmTree::new();
        let e = c.add_entity("Payment");
        let w = c.add_version(e, &[f("time", "Time of the payment")]);
        let q = c.version(e, w).unwrap().attrs[0];
        assert_eq!(c.attr(q).description, "Time of the payment");
        assert_eq!(c.path_of(q), "r.Payment.v1.time");
    }
}
