//! The wired METL pipeline (paper fig 1): Debezium-sim sources → Kafka-sim
//! CDC topic → METL (DMM mapping, Alg 6) → CDM topic → pluggable sink
//! backends, with the state-i update workflow and error management in the
//! control lane.
//!
//! # Pluggable connectors + per-sink consumer groups
//!
//! Ingress and egress are trait seams, not struct fields: the pipeline
//! holds a boxed [`SourceConnector`] and a list of [`SinkHandle`]s, each
//! wrapping a [`crate::sink::SinkConnector`] backend with its **own
//! consumer group** over the CDM topic. Wiring happens through
//! [`PipelineBuilder`]:
//!
//! ```ignore
//! let p = Pipeline::builder(cfg)
//!     .source(Connector::new("src"))
//!     .sink(DwSink::new())
//!     .sink(JsonlSink::new().with_path("cdm.jsonl"))
//!     .build()?;
//! p.run_trace(&ops)?;
//! let rows = p.with_sink("dw", |dw: &DwSink| dw.total_rows());
//! ```
//!
//! With no explicit `.sink(...)` calls the backends come from
//! `PipelineConfig::sinks` (`runtime.sinks = ["dw","ml","jsonl"]`), so
//! deployments select backends from config alone. Because every sink
//! tracks its own offsets/commits/lag, a slow warehouse no longer blocks
//! the ML feed (see [`super::egress`]).
//!
//! # Sharded mapping lane
//!
//! The live `ᵢ𝔇𝔓𝔐` is an immutable `Arc<DpmSet>` behind an epoch pointer
//! ([`EpochDmm`]). The §5.5 scale-out path ([`super::shard`]) partitions
//! the CDC stream **by source schema id** into N worker shards; each shard
//! maps against the snapshot it currently holds and refreshes it when the
//! epoch advances (one atomic load per micro-batch).
//!
//! ## Epoch-swap protocol
//!
//! 1. An Alg-5 trigger bumps state i and builds `ᵢ₊₁𝔇𝔓𝔐` *off to the
//!    side* ([`crate::matrix::update::prepare_update`]) — in-flight
//!    mapping keeps reading the old snapshot, so schema-change storms
//!    never stall the stream.
//! 2. The new set is published with a single pointer swap
//!    ([`EpochDmm::publish`]), which bumps the epoch *after* the swap: a
//!    worker that observes the new epoch is guaranteed to read the new
//!    snapshot.
//! 3. A worker holding a stale snapshot self-heals: a state-mismatched or
//!    unknown-column event triggers one snapshot refresh, then the §3.4
//!    restamp retry; only persistent failures dead-letter.
//!
//! # Online schema evolution
//!
//! Schema changes flow through the evolution lane ([`super::evolution`]):
//! Debezium-style DDL/registry events arrive on a
//! [`crate::source::SchemaChangeSource`], are validated against the
//! registry's compatibility rules (incompatible changes are rejected
//! without touching the epoch), and each accepted change becomes one
//! epoch swap with **targeted** cache eviction — only the affected
//! `(schema, version)` columns drop, so the §7 full-evict latency spike
//! disappears (`--evict full` restores the old behaviour). A CDC record
//! arriving with an unknown `(SchemaId, VersionNo)` that the registry
//! already knows triggers the same patch in-band instead of
//! dead-lettering.
//!
//! ## Ordering guarantees
//!
//! Every message maps against exactly one snapshot (never a mixed old/new
//! view — the snapshot is a frozen `Arc`). Per-key CDC order is preserved
//! end to end: a key lives in one CDC partition (keyed produce), one
//! partition is dispatched to exactly one shard (a schema's events share a
//! shard), a shard processes its queue in FIFO order, and the ordered
//! commit ([`crate::broker::Topic::produce_batch`]) appends outputs to the
//! keyed CDM partitions in processing order. Cross-key order across shards
//! is not defined, exactly like Kafka across partitions.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::Result;

use super::egress::SinkHandle;
use super::errors::{Dlq, RetryPolicy};
use super::evolution::{ChangeOutcome, EvolutionController};
use super::state::{EpochDmm, StateManager};
use super::workflow::NoticePolicy;
use crate::broker::{Consumer, Topic};
use crate::cache::DcpmCache;
use crate::config::PipelineConfig;
use crate::mapper::parallel::ParallelMapper;
use crate::mapper::MapError;
use crate::matrix::dpm::DpmSet;
use crate::matrix::dusb::DusbSet;
use crate::matrix::update::UpdateReport;
use crate::message::cdc::{CdcEvent, CdcOp};
use crate::message::{OutMessage, StateI};
use crate::metrics::{CacheView, PipelineMetrics};
use crate::sink::SinkConnector;
use crate::trace::{EventTrace, Lane, Stage, TraceCtx, Tracer, SINK_NONE};
use crate::source::{
    Connector, DdlQueue, Dml, SchemaChangeEvent, SchemaChangeSource,
    SourceConnector,
};
use crate::store::MatrixStore;
use crate::util::rng::Rng;
use crate::util::IdGen;
use crate::workload::{self, DmlKind, Landscape, TraceOp};

pub use super::arena::{OutArena, OutRecord};

/// The full pipeline.
pub struct Pipeline {
    pub cfg: PipelineConfig,
    pub landscape: RwLock<Landscape>,
    /// Merged CDC stream (per-table Debezium topics fan into this; METL
    /// consumes it partition-parallel).
    pub cdc_topic: Topic<Arc<CdcEvent>>,
    /// The outgoing CDM stream — "the API of the microservice system".
    pub out_topic: Topic<OutRecord>,
    /// The live DMM snapshot behind the epoch pointer (see module docs).
    pub dmm: EpochDmm,
    pub cache: Arc<DcpmCache>,
    pub store: Option<MatrixStore>,
    pub state: StateManager,
    pub metrics: Arc<PipelineMetrics>,
    /// Span/provenance collector (see [`crate::trace`]); enabled by
    /// `PipelineConfig::trace` (on by default).
    pub tracer: Arc<Tracer>,
    pub dlq: Dlq,
    pub retry: RetryPolicy,
    pub notice_policy: NoticePolicy,
    /// Registered egress backends, each with its own consumer group (see
    /// [`super::egress`]). Order is registration order.
    pub sinks: Vec<SinkHandle>,
    /// The online schema-evolution lane (see [`super::evolution`]):
    /// consumes schema-change events and in-band unknown-version signals,
    /// publishes new DMM epochs with targeted cache eviction.
    pub evolution: EvolutionController,
    source: Box<dyn SourceConnector>,
    rng: Mutex<Rng>,
    next_key: IdGen,
    /// Simulated µs clock (1 ms per produced event).
    clock_us: AtomicU64,
}

/// Report of one trace run.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub events: u64,
    pub out_messages: u64,
    pub dead_letters: u64,
    pub dmm_updates: u64,
    pub wall: std::time::Duration,
}

/// Fluent wiring for [`Pipeline`]: landscape, source connector, sink
/// backends and the hybrid store. With no explicit sinks the backends come
/// from `PipelineConfig::sinks`; with no explicit source the Debezium-sim
/// [`Connector`] is used.
pub struct PipelineBuilder {
    cfg: PipelineConfig,
    landscape: Option<Landscape>,
    source: Option<Box<dyn SourceConnector>>,
    schema_changes: Option<Box<dyn SchemaChangeSource>>,
    sinks: Vec<Box<dyn SinkConnector>>,
    store_dir: Option<std::path::PathBuf>,
}

impl PipelineBuilder {
    /// Use a pre-built landscape instead of generating one from the
    /// config (benches/tests that pre-populate tables).
    pub fn landscape(mut self, landscape: Landscape) -> Self {
        self.landscape = Some(landscape);
        self
    }

    /// Replace the default Debezium-sim source connector.
    pub fn source(mut self, source: impl SourceConnector + 'static) -> Self {
        self.source = Some(Box::new(source));
        self
    }

    /// Replace the default queue-backed schema-change source (the ingress
    /// of the online evolution lane; see [`super::evolution`]).
    pub fn schema_changes(
        mut self,
        source: impl SchemaChangeSource + 'static,
    ) -> Self {
        self.schema_changes = Some(Box::new(source));
        self
    }

    /// Register one sink backend. Each registered sink gets its own
    /// consumer group over the CDM topic. Registering any sink disables
    /// the config-driven default set.
    pub fn sink(mut self, sink: impl SinkConnector + 'static) -> Self {
        self.sinks.push(Box::new(sink));
        self
    }

    /// Attach the Postgres-sim store (hybrid §6.2 persistence).
    pub fn store(mut self, dir: impl AsRef<std::path::Path>) -> Self {
        self.store_dir = Some(dir.as_ref().to_path_buf());
        self
    }

    /// Wire everything into a runnable [`Pipeline`].
    pub fn build(self) -> Result<Pipeline> {
        let PipelineBuilder {
            cfg,
            landscape,
            source,
            schema_changes,
            mut sinks,
            store_dir,
        } = self;
        let landscape =
            landscape.unwrap_or_else(|| workload::generate(&cfg));
        let state = StateManager::new(StateI(0));
        let dpm = DpmSet::from_matrix(
            &landscape.matrix,
            &landscape.tree,
            &landscape.cdm,
            StateI(0),
        )
        .map_err(|e| anyhow::anyhow!("matrix violates 1:1: {e}"))?;
        let metrics = Arc::new(PipelineMetrics::default());
        // both brokers report into the same counters: segment growth and
        // batch I/O are one fleet-level signal, not per-topic
        let broker = crate::broker::Broker::with_metrics(
            cfg.partitions,
            Arc::clone(&metrics.broker),
        );
        let cdc_topic = broker.create_topic("fx.cdc", cfg.partitions);
        let out_broker = crate::broker::Broker::with_metrics(
            cfg.partitions,
            Arc::clone(&metrics.broker),
        );
        let out_topic = out_broker.create_topic("cdm.out", cfg.partitions);
        if sinks.is_empty() {
            for name in &cfg.sinks {
                sinks.push(crate::sink::from_config_name(name, &cfg)?);
            }
        }
        // sink names key consumer groups, metrics rows and `sink(name)`
        // lookup — duplicates would silently shadow each other
        let mut seen = std::collections::HashSet::new();
        for sink in &sinks {
            if !seen.insert(sink.name().to_string()) {
                anyhow::bail!(
                    "duplicate sink backend name {:?}: sink names must be unique",
                    sink.name()
                );
            }
        }
        let tracer = Arc::new(Tracer::new(Arc::clone(&metrics.trace), cfg.trace));
        let handles: Vec<SinkHandle> = sinks
            .into_iter()
            .map(|sink| {
                let sink_metrics = metrics.sinks.register(sink.name());
                SinkHandle::new(
                    sink,
                    Consumer::new(out_topic.clone(), 0, 1),
                    sink_metrics,
                    Arc::clone(&metrics),
                    Arc::clone(&tracer),
                )
            })
            .collect();
        let source: Box<dyn SourceConnector> = match source {
            Some(source) => source,
            None => Box::new(Connector::new("src")),
        };
        let evolution = EvolutionController::new(
            cfg.evolution_compatibility,
            cfg.evolution_single_change,
            schema_changes.unwrap_or_else(|| Box::new(DdlQueue::new())),
        );
        let seed = cfg.seed;
        let evict = cfg.evict;
        let pipeline = Pipeline {
            cfg,
            landscape: RwLock::new(landscape),
            cdc_topic,
            out_topic,
            dmm: EpochDmm::new(Arc::new(dpm)),
            cache: Arc::new(DcpmCache::with_mode(StateI(0), evict)),
            store: None,
            state,
            metrics,
            tracer,
            dlq: Dlq::default(),
            retry: RetryPolicy::default(),
            notice_policy: NoticePolicy::AutoConfirm,
            sinks: handles,
            evolution,
            source,
            rng: Mutex::new(Rng::seed_from(seed ^ 0xE05)),
            next_key: IdGen::new(),
            clock_us: AtomicU64::new(1_600_000_000_000_000),
        };
        let store_dir = store_dir.or_else(|| {
            pipeline.cfg.store_dir.clone().map(std::path::PathBuf::from)
        });
        match store_dir {
            Some(dir) => pipeline.with_store(dir),
            None => Ok(pipeline),
        }
    }
}

impl Pipeline {
    /// Start wiring a pipeline (see [`PipelineBuilder`]).
    pub fn builder(cfg: PipelineConfig) -> PipelineBuilder {
        PipelineBuilder {
            cfg,
            landscape: None,
            source: None,
            schema_changes: None,
            sinks: Vec::new(),
            store_dir: None,
        }
    }

    /// Build a pipeline over a freshly generated landscape with the
    /// config-driven sink set.
    pub fn new(cfg: PipelineConfig) -> Result<Pipeline> {
        Self::builder(cfg).build()
    }

    /// Build over a pre-built landscape with the config-driven sink set.
    pub fn from_landscape(
        cfg: PipelineConfig,
        landscape: Landscape,
    ) -> Result<Pipeline> {
        Self::builder(cfg).landscape(landscape).build()
    }

    /// Attach the durable matrix store (hybrid §6.2 persistence, hardened
    /// with a WAL + snapshot segments — see [`crate::store`]). Tuning
    /// comes from the config's `runtime.store.*` knobs.
    pub fn with_store(
        self,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<Self> {
        let cfg = crate::store::StoreConfig {
            segment_update_threshold: self.cfg.store_segment_threshold,
            fsync: self.cfg.store_fsync,
            recovery_budget_ms: self.cfg.store_recovery_budget_ms,
        };
        let store = MatrixStore::open_with(
            dir,
            cfg,
            Arc::new(crate::store::RealIo::default()),
            Arc::clone(&self.metrics.store),
        )?;
        self.attach_store(store)
    }

    /// Attach an already-opened store (crash tests inject fault-injecting
    /// IO here). A store that holds nothing yet gets the initial snapshot
    /// segment; one with an existing manifest is left untouched — opening
    /// must never clobber durable state (call
    /// [`Pipeline::restore_from_store`] to load it).
    pub fn attach_store(mut self, store: MatrixStore) -> Result<Self> {
        if store.manifest().is_none() && store.wal_records().is_empty() {
            let land = self.landscape.read().unwrap();
            let dusb = DusbSet::from_matrix(
                &land.matrix,
                &land.tree,
                &land.cdm,
                self.state.current(),
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?;
            store.save_dusb(&dusb, &land.tree)?;
        }
        self.store = Some(store);
        Ok(self)
    }

    pub(crate) fn now_us(&self) -> u64 {
        self.clock_us.fetch_add(1_000, Ordering::Relaxed)
    }

    /// Resolve one trace op: apply DML → CDC event → the source connector
    /// publishes it (keyed, commit order), or run the schema-change
    /// workflow.
    pub fn resolve_op(&self, op: &TraceOp) -> Result<()> {
        match op {
            TraceOp::Dml { service, kind } => {
                let ev = self.apply_dml(*service, *kind, None)?;
                if let Some(ev) = ev {
                    self.source.publish(&self.cdc_topic, ev);
                }
                Ok(())
            }
            TraceOp::SchemaChange { service } => {
                self.apply_schema_change(*service).map(|_| ())
            }
        }
    }

    /// Resolve one DML against the landscape **without publishing**: the
    /// adversarial workload engine ([`crate::workload::scenario`]) buffers
    /// the returned events so it can shuffle/duplicate them before they
    /// hit the CDC topic. `rank` targets the rank-th *oldest* live key of
    /// the service's table (Zipfian hot-key skew: rank 0 is the hottest);
    /// `None` picks uniformly like [`Pipeline::resolve_op`].
    pub fn resolve_dml(
        &self,
        service: usize,
        kind: DmlKind,
        rank: Option<u64>,
    ) -> Result<Option<CdcEvent>> {
        self.apply_dml(service, kind, rank)
    }

    /// Publish one already-resolved CDC event through the source connector
    /// (keyed produce, commit order). Pairs with [`Pipeline::resolve_dml`]
    /// so hostile traces can reorder/duplicate events between resolution
    /// and publication.
    pub fn publish_event(&self, ev: CdcEvent) {
        self.source.publish(&self.cdc_topic, ev);
    }

    /// Initial-load storm: snapshot one service's table and publish every
    /// `SnapshotRead` event onto the **same** CDC topic the live stream
    /// uses (the fig-1 race the harness must prove convergent). Returns
    /// rows published.
    pub fn publish_snapshot(&self, service: usize) -> usize {
        let ts = self.now_us();
        let events = {
            let land = self.landscape.read().unwrap();
            self.source.snapshot(
                &land.tree,
                &land.dbs[service],
                0,
                self.state.current(),
                ts,
            )
        };
        let n = events.len();
        for ev in events {
            self.source.publish(&self.cdc_topic, ev);
        }
        n
    }

    fn apply_dml(
        &self,
        service: usize,
        kind: DmlKind,
        rank: Option<u64>,
    ) -> Result<Option<CdcEvent>> {
        let mut land = self.landscape.write().unwrap();
        let state = self.state.current();
        let ts = self.now_us();
        let mut rng = self.rng.lock().unwrap();
        // split the landscape borrow: tree read-only, dbs mutable
        let Landscape { tree, dbs, .. } = &mut *land;
        let db = &mut dbs[service];
        let (schema, version) =
            (db.tables[0].schema, db.tables[0].live_version);
        let dml = match kind {
            DmlKind::Insert => {
                let key = self.next_key.next() + 1_000_000;
                let row = crate::source::random_row(
                    tree, schema, version, key, &mut rng, self.cfg.null_prob,
                );
                Dml::Insert { table: 0, row }
            }
            DmlKind::Update | DmlKind::Delete => {
                // BTreeMap keys iterate sorted ascending, so rank r is the
                // r-th oldest live key — a stable hot-key target even as
                // inserts/deletes churn the tail
                let keys: Vec<u64> = db.tables[0].keys().collect();
                let picked = match rank {
                    Some(r) if !keys.is_empty() => {
                        Some(keys[(r % keys.len() as u64) as usize])
                    }
                    Some(_) => None,
                    None => rng.choose(&keys).copied(),
                };
                match picked {
                    None => {
                        // empty table: degrade to insert
                        let key = self.next_key.next() + 1_000_000;
                        let row = crate::source::random_row(
                            tree, schema, version, key, &mut rng,
                            self.cfg.null_prob,
                        );
                        Dml::Insert { table: 0, row }
                    }
                    Some(key) if kind == DmlKind::Update => {
                        let row = crate::source::random_row(
                            tree, schema, version, key, &mut rng,
                            self.cfg.null_prob,
                        );
                        Dml::Update { table: 0, row }
                    }
                    Some(key) => Dml::Delete { table: 0, key },
                }
            }
        };
        drop(rng);
        Ok(db.apply(tree, dml, state, ts))
    }

    /// The §3.3 semi-automated workflow, routed through the online
    /// evolution lane: build a registry-style change event (add one fresh
    /// attribute to the service's schema) and apply it directly — the
    /// lane validates it, migrates the table, builds `ᵢ₊₁𝔇𝔓𝔐` off to the
    /// side and swaps the epoch (see [`super::evolution`]). Events queued
    /// on the schema-change source by other publishers are untouched;
    /// they belong to the wire lane's `pump`.
    pub fn apply_schema_change(&self, service: usize) -> Result<UpdateReport> {
        let (schema, fields) = {
            let land = self.landscape.read().unwrap();
            let schema = land.dbs[service].tables[0].schema;
            (schema, workload::evolved_fields(&land.tree, schema))
        };
        let ev = SchemaChangeEvent::add_version(schema, fields, self.now_us());
        match self.evolution.apply(self, &ev) {
            ChangeOutcome::Applied { report, .. } => Ok(report),
            ChangeOutcome::Rejected { reason, .. } => {
                Err(anyhow::anyhow!("evolution rejected: {reason}"))
            }
            ChangeOutcome::Faulted { error, .. } => Err(anyhow::anyhow!(
                "schema change applied but failed to persist: {error}"
            )),
        }
    }

    /// Map one CDC event through the DMM (Alg 6 lane), with the §3.4
    /// state-sync retry: an out-of-sync message is restamped against the
    /// current DMM state once; persistent failures go to the DLQ by the
    /// caller. An unknown `(schema, version)` first consults the in-band
    /// evolution lane — if the registry already knows the version the DMM
    /// is patched and the event maps against the fresh epoch.
    pub fn map_event(
        &self,
        ev: &CdcEvent,
    ) -> Result<Vec<(CdcOp, OutMessage)>, MapError> {
        self.map_event_traced(ev, &mut EventTrace::inactive())
    }

    /// [`Pipeline::map_event`] with span recording: an in-band heal adds a
    /// [`Stage::Heal`] span and re-stamps the trace's epoch.
    pub fn map_event_traced(
        &self,
        ev: &CdcEvent,
        tr: &mut EventTrace,
    ) -> Result<Vec<(CdcOp, OutMessage)>, MapError> {
        let Some(payload) = ev.mapping_payload() else {
            return Ok(Vec::new());
        };
        // no to_dense() copy: Alg 6 skips null fields itself, so the
        // sparse payload maps identically (perf: see EXPERIMENTS.md §Perf)
        let mapper = self.mapper_for(self.dmm.snapshot());
        let (outs, retried) = match mapper.map_or_restamp(payload) {
            Ok(mapped) => mapped,
            Err(MapError::UnknownColumn { schema, version }) => {
                let t_heal = Instant::now();
                if self.evolution.on_unknown_version(self, schema, version) {
                    // the in-band patch published a new epoch: map against it
                    tr.span(Stage::Heal, t_heal);
                    tr.stamp_epoch(self.dmm.epoch());
                    let mapper = self.mapper_for(self.dmm.snapshot());
                    mapper.map_or_restamp(payload)?
                } else {
                    tr.span_err(Stage::Heal, t_heal);
                    return Err(MapError::UnknownColumn { schema, version });
                }
            }
            Err(e) => return Err(e),
        };
        if retried {
            self.metrics.sync_retries.inc();
        }
        Ok(outs.into_iter().map(|o| (ev.op, o)).collect())
    }

    fn mapper_for(&self, dpm: Arc<DpmSet>) -> ParallelMapper {
        ParallelMapper::with_threads(
            dpm,
            Arc::clone(&self.cache),
            self.cfg.threads,
        )
        .with_kernel(self.cfg.kernel)
    }

    /// Process one CDC event end to end: map, publish, count, time.
    /// Callers that don't know the event's source position (bulk lane,
    /// scaler rounds) trace it as partition 0, offset 0.
    pub fn process_event(&self, ev: &Arc<CdcEvent>) {
        self.process_event_from(0, 0, ev);
    }

    /// [`Pipeline::process_event`] with source provenance: the trace
    /// carries the CDC partition/offset the event was consumed from, so a
    /// dead-lettered record's flight dump names its exact source position.
    pub fn process_event_from(
        &self,
        partition: usize,
        offset: u64,
        ev: &Arc<CdcEvent>,
    ) {
        self.metrics.events_in.inc();
        let t_in = Instant::now();
        let mut tr = self.tracer.begin(partition as u32, offset);
        if tr.is_active() {
            if let Some(payload) = ev.mapping_payload() {
                tr.stamp_payload(payload.schema.0, payload.version.0);
            }
            tr.stamp_epoch(self.dmm.epoch());
            tr.stamp_lane(Lane::from(self.cfg.kernel));
            tr.span(Stage::Ingest, t_in);
            self.metrics.ingest_latency.record(t_in.elapsed());
        }
        let t0 = Instant::now();
        match self.map_event_traced(ev, &mut tr) {
            Ok(outs) => {
                self.metrics.transformations.inc();
                self.metrics.map_latency.record(t0.elapsed());
                tr.span(Stage::Map, t0);
                if !outs.is_empty() {
                    // one sealed slab + one ordered batch commit per event
                    let mut arena = OutArena::for_topic(&self.out_topic);
                    for (op, out) in outs {
                        arena.push(op, out);
                    }
                    let n = self.out_topic.produce_batch(arena.seal());
                    self.metrics.messages_out.add(n as u64);
                }
                self.tracer.finish(tr);
            }
            Err(e) => {
                tr.span_err(Stage::Map, t0);
                self.metrics.dead_letters.inc();
                let error = e.to_string();
                let dump = self.tracer.finish_dead_letter(tr, &error);
                self.dlq.push_traced(
                    Arc::clone(ev),
                    error,
                    self.retry.max_attempts,
                    dump,
                );
            }
        }
    }

    /// Drain the CDM topic into every registered sink, each through its
    /// own consumer group. Returns total records applied across sinks.
    pub fn drain_sinks(&self) -> usize {
        self.sinks.iter().map(|handle| handle.drain()).sum()
    }

    /// The registered sink named `name`, if any.
    pub fn sink(&self, name: &str) -> Option<&SinkHandle> {
        self.sinks.iter().find(|handle| handle.name() == name)
    }

    /// Backend-specific view: run `f` against the concrete type of the
    /// sink named `name` (None if the name or type doesn't match).
    pub fn with_sink<T: std::any::Any, R>(
        &self,
        name: &str,
        f: impl FnOnce(&T) -> R,
    ) -> Option<R> {
        self.sink(name)?.with(f)
    }

    /// Run a whole trace single-instance: resolve ops, consume the CDC
    /// topic, map, feed the sinks; the evolution lane's control stream is
    /// pumped between ops so wire-observed schema changes apply inline.
    /// Returns the §7-style report.
    pub fn run_trace(&self, ops: &[TraceOp]) -> Result<TraceReport> {
        let start = Instant::now();
        let mut consumer: Consumer<Arc<CdcEvent>> =
            Consumer::new(self.cdc_topic.clone(), 0, 1);
        for op in ops {
            self.evolution.pump(self);
            self.resolve_op(op)?;
            loop {
                // zero-copy consume: Arc-shared segment views, no record
                // clones between the broker and the mapper
                let batches = consumer.poll_shared(64);
                if batches.is_empty() {
                    break;
                }
                for batch in &batches {
                    for rec in batch.iter() {
                        self.process_event_from(
                            batch.partition(),
                            rec.offset,
                            &rec.value,
                        );
                    }
                }
                consumer.commit();
            }
            self.drain_sinks();
        }
        // trailing pump: a change observed during the last op's batch is
        // applied before the trace returns (nothing left behind)
        self.evolution.pump(self);
        Ok(TraceReport {
            events: self.metrics.events_in.get(),
            out_messages: self.metrics.messages_out.get(),
            dead_letters: self.metrics.dead_letters.get(),
            dmm_updates: self.metrics.dmm_updates.get(),
            wall: start.elapsed(),
        })
    }

    /// Restore the DMM from the store (restart path, §6.2 hardened):
    /// segment snapshot + WAL tail replay through Alg 5 (see
    /// [`crate::store::recovery`]), published as **one fresh epoch** whose
    /// affected-column list drives targeted cache eviction — only columns
    /// the WAL tail touched drop; everything else (columns *and* compiled
    /// plans) stays warm. The state counter fast-forwards to the last
    /// committed transition so post-restore changes continue the sequence.
    pub fn restore_from_store(&self) -> Result<bool> {
        let Some(store) = &self.store else { return Ok(false) };
        let t0 = Instant::now();
        let mut land = self.landscape.write().unwrap();
        let Some(out) = store.recover(&mut land)? else {
            return Ok(false);
        };
        let crate::store::RecoveryOutcome { dpm, state, affected, .. } = out;
        let epoch = self.dmm.publish_targeted(Arc::new(dpm), affected.clone());
        self.metrics.dmm_epoch.set(epoch);
        self.state.sync_to(state);
        self.cache.advance(state, Some(&affected));
        // recovery is a provenance event: record the span and dump the
        // flight ring so the causal tail before the crash is preserved
        self.tracer.record_span(
            TraceCtx { epoch, ..TraceCtx::default() },
            Stage::Recovery,
            SINK_NONE,
            t0,
            true,
        );
        self.tracer.dump_recent("store-recovery");
        Ok(true)
    }

    /// Run a trace through the sharded mapping lane (see module docs and
    /// [`super::shard`]); `shards == 0` uses `available_parallelism`.
    pub fn run_trace_sharded(
        &self,
        ops: &[TraceOp],
        shards: usize,
    ) -> Result<TraceReport> {
        super::shard::run_sharded_trace(self, ops, shards)
    }

    /// Fig-7 dashboard snapshot (per-sink lag gauges refreshed first).
    pub fn dashboard(&self) -> String {
        for handle in &self.sinks {
            handle.lag();
        }
        self.metrics
            .dashboard(self.cache.approx_bytes(), self.cache.hit_rate())
    }

    /// Live cache-side values for exposition/snapshot.
    fn cache_view(&self) -> CacheView {
        let (plan_hits, plan_misses) = self.cache.plan_counts();
        CacheView {
            bytes: self.cache.approx_bytes(),
            hit_rate: self.cache.hit_rate(),
            plan_hits,
            plan_misses,
        }
    }

    /// Prometheus-style text exposition of all pipeline metrics (per-sink
    /// lag gauges refreshed first). See ARCHITECTURE.md §Observability
    /// for the metric name table.
    pub fn expose_text(&self) -> String {
        for handle in &self.sinks {
            handle.lag();
        }
        self.metrics.expose_text(&self.cache_view())
    }

    /// JSON snapshot of all pipeline metrics (same data as
    /// [`Pipeline::expose_text`]).
    pub fn metrics_snapshot(&self) -> crate::util::json::Json {
        for handle in &self.sinks {
            handle.lag();
        }
        self.metrics.snapshot(&self.cache_view())
    }

    /// The source connector (snapshot/initial-load paths).
    pub fn connector(&self) -> &dyn SourceConnector {
        &*self.source
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sink::{DwSink, JsonlSink, MlSink};

    fn small_pipeline() -> Pipeline {
        Pipeline::new(PipelineConfig::small()).unwrap()
    }

    #[test]
    fn insert_event_flows_to_sinks() {
        let p = small_pipeline();
        let ops = vec![TraceOp::Dml { service: 0, kind: DmlKind::Insert }];
        let report = p.run_trace(&ops).unwrap();
        assert_eq!(report.events, 1);
        assert!(report.out_messages >= 1);
        assert_eq!(report.dead_letters, 0);
        assert!(p.with_sink("dw", |dw: &DwSink| dw.total_rows()).unwrap() >= 1);
        // streaming DML went through the source connector seam
        assert_eq!(p.connector().snapshot_stats().published, 1);
    }

    #[test]
    fn duplicate_sink_names_rejected() {
        let err = Pipeline::builder(PipelineConfig::small())
            .sink(JsonlSink::new())
            .sink(JsonlSink::new())
            .build();
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("duplicate sink"));
    }

    #[test]
    fn config_sinks_register_by_name() {
        let mut cfg = PipelineConfig::small();
        cfg.sinks = vec!["dw".into(), "jsonl".into()];
        let p = Pipeline::new(cfg).unwrap();
        let names: Vec<&str> =
            p.sinks.iter().map(|handle| handle.name()).collect();
        assert_eq!(names, vec!["dw", "jsonl"]);
        assert!(p.sink("ml").is_none());
        let mut cfg = PipelineConfig::small();
        cfg.sinks = vec!["bigquery".into()];
        assert!(Pipeline::new(cfg).is_err());
    }

    #[test]
    fn builder_sinks_override_config_set() {
        let p = Pipeline::builder(PipelineConfig::small())
            .sink(JsonlSink::new())
            .build()
            .unwrap();
        assert_eq!(p.sinks.len(), 1);
        assert!(p.sink("jsonl").is_some());
        assert!(p.sink("dw").is_none());
        let ops = vec![TraceOp::Dml { service: 1, kind: DmlKind::Insert }];
        p.run_trace(&ops).unwrap();
        let applied =
            p.with_sink("jsonl", |j: &JsonlSink| j.len()).unwrap() as u64;
        assert_eq!(applied, p.metrics.messages_out.get());
    }

    #[test]
    fn per_sink_groups_have_independent_offsets() {
        let p = small_pipeline();
        let ops: Vec<TraceOp> = (0..10)
            .map(|i| TraceOp::Dml { service: i % 4, kind: DmlKind::Insert })
            .collect();
        for op in &ops {
            p.resolve_op(op).unwrap();
        }
        let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
        for (_, rec) in consumer.poll(usize::MAX) {
            p.process_event(&rec.value);
        }
        let total = p.out_topic.total_records();
        assert!(total > 0);
        // drain only the DW: its group commits, the ML group stays put
        p.sink("dw").unwrap().drain();
        assert_eq!(p.sink("dw").unwrap().lag(), 0);
        assert_eq!(p.sink("ml").unwrap().lag(), total);
        p.sink("ml").unwrap().drain();
        assert_eq!(p.sink("ml").unwrap().lag(), 0);
        assert_eq!(
            p.with_sink("ml", |ml: &MlSink| ml.observations).unwrap(),
            total
        );
    }

    #[test]
    fn trace_with_schema_change_keeps_flowing() {
        let p = small_pipeline();
        let mut ops = vec![];
        for _ in 0..20 {
            ops.push(TraceOp::Dml { service: 1, kind: DmlKind::Insert });
        }
        ops.push(TraceOp::SchemaChange { service: 1 });
        for _ in 0..20 {
            ops.push(TraceOp::Dml { service: 1, kind: DmlKind::Insert });
        }
        let report = p.run_trace(&ops).unwrap();
        assert_eq!(report.events, 40);
        assert_eq!(report.dmm_updates, 1);
        assert_eq!(report.dead_letters, 0);
        assert_eq!(p.state.current(), StateI(1));
        // cache was evicted and repopulated under the new state
        assert_eq!(p.cache.state(), StateI(1));
    }

    #[test]
    fn update_and_delete_round_trip_dw() {
        let p = small_pipeline();
        let ops = vec![
            TraceOp::Dml { service: 0, kind: DmlKind::Insert },
            TraceOp::Dml { service: 0, kind: DmlKind::Update },
            TraceOp::Dml { service: 0, kind: DmlKind::Delete },
        ];
        let report = p.run_trace(&ops).unwrap();
        assert_eq!(report.events, 3);
        // row deleted again: DW empty (the delete tombstones by key)
        assert_eq!(p.with_sink("dw", |dw: &DwSink| dw.total_rows()).unwrap(), 0);
    }

    #[test]
    fn out_of_sync_message_restamps_once() {
        let p = small_pipeline();
        // produce an event at state 0
        p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
            .unwrap();
        // bump DMM state without touching the queued message
        {
            let mut dpm = (*p.dmm.snapshot()).clone();
            dpm.state = StateI(1);
            p.dmm.publish(Arc::new(dpm));
            p.cache.evict_all(StateI(1));
        }
        let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
        for (_, rec) in consumer.poll(10) {
            p.process_event(&rec.value);
        }
        assert_eq!(p.metrics.sync_retries.get(), 1);
        assert_eq!(p.metrics.dead_letters.get(), 0);
    }

    #[test]
    fn unknown_registered_version_heals_in_band() {
        // the live version's column vanished from the DMM while the
        // registry still knows the version: the in-band lane patches the
        // column back (Alg-5 case 3) instead of dead-lettering
        let p = small_pipeline();
        p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
            .unwrap();
        {
            let land = p.landscape.read().unwrap();
            let schema = land.dbs[0].tables[0].schema;
            let v = land.dbs[0].tables[0].live_version;
            let mut dpm = (*p.dmm.snapshot()).clone();
            dpm.remove_column(schema, v);
            p.dmm.publish(Arc::new(dpm));
            p.cache.evict_all(StateI(0));
        }
        let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
        for (_, rec) in consumer.poll(10) {
            p.process_event(&rec.value);
        }
        assert_eq!(p.metrics.dead_letters.get(), 0);
        assert_eq!(p.dlq.len(), 0);
        assert!(p.metrics.messages_out.get() >= 1);
        assert_eq!(p.evolution.in_band_updates(), 1);
        // the patch is one ordinary epoch swap + state transition
        assert_eq!(p.metrics.dmm_epoch.get(), 2); // manual publish + patch
        assert_eq!(p.state.current(), StateI(1));
        {
            let land = p.landscape.read().unwrap();
            let schema = land.dbs[0].tables[0].schema;
            let v = land.dbs[0].tables[0].live_version;
            assert!(!p.dmm.snapshot().column(schema, v).is_empty());
        }
    }

    #[test]
    fn unregistered_version_goes_to_dlq() {
        use crate::message::cdc::CdcSource;
        use crate::message::InMessage;
        use crate::schema::{AttrId, VersionNo};
        let p = small_pipeline();
        let schema = p.landscape.read().unwrap().dbs[0].tables[0].schema;
        // a wire event stamped with a version the registry never saw
        let ev = Arc::new(CdcEvent {
            op: CdcOp::Create,
            before: None,
            after: Some(InMessage {
                key: 7,
                schema,
                version: VersionNo(99),
                state: p.state.current(),
                ts_us: 1,
                fields: vec![(AttrId(0), crate::util::json::Json::Num(1.0))],
            }),
            source: CdcSource {
                connector: "postgresql".into(),
                db: "svc0".into(),
                table: "main".into(),
            },
            ts_us: 1,
        });
        p.process_event(&ev);
        assert_eq!(p.metrics.dead_letters.get(), 1);
        assert_eq!(p.dlq.len(), 1);
        assert!(p.dlq.snapshot()[0].error.contains("no mapping column"));
        // no epoch or state movement for a genuinely unknown version
        assert_eq!(p.metrics.dmm_epoch.get(), 0);
        assert_eq!(p.state.current(), StateI(0));
    }

    #[test]
    fn poisoned_payload_dead_letters_instead_of_crashing() {
        use crate::message::cdc::CdcSource;
        use crate::message::InMessage;
        let p = small_pipeline();
        let (schema, version, attr) = {
            let land = p.landscape.read().unwrap();
            let schema = land.dbs[0].tables[0].schema;
            let v = land.dbs[0].tables[0].live_version;
            let sv = land.tree.version(schema, v).unwrap();
            (schema, v, sv.attrs[0])
        };
        // duplicate attr entries with conflicting nullness: Alg 1 and
        // Alg 6 would disagree on this record, so both lanes reject it —
        // it must land in the DLQ, not crash a shard worker
        let ev = Arc::new(CdcEvent {
            op: CdcOp::Create,
            before: None,
            after: Some(InMessage {
                key: 9,
                schema,
                version,
                state: p.state.current(),
                ts_us: 1,
                fields: vec![
                    (attr, crate::util::json::Json::Null),
                    (attr, crate::util::json::Json::Num(3.0)),
                ],
            }),
            source: CdcSource {
                connector: "postgresql".into(),
                db: "svc0".into(),
                table: "main".into(),
            },
            ts_us: 1,
        });
        p.process_event(&ev);
        assert_eq!(p.metrics.dead_letters.get(), 1);
        assert_eq!(p.dlq.len(), 1);
        assert!(p.dlq.snapshot()[0].error.contains("null and non-null"));
        // healthy traffic keeps flowing after the poisoned record
        p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
            .unwrap();
        let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
        for (_, rec) in consumer.poll(10) {
            p.process_event(&rec.value);
        }
        assert_eq!(p.metrics.dead_letters.get(), 1);
        assert!(p.metrics.messages_out.get() >= 1);
    }

    #[test]
    fn store_persists_and_restores() {
        let dir = crate::util::tmp::TestDir::new("pipe-store");
        let p = Pipeline::new(PipelineConfig::small())
            .unwrap()
            .with_store(dir.path())
            .unwrap();
        let before = p.dmm.snapshot().n_elements();
        p.apply_schema_change(0).unwrap();
        let after = p.dmm.snapshot().n_elements();
        assert!(after >= before);
        // the change was committed to the WAL before it published
        let store = p.store.as_ref().unwrap();
        let records = store.wal_records();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].state, StateI(1));
        // wipe in-memory DMM, restore from store (snapshot + WAL tail)
        p.dmm.publish(Arc::new(DpmSet::new(StateI(999))));
        assert!(p.restore_from_store().unwrap());
        assert_eq!(p.dmm.snapshot().n_elements(), after);
        assert_eq!(p.dmm.snapshot().state, StateI(1));
        // audit log recorded the update
        let log = p.store.as_ref().unwrap().read_log().unwrap();
        assert_eq!(log.len(), 1);
    }

    #[test]
    fn dashboard_contains_counts() {
        let p = small_pipeline();
        let ops: Vec<TraceOp> = (0..5)
            .map(|_| TraceOp::Dml { service: 0, kind: DmlKind::Insert })
            .collect();
        p.run_trace(&ops).unwrap();
        let dash = p.dashboard();
        assert!(dash.contains("METL dashboard"));
        assert!(dash.contains("transformations"));
    }
}
