//! The METL coordinator (L3): pipeline wiring, distributed state-i
//! management, the semi-automated update workflow, error management, the
//! XLA bulk lane and horizontal scaling — the paper's §3/§6 system around
//! the DMM core.

pub mod arena;
pub mod batcher;
pub mod egress;
pub mod errors;
pub mod evolution;
pub mod inspect;
pub mod pipeline;
pub mod recovery;
pub mod scaler;
pub mod shard;
pub mod state;
pub mod workflow;

pub use egress::SinkHandle;
pub use errors::DeadLetter;
pub use evolution::{ChangeOutcome, EvolutionController};
pub use pipeline::{Pipeline, PipelineBuilder};
pub use state::{EpochDmm, StateManager};
