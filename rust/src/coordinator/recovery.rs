//! Recovery procedures (paper §3.4): "it is good practice to have
//! additional error-management procedures in place as well as options to
//! set back Kafka-offsets and start new initial loads."
//!
//! The full recovery story, as a first-class coordinator API:
//! 1. quarantine — failed events accumulate in the DLQ with reasons;
//! 2. repair — the operator (or the workflow) restores a consistent DMM
//!    (store restore, or recompute from the ground-truth matrix);
//! 3. replay — DLQ events are re-mapped under the repaired state;
//! 4. reload — if replay cannot recover (schema truly gone), the affected
//!    service is re-snapshotted through an initial load, after setting
//!    the consumer offsets back.

use std::sync::Arc;

use anyhow::Result;

use super::batcher::InitialLoader;
use super::pipeline::{OutArena, Pipeline};
use crate::broker::Consumer;
use crate::matrix::dpm::DpmSet;
use crate::message::cdc::CdcEvent;

/// Outcome of a recovery round.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryReport {
    /// DLQ entries taken into the round.
    pub quarantined: usize,
    /// Entries that mapped successfully after the repair.
    pub replayed: usize,
    /// Entries still failing → returned to the DLQ.
    pub still_failing: usize,
    /// Services re-snapshotted through the initial-load fallback.
    pub reloaded_services: Vec<usize>,
}

/// Step 2 — repair: rebuild the DMM from the landscape's ground-truth
/// matrix under the *current* state (operator action "recompute the
/// mapping configuration").
pub fn repair_dmm_from_truth(pipeline: &Pipeline) -> Result<()> {
    let land = pipeline.landscape.read().unwrap();
    let dpm = DpmSet::from_matrix(
        &land.matrix,
        &land.tree,
        &land.cdm,
        pipeline.state.current(),
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    drop(land);
    let epoch = pipeline.dmm.publish(Arc::new(dpm));
    pipeline.metrics.dmm_epoch.set(epoch);
    pipeline.cache.evict_all(pipeline.state.current());
    Ok(())
}

/// Steps 3+4 — replay the DLQ; events that still fail send their source
/// service through an offset-reset + initial load (the paper's last
/// resort), after which they are dropped from the queue (the reload
/// re-produced their rows authoritatively).
pub fn replay_dlq(
    pipeline: &Pipeline,
    loader: &InitialLoader,
) -> Result<RecoveryReport> {
    let dead = pipeline.dlq.drain();
    let mut report = RecoveryReport {
        quarantined: dead.len(),
        replayed: 0,
        still_failing: 0,
        reloaded_services: Vec::new(),
    };
    for entry in dead {
        match pipeline.map_event(&entry.event) {
            Ok(outs) => {
                report.replayed += 1;
                // sealed per entry: a mid-loop reload (the Err arm below)
                // must not leapfrog records replayed before it
                let mut arena = OutArena::for_topic(&pipeline.out_topic);
                for (op, out) in outs {
                    arena.push(op, out);
                }
                let n = pipeline.out_topic.produce_batch(arena.seal());
                pipeline.metrics.messages_out.add(n as u64);
            }
            Err(_) => {
                report.still_failing += 1;
                // find the owning service by source db name
                let service = {
                    let land = pipeline.landscape.read().unwrap();
                    land.dbs
                        .iter()
                        .position(|db| db.db_name == entry.event.source.db)
                };
                if let Some(service) = service {
                    if !report.reloaded_services.contains(&service) {
                        loader.initial_load(pipeline, service)?;
                        report.reloaded_services.push(service);
                    }
                } else {
                    // unknown source: keep it quarantined
                    pipeline.dlq.push(
                        entry.event,
                        entry.error,
                        entry.attempts + 1,
                    );
                }
            }
        }
    }
    Ok(report)
}

/// Full §3.4 fallback: set the CDC consumer back to the beginning and
/// reprocess everything (idempotent sinks absorb the duplicates).
pub fn offset_reset_reprocess(
    pipeline: &Pipeline,
    consumer: &mut Consumer<Arc<CdcEvent>>,
) -> usize {
    consumer.reset_to_beginning();
    let mut n = 0;
    loop {
        let batches = consumer.poll_shared(256);
        if batches.is_empty() {
            break;
        }
        for batch in &batches {
            for rec in batch.iter() {
                pipeline.process_event_from(
                    batch.partition(),
                    rec.offset,
                    &rec.value,
                );
                n += 1;
            }
        }
        consumer.commit();
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::message::StateI;
    use crate::workload::{DmlKind, TraceOp};

    fn poisoned_pipeline() -> Pipeline {
        // a pipeline whose DMM lost EVERY column of a schema → events
        // dead-letter (with only the live column gone the in-band
        // evolution lane would re-derive it from the previous version;
        // with the whole lineage gone there is nothing to copy from)
        let p = Pipeline::new(PipelineConfig::small()).unwrap();
        for _ in 0..5 {
            p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
                .unwrap();
        }
        {
            let land = p.landscape.read().unwrap();
            let schema = land.dbs[0].tables[0].schema;
            let mut dpm = (*p.dmm.snapshot()).clone();
            for &v in land.tree.versions_of(schema) {
                dpm.remove_column(schema, v);
            }
            p.dmm.publish(Arc::new(dpm));
            p.cache.evict_all(StateI(0));
        }
        let mut c = Consumer::new(p.cdc_topic.clone(), 0, 1);
        loop {
            let batch = c.poll(64);
            if batch.is_empty() {
                break;
            }
            for (_, rec) in &batch {
                p.process_event(&rec.value);
            }
            c.commit();
        }
        p
    }

    #[test]
    fn repair_then_replay_recovers_everything() {
        let p = poisoned_pipeline();
        assert_eq!(p.dlq.len(), 5);
        repair_dmm_from_truth(&p).unwrap();
        let loader = InitialLoader { runtime: None };
        let report = replay_dlq(&p, &loader).unwrap();
        assert_eq!(report.quarantined, 5);
        assert_eq!(report.replayed, 5);
        assert_eq!(report.still_failing, 0);
        assert!(report.reloaded_services.is_empty());
        assert!(p.dlq.is_empty());
    }

    #[test]
    fn unrecoverable_events_trigger_initial_load() {
        let p = poisoned_pipeline();
        // do NOT repair: replay fails again → service reload kicks in
        let loader = InitialLoader { runtime: None };
        let report = replay_dlq(&p, &loader).unwrap();
        assert_eq!(report.quarantined, 5);
        assert_eq!(report.replayed, 0);
        assert_eq!(report.still_failing, 5);
        assert_eq!(report.reloaded_services, vec![0]);
        // the reload snapshot re-produced the service's rows
        assert!(p.metrics.events_in.get() >= 5);
    }

    #[test]
    fn offset_reset_reprocesses_idempotently() {
        let p = Pipeline::new(PipelineConfig::small()).unwrap();
        for _ in 0..8 {
            p.resolve_op(&TraceOp::Dml { service: 1, kind: DmlKind::Insert })
                .unwrap();
        }
        let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
        // normal pass
        loop {
            let batch = consumer.poll(64);
            if batch.is_empty() {
                break;
            }
            for (_, rec) in &batch {
                p.process_event(&rec.value);
            }
            consumer.commit();
        }
        // full reprocess
        let n = offset_reset_reprocess(&p, &mut consumer);
        assert_eq!(n, 8);
        assert_eq!(p.metrics.events_in.get(), 16);
        // sinks stay consistent
        p.drain_sinks();
        let dupes = p
            .with_sink("dw", |dw: &crate::sink::DwSink| dw.total_duplicates())
            .unwrap();
        assert!(dupes > 0);
    }
}
