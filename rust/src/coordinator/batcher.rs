//! The bulk lane: initial loads through the AOT-compiled XLA kernels
//! (paper §3.4/§6.4 — offset resets and initial loads are the fallback
//! and scale-out moments; thousands of snapshot messages per block
//! amortize one compiled executable).
//!
//! Messages are packed into presence tensors in *block-local* coordinates,
//! executed through [`BulkRuntime`], and unpacked into the same
//! `OutMessage`s the Alg-6 lane would produce — the two lanes are
//! equivalence-tested in `rust/tests/integration_runtime.rs`.

use std::time::Instant;

use anyhow::{Context, Result};

use super::pipeline::{OutArena, Pipeline};
use crate::mapper::kernel::{self, KernelMode};
use crate::matrix::blocks;
use crate::message::cdc::CdcOp;
use crate::message::{InMessage, OutMessage};
use crate::runtime::BulkRuntime;
use crate::trace::{Lane, Stage, TraceCtx, SINK_NONE};
use crate::util::json::Json;

/// Outcome of one initial load.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadReport {
    pub rows: usize,
    pub out_messages: usize,
    /// Whether the XLA lane served the load (false = native kernel or
    /// Alg 6 fallback).
    pub used_bulk: bool,
    /// Which lane served the load: `"xla"`, `"native"` or `"scalar"`.
    pub lane: &'static str,
}

/// The initial-load driver.
pub struct InitialLoader {
    pub runtime: Option<BulkRuntime>,
}

impl InitialLoader {
    /// Build from a pipeline config's artifacts dir (None → fallback lane).
    pub fn from_config(cfg: &crate::config::PipelineConfig) -> InitialLoader {
        let runtime = cfg
            .artifacts_dir
            .as_ref()
            .and_then(BulkRuntime::try_load);
        InitialLoader { runtime }
    }

    /// Snapshot one service's table and map every row to the CDM,
    /// publishing to the out topic. Uses the XLA bulk lane when available
    /// and the blocks fit the compiled dims.
    pub fn initial_load(
        &self,
        pipeline: &Pipeline,
        service: usize,
    ) -> Result<LoadReport> {
        let t_load = Instant::now();
        let land = pipeline.landscape.read().unwrap();
        let db = &land.dbs[service];
        let state = pipeline.state.current();
        let snapshot = pipeline.connector().snapshot(
            &land.tree,
            db,
            0,
            state,
            0,
        );
        let rows = snapshot.len();
        let schema = db.tables[0].schema;
        let version = db.tables[0].live_version;
        let dpm = pipeline.dmm.snapshot();
        let column = dpm.column(schema, version);

        // decide lane
        let bulk_ok = self.runtime.as_ref().is_some_and(|rt| {
            let (pmax, qmax) = rt.block_dims();
            column.iter().all(|b| {
                blocks::block_extent(&land.tree, &land.cdm, b.key)
                    .is_some_and(|ext| {
                        ext.cols.len() <= pmax && ext.rows.len() <= qmax
                    })
            })
        });

        let has_payload = snapshot.iter().any(|ev| ev.after.is_some());

        let mut out_messages = 0usize;
        if bulk_ok && has_payload {
            // dense copies only here: the presence packing below indexes
            // positional fields, which the sparse wire form doesn't carry
            let messages: Vec<InMessage> = snapshot
                .iter()
                .filter_map(|ev| ev.after.as_ref().map(|m| m.to_dense()))
                .collect();
            let rt = self.runtime.as_ref().unwrap();
            let mut arena = OutArena::for_topic(&pipeline.out_topic);
            for block in column.iter() {
                let ext = blocks::block_extent(&land.tree, &land.cdm, block.key)
                    .context("live block")?;
                // block-local permutation elements
                let elements: Vec<(usize, usize)> = block
                    .elements
                    .iter()
                    .map(|&(q, p)| {
                        (q.index() - ext.rows.start, p.index() - ext.cols.start)
                    })
                    .collect();
                // block-local presence per message
                let presence: Vec<Vec<usize>> = messages
                    .iter()
                    .map(|m| {
                        m.fields
                            .iter()
                            .filter(|(a, v)| {
                                !v.is_null()
                                    && ext.cols.contains(&a.index())
                            })
                            .map(|(a, _)| a.index() - ext.cols.start)
                            .collect()
                    })
                    .collect();
                let mapped = rt.bulk_map_block(&elements, &presence)?;
                for (msg, pairs) in messages.iter().zip(mapped) {
                    if pairs.is_empty() {
                        continue;
                    }
                    let fields: Vec<(crate::cdm::CdmAttrId, Json)> = pairs
                        .iter()
                        .map(|&(ql, pl)| {
                            let q = crate::cdm::CdmAttrId(
                                (ext.rows.start + ql) as u32,
                            );
                            let p = crate::schema::AttrId(
                                (ext.cols.start + pl) as u32,
                            );
                            let data = msg
                                .data_object(p)
                                .expect("bulk presence implies data")
                                .clone();
                            (q, data)
                        })
                        .collect();
                    let out = OutMessage {
                        key: msg.key,
                        entity: block.key.entity,
                        version: block.key.w,
                        state,
                        ts_us: msg.ts_us,
                        fields,
                    };
                    arena.push(CdcOp::SnapshotRead, out);
                }
            }
            // one slab for the whole load, one publish per partition
            out_messages = pipeline.out_topic.produce_batch(arena.seal());
            pipeline.metrics.messages_out.add(out_messages as u64);
            pipeline.metrics.bulk_events.add(rows as u64);
            pipeline.metrics.events_in.add(rows as u64);
            pipeline.metrics.transformations.add(rows as u64);
            self.bulk_span(pipeline, schema.0, version.0, t_load);
            Ok(LoadReport { rows, out_messages, used_bulk: true, lane: "xla" })
        } else if pipeline.cfg.kernel == KernelMode::Native {
            drop(land);
            // Native block-permutation lane: compile the column's gather
            // plan once and push every snapshot message through it with one
            // warm scratch — same outputs as the Alg-6 lane (equivalence:
            // rust/tests/kernel_equivalence.rs), without the per-event
            // mapper setup of the fallback below.
            let (_, plan) = pipeline.cache.plan(&dpm, schema, version);
            let mut arena = OutArena::for_topic(&pipeline.out_topic);
            kernel::with_scratch(|scratch| {
                // no to_dense() copies: the gather plan skips null fields
                // itself, so the sparse wire form maps identically
                for msg in snapshot.iter().filter_map(|ev| ev.after.as_ref()) {
                    for out in plan.map_message(msg, scratch) {
                        arena.push(CdcOp::SnapshotRead, out);
                    }
                }
            });
            out_messages = pipeline.out_topic.produce_batch(arena.seal());
            pipeline.metrics.messages_out.add(out_messages as u64);
            pipeline.metrics.bulk_events.add(rows as u64);
            pipeline.metrics.events_in.add(rows as u64);
            pipeline.metrics.transformations.add(rows as u64);
            self.bulk_span(pipeline, schema.0, version.0, t_load);
            Ok(LoadReport { rows, out_messages, used_bulk: false, lane: "native" })
        } else {
            drop(land);
            // Alg-6 fallback lane
            for ev in &snapshot {
                let ev = std::sync::Arc::new(ev.clone());
                let before = pipeline.metrics.messages_out.get();
                pipeline.process_event(&ev);
                out_messages +=
                    (pipeline.metrics.messages_out.get() - before) as usize;
            }
            Ok(LoadReport { rows, out_messages, used_bulk: false, lane: "scalar" })
        }
    }

    /// One batch-level map span for a whole bulk load (the per-event lanes
    /// trace per event instead); the `Bulk` lane tag marks it in exports.
    fn bulk_span(
        &self,
        pipeline: &Pipeline,
        schema: u32,
        version: u32,
        t0: Instant,
    ) {
        pipeline.tracer.record_span(
            TraceCtx {
                schema,
                version,
                epoch: pipeline.dmm.epoch(),
                lane: Lane::Bulk,
                ..TraceCtx::default()
            },
            Stage::Map,
            SINK_NONE,
            t0,
            true,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::util::rng::Rng;

    fn loaded_pipeline_with(cfg: PipelineConfig, rows: usize) -> Pipeline {
        let mut land = crate::workload::generate(&cfg);
        let mut rng = Rng::seed_from(5);
        crate::workload::populate(&mut land, rows, &mut rng);
        // keep only the rows we just made
        Pipeline::from_landscape(cfg, land).unwrap()
    }

    fn loaded_pipeline(rows: usize) -> Pipeline {
        loaded_pipeline_with(PipelineConfig::small(), rows)
    }

    #[test]
    fn fallback_lane_loads_snapshot() {
        let p = loaded_pipeline(25);
        let loader = InitialLoader { runtime: None };
        let report = loader.initial_load(&p, 0).unwrap();
        assert_eq!(report.rows, 25);
        assert!(!report.used_bulk);
        // without XLA artifacts the default config serves the load from
        // the native kernel lane
        assert_eq!(report.lane, "native");
        assert!(report.out_messages > 0);
        // outputs reached the topic
        assert!(p.out_topic.total_records() >= report.out_messages as u64);
    }

    #[test]
    fn native_and_scalar_load_lanes_agree() {
        let p_native = loaded_pipeline(30);
        let mut cfg = PipelineConfig::small();
        cfg.kernel = KernelMode::Scalar;
        let p_scalar = loaded_pipeline_with(cfg, 30);
        let loader = InitialLoader { runtime: None };
        let rn = loader.initial_load(&p_native, 0).unwrap();
        let rs = loader.initial_load(&p_scalar, 0).unwrap();
        assert_eq!(rn.lane, "native");
        assert_eq!(rs.lane, "scalar");
        assert!(!rn.used_bulk && !rs.used_bulk);
        assert_eq!(rn.rows, rs.rows);
        assert_eq!(rn.out_messages, rs.out_messages);
        // drain both DWs and compare materialized rows
        p_native.drain_sinks();
        p_scalar.drain_sinks();
        let rows = |p: &Pipeline| {
            p.with_sink("dw", |dw: &crate::sink::DwSink| dw.total_rows())
                .unwrap()
        };
        assert_eq!(rows(&p_native), rows(&p_scalar));
        assert_eq!(p_scalar.metrics.dead_letters.get(), 0);
    }

    #[test]
    fn bulk_lane_matches_fallback_when_artifacts_present() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let p_bulk = loaded_pipeline(40);
        let p_fall = loaded_pipeline(40);
        let bulk = InitialLoader {
            runtime: crate::runtime::BulkRuntime::try_load(&dir),
        };
        assert!(bulk.runtime.is_some());
        let fall = InitialLoader { runtime: None };
        let rb = bulk.initial_load(&p_bulk, 1).unwrap();
        let rf = fall.initial_load(&p_fall, 1).unwrap();
        assert!(rb.used_bulk);
        assert_eq!(rb.rows, rf.rows);
        assert_eq!(rb.out_messages, rf.out_messages);
        // drain both sinks and compare DW contents
        p_bulk.drain_sinks();
        p_fall.drain_sinks();
        let rows = |p: &Pipeline| {
            p.with_sink("dw", |dw: &crate::sink::DwSink| dw.total_rows())
                .unwrap()
        };
        assert_eq!(rows(&p_bulk), rows(&p_fall));
    }
}
