//! Per-sink egress lanes: every registered [`SinkConnector`] backend gets
//! its **own consumer group** over the CDM topic, with independent
//! offsets, commits and lag — one slow or stalled backend never blocks
//! the others (the fig-1 fan-out property; DOD-ETL's pluggable stage
//! boundaries applied to the load side).
//!
//! A [`SinkHandle`] bundles the backend, its single-member consumer group
//! and its metrics. Draining is at-least-once: records are applied, then
//! the offset commits; a crash in between re-delivers on the next drain
//! and the backend's idempotent `apply` absorbs the duplicates.

use std::any::Any;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::pipeline::OutRecord;
use crate::broker::{Consumer, SharedBatch};
use crate::metrics::{PipelineMetrics, SinkMetrics};
use crate::sink::{DeliveryTag, SinkConnector, SinkStats};
use crate::trace::{Stage, TraceCtx, Tracer};

/// Batch size of one egress poll round.
const DRAIN_BATCH: usize = 256;

/// One registered sink backend plus its own consumer group + metrics.
pub struct SinkHandle {
    name: String,
    sink: Mutex<Box<dyn SinkConnector>>,
    consumer: Mutex<Consumer<OutRecord>>,
    metrics: Arc<SinkMetrics>,
    metrics_root: Arc<PipelineMetrics>,
    tracer: Arc<Tracer>,
    /// This sink's id in the tracer's sink registry — egress spans carry
    /// it so Chrome exports land each backend on its own track.
    sink_idx: u8,
}

impl SinkHandle {
    pub(crate) fn new(
        sink: Box<dyn SinkConnector>,
        consumer: Consumer<OutRecord>,
        metrics: Arc<SinkMetrics>,
        metrics_root: Arc<PipelineMetrics>,
        tracer: Arc<Tracer>,
    ) -> Self {
        let sink_idx = tracer.register_sink(sink.name());
        Self {
            name: sink.name().to_string(),
            sink: Mutex::new(sink),
            consumer: Mutex::new(consumer),
            metrics,
            metrics_root,
            tracer,
            sink_idx,
        }
    }

    /// Backend name (`"dw"`, `"ml"`, ... — `Pipeline::sink` lookup key).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// This sink's metrics (drained/duplicates/dropped/lag/flush errors).
    pub fn metrics(&self) -> &SinkMetrics {
        &self.metrics
    }

    /// Drain this sink's consumer group: poll → apply → flush → commit
    /// until the CDM topic is exhausted, then refresh the metrics gauges.
    /// Returns records durably drained this round.
    ///
    /// Durability before progress: the backend flushes **before** the
    /// offsets commit. A failed flush rewinds to the last commit and
    /// stops the round (counted in `flush_errors`, visible as lag) — the
    /// next drain redelivers the batch once the backend recovers, and the
    /// at-least-once contract means backends absorb the re-applies.
    pub fn drain(&self) -> usize {
        let mut consumer = self.consumer.lock().unwrap();
        let mut sink = self.sink.lock().unwrap();
        let mut n = 0;
        loop {
            let batches = consumer.poll_shared(DRAIN_BATCH);
            if batches.is_empty() {
                break;
            }
            let t0 = Instant::now();
            for batch in &batches {
                Self::apply_batch(&mut **sink, batch);
            }
            let ok = sink.flush().is_ok();
            self.metrics_root.egress_latency.record(t0.elapsed());
            self.tracer
                .record_span(TraceCtx::default(), Stage::Egress, self.sink_idx, t0, ok);
            if !ok {
                self.metrics.flush_errors.inc();
                // ship the causal history with the failure: the last N
                // completed traces tell which events fed this batch
                self.tracer
                    .dump_recent(&format!("sink {} flush error", self.name));
                consumer.rewind_to_committed();
                break;
            }
            consumer.commit();
            n += batches.iter().map(SharedBatch::len).sum::<usize>();
        }
        self.metrics.drained.add(n as u64);
        let stats = sink.snapshot_stats();
        self.metrics.duplicates.set(stats.duplicates);
        self.metrics.dropped.set(stats.dropped);
        self.metrics.lag.set(consumer.lag());
        n
    }

    /// Apply one shared segment view through the delivery-aware path:
    /// records are read by reference straight out of the broker segment
    /// (every sink group shares the same slabs), and each carries its
    /// `(partition, offset)` tag so backends dedupe at-least-once
    /// redelivery exactly.
    fn apply_batch(sink: &mut dyn SinkConnector, batch: &SharedBatch<OutRecord>) {
        let partition = batch.partition() as u32;
        for rec in batch.iter() {
            let (op, msg) = &*rec.value;
            let tag = DeliveryTag { partition, offset: rec.offset };
            sink.apply_at(tag, msg, *op);
        }
    }

    /// Crash-injection seam for the at-least-once conformance tests:
    /// poll → apply → flush exactly like [`Self::drain`], but "crash"
    /// before any offset commit — the consumer position rewinds to the
    /// last commit, so the next [`Self::drain`] redelivers everything
    /// this round applied and the backend's offset-watermark dedupe must
    /// absorb it. Returns records applied (none of them committed).
    pub fn drain_crash_before_commit(&self) -> usize {
        let mut consumer = self.consumer.lock().unwrap();
        let mut sink = self.sink.lock().unwrap();
        let mut n = 0;
        loop {
            let batches = consumer.poll_shared(DRAIN_BATCH);
            if batches.is_empty() {
                break;
            }
            for batch in &batches {
                Self::apply_batch(&mut **sink, batch);
            }
            if sink.flush().is_err() {
                self.metrics.flush_errors.inc();
                break;
            }
            n += batches.iter().map(SharedBatch::len).sum::<usize>();
        }
        // the crash: applied + flushed, but the commit never happened
        consumer.rewind_to_committed();
        self.metrics.lag.set(consumer.lag());
        n
    }

    /// Current consumer lag (CDM records this backend has not consumed);
    /// also refreshes the lag gauge.
    pub fn lag(&self) -> u64 {
        let lag = self.consumer.lock().unwrap().lag();
        self.metrics.lag.set(lag);
        lag
    }

    /// Backend counters snapshot.
    pub fn stats(&self) -> SinkStats {
        self.sink.lock().unwrap().snapshot_stats()
    }

    /// Flush the backend's buffered state.
    pub fn flush(&self) -> Result<()> {
        self.sink.lock().unwrap().flush()
    }

    /// Reset this group's offsets to the beginning of the CDM topic — the
    /// §3.4 "set back Kafka-offsets" fallback, per sink (idempotent
    /// backends absorb the re-deliveries). The backend's delivery-dedupe
    /// watermarks reset with it: this replay is deliberate — a wiped
    /// backend must be rebuilt, not have the whole stream deduplicated
    /// away.
    pub fn reset_to_beginning(&self) {
        self.consumer.lock().unwrap().reset_to_beginning();
        self.sink.lock().unwrap().reset_dedupe();
    }

    /// Abandon uncommitted progress (crash simulation: next drain
    /// re-delivers everything past the last commit).
    pub fn rewind_to_committed(&self) {
        self.consumer.lock().unwrap().rewind_to_committed();
    }

    /// Backend-specific view: run `f` against the concrete sink type, if
    /// this handle's backend is a `T`.
    pub fn with<T: Any, R>(&self, f: impl FnOnce(&T) -> R) -> Option<R> {
        let sink = self.sink.lock().unwrap();
        sink.as_any().downcast_ref::<T>().map(f)
    }
}
