//! Error management (paper §3.4): dead-letter queue for events that cannot
//! be mapped, retry accounting, and the offset-reset / initial-load
//! fallback options "one needs to keep in mind when reading the paper".

use std::sync::{Arc, Mutex};

use crate::message::cdc::CdcEvent;

/// One event that exhausted its mapping attempts.
#[derive(Debug, Clone)]
pub struct DeadLetter {
    pub event: Arc<CdcEvent>,
    pub error: String,
    pub attempts: u32,
    /// Rendered flight-recorder trace (full causal history: source
    /// offset → epoch → failing stage) when tracing was enabled.
    pub trace: Option<String>,
}

/// Thread-safe dead-letter queue.
#[derive(Debug, Default)]
pub struct Dlq {
    entries: Mutex<Vec<DeadLetter>>,
}

impl Dlq {
    pub fn push(&self, event: Arc<CdcEvent>, error: String, attempts: u32) {
        self.push_traced(event, error, attempts, None);
    }

    /// [`Dlq::push`] with the record's rendered flight-recorder trace, so
    /// a quarantined event ships with its provenance.
    pub fn push_traced(
        &self,
        event: Arc<CdcEvent>,
        error: String,
        attempts: u32,
        trace: Option<String>,
    ) {
        self.entries
            .lock()
            .unwrap()
            .push(DeadLetter { event, error, attempts, trace });
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain for reprocessing (after an offset reset / matrix fix).
    pub fn drain(&self) -> Vec<DeadLetter> {
        std::mem::take(&mut self.entries.lock().unwrap())
    }

    pub fn snapshot(&self) -> Vec<DeadLetter> {
        self.entries.lock().unwrap().clone()
    }
}

/// Retry policy for state-sync mapping failures.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_attempts: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 2 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::message::cdc::{CdcOp, CdcSource};

    fn ev() -> Arc<CdcEvent> {
        Arc::new(CdcEvent {
            op: CdcOp::Create,
            before: None,
            after: None,
            source: CdcSource {
                connector: "pg".into(),
                db: "d".into(),
                table: "t".into(),
            },
            ts_us: 0,
        })
    }

    #[test]
    fn push_drain() {
        let dlq = Dlq::default();
        assert!(dlq.is_empty());
        dlq.push(ev(), "unknown column".into(), 2);
        dlq.push(ev(), "state mismatch".into(), 3);
        assert_eq!(dlq.len(), 2);
        let drained = dlq.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].error, "unknown column");
        assert!(dlq.is_empty());
    }

    #[test]
    fn snapshot_does_not_drain() {
        let dlq = Dlq::default();
        dlq.push(ev(), "x".into(), 1);
        assert_eq!(dlq.snapshot().len(), 1);
        assert_eq!(dlq.len(), 1);
    }
}
