//! The **online schema-evolution lane** (paper §3.3/§5.4, the "automation
//! of updates of the matrix in response to changes in the extraction
//! sources" of §3.5) — the runtime path from a schema change observed on
//! the wire to a new DMM epoch, while mapping continues.
//!
//! Two signals feed the lane:
//!
//! 1. **Control stream**: Debezium-style DDL/registry events arrive on a
//!    [`SchemaChangeSource`]; [`EvolutionController::pump`] drains and
//!    applies them between mapping batches.
//! 2. **In-band detection**: a CDC record whose `(SchemaId, VersionNo)`
//!    has no mapping column means the source migrated before the registry
//!    event reached METL. [`EvolutionController::on_unknown_version`]
//!    patches the DMM from the registered tree version (Alg-5 case 3) so
//!    the record maps instead of dead-lettering.
//!
//! Per accepted change the lane: validates against the registry's
//! [`Compatibility`] rules (incompatible changes are **rejected without
//! touching the epoch** — the `rejected_changes` counter records them),
//! registers the version and migrates the bound tables, builds
//! `ᵢ₊₁𝔇𝔓𝔐` off to the side ([`prepare_update`]), publishes it with one
//! epoch swap ([`EpochDmm::publish_targeted`]) and evicts **only the
//! affected cache columns** ([`crate::cache::DcpmCache::advance`]) — the
//! targeted default that removes the §7 full-evict latency spike
//! (`--evict full` restores the old behaviour). Update latency and the
//! pending-event backlog are surfaced as `update_latency` / `epoch_lag`
//! metrics.
//!
//! [`EpochDmm::publish_targeted`]: super::state::EpochDmm::publish_targeted

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::pipeline::Pipeline;
use super::workflow::WorkflowOutcome;
use crate::matrix::dusb::DusbSet;
use crate::matrix::update::{prepare_update, ChangeCase, UpdateReport};
use crate::message::StateI;
use crate::schema::evolution::{self, Compatibility};
use crate::schema::{ExtractType, SchemaId, VersionNo};
use crate::source::{SchemaChange, SchemaChangeEvent, SchemaChangeSource};
use crate::store::WalOp;
use crate::trace::{Stage, TraceCtx, SINK_NONE};
use crate::workload::Landscape;

/// Result of applying one schema-change event.
#[derive(Debug)]
pub enum ChangeOutcome {
    /// The change passed validation and is live: the DMM swapped to
    /// `epoch`, only the listed columns were evicted.
    Applied {
        schema: SchemaId,
        v: VersionNo,
        epoch: u64,
        report: UpdateReport,
    },
    /// The change violated the compatibility rules (or referenced an
    /// unknown/live version) and was dropped — state and epoch untouched.
    Rejected { schema: SchemaId, reason: String },
    /// Store I/O failed — an infrastructure fault the operator must look
    /// at, not a validation rejection. If the WAL commit itself failed the
    /// change is **not** live (nothing was mutated or published); if a
    /// post-publish step failed (audit line, snapshot segment) the change
    /// is live *and* durable — the WAL already carries it.
    Faulted { schema: SchemaId, error: String },
}

impl ChangeOutcome {
    pub fn is_applied(&self) -> bool {
        matches!(self, ChangeOutcome::Applied { .. })
    }
}

/// The evolution-lane controller: owns the change source and the
/// validation policy; applies accepted changes end to end against a
/// [`Pipeline`].
pub struct EvolutionController {
    compatibility: Compatibility,
    single_change: bool,
    source: Box<dyn SchemaChangeSource>,
    /// Epoch bumps triggered by in-band unknown-version detection.
    in_band_updates: AtomicU64,
}

impl EvolutionController {
    pub fn new(
        compatibility: Compatibility,
        single_change: bool,
        source: Box<dyn SchemaChangeSource>,
    ) -> Self {
        Self {
            compatibility,
            single_change,
            source,
            in_band_updates: AtomicU64::new(0),
        }
    }

    /// The schema-change ingress (publish events here; `pump` drains it).
    pub fn source(&self) -> &dyn SchemaChangeSource {
        &*self.source
    }

    pub fn compatibility(&self) -> Compatibility {
        self.compatibility
    }

    /// Epoch bumps triggered by in-band unknown-version detection.
    pub fn in_band_updates(&self) -> u64 {
        self.in_band_updates.load(Ordering::Relaxed)
    }

    /// Drain every pending change event and apply it. Returns one outcome
    /// per event, in arrival order — validation failures come back as
    /// [`ChangeOutcome::Rejected`], infrastructure failures (store I/O
    /// after the epoch swapped) as [`ChangeOutcome::Faulted`]. One faulty
    /// event never swallows the events drained after it; the `epoch_lag`
    /// gauge is refreshed at the end of every pump.
    pub fn pump(&self, p: &Pipeline) -> Vec<ChangeOutcome> {
        let events = self.source.poll_changes();
        let mut outcomes = Vec::with_capacity(events.len());
        for ev in events {
            outcomes.push(self.apply(p, &ev));
        }
        p.metrics.epoch_lag.set(self.source.pending() as u64);
        outcomes
    }

    /// Apply one schema-change event end to end (validate → **WAL
    /// commit** → register → migrate → Alg 5 off to the side → epoch swap
    /// → targeted eviction → audit/snapshot). Every failure is
    /// classified: validation failures are [`ChangeOutcome::Rejected`];
    /// store faults are [`ChangeOutcome::Faulted`] (also logged to
    /// stderr, since production loops pump fire-and-forget). The WAL
    /// commit runs *before* any mutation, so a change that was
    /// acknowledged as applied is always recoverable, and a change whose
    /// commit failed left no trace.
    pub fn apply(&self, p: &Pipeline, ev: &SchemaChangeEvent) -> ChangeOutcome {
        let t0 = Instant::now();
        let result = match &ev.change {
            SchemaChange::AddVersion { fields } => {
                self.apply_add(p, ev.schema, fields, ev.ts_us, t0)
            }
            SchemaChange::DropVersion { v } => {
                self.apply_drop(p, ev.schema, *v, ev.ts_us, t0)
            }
        };
        result.unwrap_or_else(|e| {
            eprintln!(
                "evolution: store fault while applying change for schema \
                 {:?}: {e}",
                ev.schema
            );
            ChangeOutcome::Faulted { schema: ev.schema, error: e.to_string() }
        })
    }

    fn reject(
        &self,
        p: &Pipeline,
        schema: SchemaId,
        reason: String,
    ) -> ChangeOutcome {
        p.metrics.rejected_changes.inc();
        ChangeOutcome::Rejected { schema, reason }
    }

    /// A new version arrived (full field list): validate the evolution
    /// step, register it, migrate the bound tables, patch the DMM column.
    fn apply_add(
        &self,
        p: &Pipeline,
        schema: SchemaId,
        fields: &[(String, ExtractType, bool)],
        ts_us: u64,
        t0: Instant,
    ) -> Result<ChangeOutcome> {
        let mut land = p.landscape.write().unwrap();
        let Some(latest) = land.tree.latest_version(schema) else {
            // pre-validation failure: nothing swapped, nothing persisted
            return Ok(self.reject(
                p,
                schema,
                "schema has no registered versions".to_string(),
            ));
        };
        let prev_fields =
            land.tree.field_list(schema, latest).expect("latest registered");
        if let Err(e) = evolution::validate(
            self.compatibility,
            &prev_fields,
            fields,
            self.single_change,
        ) {
            return Ok(self.reject(p, schema, e.to_string()));
        }
        // durability point: commit to the WAL before touching the tree.
        // The version number and state are deterministic under the write
        // lock (add_version assigns latest+1; only this lane bumps state),
        // so the record carries exactly what the mutation will do — a
        // commit failure leaves the pipeline untouched.
        self.wal_commit(
            p,
            schema,
            VersionNo(latest.0 + 1),
            WalOp::Add { fields: fields.to_vec() },
            ts_us,
        )?;
        let v = land.tree.add_version(schema, fields);
        debug_assert_eq!(v, VersionNo(latest.0 + 1));
        {
            // the sources migrate with the registry: new writes conform to
            // the new live version (values carried across ≡, else null)
            let Landscape { tree, dbs, .. } = &mut *land;
            for db in dbs.iter_mut() {
                for t in 0..db.tables.len() {
                    if db.tables[t].schema == schema {
                        db.migrate_table(tree, t, v);
                    }
                }
            }
        }
        let (new_state, epoch, report) = self.swap_in(
            p,
            &mut land,
            ChangeCase::AddedSchemaVersion { schema, v },
            (schema, v),
            t0,
        );
        drop(land);
        self.persist(p, new_state, &report, "added-schema-version")?;
        Ok(ChangeOutcome::Applied { schema, v, epoch, report })
    }

    /// A version retirement: drop the column set (Alg-5 case 1) and the
    /// tree node. The live version of a bound table cannot be dropped.
    fn apply_drop(
        &self,
        p: &Pipeline,
        schema: SchemaId,
        v: VersionNo,
        ts_us: u64,
        t0: Instant,
    ) -> Result<ChangeOutcome> {
        let mut land = p.landscape.write().unwrap();
        let Some(sv) = land.tree.version(schema, v) else {
            return Ok(self.reject(
                p,
                schema,
                format!("cannot drop unregistered version v{}", v.0),
            ));
        };
        let (col_start, width) = (sv.col_start(), sv.width());
        let still_live = land.dbs.iter().any(|db| {
            db.tables
                .iter()
                .any(|t| t.schema == schema && t.live_version == v)
        });
        if still_live {
            return Ok(self.reject(
                p,
                schema,
                format!("cannot drop live version v{}", v.0),
            ));
        }
        // durability point: the retirement is in the WAL before the
        // column clears or the tree node goes
        self.wal_commit(p, schema, v, WalOp::Drop, ts_us)?;
        let n_rows = land.matrix.n_rows();
        land.matrix.clear_block(0..n_rows, col_start..col_start + width);
        land.tree.delete_version(schema, v);
        let (new_state, epoch, report) = self.swap_in(
            p,
            &mut land,
            ChangeCase::DeletedSchemaVersion { schema, v },
            (schema, v),
            t0,
        );
        drop(land);
        self.persist(p, new_state, &report, "deleted-schema-version")?;
        Ok(ChangeOutcome::Applied { schema, v, epoch, report })
    }

    /// The in-memory tail of every accepted change: bump state i, build
    /// `ᵢ₊₁𝔇𝔓𝔐` off to the side, mirror the ground-truth matrix, publish
    /// with one epoch swap, evict only the affected cache column, record
    /// metrics. Infallible; persistence runs afterwards *outside* the
    /// landscape write lock (see [`EvolutionController::apply`] — the
    /// in-band path must not hold the global lock across store I/O).
    fn swap_in(
        &self,
        p: &Pipeline,
        land: &mut Landscape,
        case: ChangeCase,
        affected: (SchemaId, VersionNo),
        t0: Instant,
    ) -> (StateI, u64, UpdateReport) {
        let new_state = p.state.bump();
        let (dpm, report) =
            prepare_update(&p.dmm.snapshot(), &land.tree, &land.cdm, case, new_state);
        // mirror into the ground-truth matrix (kept for benches/invariants)
        if let ChangeCase::AddedSchemaVersion { schema, v } = case {
            let (n_rows, n_cols) =
                (land.cdm.n_attr_ids(), land.tree.n_attr_ids());
            land.matrix.grow(n_rows, n_cols);
            for block in dpm.column(schema, v) {
                for &(q, pp) in &block.elements {
                    land.matrix.set(q.index(), pp.index(), true);
                }
            }
        }
        let epoch = p.dmm.publish_targeted(Arc::new(dpm), vec![affected]);
        p.metrics.dmm_epoch.set(epoch);
        p.cache.advance(new_state, Some(&[affected]));
        p.metrics.dmm_updates.inc();
        p.metrics.update_latency.record(t0.elapsed());
        (new_state, epoch, report)
    }

    /// Commit one evolution record to the store's WAL (no-op without a
    /// store). Runs under the landscape write lock, *before* any
    /// mutation: the predicted `(state, version)` is deterministic there
    /// (`add_version` assigns latest+1, and only this lane bumps the
    /// state), so the record carries exactly what the mutation will do.
    fn wal_commit(
        &self,
        p: &Pipeline,
        schema: SchemaId,
        v: VersionNo,
        op: WalOp,
        ts_us: u64,
    ) -> Result<()> {
        let Some(store) = &p.store else { return Ok(()) };
        let t0 = Instant::now();
        let result = store.commit_update(
            StateI(p.state.current().0 + 1),
            schema,
            v,
            op,
            ts_us,
        );
        p.metrics.store_latency.record(t0.elapsed());
        p.tracer.record_span(
            TraceCtx {
                schema: schema.0,
                version: v.0,
                epoch: p.dmm.epoch(),
                ..TraceCtx::default()
            },
            Stage::StoreCommit,
            SINK_NONE,
            t0,
            result.is_ok(),
        );
        result?;
        Ok(())
    }

    /// Post-publish bookkeeping, under a fresh *read* lock: append the
    /// audit line, and — once enough WAL records accumulated past the
    /// live segment — compact the ground-truth matrix into a fresh
    /// snapshot segment (`ᵢ𝔇𝔘𝔖𝔅`, atomic manifest swap, old segment
    /// GCed). Durability does **not** depend on this: the change is
    /// already in the WAL; a racing change simply snapshots its own newer
    /// DUSB afterwards — last writer wins.
    fn persist(
        &self,
        p: &Pipeline,
        new_state: StateI,
        report: &UpdateReport,
        audit_case: &str,
    ) -> Result<()> {
        let Some(store) = &p.store else { return Ok(()) };
        let outcome = WorkflowOutcome::evaluate(
            p.notice_policy,
            new_state,
            report.clone(),
        );
        store.log_update(&outcome.audit_json(audit_case))?;
        store.sync()?;
        if store.snapshot_due() {
            let land = p.landscape.read().unwrap();
            let dusb = DusbSet::from_matrix(
                &land.matrix,
                &land.tree,
                &land.cdm,
                p.state.current(),
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?;
            store.save_dusb(&dusb, &land.tree)?;
        }
        Ok(())
    }

    /// Would Alg-5 case 3 produce a non-empty column for `(schema,
    /// version)`? The copy source is the shared
    /// [`case3_source`](crate::matrix::update::case3_source); the check
    /// applies [`auto_update`](crate::matrix::update::auto_update)'s
    /// `≡`-copy predicate without building anything.
    fn patchable(
        dmm: &crate::matrix::dpm::DpmSet,
        tree: &crate::schema::SchemaTree,
        schema: SchemaId,
        version: VersionNo,
    ) -> bool {
        let Some(prev) =
            crate::matrix::update::case3_source(dmm, schema, version)
        else {
            return false;
        };
        dmm.column(schema, prev).iter().any(|block| {
            block.elements.iter().any(|&(_, attr)| {
                tree.equivalent_in(attr, schema, version).is_some()
            })
        })
    }

    /// In-band detection: a CDC record arrived with a `(schema, version)`
    /// the DMM has no column for. If the registry (tree) already knows the
    /// version — the source migrated before the control event landed — the
    /// Alg-5 case-3 patch is applied immediately and `true` is returned so
    /// the caller retries the map against the fresh epoch. `false` means
    /// the version is genuinely unknown (or has nothing to copy from) and
    /// the record belongs in the DLQ. Unpatchable records never move the
    /// state or epoch, and the unregistered-version check runs under a
    /// read lock so a rogue-traffic storm does not serialize the workers.
    pub fn on_unknown_version(
        &self,
        p: &Pipeline,
        schema: SchemaId,
        version: VersionNo,
    ) -> bool {
        // fast path: a racing worker already patched it
        if !p.dmm.snapshot().column(schema, version).is_empty() {
            return true;
        }
        {
            // cheap read-locked screen for the common dead-letter cases
            let land = p.landscape.read().unwrap();
            if land.tree.version(schema, version).is_none() {
                return false; // not registered: a real mapping error
            }
            if !Self::patchable(&p.dmm.snapshot(), &land.tree, schema, version)
            {
                return false; // nothing to copy from: would stay empty
            }
        }
        let mut land = p.landscape.write().unwrap();
        // re-check everything under the write lock (patch races serialize
        // here; a concurrent drop may have retired the version meanwhile)
        if !p.dmm.snapshot().column(schema, version).is_empty() {
            return true;
        }
        if land.tree.version(schema, version).is_none() {
            return false;
        }
        if !Self::patchable(&p.dmm.snapshot(), &land.tree, schema, version) {
            return false;
        }
        let t0 = Instant::now();
        // durability point: the patch is logged before it publishes. If
        // the WAL is unwritable the record dead-letters instead — an
        // unlogged epoch would vanish on restart while its consumers saw
        // mapped output.
        if let Err(e) = self.wal_commit(
            p,
            schema,
            version,
            WalOp::InBand,
            p.now_us(),
        ) {
            eprintln!(
                "evolution: in-band patch for schema {schema:?} v{} not \
                 applied, wal commit failed: {e}",
                version.0
            );
            return false;
        }
        let (new_state, _epoch, report) = self.swap_in(
            p,
            &mut land,
            ChangeCase::AddedSchemaVersion { schema, v: version },
            (schema, version),
            t0,
        );
        // release the global lock BEFORE persistence: store I/O on the
        // per-event mapping path must not stall every other worker
        drop(land);
        if let Err(e) =
            self.persist(p, new_state, &report, "in-band-schema-version")
        {
            // the patched column is already live — surface the store
            // fault without dead-lettering a perfectly mappable record
            eprintln!(
                "evolution: in-band patch for schema {schema:?} v{} \
                 published but failed to persist: {e}",
                version.0
            );
        }
        self.in_band_updates.fetch_add(1, Ordering::Relaxed);
        !p.dmm.snapshot().column(schema, version).is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::message::StateI;
    use crate::source::SchemaChangeEvent;

    fn pipeline() -> Pipeline {
        Pipeline::new(PipelineConfig::small()).unwrap()
    }

    fn latest_fields(
        p: &Pipeline,
        schema: SchemaId,
    ) -> Vec<(String, ExtractType, bool)> {
        let land = p.landscape.read().unwrap();
        let latest = land.tree.latest_version(schema).unwrap();
        land.tree.field_list(schema, latest).unwrap()
    }

    #[test]
    fn accepted_add_bumps_epoch_and_migrates() {
        let p = pipeline();
        let schema = p.landscape.read().unwrap().dbs[0].tables[0].schema;
        let mut fields = latest_fields(&p, schema);
        fields.push(("evolved".into(), ExtractType::Varchar, true));
        p.evolution.source().publish_change(SchemaChangeEvent::add_version(
            schema,
            fields.clone(),
            1,
        ));
        let outcomes = p.evolution.pump(&p);
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].is_applied());
        assert_eq!(p.metrics.dmm_epoch.get(), 1);
        assert_eq!(p.state.current(), StateI(1));
        assert_eq!(p.metrics.dmm_updates.get(), 1);
        assert_eq!(p.metrics.update_latency.count(), 1);
        assert_eq!(p.metrics.epoch_lag.get(), 0);
        // the bound table migrated to the new live version
        let land = p.landscape.read().unwrap();
        let live = land.dbs[0].tables[0].live_version;
        assert_eq!(land.tree.version(schema, live).unwrap().width(), fields.len());
    }

    #[test]
    fn retype_is_rejected_without_epoch_bump() {
        let p = pipeline();
        let schema = p.landscape.read().unwrap().dbs[0].tables[0].schema;
        let before = latest_fields(&p, schema);
        let mut fields = before.clone();
        fields[0].1 = if fields[0].1 == ExtractType::Varchar {
            ExtractType::Int64
        } else {
            ExtractType::Varchar
        };
        p.evolution.source().publish_change(SchemaChangeEvent::add_version(
            schema, fields, 1,
        ));
        let outcomes = p.evolution.pump(&p);
        assert!(matches!(&outcomes[0], ChangeOutcome::Rejected { reason, .. }
            if reason.contains("type changes")));
        assert_eq!(p.metrics.rejected_changes.get(), 1);
        assert_eq!(p.metrics.dmm_epoch.get(), 0);
        assert_eq!(p.state.current(), StateI(0));
        assert_eq!(p.metrics.dmm_updates.get(), 0);
        // the tree is untouched by the rejection
        assert_eq!(latest_fields(&p, schema), before);
    }

    #[test]
    fn drop_of_live_version_is_rejected() {
        let p = pipeline();
        let (schema, live) = {
            let land = p.landscape.read().unwrap();
            let t = &land.dbs[0].tables[0];
            (t.schema, t.live_version)
        };
        p.evolution.source().publish_change(SchemaChangeEvent::drop_version(
            schema, live, 1,
        ));
        let outcomes = p.evolution.pump(&p);
        assert!(matches!(&outcomes[0], ChangeOutcome::Rejected { reason, .. }
            if reason.contains("live version")));
        assert_eq!(p.metrics.dmm_epoch.get(), 0);
    }

    #[test]
    fn drop_of_old_version_evicts_its_column() {
        let p = pipeline();
        let schema = p.landscape.read().unwrap().dbs[0].tables[0].schema;
        // v1 is never the live version in the small profile (3 versions)
        p.evolution.source().publish_change(SchemaChangeEvent::drop_version(
            schema,
            VersionNo(1),
            1,
        ));
        let outcomes = p.evolution.pump(&p);
        assert!(outcomes[0].is_applied());
        assert!(p.dmm.snapshot().column(schema, VersionNo(1)).is_empty());
        assert!(p
            .landscape
            .read()
            .unwrap()
            .tree
            .version(schema, VersionNo(1))
            .is_none());
        assert_eq!(p.metrics.dmm_epoch.get(), 1);
    }
}
