//! Horizontal scaling (paper §5.5): "reading from different
//! Kafka-partitions with different horizontally scaled apps ... under the
//! condition that we keep the configuration state stable" — N instances
//! form one consumer group over the CDC topic, each pinned to a partition
//! subset, all sharing one DMM snapshot/state i. Schema changes are
//! disabled during the scaled window, exactly as the paper prescribes for
//! initial loads.
//!
//! This is the *frozen-state* scale-out axis; its complement is the
//! sharded mapping lane ([`super::shard`]), which tolerates live epoch
//! swaps from the evolution lane ([`super::evolution`]) mid-drain.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::pipeline::Pipeline;
use crate::broker::Consumer;
use crate::message::cdc::CdcEvent;

/// Report of a scaled processing window.
#[derive(Debug, Clone)]
pub struct ScaleReport {
    pub instances: usize,
    pub processed: u64,
    pub per_instance: Vec<u64>,
    pub wall: std::time::Duration,
}

impl ScaleReport {
    pub fn throughput_eps(&self) -> f64 {
        self.processed as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Drain everything currently in the CDC topic with `instances` parallel
/// METL instances. The configuration state is pinned: all instances map
/// against the same DMM snapshot (the §5.5 precondition); the caller must
/// not run schema changes concurrently.
pub fn run_scaled(pipeline: &Pipeline, instances: usize) -> ScaleReport {
    let instances = instances.max(1);
    let start = Instant::now();
    let counters: Vec<AtomicU64> =
        (0..instances).map(|_| AtomicU64::new(0)).collect();
    std::thread::scope(|scope| {
        for member in 0..instances {
            let counters = &counters;
            // NOTE: per-instance counts stay in this report; the
            // `metrics.shard` registry is reserved for the sharded mapping
            // lane (`super::shard`) so the two scale-out axes never mix.
            scope.spawn(move || {
                let mut consumer: Consumer<std::sync::Arc<CdcEvent>> =
                    Consumer::new(pipeline.cdc_topic.clone(), member, instances);
                loop {
                    let batches = consumer.poll_shared(128);
                    if batches.is_empty() {
                        break; // drained this member's partitions
                    }
                    let mut n = 0u64;
                    for batch in &batches {
                        for rec in batch.iter() {
                            pipeline.process_event_from(
                                batch.partition(),
                                rec.offset,
                                &rec.value,
                            );
                        }
                        n += batch.len() as u64;
                    }
                    consumer.commit();
                    counters[member].fetch_add(n, Ordering::Relaxed);
                }
            });
        }
    });
    let per_instance: Vec<u64> =
        counters.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    ScaleReport {
        instances,
        processed: per_instance.iter().sum(),
        per_instance,
        wall: start.elapsed(),
    }
}

/// Lag-driven worker-count policy: grow one worker when the backlog
/// exceeds the fleet's per-round capacity, release one when it would fit
/// comfortably on a smaller fleet. Growth triggers at 100% of capacity
/// and shrink only below 50% of the *smaller* fleet's capacity — the
/// hysteresis band that keeps a steady backlog from flapping the count.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Backlog one worker is expected to absorb per round.
    pub lag_per_worker: u64,
    workers: usize,
}

impl Autoscaler {
    pub fn new(min_workers: usize, max_workers: usize, lag_per_worker: u64) -> Self {
        let min_workers = min_workers.max(1);
        Self {
            min_workers,
            max_workers: max_workers.max(min_workers),
            lag_per_worker: lag_per_worker.max(1),
            workers: min_workers,
        }
    }

    /// Current fleet size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Observe the current total lag and adjust the fleet by at most one
    /// worker; returns the new size.
    pub fn observe(&mut self, lag: u64) -> usize {
        if lag > self.workers as u64 * self.lag_per_worker {
            self.workers = (self.workers + 1).min(self.max_workers);
        } else if self.workers > self.min_workers
            && lag * 2 <= (self.workers as u64 - 1) * self.lag_per_worker
        {
            self.workers -= 1;
        }
        self.workers
    }
}

/// One autoscale round + its inputs (the scaling-decision audit trail).
#[derive(Debug, Clone)]
pub struct AutoscaleRound {
    /// Backlog observed before the round.
    pub lag: u64,
    /// Fleet size the policy chose for the round.
    pub workers: usize,
    /// Records the round processed.
    pub processed: u64,
}

/// Report of a [`run_autoscaled`] window.
#[derive(Debug, Clone)]
pub struct AutoscaleReport {
    pub rounds: Vec<AutoscaleRound>,
    pub processed: u64,
    pub peak_workers: usize,
}

/// Total CDC backlog past the caller-tracked `next` offsets (one slot
/// per partition). Wait-free: `end_offset` is a single atomic
/// acquire-load per partition, so the scaling policy reads honest lag
/// without ever contending with producers (see `Topic::end_offset`).
pub fn total_lag(pipeline: &Pipeline, next: &[u64]) -> u64 {
    next.iter()
        .enumerate()
        .map(|(p, &o)| pipeline.cdc_topic.end_offset(p).saturating_sub(o))
        .sum()
}

/// One bounded scaled round over the frozen state: partition `p` is
/// handled by member `p % workers`, each fetching at most `budget`
/// records per owned partition. `next` carries the per-partition resume
/// offsets across rounds (the group's "committed" positions). Returns
/// records processed.
pub fn autoscale_round(
    pipeline: &Pipeline,
    next: &mut [u64],
    workers: usize,
    budget: usize,
) -> u64 {
    let workers = workers.max(1);
    let counters: Vec<AtomicU64> =
        (0..workers).map(|_| AtomicU64::new(0)).collect();
    let cells: Vec<AtomicU64> =
        next.iter().map(|&o| AtomicU64::new(o)).collect();
    std::thread::scope(|scope| {
        for member in 0..workers {
            let counters = &counters;
            let cells = &cells;
            scope.spawn(move || {
                for p in
                    (0..cells.len()).filter(|p| p % workers == member)
                {
                    let from = cells[p].load(Ordering::Relaxed);
                    let batches = pipeline.cdc_topic.fetch_shared(p, from, budget);
                    for batch in &batches {
                        for rec in batch.iter() {
                            pipeline.process_event_from(p, rec.offset, &rec.value);
                        }
                        cells[p].store(
                            batch.first_offset() + batch.len() as u64,
                            Ordering::Relaxed,
                        );
                        counters[member]
                            .fetch_add(batch.len() as u64, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    for (slot, cell) in next.iter_mut().zip(&cells) {
        *slot = cell.load(Ordering::Relaxed);
    }
    counters.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

/// Drive the [`Autoscaler`] until the backlog drains: observe lag →
/// adjust the fleet → one bounded [`autoscale_round`]; stops at zero
/// lag. Like [`run_scaled`] the configuration state is frozen — the
/// caller must not run schema changes concurrently. `next` persists the
/// consumed offsets across calls, so successive burst/drain windows
/// continue where the last one stopped.
pub fn run_autoscaled(
    pipeline: &Pipeline,
    policy: &mut Autoscaler,
    budget: usize,
    next: &mut [u64],
) -> AutoscaleReport {
    let mut rounds = Vec::new();
    let mut peak_workers = policy.workers();
    loop {
        let lag = total_lag(pipeline, next);
        if lag == 0 {
            break;
        }
        let workers = policy.observe(lag);
        peak_workers = peak_workers.max(workers);
        let n = autoscale_round(pipeline, next, workers, budget);
        rounds.push(AutoscaleRound { lag, workers, processed: n });
    }
    let processed = rounds.iter().map(|r| r.processed).sum();
    AutoscaleReport { rounds, processed, peak_workers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::workload::{DmlKind, TraceOp};

    fn pipeline_with_backlog(n: usize) -> Pipeline {
        let p = Pipeline::new(PipelineConfig::small()).unwrap();
        for i in 0..n {
            p.resolve_op(&TraceOp::Dml {
                service: i % 4,
                kind: DmlKind::Insert,
            })
            .unwrap();
        }
        p
    }

    #[test]
    fn scaled_drain_processes_everything_once() {
        let p = pipeline_with_backlog(200);
        let report = run_scaled(&p, 4);
        assert_eq!(report.processed, 200);
        assert_eq!(report.instances, 4);
        assert_eq!(p.metrics.events_in.get(), 200);
        assert_eq!(p.metrics.dead_letters.get(), 0);
        // each member saw a disjoint share (4 partitions in small profile)
        assert_eq!(report.per_instance.iter().sum::<u64>(), 200);
    }

    #[test]
    fn single_instance_equivalent_counts() {
        let p1 = pipeline_with_backlog(100);
        let p4 = pipeline_with_backlog(100);
        let r1 = run_scaled(&p1, 1);
        let r4 = run_scaled(&p4, 4);
        assert_eq!(r1.processed, r4.processed);
        assert_eq!(
            p1.metrics.messages_out.get(),
            p4.metrics.messages_out.get()
        );
    }

    #[test]
    fn more_instances_than_partitions_is_safe() {
        let p = pipeline_with_backlog(50);
        // small profile has 4 partitions; 8 instances → 4 idle members
        let report = run_scaled(&p, 8);
        assert_eq!(report.processed, 50);
        assert!(report.per_instance[4..].iter().all(|&c| c == 0));
    }

    #[test]
    fn autoscaler_policy_grows_and_shrinks_with_hysteresis() {
        let mut policy = Autoscaler::new(1, 4, 100);
        assert_eq!(policy.workers(), 1);
        assert_eq!(policy.observe(400), 2); // 400 > 1×100
        assert_eq!(policy.observe(250), 3); // 250 > 2×100
        assert_eq!(policy.observe(310), 4); // 310 > 3×100
        assert_eq!(policy.observe(5000), 4); // capped at max
        assert_eq!(policy.observe(120), 3); // 2×120 ≤ 3×100: release
        assert_eq!(policy.observe(120), 3); // 2×120 > 2×100: hold (band)
        assert_eq!(policy.observe(0), 2);
        assert_eq!(policy.observe(0), 1);
        assert_eq!(policy.observe(0), 1); // floored at min
    }

    #[test]
    fn burst_drain_cycle_scales_workers_up_then_down() {
        let p = pipeline_with_backlog(400);
        let mut policy = Autoscaler::new(1, 4, 80);
        let mut next = vec![0u64; p.cdc_topic.n_partitions()];
        // burst: a 400-event backlog against 1 starting worker. Round
        // capacity is workers-agnostic here (every partition is fetched
        // with the same budget), but the policy sees the honest lag and
        // must scale out before the backlog drains.
        let burst = run_autoscaled(&p, &mut policy, 50, &mut next);
        assert_eq!(burst.processed, 400);
        assert!(
            burst.peak_workers >= 3,
            "burst must scale out, rounds: {:?}",
            burst.rounds
        );
        assert_eq!(burst.rounds[0].lag, 400);
        assert_eq!(burst.rounds[0].workers, 2);
        // worker counts never move by more than one per round
        for w in burst.rounds.windows(2) {
            assert!(w[1].workers.abs_diff(w[0].workers) <= 1);
        }
        // drain: a trickle after the burst — the policy releases workers
        for _ in 0..30 {
            p.resolve_op(&TraceOp::Dml {
                service: 0,
                kind: DmlKind::Insert,
            })
            .unwrap();
        }
        let drain = run_autoscaled(&p, &mut policy, 50, &mut next);
        assert_eq!(drain.processed, 30);
        assert!(
            policy.workers() <= 2,
            "quiet stretch must release workers, rounds: {:?}",
            drain.rounds
        );
        // a second, even quieter stretch settles back at the floor
        for _ in 0..10 {
            p.resolve_op(&TraceOp::Dml {
                service: 1,
                kind: DmlKind::Insert,
            })
            .unwrap();
        }
        let settle = run_autoscaled(&p, &mut policy, 50, &mut next);
        assert_eq!(settle.processed, 10);
        assert_eq!(policy.workers(), 1);
        // nothing lost or double-processed across the three windows
        assert_eq!(p.metrics.events_in.get(), 440);
        assert_eq!(p.metrics.dead_letters.get(), 0);
    }

    #[test]
    fn autoscale_round_resumes_from_tracked_offsets() {
        let p = pipeline_with_backlog(120);
        let mut next = vec![0u64; p.cdc_topic.n_partitions()];
        let first = autoscale_round(&p, &mut next, 2, 10);
        // a budget-10 round over 4 partitions moves at most 40 records,
        // and the lag accounting must agree with what was consumed
        assert!(first > 0 && first <= 40);
        assert_eq!(total_lag(&p, &next), 120 - first);
        let mut rest = 0;
        while total_lag(&p, &next) > 0 {
            rest += autoscale_round(&p, &mut next, 3, 10);
        }
        assert_eq!(first + rest, 120);
        assert_eq!(p.metrics.events_in.get(), 120);
    }
}
