//! Horizontal scaling (paper §5.5): "reading from different
//! Kafka-partitions with different horizontally scaled apps ... under the
//! condition that we keep the configuration state stable" — N instances
//! form one consumer group over the CDC topic, each pinned to a partition
//! subset, all sharing one DMM snapshot/state i. Schema changes are
//! disabled during the scaled window, exactly as the paper prescribes for
//! initial loads.
//!
//! This is the *frozen-state* scale-out axis; its complement is the
//! sharded mapping lane ([`super::shard`]), which tolerates live epoch
//! swaps from the evolution lane ([`super::evolution`]) mid-drain.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::pipeline::Pipeline;
use crate::broker::Consumer;
use crate::message::cdc::CdcEvent;

/// Report of a scaled processing window.
#[derive(Debug, Clone)]
pub struct ScaleReport {
    pub instances: usize,
    pub processed: u64,
    pub per_instance: Vec<u64>,
    pub wall: std::time::Duration,
}

impl ScaleReport {
    pub fn throughput_eps(&self) -> f64 {
        self.processed as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Drain everything currently in the CDC topic with `instances` parallel
/// METL instances. The configuration state is pinned: all instances map
/// against the same DMM snapshot (the §5.5 precondition); the caller must
/// not run schema changes concurrently.
pub fn run_scaled(pipeline: &Pipeline, instances: usize) -> ScaleReport {
    let instances = instances.max(1);
    let start = Instant::now();
    let counters: Vec<AtomicU64> =
        (0..instances).map(|_| AtomicU64::new(0)).collect();
    std::thread::scope(|scope| {
        for member in 0..instances {
            let counters = &counters;
            // NOTE: per-instance counts stay in this report; the
            // `metrics.shard` registry is reserved for the sharded mapping
            // lane (`super::shard`) so the two scale-out axes never mix.
            scope.spawn(move || {
                let mut consumer: Consumer<std::sync::Arc<CdcEvent>> =
                    Consumer::new(pipeline.cdc_topic.clone(), member, instances);
                loop {
                    let batch = consumer.poll(128);
                    if batch.is_empty() {
                        break; // drained this member's partitions
                    }
                    for (_, rec) in &batch {
                        pipeline.process_event(&rec.value);
                    }
                    consumer.commit();
                    counters[member]
                        .fetch_add(batch.len() as u64, Ordering::Relaxed);
                }
            });
        }
    });
    let per_instance: Vec<u64> =
        counters.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    ScaleReport {
        instances,
        processed: per_instance.iter().sum(),
        per_instance,
        wall: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::workload::{DmlKind, TraceOp};

    fn pipeline_with_backlog(n: usize) -> Pipeline {
        let p = Pipeline::new(PipelineConfig::small()).unwrap();
        for i in 0..n {
            p.resolve_op(&TraceOp::Dml {
                service: i % 4,
                kind: DmlKind::Insert,
            })
            .unwrap();
        }
        p
    }

    #[test]
    fn scaled_drain_processes_everything_once() {
        let p = pipeline_with_backlog(200);
        let report = run_scaled(&p, 4);
        assert_eq!(report.processed, 200);
        assert_eq!(report.instances, 4);
        assert_eq!(p.metrics.events_in.get(), 200);
        assert_eq!(p.metrics.dead_letters.get(), 0);
        // each member saw a disjoint share (4 partitions in small profile)
        assert_eq!(report.per_instance.iter().sum::<u64>(), 200);
    }

    #[test]
    fn single_instance_equivalent_counts() {
        let p1 = pipeline_with_backlog(100);
        let p4 = pipeline_with_backlog(100);
        let r1 = run_scaled(&p1, 1);
        let r4 = run_scaled(&p4, 4);
        assert_eq!(r1.processed, r4.processed);
        assert_eq!(
            p1.metrics.messages_out.get(),
            p4.metrics.messages_out.get()
        );
    }

    #[test]
    fn more_instances_than_partitions_is_safe() {
        let p = pipeline_with_backlog(50);
        // small profile has 4 partitions; 8 instances → 4 idle members
        let report = run_scaled(&p, 8);
        assert_eq!(report.processed, 50);
        assert!(report.per_instance[4..].iter().all(|&c| c == 0));
    }
}
