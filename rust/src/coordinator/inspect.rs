//! UI-sim inspection queries (paper §6.3): the reverse search over
//! `ᵢ𝔇ℜ𝔓𝔐` ("which incoming Kafka messages map to one business entity
//! version") and the version-progression view over one extracting schema —
//! the two data-owner feature requests the paper describes — rendered as
//! text for the CLI.

use crate::cdm::{CdmTree, CdmVersionNo, EntityId};
use crate::matrix::dpm::DpmSet;
use crate::schema::{SchemaId, SchemaTree};

/// Reverse search: all incoming schema versions feeding one business
/// entity version, with per-element mapping paths.
pub fn reverse_search(
    dpm: &DpmSet,
    tree: &SchemaTree,
    cdm: &CdmTree,
    entity: EntityId,
    w: CdmVersionNo,
) -> String {
    let mut out = format!(
        "reverse search: {} v{} (state {})\n",
        cdm.entity(entity).name,
        w.0,
        dpm.state.0
    );
    let blocks = dpm.row(entity, w);
    if blocks.is_empty() {
        out.push_str("  (no incoming mappings)\n");
        return out;
    }
    for block in blocks {
        let schema = tree.schema(block.key.schema);
        out.push_str(&format!(
            "  <- {} v{} ({} elements)\n",
            schema.name,
            block.key.v.0,
            block.elements.len()
        ));
        for &(q, p) in &block.elements {
            out.push_str(&format!(
                "     {} <- {}\n",
                cdm.path_of(q),
                tree.path_of(p)
            ));
        }
    }
    out
}

/// Version progression: how one schema's mappings evolve across versions
/// (paper: "a search function which exhibits all mappings with relation to
/// one extracting schema and multiple versions").
pub fn version_progression(
    dpm: &DpmSet,
    tree: &SchemaTree,
    cdm: &CdmTree,
    schema: SchemaId,
) -> String {
    let node = tree.schema(schema);
    let mut out = format!("version progression: {}\n", node.name);
    for &v in &node.versions {
        let column = dpm.column(schema, v);
        let elements: usize = column.iter().map(|b| b.elements.len()).sum();
        out.push_str(&format!(
            "  v{}: {} block(s), {} mapped attribute(s)\n",
            v.0,
            column.len(),
            elements
        ));
        for block in column {
            out.push_str(&format!(
                "    -> {} v{}:",
                cdm.entity(block.key.entity).name,
                block.key.w.0
            ));
            for &(q, p) in &block.elements {
                out.push_str(&format!(
                    " {}≡{}",
                    tree.attr(p).name,
                    cdm.attr(q).name
                ));
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::dpm::DpmSet;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::message::StateI;
    use crate::schema::VersionNo;

    #[test]
    fn reverse_search_lists_feeding_versions() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        let text = reverse_search(&dpm, &t, &c, be1, CdmVersionNo(2));
        assert!(text.contains("<- s1 v1 (2 elements)"));
        assert!(text.contains("<- s1 v2 (2 elements)"));
        assert!(text.contains("r.be1.v2.c3 <- d.s1.v1.a1"));
    }

    #[test]
    fn reverse_search_empty_entity() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        // be1 v1 was superseded: no mappings
        let text = reverse_search(&dpm, &t, &c, be1, CdmVersionNo(1));
        assert!(text.contains("no incoming mappings"));
    }

    #[test]
    fn version_progression_shows_block_evolution() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        let text = version_progression(&dpm, &t, &c, s1);
        assert!(text.contains("v1: 2 block(s), 4 mapped attribute(s)"));
        assert!(text.contains("v2: 1 block(s), 2 mapped attribute(s)"));
        assert!(text.contains("a1≡c3"));
        let _ = VersionNo(1);
    }
}
