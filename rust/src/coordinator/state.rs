//! Distributed configuration state `i` (paper §3.4/§3.5): every core
//! element — message, schema snapshot, DMM, cache — inherits the state;
//! transitions happen only through the update workflow, and components
//! check sync at their boundaries.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::message::StateI;

/// The pipeline-wide state counter.
#[derive(Debug, Default)]
pub struct StateManager {
    i: AtomicU64,
}

impl StateManager {
    pub fn new(initial: StateI) -> Self {
        Self { i: AtomicU64::new(initial.0) }
    }

    pub fn current(&self) -> StateI {
        StateI(self.i.load(Ordering::Acquire))
    }

    /// Transition i → i+1 (one external trigger applied). Returns the new
    /// state.
    pub fn bump(&self) -> StateI {
        StateI(self.i.fetch_add(1, Ordering::AcqRel) + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_is_monotonic() {
        let s = StateManager::new(StateI(0));
        assert_eq!(s.current(), StateI(0));
        assert_eq!(s.bump(), StateI(1));
        assert_eq!(s.bump(), StateI(2));
        assert_eq!(s.current(), StateI(2));
    }

    #[test]
    fn concurrent_bumps_unique() {
        let s = std::sync::Arc::new(StateManager::new(StateI(0)));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                (0..100).map(|_| s.bump().0).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 800);
        assert_eq!(s.current(), StateI(800));
    }
}
