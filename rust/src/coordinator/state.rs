//! Distributed configuration state `i` (paper §3.4/§3.5): every core
//! element — message, schema snapshot, DMM, cache — inherits the state;
//! transitions happen only through the update workflow, and components
//! check sync at their boundaries.
//!
//! [`EpochDmm`] is the epoch pointer of the sharded mapping lane: the live
//! `ᵢ𝔇𝔓𝔐` is always an immutable `Arc` snapshot, Alg-5 updates build the
//! next set off to the side, and publication is a single pointer swap that
//! bumps a monotonically increasing epoch. Mapping workers poll the epoch
//! (one relaxed atomic load) instead of holding the lock across mapping.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::matrix::dpm::DpmSet;
use crate::message::StateI;

/// The pipeline-wide state counter.
#[derive(Debug, Default)]
pub struct StateManager {
    i: AtomicU64,
}

impl StateManager {
    pub fn new(initial: StateI) -> Self {
        Self { i: AtomicU64::new(initial.0) }
    }

    pub fn current(&self) -> StateI {
        StateI(self.i.load(Ordering::Acquire))
    }

    /// Transition i → i+1 (one external trigger applied). Returns the new
    /// state.
    pub fn bump(&self) -> StateI {
        StateI(self.i.fetch_add(1, Ordering::AcqRel) + 1)
    }
}

/// Epoch-swapped pointer to the live immutable `ᵢ𝔇𝔓𝔐` snapshot.
///
/// Readers take O(1) `Arc` clones and map against a frozen set; writers
/// publish a fully built successor with one swap. The epoch counter lets
/// shard workers detect a swap without re-reading the pointer, and the
/// swap-before-bump order guarantees that any reader observing epoch `e`
/// sees a snapshot at least as new as the one published at `e`.
#[derive(Debug)]
pub struct EpochDmm {
    current: RwLock<Arc<DpmSet>>,
    epoch: AtomicU64,
}

impl EpochDmm {
    pub fn new(dpm: Arc<DpmSet>) -> Self {
        Self { current: RwLock::new(dpm), epoch: AtomicU64::new(0) }
    }

    /// The live snapshot: an O(1) pointer clone, safe to map against while
    /// an Alg-5 update builds the next set off to the side.
    pub fn snapshot(&self) -> Arc<DpmSet> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// Publish the next snapshot with a single pointer swap; returns the
    /// new epoch. The bump happens while the write lock is still held so
    /// concurrent publishers get epochs that correspond to their swap
    /// order (a reader observing epoch e always sees the snapshot
    /// published at e or newer).
    pub fn publish(&self, next: Arc<DpmSet>) -> u64 {
        let mut current = self.current.write().unwrap();
        *current = next;
        self.epoch.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Current epoch (bumped once per publish).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_is_monotonic() {
        let s = StateManager::new(StateI(0));
        assert_eq!(s.current(), StateI(0));
        assert_eq!(s.bump(), StateI(1));
        assert_eq!(s.bump(), StateI(2));
        assert_eq!(s.current(), StateI(2));
    }

    #[test]
    fn epoch_dmm_swap_bumps_epoch() {
        let dmm = EpochDmm::new(Arc::new(DpmSet::new(StateI(0))));
        assert_eq!(dmm.epoch(), 0);
        assert_eq!(dmm.snapshot().state, StateI(0));
        let first = dmm.snapshot();
        assert_eq!(dmm.publish(Arc::new(DpmSet::new(StateI(1)))), 1);
        assert_eq!(dmm.epoch(), 1);
        assert_eq!(dmm.snapshot().state, StateI(1));
        // the old snapshot stays valid for readers that still hold it
        assert_eq!(first.state, StateI(0));
    }

    #[test]
    fn concurrent_bumps_unique() {
        let s = std::sync::Arc::new(StateManager::new(StateI(0)));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                (0..100).map(|_| s.bump().0).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 800);
        assert_eq!(s.current(), StateI(800));
    }
}
