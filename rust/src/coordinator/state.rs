//! Distributed configuration state `i` (paper §3.4/§3.5): every core
//! element — message, schema snapshot, DMM, cache — inherits the state;
//! transitions happen only through the update workflow, and components
//! check sync at their boundaries.
//!
//! [`EpochDmm`] is the epoch pointer of the sharded mapping lane: the live
//! `ᵢ𝔇𝔓𝔐` is always an immutable `Arc` snapshot, Alg-5 updates build the
//! next set off to the side, and publication is a single pointer swap that
//! bumps a monotonically increasing epoch. Mapping workers poll the epoch
//! (one relaxed atomic load) instead of holding the lock across mapping.
//!
//! The pointer also keeps an **epoch journal**: every publish records
//! which mapping columns `(SchemaId, VersionNo)` changed relative to its
//! predecessor (when the publisher knows — the online evolution lane
//! does). A reader that held the snapshot at state `i` and refreshes to
//! state `j` asks [`EpochDmm::affected_between`] for the union of columns
//! changed in `(i, j]` and evicts only those from its `DcpmCache` instead
//! of wiping it — the targeted-eviction path that removes the §7
//! full-evict latency spike (see [`crate::cache::DcpmCache::advance`]).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::matrix::dpm::DpmSet;
use crate::message::StateI;
use crate::schema::{SchemaId, VersionNo};

/// The pipeline-wide state counter.
#[derive(Debug, Default)]
pub struct StateManager {
    i: AtomicU64,
}

impl StateManager {
    pub fn new(initial: StateI) -> Self {
        Self { i: AtomicU64::new(initial.0) }
    }

    pub fn current(&self) -> StateI {
        StateI(self.i.load(Ordering::Acquire))
    }

    /// Transition i → i+1 (one external trigger applied). Returns the new
    /// state.
    pub fn bump(&self) -> StateI {
        StateI(self.i.fetch_add(1, Ordering::AcqRel) + 1)
    }

    /// Fast-forward to `target` (store recovery replaying committed
    /// transitions). Never moves backwards.
    pub fn sync_to(&self, target: StateI) {
        self.i.fetch_max(target.0, Ordering::AcqRel);
    }
}

/// Journal entries kept; old entries fall off and force a full eviction
/// for readers that lag further than this many publishes.
const JOURNAL_CAP: usize = 64;

/// One epoch-journal record: the state a publish installed and the mapping
/// columns it changed relative to its predecessor (`None` = unknown diff,
/// e.g. a store restore or a test swapping in an arbitrary set).
#[derive(Debug)]
struct JournalEntry {
    state: StateI,
    affected: Option<Vec<(SchemaId, VersionNo)>>,
}

/// The epoch journal proper: entries plus the poison floor guarding
/// against *non-advancing* publishes. A publish whose state does not move
/// forward (a repair republishing at the current state) changes snapshot
/// content without changing the state number, so a reader identifying its
/// old snapshot by state alone can no longer tell which content it held —
/// every range starting at or below the floor must fully evict.
#[derive(Debug, Default)]
struct Journal {
    entries: VecDeque<JournalEntry>,
    /// Highest snapshot state ever published (including the initial one).
    max_state: StateI,
    /// Ranges with `old <= floor` are not reconstructible.
    poison_floor: Option<StateI>,
}

/// Epoch-swapped pointer to the live immutable `ᵢ𝔇𝔓𝔐` snapshot.
///
/// Readers take O(1) `Arc` clones and map against a frozen set; writers
/// publish a fully built successor with one swap. The epoch counter lets
/// shard workers detect a swap without re-reading the pointer, and the
/// swap-before-bump order guarantees that any reader observing epoch `e`
/// sees a snapshot at least as new as the one published at `e`.
#[derive(Debug)]
pub struct EpochDmm {
    current: RwLock<Arc<DpmSet>>,
    epoch: AtomicU64,
    journal: Mutex<Journal>,
}

impl EpochDmm {
    pub fn new(dpm: Arc<DpmSet>) -> Self {
        let initial = dpm.state;
        Self {
            current: RwLock::new(dpm),
            epoch: AtomicU64::new(0),
            journal: Mutex::new(Journal {
                max_state: initial,
                ..Journal::default()
            }),
        }
    }

    /// The live snapshot: an O(1) pointer clone, safe to map against while
    /// an Alg-5 update builds the next set off to the side.
    pub fn snapshot(&self) -> Arc<DpmSet> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// Publish the next snapshot with a single pointer swap; returns the
    /// new epoch. The bump happens while the write lock is still held so
    /// concurrent publishers get epochs that correspond to their swap
    /// order (a reader observing epoch e always sees the snapshot
    /// published at e or newer).
    ///
    /// The diff against the predecessor is recorded as *unknown*, so
    /// readers crossing this publish fall back to a full cache eviction.
    /// Publishers that know the changed columns (the evolution lane)
    /// use [`EpochDmm::publish_targeted`] instead.
    pub fn publish(&self, next: Arc<DpmSet>) -> u64 {
        self.publish_entry(next, None)
    }

    /// [`EpochDmm::publish`] plus a journal record of exactly which
    /// mapping columns changed, enabling targeted cache eviction in
    /// readers (see [`EpochDmm::affected_between`]).
    pub fn publish_targeted(
        &self,
        next: Arc<DpmSet>,
        affected: Vec<(SchemaId, VersionNo)>,
    ) -> u64 {
        self.publish_entry(next, Some(affected))
    }

    fn publish_entry(
        &self,
        next: Arc<DpmSet>,
        affected: Option<Vec<(SchemaId, VersionNo)>>,
    ) -> u64 {
        let state = next.state;
        let mut current = self.current.write().unwrap();
        {
            let mut journal = self.journal.lock().unwrap();
            if state <= journal.max_state {
                // non-advancing publish: content changed without a new
                // state number — poison every range that starts at or
                // below the current maximum (see [`Journal`])
                let floor = journal.max_state;
                journal.poison_floor = Some(
                    journal.poison_floor.map_or(floor, |f| f.max(floor)),
                );
            } else {
                journal.max_state = state;
            }
            journal.entries.push_back(JournalEntry { state, affected });
            while journal.entries.len() > JOURNAL_CAP {
                journal.entries.pop_front();
            }
        }
        *current = next;
        self.epoch.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Current epoch (bumped once per publish).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// The union of mapping columns changed between the snapshot at state
    /// `old` and the snapshot at state `new`, if the journal covers every
    /// transition in `(old, new]` with a known diff. `None` means the
    /// range is not reconstructible (journal truncated, an unknown-diff
    /// publish in between, or a non-advancing state) and the caller must
    /// fall back to a full cache eviction — always safe, never stale.
    pub fn affected_between(
        &self,
        old: StateI,
        new: StateI,
    ) -> Option<Vec<(SchemaId, VersionNo)>> {
        if new <= old {
            return None;
        }
        let journal = self.journal.lock().unwrap();
        if journal.poison_floor.is_some_and(|floor| old <= floor) {
            // a non-advancing publish changed content under this reader's
            // state number — only a full eviction is safe
            return None;
        }
        let mut out: Vec<(SchemaId, VersionNo)> = Vec::new();
        let mut covered: Vec<u64> = Vec::new();
        for entry in journal.entries.iter() {
            if entry.state <= old || entry.state > new {
                continue;
            }
            let cols = entry.affected.as_ref()?;
            for &c in cols {
                if !out.contains(&c) {
                    out.push(c);
                }
            }
            if !covered.contains(&entry.state.0) {
                covered.push(entry.state.0);
            }
        }
        if covered.len() as u64 == new.0 - old.0 {
            Some(out)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_is_monotonic() {
        let s = StateManager::new(StateI(0));
        assert_eq!(s.current(), StateI(0));
        assert_eq!(s.bump(), StateI(1));
        assert_eq!(s.bump(), StateI(2));
        assert_eq!(s.current(), StateI(2));
    }

    #[test]
    fn epoch_dmm_swap_bumps_epoch() {
        let dmm = EpochDmm::new(Arc::new(DpmSet::new(StateI(0))));
        assert_eq!(dmm.epoch(), 0);
        assert_eq!(dmm.snapshot().state, StateI(0));
        let first = dmm.snapshot();
        assert_eq!(dmm.publish(Arc::new(DpmSet::new(StateI(1)))), 1);
        assert_eq!(dmm.epoch(), 1);
        assert_eq!(dmm.snapshot().state, StateI(1));
        // the old snapshot stays valid for readers that still hold it
        assert_eq!(first.state, StateI(0));
    }

    #[test]
    fn concurrent_bumps_unique() {
        let s = std::sync::Arc::new(StateManager::new(StateI(0)));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                (0..100).map(|_| s.bump().0).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 800);
        assert_eq!(s.current(), StateI(800));
    }

    #[test]
    fn journal_reconstructs_targeted_ranges() {
        let dmm = EpochDmm::new(Arc::new(DpmSet::new(StateI(0))));
        let s1 = (SchemaId(1), VersionNo(4));
        let s2 = (SchemaId(2), VersionNo(1));
        dmm.publish_targeted(Arc::new(DpmSet::new(StateI(1))), vec![s1]);
        dmm.publish_targeted(Arc::new(DpmSet::new(StateI(2))), vec![s2, s1]);
        // single step
        assert_eq!(
            dmm.affected_between(StateI(1), StateI(2)),
            Some(vec![s2, s1])
        );
        // two-step union, deduplicated
        assert_eq!(
            dmm.affected_between(StateI(0), StateI(2)),
            Some(vec![s1, s2])
        );
        // non-advancing or reversed ranges are unknown
        assert_eq!(dmm.affected_between(StateI(2), StateI(2)), None);
        assert_eq!(dmm.affected_between(StateI(2), StateI(0)), None);
        // a gap the journal never saw is unknown
        assert_eq!(dmm.affected_between(StateI(0), StateI(9)), None);
    }

    #[test]
    fn unknown_diff_publish_poisons_the_range() {
        let dmm = EpochDmm::new(Arc::new(DpmSet::new(StateI(0))));
        let s1 = (SchemaId(1), VersionNo(1));
        dmm.publish_targeted(Arc::new(DpmSet::new(StateI(1))), vec![s1]);
        // a restore-style publish with no diff information
        dmm.publish(Arc::new(DpmSet::new(StateI(2))));
        dmm.publish_targeted(Arc::new(DpmSet::new(StateI(3))), vec![s1]);
        assert_eq!(dmm.affected_between(StateI(0), StateI(1)), Some(vec![s1]));
        assert_eq!(dmm.affected_between(StateI(2), StateI(3)), Some(vec![s1]));
        // any range crossing the unknown publish must full-evict
        assert_eq!(dmm.affected_between(StateI(1), StateI(2)), None);
        assert_eq!(dmm.affected_between(StateI(0), StateI(3)), None);
    }

    #[test]
    fn non_advancing_publish_poisons_older_readers() {
        let dmm = EpochDmm::new(Arc::new(DpmSet::new(StateI(0))));
        let c = (SchemaId(1), VersionNo(1));
        dmm.publish_targeted(Arc::new(DpmSet::new(StateI(1))), vec![c]);
        // a repair republishes at the SAME state: content may differ while
        // the state number does not
        dmm.publish(Arc::new(DpmSet::new(StateI(1))));
        dmm.publish_targeted(Arc::new(DpmSet::new(StateI(2))), vec![c]);
        // a reader that held "state 1" cannot know WHICH state-1 snapshot
        // it cached from — it must fully evict
        assert_eq!(dmm.affected_between(StateI(1), StateI(2)), None);
        assert_eq!(dmm.affected_between(StateI(0), StateI(2)), None);
        // readers whose snapshot postdates the anomaly regain targeted
        // eviction
        dmm.publish_targeted(Arc::new(DpmSet::new(StateI(3))), vec![c]);
        assert_eq!(
            dmm.affected_between(StateI(2), StateI(3)),
            Some(vec![c])
        );
    }

    #[test]
    fn journal_is_bounded() {
        let dmm = EpochDmm::new(Arc::new(DpmSet::new(StateI(0))));
        for i in 1..=(JOURNAL_CAP as u64 + 10) {
            dmm.publish_targeted(Arc::new(DpmSet::new(StateI(i))), vec![]);
        }
        // recent ranges still resolve...
        let hi = JOURNAL_CAP as u64 + 10;
        assert!(dmm.affected_between(StateI(hi - 5), StateI(hi)).is_some());
        // ...but ranges starting before the truncation horizon do not
        assert_eq!(dmm.affected_between(StateI(0), StateI(hi)), None);
    }
}
