//! The semi-automated update workflow (paper §3.3/§5.4.2): registry
//! change events become Alg-5 change cases; notices from automated
//! updates are routed to a confirmation policy (the paper's UI-based
//! confirmation, "scheduled for full automation" — our sim defaults to
//! auto-confirm and records what a user would have seen).

use crate::matrix::update::{ChangeCase, Notice, UpdateReport};
use crate::message::StateI;
use crate::schema::RegistryEvent;
use crate::util::json::Json;

/// Translate a registry event into the Alg-5 change case it triggers.
/// `SchemaCreated` yields none — the first version arrives separately and
/// needs manual initialization anyway (§5.4.2).
pub fn change_case_for(event: &RegistryEvent) -> Option<ChangeCase> {
    match event {
        RegistryEvent::SchemaCreated { .. } => None,
        RegistryEvent::VersionAdded { schema, version, .. } => {
            Some(ChangeCase::AddedSchemaVersion { schema: *schema, v: *version })
        }
        RegistryEvent::VersionDeleted { schema, version } => {
            Some(ChangeCase::DeletedSchemaVersion { schema: *schema, v: *version })
        }
    }
}

/// What to do with semi-automated notices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NoticePolicy {
    /// Accept the automated result, record the notice (current METL
    /// behaviour per §6.3's error-and-update process).
    #[default]
    AutoConfirm,
    /// Treat smaller-permutation notices as failures needing a user.
    Strict,
}

/// Outcome of the workflow around one update.
#[derive(Debug, Clone)]
pub struct WorkflowOutcome {
    pub new_state: StateI,
    pub report: UpdateReport,
    /// Notices a user must review under `Strict`.
    pub pending_review: Vec<Notice>,
}

impl WorkflowOutcome {
    pub fn evaluate(
        policy: NoticePolicy,
        new_state: StateI,
        report: UpdateReport,
    ) -> WorkflowOutcome {
        let pending_review = match policy {
            NoticePolicy::AutoConfirm => Vec::new(),
            NoticePolicy::Strict => report.notices.clone(),
        };
        WorkflowOutcome { new_state, report, pending_review }
    }

    /// Audit-log line for the store's update log.
    pub fn audit_json(&self, case: &str) -> Json {
        let mut j = Json::obj();
        j.set("state", Json::Num(self.new_state.0 as f64));
        j.set("case", Json::Str(case.to_string()));
        j.set("blocks_added", Json::Num(self.report.blocks_added as f64));
        j.set("blocks_removed", Json::Num(self.report.blocks_removed as f64));
        j.set("elements_added", Json::Num(self.report.elements_added as f64));
        j.set(
            "elements_removed",
            Json::Num(self.report.elements_removed as f64),
        );
        j.set("notices", Json::Num(self.report.notices.len() as f64));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::{SchemaId, VersionNo};

    #[test]
    fn registry_events_translate() {
        let ev = RegistryEvent::VersionAdded {
            schema: SchemaId(2),
            version: VersionNo(3),
            diff: Default::default(),
        };
        assert_eq!(
            change_case_for(&ev),
            Some(ChangeCase::AddedSchemaVersion {
                schema: SchemaId(2),
                v: VersionNo(3)
            })
        );
        let ev = RegistryEvent::VersionDeleted {
            schema: SchemaId(2),
            version: VersionNo(1),
        };
        assert_eq!(
            change_case_for(&ev),
            Some(ChangeCase::DeletedSchemaVersion {
                schema: SchemaId(2),
                v: VersionNo(1)
            })
        );
        assert_eq!(
            change_case_for(&RegistryEvent::SchemaCreated { schema: SchemaId(0) }),
            None
        );
    }

    #[test]
    fn strict_policy_surfaces_notices() {
        let mut report = UpdateReport::default();
        report.notices.push(Notice::EmptyBlock {
            source: crate::matrix::BlockKey::new(
                SchemaId(0),
                VersionNo(1),
                crate::cdm::EntityId(0),
                crate::cdm::CdmVersionNo(1),
            ),
        });
        let auto = WorkflowOutcome::evaluate(
            NoticePolicy::AutoConfirm,
            StateI(1),
            report.clone(),
        );
        assert!(auto.pending_review.is_empty());
        let strict =
            WorkflowOutcome::evaluate(NoticePolicy::Strict, StateI(1), report);
        assert_eq!(strict.pending_review.len(), 1);
    }

    #[test]
    fn audit_json_shape() {
        let outcome = WorkflowOutcome::evaluate(
            NoticePolicy::AutoConfirm,
            StateI(4),
            UpdateReport { blocks_added: 2, elements_added: 9, ..Default::default() },
        );
        let j = outcome.audit_json("added-schema-version");
        assert_eq!(j.get("state").unwrap().as_u64(), Some(4));
        assert_eq!(j.get("elements_added").unwrap().as_u64(), Some(9));
        assert_eq!(
            j.get("case").unwrap().as_str(),
            Some("added-schema-version")
        );
    }
}
