//! The sharded mapping lane (paper §5.5, Alg 6 at the stream level): the
//! CDC stream is partitioned **by source schema id** into N worker shards,
//! each mapping against an immutable `ᵢ𝔇𝔓𝔐` snapshot behind the epoch
//! pointer ([`super::state::EpochDmm`]). Alg-5 updates are built off to
//! the side and published with one pointer swap, so schema-change storms
//! never stall in-flight mapping — the property the paper's "automated
//! updates" promise (§5.4) and DOD-ETL's distributed workers deliver.
//!
//! Ordering: a schema's events all land on one shard and are processed in
//! dispatch order; since every key belongs to exactly one schema, per-key
//! CDC order is preserved through the shard queue and the ordered commit
//! ([`crate::broker::Topic::produce_batch`]) into the keyed CDM topic.
//! See the `pipeline` module docs for the full epoch-swap protocol.
//!
//! The shard channels are unbounded `mpsc` queues — backpressure is out of
//! scope for the simulation (the dispatcher is far cheaper than mapping).
//!
//! On an epoch swap each worker consults the epoch journal
//! ([`super::state::EpochDmm::affected_between`]) and evicts only the
//! mapping columns the update touched from its worker-local cache
//! (targeted eviction, the default) instead of wiping it; unknown
//! versions observed on the wire route through the in-band evolution
//! lane ([`super::evolution`]) before they can dead-letter.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::pipeline::{OutArena, Pipeline, TraceReport};
use crate::broker::{Consumer, SharedBatch};
use crate::cache::DcpmCache;
use crate::mapper::parallel::ParallelMapper;
use crate::mapper::MapError;
use crate::message::cdc::{CdcEvent, CdcOp};
use crate::message::OutMessage;
use crate::trace::{EventTrace, Stage};
use crate::workload::TraceOp;

/// One dispatched slice of the CDC log: an `Arc`-shared segment view plus
/// the indices within it routed to this shard. The queue carries shared
/// views instead of per-event clones — a worker reads its records
/// straight out of the broker segments (provenance comes free: the view
/// knows its partition, each record its offset), and the only `Arc` bump
/// per dispatch is the view's segment handle, not one per event.
struct ShardBatch {
    batch: SharedBatch<Arc<CdcEvent>>,
    /// Indices into `batch` owned by this shard, in partition order.
    picks: Vec<u32>,
}

/// Largest number of queued events a worker folds into one mapping
/// micro-batch (one epoch check + one ordered commit per batch).
const MICRO_BATCH: usize = 256;

/// Report of one sharded run.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shards: usize,
    pub processed: u64,
    /// Events mapped per shard, in shard order.
    pub per_shard: Vec<u64>,
    pub wall: std::time::Duration,
}

impl ShardReport {
    pub fn throughput_eps(&self) -> f64 {
        self.processed as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Resolve the effective worker count (`0` = `available_parallelism`, the
/// `PipelineConfig::shards` default).
pub fn effective_shards(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }
}

/// Shard routing: all versions of one schema share a shard, so per-key
/// order survives (a key belongs to exactly one schema).
fn shard_of(ev: &CdcEvent, shards: usize) -> usize {
    ev.mapping_payload()
        .map(|m| m.schema.0 as usize)
        .unwrap_or(0)
        % shards
}

/// Run a whole trace through the sharded lane: this thread resolves ops
/// (publishing new snapshots mid-stream on schema changes, without
/// stalling the workers) and dispatches CDC events to the shards; the
/// per-sink consumer groups are drained at the end exactly like
/// `Pipeline::run_trace`.
pub fn run_sharded_trace(
    pipeline: &Pipeline,
    ops: &[TraceOp],
    shards: usize,
) -> Result<TraceReport> {
    let n = effective_shards(shards);
    let start = Instant::now();
    let (_per_shard, driven) = with_shard_pool(pipeline, n, |consumer, txs| {
        for op in ops {
            // wire-observed schema changes apply between trace ops
            pipeline.evolution.pump(pipeline);
            pipeline.resolve_op(op)?;
            dispatch_available(consumer, txs, n);
        }
        pipeline.evolution.pump(pipeline);
        dispatch_available(consumer, txs, n);
        Ok(())
    });
    driven?;
    pipeline.drain_sinks();
    Ok(TraceReport {
        events: pipeline.metrics.events_in.get(),
        out_messages: pipeline.metrics.messages_out.get(),
        dead_letters: pipeline.metrics.dead_letters.get(),
        dmm_updates: pipeline.metrics.dmm_updates.get(),
        wall: start.elapsed(),
    })
}

/// Drain everything currently in the CDC topic through N shards (the bench
/// path). Like `scaler::run_scaled`, the caller coordinates updates — but
/// unlike the scaler, an `apply_schema_change` racing this drain is safe:
/// workers pick up the new snapshot at the next epoch check or via the
/// refresh-retry, they never block on the update.
pub fn run_sharded_drain(pipeline: &Pipeline, shards: usize) -> ShardReport {
    let (report, ()) = run_sharded_session(pipeline, shards, |_| {});
    report
}

/// Run a custom driver against a live shard pool. `drive` receives a
/// `dispatch` callback that forwards everything currently fetchable in
/// the CDC topic to the shard workers; the driver can interleave event
/// production, schema changes (which land mid-stream while workers are
/// still mapping previously dispatched events) and dispatch rounds. A
/// final dispatch runs automatically before the pool winds down, so
/// nothing produced by the driver is left behind.
pub fn run_sharded_session<R>(
    pipeline: &Pipeline,
    shards: usize,
    drive: impl FnOnce(&mut dyn FnMut()) -> R,
) -> (ShardReport, R) {
    let n = effective_shards(shards);
    let start = Instant::now();
    let (per_shard, result) = with_shard_pool(pipeline, n, |consumer, txs| {
        let result = {
            let mut dispatch = || {
                // drain the control stream first: wire-observed schema
                // changes land before the next data batch is dispatched
                pipeline.evolution.pump(pipeline);
                dispatch_available(&mut *consumer, txs, n);
            };
            drive(&mut dispatch)
        };
        pipeline.evolution.pump(pipeline);
        dispatch_available(consumer, txs, n);
        result
    });
    (
        ShardReport {
            shards: n,
            processed: per_shard.iter().sum(),
            per_shard,
            wall: start.elapsed(),
        },
        result,
    )
}

/// Shared worker-pool scaffolding: spawn N workers, hand the dispatcher
/// consumer + shard queues to `drive`, then close the queues and join.
/// Returns (events processed per shard, `drive`'s result).
fn with_shard_pool<R>(
    pipeline: &Pipeline,
    n: usize,
    drive: impl FnOnce(&mut Consumer<Arc<CdcEvent>>, &[Sender<ShardBatch>]) -> R,
) -> (Vec<u64>, R) {
    std::thread::scope(|scope| {
        let mut txs: Vec<Sender<ShardBatch>> = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for shard_idx in 0..n {
            let (tx, rx) = mpsc::channel::<ShardBatch>();
            txs.push(tx);
            handles.push(scope.spawn(move || run_worker(pipeline, shard_idx, rx)));
        }
        let mut consumer: Consumer<Arc<CdcEvent>> =
            Consumer::new(pipeline.cdc_topic.clone(), 0, 1);
        let result = drive(&mut consumer, &txs);
        drop(txs); // close the queues: workers drain and exit
        let per_shard = handles
            .into_iter()
            .map(|h| h.join().expect("shard worker"))
            .collect();
        (per_shard, result)
    })
}

/// Forward every currently fetchable CDC event to its shard queue: one
/// zero-copy poll, one routing pass per shared view, one queue send per
/// `(view × shard)` — events are never cloned out of the broker segments.
fn dispatch_available(
    consumer: &mut Consumer<Arc<CdcEvent>>,
    txs: &[Sender<ShardBatch>],
    shards: usize,
) {
    loop {
        let batches = consumer.poll_shared(MICRO_BATCH);
        if batches.is_empty() {
            break;
        }
        for batch in batches {
            let mut picks: Vec<Vec<u32>> = vec![Vec::new(); shards];
            for i in 0..batch.len() {
                picks[shard_of(&batch.get(i).value, shards)].push(i as u32);
            }
            for (shard, picks) in picks.into_iter().enumerate() {
                if picks.is_empty() {
                    continue;
                }
                // a closed queue means the worker already exited (only
                // possible after the driver dropped the senders) —
                // unreachable here
                let _ = txs[shard]
                    .send(ShardBatch { batch: batch.clone(), picks });
            }
        }
        consumer.commit();
    }
}

/// Refresh a worker's snapshot to the current epoch. The epoch journal
/// ([`super::state::EpochDmm::affected_between`]) tells the worker which
/// mapping columns changed between the snapshot it held and the one it
/// now takes; with a known diff only those columns are evicted from the
/// worker-local cache and the warm remainder survives the swap (the
/// targeted-eviction default — `--evict full` restores the §7
/// wipe-everything behaviour).
fn refresh_worker(
    pipeline: &Pipeline,
    mapper: &mut ParallelMapper,
    cache: &DcpmCache,
    epoch: &mut u64,
) {
    // read the epoch BEFORE the snapshot: the snapshot is then at least
    // as new, so a racing publish is re-detected at the next check
    *epoch = pipeline.dmm.epoch();
    let next = pipeline.dmm.snapshot();
    if Arc::ptr_eq(&next, mapper.dpm()) {
        // a publish raced our previous refresh: we already hold this
        // exact snapshot, so there is nothing to evict (ptr equality is
        // the safe test — same-state republishes carry different Arcs)
        return;
    }
    let affected = pipeline.dmm.affected_between(mapper.state(), next.state);
    cache.advance(next.state, affected.as_deref());
    mapper.replace_dpm(next);
}

/// One shard worker: an epoch-cached mapper over a worker-local column
/// cache (eviction storms stay shard-local), FIFO over the shard queue,
/// arena-sealed ordered batch commit into the CDM topic. Returns events
/// processed.
///
/// The worker parks on the queue receive (no spin-poll: `mpsc::recv`
/// parks the thread until the dispatcher sends or hangs up) and wakes to
/// whole shared views — records are read by reference out of the broker
/// segments; the only per-event `Arc` bump left is the DLQ push on the
/// failure path.
fn run_worker(
    pipeline: &Pipeline,
    shard_idx: usize,
    rx: Receiver<ShardBatch>,
) -> u64 {
    let shard_counters = pipeline.metrics.shard.shard(shard_idx);
    let cache = Arc::new(DcpmCache::with_mode(
        pipeline.dmm.snapshot().state,
        pipeline.cfg.evict,
    ));
    let mut epoch = pipeline.dmm.epoch();
    let mut mapper =
        ParallelMapper::with_threads(pipeline.dmm.snapshot(), Arc::clone(&cache), 1)
            .with_kernel(pipeline.cfg.kernel);
    let mut processed = 0u64;
    let mut arena = OutArena::for_topic(&pipeline.out_topic);
    while let Ok(first) = rx.recv() {
        let mut queued = first.picks.len();
        let mut batches = vec![first];
        while queued < MICRO_BATCH {
            match rx.try_recv() {
                Ok(b) => {
                    queued += b.picks.len();
                    batches.push(b);
                }
                Err(_) => break,
            }
        }
        // one epoch check per micro-batch; a swap racing the batch is
        // caught by the refresh-retry below
        if pipeline.dmm.epoch() != epoch {
            refresh_worker(pipeline, &mut mapper, &cache, &mut epoch);
        }
        for sb in &batches {
            let partition = sb.batch.partition() as u32;
            for &i in &sb.picks {
                let rec = sb.batch.get(i as usize);
                pipeline.metrics.events_in.inc();
                shard_counters.events.inc();
                processed += 1;
                let t_in = Instant::now();
                let mut tr = pipeline.tracer.begin(partition, rec.offset);
                if tr.is_active() {
                    if let Some(payload) = rec.value.mapping_payload() {
                        tr.stamp_payload(payload.schema.0, payload.version.0);
                    }
                    tr.stamp_shard(shard_idx as u16);
                    tr.stamp_lane(mapper.lane());
                    tr.span(Stage::Ingest, t_in);
                    pipeline.metrics.ingest_latency.record(t_in.elapsed());
                }
                let t0 = Instant::now();
                match map_on_shard(
                    pipeline, &mut mapper, &cache, &mut epoch, &rec.value, &mut tr,
                ) {
                    Ok(outs) => {
                        pipeline.metrics.transformations.inc();
                        pipeline.metrics.map_latency.record(t0.elapsed());
                        tr.stamp_epoch(epoch);
                        tr.span(Stage::Map, t0);
                        pipeline.tracer.finish(tr);
                        for (op, out) in outs {
                            arena.push(op, out);
                        }
                    }
                    Err(e) => {
                        pipeline.metrics.dead_letters.inc();
                        tr.stamp_epoch(epoch);
                        tr.span_err(Stage::Map, t0);
                        let error = e.to_string();
                        let dump = pipeline.tracer.finish_dead_letter(tr, &error);
                        pipeline.dlq.push_traced(
                            Arc::clone(&rec.value),
                            error,
                            pipeline.retry.max_attempts,
                            dump,
                        );
                    }
                }
            }
        }
        if !arena.is_empty() {
            // one sealed slab + one atomic publish per touched partition
            let n = pipeline.out_topic.produce_batch(arena.seal());
            pipeline.metrics.messages_out.add(n as u64);
            shard_counters.out.add(n as u64);
        }
    }
    processed
}

/// Map one event on a shard: try the held snapshot; on any failure refresh
/// it once if the epoch moved (the snapshot was stale), then consult the
/// in-band evolution lane for unknown versions, then fall back to the
/// §3.4 restamp retry. Only persistent failures reach the DLQ.
fn map_on_shard(
    pipeline: &Pipeline,
    mapper: &mut ParallelMapper,
    cache: &DcpmCache,
    epoch: &mut u64,
    ev: &CdcEvent,
    tr: &mut EventTrace,
) -> Result<Vec<(CdcOp, OutMessage)>, MapError> {
    let Some(payload) = ev.mapping_payload() else {
        return Ok(Vec::new());
    };
    match mapper.map(payload) {
        Ok(outs) => Ok(pair(ev.op, outs)),
        Err(first_err) => {
            // refresh once if the epoch moved under us, without repeating
            // a map already known to fail against the same snapshot
            let err = {
                if pipeline.dmm.epoch() != *epoch {
                    refresh_worker(pipeline, mapper, cache, epoch);
                    match mapper.map(payload) {
                        Ok(outs) => return Ok(pair(ev.op, outs)),
                        Err(e) => e,
                    }
                } else {
                    first_err
                }
            };
            // in-band evolution: a version the registry knows but the DMM
            // does not yet is patched into a fresh epoch, then retried
            let err = match err {
                MapError::UnknownColumn { schema, version } => {
                    let t_heal = Instant::now();
                    if pipeline.evolution.on_unknown_version(pipeline, schema, version) {
                        tr.span(Stage::Heal, t_heal);
                        refresh_worker(pipeline, mapper, cache, epoch);
                        match mapper.map(payload) {
                            Ok(outs) => return Ok(pair(ev.op, outs)),
                            Err(e) => e,
                        }
                    } else {
                        tr.span_err(Stage::Heal, t_heal);
                        MapError::UnknownColumn { schema, version }
                    }
                }
                e => e,
            };
            match err {
                MapError::StateMismatch { .. } => {
                    pipeline.metrics.sync_retries.inc();
                    let mut restamped = payload.clone();
                    restamped.state = mapper.state();
                    match mapper.map(&restamped) {
                        Ok(outs) => Ok(pair(ev.op, outs)),
                        // the restamp can itself surface an unknown
                        // version (the state moved for an unrelated
                        // schema while this one migrated early) — give
                        // the in-band lane the same chance it gets on
                        // the first attempt
                        Err(MapError::UnknownColumn { schema, version }) => {
                            let t_heal = Instant::now();
                            if pipeline
                                .evolution
                                .on_unknown_version(pipeline, schema, version)
                            {
                                tr.span(Stage::Heal, t_heal);
                                refresh_worker(pipeline, mapper, cache, epoch);
                                let mut restamped = payload.clone();
                                restamped.state = mapper.state();
                                Ok(pair(ev.op, mapper.map(&restamped)?))
                            } else {
                                tr.span_err(Stage::Heal, t_heal);
                                Err(MapError::UnknownColumn { schema, version })
                            }
                        }
                        Err(e) => Err(e),
                    }
                }
                e => Err(e),
            }
        }
    }
}

fn pair(op: CdcOp, outs: Vec<OutMessage>) -> Vec<(CdcOp, OutMessage)> {
    outs.into_iter().map(|o| (op, o)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineConfig;
    use crate::workload::{DmlKind, TraceOp};

    fn pipeline_with_backlog(n: usize) -> Pipeline {
        let p = Pipeline::new(PipelineConfig::small()).unwrap();
        for i in 0..n {
            p.resolve_op(&TraceOp::Dml {
                service: i % 4,
                kind: DmlKind::Insert,
            })
            .unwrap();
        }
        p
    }

    #[test]
    fn sharded_drain_processes_everything_once() {
        let p = pipeline_with_backlog(200);
        let report = run_sharded_drain(&p, 4);
        assert_eq!(report.shards, 4);
        assert_eq!(report.processed, 200);
        assert_eq!(report.per_shard.iter().sum::<u64>(), 200);
        assert_eq!(p.metrics.events_in.get(), 200);
        assert_eq!(p.metrics.dead_letters.get(), 0);
        // the small profile has 4 services: every shard saw one schema
        assert!(report.per_shard.iter().all(|&c| c > 0));
        assert_eq!(p.metrics.shard.events_per_shard(), report.per_shard);
    }

    #[test]
    fn schema_sharding_is_stable_per_schema() {
        let p = pipeline_with_backlog(40);
        let mut consumer: Consumer<Arc<CdcEvent>> =
            Consumer::new(p.cdc_topic.clone(), 0, 1);
        for (_, rec) in consumer.poll(64) {
            let s = shard_of(&rec.value, 4);
            let again = shard_of(&rec.value, 4);
            assert_eq!(s, again);
            assert!(s < 4);
        }
    }

    #[test]
    fn update_mid_drain_does_not_dead_letter() {
        let p = pipeline_with_backlog(150);
        let report = std::thread::scope(|scope| {
            let handle = scope.spawn(|| run_sharded_drain(&p, 2));
            // race an Alg-5 update against the drain: the epoch swap must
            // not stall or poison the in-flight mapping
            p.apply_schema_change(0).unwrap();
            handle.join().unwrap()
        });
        assert_eq!(report.processed, 150);
        assert_eq!(p.metrics.dead_letters.get(), 0);
        assert_eq!(p.metrics.dmm_updates.get(), 1);
        assert!(p.metrics.dmm_epoch.get() >= 1);
    }

    #[test]
    fn effective_shards_resolves_zero() {
        assert!(effective_shards(0) >= 1);
        assert_eq!(effective_shards(3), 3);
    }
}
