//! Arena-backed CDM records: one shared slab allocation per produced
//! batch instead of one `Arc<(CdcOp, OutMessage)>` per record.
//!
//! The mapping lanes emit bursts of CDM messages (a micro-batch on a
//! shard worker, a whole initial-load block on the bulk lane). Before the
//! segmented-broker refactor every one of those messages paid an `Arc`
//! allocation just to become cheaply cloneable across the per-sink
//! consumer groups. An [`OutArena`] collects a burst into one contiguous
//! buffer and seals it into a single `Arc<[(CdcOp, OutMessage)]>` slab;
//! each [`OutRecord`] is then a `{slab, index}` handle — cloning it (the
//! broker does, once per consumer-group fetch before zero-copy fetch, and
//! still does for compat `fetch`/`poll`) bumps one refcount, and the
//! messages themselves are never moved again.
//!
//! [`OutRecord`] derefs to `(CdcOp, OutMessage)`, so consumers keep the
//! `let (op, msg) = &*rec.value` shape they used when the type was an
//! `Arc` of the tuple.

use std::ops::Deref;
use std::sync::Arc;

use crate::broker::Topic;
use crate::message::cdc::CdcOp;
use crate::message::OutMessage;
use crate::metrics::BrokerMetrics;

/// A mapped output record on the CDM topic: the originating CDC op
/// travels with the message so the DW can upsert/tombstone. A handle into
/// an arena slab — see the module docs.
#[derive(Debug)]
pub struct OutRecord {
    slab: Arc<[(CdcOp, OutMessage)]>,
    idx: u32,
}

impl Clone for OutRecord {
    fn clone(&self) -> Self {
        Self { slab: Arc::clone(&self.slab), idx: self.idx }
    }
}

impl Deref for OutRecord {
    type Target = (CdcOp, OutMessage);

    fn deref(&self) -> &Self::Target {
        &self.slab[self.idx as usize]
    }
}

impl OutRecord {
    /// A single-record slab, for callers without a batch to amortize
    /// (tests, one-off repairs).
    pub fn single(op: CdcOp, msg: OutMessage) -> Self {
        Self { slab: Arc::from(vec![(op, msg)]), idx: 0 }
    }

    /// The CDM partitioning key (the message key).
    pub fn key(&self) -> u64 {
        self.1.key
    }
}

/// Collects one burst of mapped outputs, then seals them into a single
/// shared slab (one allocation for the whole batch). Reusable: `seal`
/// drains the arena, so a worker keeps one arena alive across
/// micro-batches.
pub struct OutArena {
    buf: Vec<(CdcOp, OutMessage)>,
    metrics: Arc<BrokerMetrics>,
}

impl OutArena {
    /// An arena whose sealed bytes are reported into `topic`'s broker
    /// counters (`metl_broker_arena_bytes_total`).
    pub fn for_topic(topic: &Topic<OutRecord>) -> Self {
        Self { buf: Vec::new(), metrics: Arc::clone(topic.metrics()) }
    }

    pub fn push(&mut self, op: CdcOp, msg: OutMessage) {
        self.buf.push((op, msg));
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Seal the collected outputs into one shared slab and return the
    /// keyed records ready for [`Topic::produce_batch`]. The arena is
    /// left empty and reusable.
    pub fn seal(&mut self) -> Vec<(u64, OutRecord)> {
        if self.buf.is_empty() {
            return Vec::new();
        }
        let slab: Arc<[(CdcOp, OutMessage)]> =
            std::mem::take(&mut self.buf).into();
        self.metrics.arena_bytes.add(
            (slab.len() * std::mem::size_of::<(CdcOp, OutMessage)>()) as u64,
        );
        (0..slab.len())
            .map(|i| {
                let rec = OutRecord { slab: Arc::clone(&slab), idx: i as u32 };
                (rec.key(), rec)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cdm::{CdmAttrId, CdmVersionNo, EntityId};
    use crate::message::StateI;
    use crate::util::json::Json;

    fn msg(key: u64) -> OutMessage {
        OutMessage {
            key,
            entity: EntityId(1),
            version: CdmVersionNo(0),
            state: StateI(0),
            ts_us: 7,
            fields: vec![(CdmAttrId(3), Json::Num(1.0))],
        }
    }

    #[test]
    fn sealed_records_share_one_slab() {
        let metrics = Arc::new(BrokerMetrics::default());
        let mut arena =
            OutArena { buf: Vec::new(), metrics: Arc::clone(&metrics) };
        arena.push(CdcOp::Create, msg(10));
        arena.push(CdcOp::Delete, msg(11));
        assert_eq!(arena.len(), 2);
        let sealed = arena.seal();
        assert!(arena.is_empty());
        assert_eq!(sealed.len(), 2);
        assert_eq!(sealed[0].0, 10);
        assert_eq!(sealed[1].0, 11);
        // both records alias the same slab allocation
        assert!(Arc::ptr_eq(&sealed[0].1.slab, &sealed[1].1.slab));
        // deref keeps the (op, msg) tuple shape
        let (op, m) = &*sealed[1].1;
        assert_eq!(*op, CdcOp::Delete);
        assert_eq!(m.key, 11);
        assert_eq!(
            metrics.arena_bytes.get(),
            (2 * std::mem::size_of::<(CdcOp, OutMessage)>()) as u64
        );
        // sealing an empty arena is free
        assert!(arena.seal().is_empty());
        assert_eq!(
            metrics.arena_bytes.get(),
            (2 * std::mem::size_of::<(CdcOp, OutMessage)>()) as u64
        );
    }

    #[test]
    fn single_record_slab() {
        let rec = OutRecord::single(CdcOp::Update, msg(42));
        assert_eq!(rec.key(), 42);
        assert_eq!(rec.0, CdcOp::Update);
        let clone = rec.clone();
        assert!(Arc::ptr_eq(&rec.slab, &clone.slab));
    }
}
