//! Egress connector API: the consumers of the CDM stream (paper fig 1).
//!
//! # The `SinkConnector` trait
//!
//! The paper's fig-1 pipeline fans the CDM stream out to "an increasing
//! number of systems" — a data warehouse and ML platform today, more
//! backends tomorrow. Every backend implements the object-safe
//! [`SinkConnector`] trait and is registered on the pipeline through
//! [`PipelineBuilder::sink`](crate::coordinator::pipeline::PipelineBuilder::sink)
//! (or by name in `PipelineConfig::sinks`, the `runtime.sinks` config key).
//! The coordinator wraps each registered sink in its **own consumer group**
//! over the CDM topic ([`crate::coordinator::egress::SinkHandle`]), so each
//! backend tracks independent offsets/commits/lag and one slow backend
//! never blocks the others.
//!
//! Contract for implementors:
//!
//! - [`SinkConnector::apply`] receives every mapped CDM record together
//!   with the originating CDC op. Delivery is **at-least-once**: a record
//!   may be re-applied after a crash between poll and commit, so applies
//!   must be idempotent (upsert/dedup by key + payload, like [`DwSink`]).
//! - [`SinkConnector::apply_at`] is the delivery-aware variant the egress
//!   drain calls: it carries the record's [`DeliveryTag`] (CDM partition +
//!   offset), letting backends dedupe consumer-side redeliveries exactly
//!   — an [`OffsetTracker`] watermark per partition absorbs any replay of
//!   already-applied offsets (the crash-between-flush-and-commit window).
//!   The default forwards to `apply`, so direct/test callers without
//!   delivery metadata keep working.
//! - [`SinkConnector::reset_dedupe`] clears that delivery state; the
//!   egress calls it on a §3.4 full offset reset so a deliberate
//!   from-the-beginning replay can rebuild a wiped backend.
//! - [`SinkConnector::flush`] is called after every drain round; buffered
//!   backends (files, network batches) persist there.
//! - [`SinkConnector::snapshot_stats`] is a cheap counters snapshot the
//!   dashboard polls; it must not block on I/O.
//! - [`SinkConnector::as_any`] enables backend-specific inspection
//!   (`Pipeline::with_sink::<DwSink, _>("dw", ...)`) without widening the
//!   trait.
//!
//! Built-in backends: [`DwSink`] (`"dw"`), [`MlSink`] (`"ml"`),
//! [`JsonlSink`] (`"jsonl"`, file/lakehouse append log) and
//! [`AuditMirrorSink`] (`"audit"`, tombstone/contract auditing mirror).
//! [`from_config_name`] is the name → backend factory used for
//! config-driven selection.

pub mod audit;
pub mod jsonl;

use std::any::Any;
use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::cdm::{CdmAttrId, CdmVersionNo, EntityId};
use crate::config::PipelineConfig;
use crate::message::cdc::CdcOp;
use crate::message::OutMessage;
use crate::util::json::Json;

pub use audit::{AuditMirrorSink, AuditRecord};
pub use jsonl::JsonlSink;

/// Cheap counters snapshot of one sink backend (dashboard/metrics feed).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SinkStats {
    /// Records the backend accepted and reflected in its state.
    pub applied: u64,
    /// At-least-once redeliveries the backend deduplicated.
    pub duplicates: u64,
    /// Records the backend intentionally skipped (e.g. delete tombstones
    /// at the ML sink, deletes of missing rows at the DW).
    pub dropped: u64,
}

/// Broker coordinates of one delivered CDM record: the consumer's
/// partition index plus the record's offset within it. Offsets are
/// totally ordered per partition and delivered in order, so a
/// per-partition high-water mark ([`OffsetTracker`]) recognizes every
/// at-least-once redelivery exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeliveryTag {
    pub partition: u32,
    pub offset: u64,
}

/// Per-partition next-expected-offset watermarks: the idempotence state
/// backends embed to dedupe consumer-side redelivery (a crash between
/// flush and offset commit re-polls already-applied records with the
/// *same* tag; producer-side retries arrive as fresh offsets and are
/// absorbed by payload dedupe instead).
#[derive(Debug, Default, Clone)]
pub struct OffsetTracker {
    watermarks: HashMap<u32, u64>,
    /// Redeliveries recognized (offset below the partition watermark).
    pub duplicates: u64,
}

impl OffsetTracker {
    /// True iff `tag` has not been applied yet; advances the watermark
    /// for fresh deliveries and counts replays.
    pub fn is_new(&mut self, tag: DeliveryTag) -> bool {
        let next = self.watermarks.entry(tag.partition).or_insert(0);
        if tag.offset >= *next {
            *next = tag.offset + 1;
            true
        } else {
            self.duplicates += 1;
            false
        }
    }

    /// Forget everything (deliberate §3.4 full replay: the backend will
    /// be rebuilt from offset zero).
    pub fn reset(&mut self) {
        self.watermarks.clear();
        self.duplicates = 0;
    }

    /// Roll the partition watermark back to `tag.offset` (a failed flush
    /// dropped this un-durable record; its redelivery must re-apply).
    pub fn forget(&mut self, tag: DeliveryTag) {
        if let Some(next) = self.watermarks.get_mut(&tag.partition) {
            *next = (*next).min(tag.offset);
        }
    }
}

/// An egress backend of the CDM stream. Object-safe; see the module docs
/// for the implementor contract.
pub trait SinkConnector: Send {
    /// Stable backend name — used for consumer-group naming, metrics rows
    /// and `Pipeline::sink(name)` lookup.
    fn name(&self) -> &str;

    /// Apply one mapped CDM record; `op` is the CDC op of the originating
    /// event (deletes tombstone, everything else upserts/observes).
    fn apply(&mut self, msg: &OutMessage, op: CdcOp);

    /// Delivery-aware apply: like [`Self::apply`] but carrying the CDM
    /// record's broker coordinates, so backends can dedupe at-least-once
    /// redelivery by `(partition, offset)` watermark. The egress drain
    /// always calls this; the default ignores the tag and forwards to
    /// `apply` (for backends that are naturally idempotent or want every
    /// delivery, like the audit mirror).
    fn apply_at(&mut self, tag: DeliveryTag, msg: &OutMessage, op: CdcOp) {
        let _ = tag;
        self.apply(msg, op);
    }

    /// Drop all delivery-dedupe state (offset watermarks). Called by the
    /// egress on a §3.4 full offset reset: the subsequent replay from the
    /// beginning is deliberate and must re-apply, not be deduplicated.
    fn reset_dedupe(&mut self) {}

    /// Persist buffered state (called after every drain round). The
    /// default is a no-op for purely in-memory backends.
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    /// Counters snapshot; must be cheap and non-blocking.
    fn snapshot_stats(&self) -> SinkStats;

    /// Downcast support for backend-specific views.
    fn as_any(&self) -> &dyn Any;
}

/// Name → backend factory for config-driven sink selection
/// (`runtime.sinks = ["dw","ml","jsonl"]`).
pub fn from_config_name(
    name: &str,
    cfg: &PipelineConfig,
) -> Result<Box<dyn SinkConnector>> {
    Ok(match name {
        "dw" => Box::new(DwSink::new()),
        "ml" => Box::new(MlSink::new()),
        "jsonl" => {
            let mut sink = JsonlSink::new();
            if let Some(path) = &cfg.jsonl_path {
                sink = sink.with_path(path);
            }
            Box::new(sink)
        }
        "audit" => Box::new(AuditMirrorSink::new(256)),
        other => bail!(
            "unknown sink backend {other:?} (known: dw, ml, jsonl, audit)"
        ),
    })
}

/// One DW table per (business entity, CDM version): upsert-by-key rows,
/// delete tombstones, idempotent under at-least-once redelivery.
#[derive(Debug, Default)]
pub struct DwTable {
    rows: HashMap<u64, Vec<(CdmAttrId, Json)>>,
    pub upserts: u64,
    pub deletes: u64,
    /// Redeliveries observed (same key + identical payload).
    pub duplicates: u64,
}

impl DwTable {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn row(&self, key: u64) -> Option<&Vec<(CdmAttrId, Json)>> {
        self.rows.get(&key)
    }

    /// All rows as (key, fields), unordered (warehouse-state audits).
    pub fn rows(&self) -> impl Iterator<Item = (u64, &Vec<(CdmAttrId, Json)>)> {
        self.rows.iter().map(|(k, v)| (*k, v))
    }
}

/// The data-warehouse sink (backend name `"dw"`).
#[derive(Debug, Default)]
pub struct DwSink {
    tables: HashMap<(EntityId, CdmVersionNo), DwTable>,
    /// Deletes of rows the DW never held (no-ops, kept for audits).
    pub noop_deletes: u64,
    /// Consumer-side delivery dedupe (offset watermarks per partition).
    delivery: OffsetTracker,
}

impl DwSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn table(&self, entity: EntityId, w: CdmVersionNo) -> Option<&DwTable> {
        self.tables.get(&(entity, w))
    }

    /// All materialized tables, unordered (warehouse-state audits).
    pub fn tables(
        &self,
    ) -> impl Iterator<Item = ((EntityId, CdmVersionNo), &DwTable)> {
        self.tables.iter().map(|(k, t)| (*k, t))
    }

    pub fn total_rows(&self) -> usize {
        self.tables.values().map(|t| t.len()).sum()
    }

    pub fn total_upserts(&self) -> u64 {
        self.tables.values().map(|t| t.upserts).sum()
    }

    pub fn total_deletes(&self) -> u64 {
        self.tables.values().map(|t| t.deletes).sum()
    }

    pub fn total_duplicates(&self) -> u64 {
        self.tables.values().map(|t| t.duplicates).sum()
    }

    /// Consumer-side redeliveries absorbed by the offset watermark (a
    /// subset of [`SinkStats::duplicates`], which also counts
    /// producer-retry payload duplicates).
    pub fn delivery_duplicates(&self) -> u64 {
        self.delivery.duplicates
    }
}

impl SinkConnector for DwSink {
    fn name(&self) -> &str {
        "dw"
    }

    /// Deletes tombstone the row, everything else upserts; identical
    /// redeliveries are deduplicated (at-least-once absorption).
    fn apply(&mut self, msg: &OutMessage, op: CdcOp) {
        let table = self
            .tables
            .entry((msg.entity, msg.version))
            .or_default();
        match op {
            CdcOp::Delete => {
                if table.rows.remove(&msg.key).is_some() {
                    table.deletes += 1;
                } else {
                    self.noop_deletes += 1;
                }
            }
            _ => {
                let existing = table.rows.get(&msg.key);
                if existing.is_some_and(|prev| *prev == msg.fields) {
                    table.duplicates += 1; // at-least-once redelivery
                } else {
                    table.rows.insert(msg.key, msg.fields.clone());
                    table.upserts += 1;
                }
            }
        }
    }

    /// Delivery-exact apply: an offset the watermark has already seen is
    /// a consumer-side redelivery and is absorbed without touching table
    /// state (fresh offsets still go through the payload dedupe above).
    fn apply_at(&mut self, tag: DeliveryTag, msg: &OutMessage, op: CdcOp) {
        if self.delivery.is_new(tag) {
            self.apply(msg, op);
        }
    }

    fn reset_dedupe(&mut self) {
        self.delivery.reset();
    }

    fn snapshot_stats(&self) -> SinkStats {
        SinkStats {
            applied: self.total_upserts() + self.total_deletes(),
            duplicates: self.total_duplicates() + self.delivery.duplicates,
            dropped: self.noop_deletes,
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Per-attribute running statistics (count/mean/M2 — Welford).
#[derive(Debug, Default, Clone)]
pub struct FeatureStat {
    pub count: u64,
    mean: f64,
    m2: f64,
}

impl FeatureStat {
    fn observe(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / self.count as f64
        }
    }
}

/// The ML-platform sink (backend name `"ml"`): accumulates numeric
/// features per business entity (fig 1's "machine learning systems"; the
/// paper's next-best-action models train on exactly this CDM stream).
#[derive(Debug, Default)]
pub struct MlSink {
    features: HashMap<(EntityId, CdmAttrId), FeatureStat>,
    pub observations: u64,
    /// Delete tombstones skipped — a deleted row's before-image is not a
    /// training observation and must not move feature means/variances.
    pub deletes_skipped: u64,
    /// Consumer-side delivery dedupe. Running moments are **not**
    /// naturally idempotent — re-observing a redelivered record drags
    /// count/mean/variance — so the ML sink must dedupe exactly, by
    /// offset watermark.
    delivery: OffsetTracker,
}

impl MlSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Consumer-side redeliveries absorbed by the offset watermark.
    pub fn delivery_duplicates(&self) -> u64 {
        self.delivery.duplicates
    }

    /// Fold one upsert payload into the running feature statistics.
    /// Callers routing raw CDC traffic must go through
    /// [`SinkConnector::apply`], which screens out delete tombstones.
    pub fn observe(&mut self, msg: &OutMessage) {
        self.observations += 1;
        for (attr, value) in &msg.fields {
            if let Some(x) = value.as_f64() {
                self.features
                    .entry((msg.entity, *attr))
                    .or_default()
                    .observe(x);
            }
        }
    }

    pub fn feature(&self, entity: EntityId, attr: CdmAttrId) -> Option<&FeatureStat> {
        self.features.get(&(entity, attr))
    }

    /// All accumulated features, unordered (conformance audits).
    pub fn features(
        &self,
    ) -> impl Iterator<Item = ((EntityId, CdmAttrId), &FeatureStat)> {
        self.features.iter().map(|(k, v)| (*k, v))
    }

    pub fn n_features(&self) -> usize {
        self.features.len()
    }
}

impl SinkConnector for MlSink {
    fn name(&self) -> &str {
        "ml"
    }

    /// A delete carries the row's before-image so the DW can tombstone —
    /// observing it would pollute the feature means/variances, so the ML
    /// sink skips deletes entirely.
    fn apply(&mut self, msg: &OutMessage, op: CdcOp) {
        if op == CdcOp::Delete {
            self.deletes_skipped += 1;
            return;
        }
        self.observe(msg);
    }

    /// Welford moments double-count on redelivery, so the watermark check
    /// comes first: replayed offsets never reach [`MlSink::observe`].
    fn apply_at(&mut self, tag: DeliveryTag, msg: &OutMessage, op: CdcOp) {
        if self.delivery.is_new(tag) {
            self.apply(msg, op);
        }
    }

    fn reset_dedupe(&mut self) {
        self.delivery.reset();
    }

    fn snapshot_stats(&self) -> SinkStats {
        SinkStats {
            applied: self.observations,
            duplicates: self.delivery.duplicates,
            dropped: self.deletes_skipped,
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::message::StateI;

    fn out(key: u64, value: f64) -> OutMessage {
        OutMessage {
            key,
            entity: EntityId(0),
            version: CdmVersionNo(1),
            state: StateI(0),
            ts_us: 0,
            fields: vec![(CdmAttrId(0), Json::Num(value))],
        }
    }

    #[test]
    fn upsert_then_delete() {
        let mut dw = DwSink::new();
        dw.apply(&out(1, 10.0), CdcOp::Create);
        dw.apply(&out(1, 11.0), CdcOp::Update);
        assert_eq!(dw.total_rows(), 1);
        let t = dw.table(EntityId(0), CdmVersionNo(1)).unwrap();
        assert_eq!(t.row(1).unwrap()[0].1.as_f64(), Some(11.0));
        assert_eq!(t.upserts, 2);
        dw.apply(&out(1, 11.0), CdcOp::Delete);
        assert_eq!(dw.total_rows(), 0);
        assert_eq!(dw.total_deletes(), 1);
    }

    #[test]
    fn redelivery_is_idempotent() {
        let mut dw = DwSink::new();
        dw.apply(&out(1, 10.0), CdcOp::Create);
        dw.apply(&out(1, 10.0), CdcOp::Create); // redelivered
        let t = dw.table(EntityId(0), CdmVersionNo(1)).unwrap();
        assert_eq!(t.upserts, 1);
        assert_eq!(t.duplicates, 1);
        assert_eq!(dw.total_rows(), 1);
        assert_eq!(
            dw.snapshot_stats(),
            SinkStats { applied: 1, duplicates: 1, dropped: 0 }
        );
    }

    #[test]
    fn delete_of_missing_row_is_noop() {
        let mut dw = DwSink::new();
        dw.apply(&out(9, 1.0), CdcOp::Delete);
        assert_eq!(dw.total_rows(), 0);
        assert_eq!(dw.table(EntityId(0), CdmVersionNo(1)).unwrap().deletes, 0);
        assert_eq!(dw.noop_deletes, 1);
        assert_eq!(dw.snapshot_stats().dropped, 1);
    }

    #[test]
    fn ml_sink_accumulates_running_stats() {
        let mut ml = MlSink::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            ml.apply(&out(1, v), CdcOp::Create);
        }
        let f = ml.feature(EntityId(0), CdmAttrId(0)).unwrap();
        assert_eq!(f.count, 4);
        assert!((f.mean() - 2.5).abs() < 1e-12);
        assert!((f.variance() - 1.25).abs() < 1e-12);
        assert_eq!(ml.observations, 4);
        assert_eq!(ml.n_features(), 1);
    }

    /// Regression: a delete tombstone carries the row's before-image; the
    /// ML sink must skip it, not fold it into the running moments.
    #[test]
    fn ml_sink_skips_delete_tombstones() {
        let mut ml = MlSink::new();
        for v in [1.0, 3.0] {
            ml.apply(&out(1, v), CdcOp::Create);
        }
        let before = ml.feature(EntityId(0), CdmAttrId(0)).unwrap().clone();
        // the tombstone replays the last value — observing it would drag
        // the mean toward 3.0 and shrink the variance
        ml.apply(&out(1, 3.0), CdcOp::Delete);
        let after = ml.feature(EntityId(0), CdmAttrId(0)).unwrap();
        assert_eq!(after.count, before.count);
        assert!((after.mean() - before.mean()).abs() < 1e-12);
        assert!((after.variance() - before.variance()).abs() < 1e-12);
        assert_eq!(ml.observations, 2);
        assert_eq!(ml.deletes_skipped, 1);
        assert_eq!(
            ml.snapshot_stats(),
            SinkStats { applied: 2, duplicates: 0, dropped: 1 }
        );
    }

    #[test]
    fn non_numeric_fields_ignored_by_ml() {
        let mut ml = MlSink::new();
        let mut m = out(1, 0.0);
        m.fields = vec![(CdmAttrId(1), Json::Str("EUR".into()))];
        ml.apply(&m, CdcOp::Create);
        assert_eq!(ml.n_features(), 0);
        assert_eq!(ml.observations, 1);
    }

    fn tag(partition: u32, offset: u64) -> DeliveryTag {
        DeliveryTag { partition, offset }
    }

    #[test]
    fn offset_tracker_recognizes_replays_per_partition() {
        let mut t = OffsetTracker::default();
        assert!(t.is_new(tag(0, 0)));
        assert!(t.is_new(tag(0, 1)));
        assert!(t.is_new(tag(1, 0))); // partitions are independent
        assert!(!t.is_new(tag(0, 0))); // rewind replay
        assert!(!t.is_new(tag(0, 1)));
        assert_eq!(t.duplicates, 2);
        t.forget(tag(0, 1));
        assert!(!t.is_new(tag(0, 0)), "offset 0 is still durable");
        assert!(t.is_new(tag(0, 1)), "forgotten offset re-applies");
        t.reset();
        assert!(t.is_new(tag(0, 0)));
        assert_eq!(t.duplicates, 0);
    }

    /// The satellite regression in miniature: a crash between flush and
    /// commit replays the same (partition, offset) records; the ML
    /// moments must not move.
    #[test]
    fn ml_sink_dedupes_offset_replay_exactly() {
        let mut ml = MlSink::new();
        for (i, v) in [1.0, 2.0, 3.0].iter().enumerate() {
            ml.apply_at(tag(0, i as u64), &out(1, *v), CdcOp::Create);
        }
        let before = ml.feature(EntityId(0), CdmAttrId(0)).unwrap().clone();
        // redeliver the whole uncommitted batch
        for (i, v) in [1.0, 2.0, 3.0].iter().enumerate() {
            ml.apply_at(tag(0, i as u64), &out(1, *v), CdcOp::Create);
        }
        let after = ml.feature(EntityId(0), CdmAttrId(0)).unwrap();
        assert_eq!(after.count, before.count);
        assert!((after.mean() - before.mean()).abs() < 1e-12);
        assert!((after.variance() - before.variance()).abs() < 1e-12);
        assert_eq!(ml.observations, 3);
        assert_eq!(ml.delivery_duplicates(), 3);
        assert_eq!(
            ml.snapshot_stats(),
            SinkStats { applied: 3, duplicates: 3, dropped: 0 }
        );
    }

    #[test]
    fn dw_sink_offset_dedupe_composes_with_payload_dedupe() {
        let mut dw = DwSink::new();
        dw.apply_at(tag(0, 0), &out(1, 10.0), CdcOp::Create);
        // producer retry: same payload at a fresh offset → payload dedupe
        dw.apply_at(tag(0, 1), &out(1, 10.0), CdcOp::Create);
        // consumer replay: same offset → watermark dedupe, state untouched
        dw.apply_at(tag(0, 0), &out(1, 10.0), CdcOp::Create);
        let t = dw.table(EntityId(0), CdmVersionNo(1)).unwrap();
        assert_eq!(t.upserts, 1);
        assert_eq!(t.duplicates, 1);
        assert_eq!(dw.delivery_duplicates(), 1);
        assert_eq!(dw.snapshot_stats().duplicates, 2);
        assert_eq!(dw.total_rows(), 1);
        // a replayed *stale* payload must not overwrite newer state
        dw.apply_at(tag(0, 2), &out(1, 11.0), CdcOp::Update);
        dw.apply_at(tag(0, 0), &out(1, 10.0), CdcOp::Create);
        let t = dw.table(EntityId(0), CdmVersionNo(1)).unwrap();
        assert_eq!(t.row(1).unwrap()[0].1.as_f64(), Some(11.0));
    }

    #[test]
    fn reset_dedupe_lets_full_replay_rebuild() {
        let mut dw = DwSink::new();
        dw.apply_at(tag(0, 0), &out(1, 10.0), CdcOp::Create);
        // §3.4 full replay of a deliberately wiped backend
        dw.reset_dedupe();
        dw.apply_at(tag(0, 0), &out(1, 10.0), CdcOp::Create);
        assert_eq!(dw.delivery_duplicates(), 0);
        // the payload dedupe still recognizes the unchanged row
        assert_eq!(dw.total_duplicates(), 1);
        assert_eq!(dw.total_rows(), 1);
    }

    #[test]
    fn factory_builds_known_backends_and_rejects_unknown() {
        let cfg = PipelineConfig::small();
        for name in ["dw", "ml", "jsonl", "audit"] {
            let sink = from_config_name(name, &cfg).unwrap();
            assert_eq!(sink.name(), name);
        }
        assert!(from_config_name("bigquery", &cfg).is_err());
    }
}
