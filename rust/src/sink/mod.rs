//! The two consumers of the CDM stream (paper fig 1): the data warehouse
//! and the ML platform. Both consume `OutMessage`s from the CDM topics.

use std::collections::HashMap;

use crate::cdm::{CdmAttrId, CdmVersionNo, EntityId};
use crate::message::cdc::CdcOp;
use crate::message::OutMessage;
use crate::util::json::Json;

/// One DW table per (business entity, CDM version): upsert-by-key rows,
/// delete tombstones, idempotent under at-least-once redelivery.
#[derive(Debug, Default)]
pub struct DwTable {
    rows: HashMap<u64, Vec<(CdmAttrId, Json)>>,
    pub upserts: u64,
    pub deletes: u64,
    /// Redeliveries observed (same key + identical payload).
    pub duplicates: u64,
}

impl DwTable {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn row(&self, key: u64) -> Option<&Vec<(CdmAttrId, Json)>> {
        self.rows.get(&key)
    }
}

/// The data-warehouse sink.
#[derive(Debug, Default)]
pub struct DwSink {
    tables: HashMap<(EntityId, CdmVersionNo), DwTable>,
}

impl DwSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply one mapped message. `op` is the CDC op of the originating
    /// event: deletes tombstone the row, everything else upserts.
    pub fn apply(&mut self, msg: &OutMessage, op: CdcOp) {
        let table = self
            .tables
            .entry((msg.entity, msg.version))
            .or_default();
        match op {
            CdcOp::Delete => {
                if table.rows.remove(&msg.key).is_some() {
                    table.deletes += 1;
                }
            }
            _ => {
                let existing = table.rows.get(&msg.key);
                if existing.is_some_and(|prev| *prev == msg.fields) {
                    table.duplicates += 1; // at-least-once redelivery
                } else {
                    table.rows.insert(msg.key, msg.fields.clone());
                    table.upserts += 1;
                }
            }
        }
    }

    pub fn table(&self, entity: EntityId, w: CdmVersionNo) -> Option<&DwTable> {
        self.tables.get(&(entity, w))
    }

    pub fn total_rows(&self) -> usize {
        self.tables.values().map(|t| t.len()).sum()
    }

    pub fn total_upserts(&self) -> u64 {
        self.tables.values().map(|t| t.upserts).sum()
    }

    pub fn total_duplicates(&self) -> u64 {
        self.tables.values().map(|t| t.duplicates).sum()
    }
}

/// Per-attribute running statistics (count/mean/M2 — Welford).
#[derive(Debug, Default, Clone)]
pub struct FeatureStat {
    pub count: u64,
    mean: f64,
    m2: f64,
}

impl FeatureStat {
    fn observe(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / self.count as f64
        }
    }
}

/// The ML-platform sink: accumulates numeric features per business entity
/// (fig 1's "machine learning systems"; the paper's next-best-action
/// models train on exactly this CDM stream).
#[derive(Debug, Default)]
pub struct MlSink {
    features: HashMap<(EntityId, CdmAttrId), FeatureStat>,
    pub observations: u64,
}

impl MlSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, msg: &OutMessage) {
        self.observations += 1;
        for (attr, value) in &msg.fields {
            if let Some(x) = value.as_f64() {
                self.features
                    .entry((msg.entity, *attr))
                    .or_default()
                    .observe(x);
            }
        }
    }

    pub fn feature(&self, entity: EntityId, attr: CdmAttrId) -> Option<&FeatureStat> {
        self.features.get(&(entity, attr))
    }

    pub fn n_features(&self) -> usize {
        self.features.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::message::StateI;

    fn out(key: u64, value: f64) -> OutMessage {
        OutMessage {
            key,
            entity: EntityId(0),
            version: CdmVersionNo(1),
            state: StateI(0),
            ts_us: 0,
            fields: vec![(CdmAttrId(0), Json::Num(value))],
        }
    }

    #[test]
    fn upsert_then_delete() {
        let mut dw = DwSink::new();
        dw.apply(&out(1, 10.0), CdcOp::Create);
        dw.apply(&out(1, 11.0), CdcOp::Update);
        assert_eq!(dw.total_rows(), 1);
        let t = dw.table(EntityId(0), CdmVersionNo(1)).unwrap();
        assert_eq!(t.row(1).unwrap()[0].1.as_f64(), Some(11.0));
        assert_eq!(t.upserts, 2);
        dw.apply(&out(1, 11.0), CdcOp::Delete);
        assert_eq!(dw.total_rows(), 0);
    }

    #[test]
    fn redelivery_is_idempotent() {
        let mut dw = DwSink::new();
        dw.apply(&out(1, 10.0), CdcOp::Create);
        dw.apply(&out(1, 10.0), CdcOp::Create); // redelivered
        let t = dw.table(EntityId(0), CdmVersionNo(1)).unwrap();
        assert_eq!(t.upserts, 1);
        assert_eq!(t.duplicates, 1);
        assert_eq!(dw.total_rows(), 1);
    }

    #[test]
    fn delete_of_missing_row_is_noop() {
        let mut dw = DwSink::new();
        dw.apply(&out(9, 1.0), CdcOp::Delete);
        assert_eq!(dw.total_rows(), 0);
        assert_eq!(dw.table(EntityId(0), CdmVersionNo(1)).unwrap().deletes, 0);
    }

    #[test]
    fn ml_sink_accumulates_running_stats() {
        let mut ml = MlSink::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            ml.observe(&out(1, v));
        }
        let f = ml.feature(EntityId(0), CdmAttrId(0)).unwrap();
        assert_eq!(f.count, 4);
        assert!((f.mean() - 2.5).abs() < 1e-12);
        assert!((f.variance() - 1.25).abs() < 1e-12);
        assert_eq!(ml.observations, 4);
        assert_eq!(ml.n_features(), 1);
    }

    #[test]
    fn non_numeric_fields_ignored_by_ml() {
        let mut ml = MlSink::new();
        let mut m = out(1, 0.0);
        m.fields = vec![(CdmAttrId(1), Json::Str("EUR".into()))];
        ml.observe(&m);
        assert_eq!(ml.n_features(), 0);
        assert_eq!(ml.observations, 1);
    }
}
