//! JSONL file/lakehouse sink: an append-only log of the CDM stream, one
//! self-contained JSON object per record — the open-table-format shape
//! (bronze-layer lakehouse ingestion) that downstream batch engines read
//! without access to METL's in-memory trees.
//!
//! Records are buffered in memory (so tests and the dashboard can inspect
//! them) and appended to the configured file on [`SinkConnector::flush`];
//! with no path configured the sink is a pure in-memory log. Tombstones
//! are appended like every other record (`"op": "d"`) — an append log
//! never loses history, compaction is the lakehouse's job.

use std::any::Any;
use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};

use super::{DeliveryTag, OffsetTracker, SinkConnector, SinkStats};
use crate::message::cdc::CdcOp;
use crate::message::OutMessage;
use crate::util::json::Json;

/// The JSONL lakehouse sink (backend name `"jsonl"`).
///
/// In-memory mode (no path) retains every record for inspection. File
/// mode appends to the path on flush and then drops the written records
/// from memory, so a long-running pipeline's footprint stays bounded by
/// one drain round.
#[derive(Debug, Default)]
pub struct JsonlSink {
    path: Option<PathBuf>,
    /// Buffered append handle, opened lazily on the first flush and kept
    /// open (drains flush every round — reopening per flush is wasteful).
    file: Option<std::io::BufWriter<std::fs::File>>,
    /// (partition key, serialized line) buffered in apply order. File
    /// mode drains this on flush; in-memory mode retains everything.
    records: Vec<(u64, String)>,
    /// Write progress within the current flush attempt (reset when the
    /// buffer drains on success or drops on failure).
    flushed: usize,
    /// Total records ever applied (survives the file-mode buffer drain).
    applied: u64,
    /// Consumer-side delivery dedupe: an append log is *not* naturally
    /// idempotent (a replayed record would simply append again), so
    /// redelivered offsets are recognized by watermark and skipped.
    delivery: OffsetTracker,
    /// Delivery tags of the records currently buffered (apply order,
    /// tagged applies only): a failed flush drops the buffer, so these
    /// watermark entries are rolled back for clean redelivery.
    pending_tags: Vec<DeliveryTag>,
}

impl JsonlSink {
    /// In-memory-only log (no file until [`Self::with_path`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Append flushed records to `path` (created on first flush).
    pub fn with_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.path = Some(path.into());
        self.file = None;
        self
    }

    /// Total records applied over the sink's lifetime.
    pub fn len(&self) -> usize {
        self.applied as usize
    }

    pub fn is_empty(&self) -> bool {
        self.applied == 0
    }

    /// Buffered records as (partition key, JSON line): everything applied
    /// in in-memory mode, the unflushed tail in file mode.
    pub fn records(&self) -> &[(u64, String)] {
        &self.records
    }

    /// Buffered serialized lines in apply order (see [`Self::records`]).
    pub fn lines(&self) -> impl Iterator<Item = &str> {
        self.records.iter().map(|(_, line)| line.as_str())
    }

    /// Write the unflushed records through the buffered handle, then
    /// flush the buffer to the OS (one syscall burst per drain round).
    fn write_tail(&mut self) -> Result<()> {
        let path = self.path.clone().expect("flush checked file mode");
        if self.file.is_none() {
            let file = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("open jsonl sink {}", path.display()))?;
            self.file = Some(std::io::BufWriter::new(file));
        }
        let file = self.file.as_mut().expect("jsonl file opened above");
        while self.flushed < self.records.len() {
            let line = &self.records[self.flushed].1;
            writeln!(file, "{line}")
                .with_context(|| format!("append jsonl sink {}", path.display()))?;
            self.flushed += 1;
        }
        file.flush()
            .with_context(|| format!("flush jsonl sink {}", path.display()))
    }

    /// One record as a self-contained JSON object. CDM attribute ids are
    /// written as `"c<id>"` keys — stable without the CDM tree at hand.
    fn encode(msg: &OutMessage, op: CdcOp) -> String {
        let mut fields = Json::obj();
        for (attr, value) in &msg.fields {
            fields.set(&format!("c{}", attr.0), value.clone());
        }
        let mut line = Json::obj();
        line.set("op", Json::Str(op.code().to_string()));
        line.set("key", Json::Num(msg.key as f64));
        line.set("entity", Json::Num(msg.entity.0 as f64));
        line.set("w", Json::Num(msg.version.0 as f64));
        line.set("state", Json::Num(msg.state.0 as f64));
        line.set("ts_us", Json::Num(msg.ts_us as f64));
        line.set("fields", fields);
        line.to_string()
    }
}

impl SinkConnector for JsonlSink {
    fn name(&self) -> &str {
        "jsonl"
    }

    fn apply(&mut self, msg: &OutMessage, op: CdcOp) {
        self.records.push((msg.key, Self::encode(msg, op)));
        self.applied += 1;
    }

    /// Delivery-exact append: offsets the watermark has already seen are
    /// consumer-side redeliveries and never reach the log twice.
    fn apply_at(&mut self, tag: DeliveryTag, msg: &OutMessage, op: CdcOp) {
        if self.delivery.is_new(tag) {
            self.pending_tags.push(tag);
            self.apply(msg, op);
        }
    }

    fn reset_dedupe(&mut self) {
        self.delivery.reset();
        self.pending_tags.clear();
    }

    /// Append the buffered records to the configured file, if any.
    ///
    /// On failure the **whole** buffer is dropped and the lifetime count
    /// rolled back: the egress drain rewinds to its last commit when a
    /// flush fails, so the entire uncommitted batch is re-applied on the
    /// next drain — keeping anything buffered would double-append and
    /// double-count it on retry. Lines that already reached the file
    /// before the failure reappear as redelivered duplicates — the
    /// at-least-once artifact of an append log; readers dedupe by
    /// (key, ts, op) or tolerate duplicates.
    fn flush(&mut self) -> Result<()> {
        if self.path.is_none() {
            self.flushed = self.records.len();
            self.pending_tags.clear();
            return Ok(());
        }
        if self.flushed == self.records.len() {
            self.pending_tags.clear();
            return Ok(());
        }
        match self.write_tail() {
            Ok(()) => {
                // everything is durable: drop the written buffer (file
                // mode keeps memory bounded by one drain round)
                self.records.clear();
                self.flushed = 0;
                self.pending_tags.clear();
                Ok(())
            }
            Err(e) => {
                self.applied -= self.records.len() as u64;
                self.records.clear();
                self.flushed = 0;
                // the dropped records must re-apply when the egress
                // redelivers them — roll their watermarks back so the
                // dedupe doesn't swallow the retry
                for tag in self.pending_tags.drain(..) {
                    self.delivery.forget(tag);
                }
                Err(e)
            }
        }
    }

    fn snapshot_stats(&self) -> SinkStats {
        SinkStats {
            applied: self.applied,
            duplicates: self.delivery.duplicates,
            dropped: 0,
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cdm::{CdmAttrId, CdmVersionNo, EntityId};
    use crate::message::StateI;
    use crate::util::json;

    fn out(key: u64, value: f64) -> OutMessage {
        OutMessage {
            key,
            entity: EntityId(3),
            version: CdmVersionNo(2),
            state: StateI(1),
            ts_us: 77,
            fields: vec![(CdmAttrId(5), Json::Num(value))],
        }
    }

    #[test]
    fn lines_are_valid_self_contained_json() {
        let mut sink = JsonlSink::new();
        sink.apply(&out(9, 1.5), CdcOp::Create);
        sink.apply(&out(9, 2.5), CdcOp::Delete);
        assert_eq!(sink.len(), 2);
        let lines: Vec<&str> = sink.lines().collect();
        let first = json::parse(lines[0]).unwrap();
        assert_eq!(first.get("op").and_then(|v| v.as_str()), Some("c"));
        assert_eq!(first.get("key").and_then(|v| v.as_f64()), Some(9.0));
        assert_eq!(first.get("entity").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(
            first.get("fields").and_then(|f| f.get("c5")).and_then(|v| v.as_f64()),
            Some(1.5)
        );
        // tombstones are appended, never dropped
        let second = json::parse(lines[1]).unwrap();
        assert_eq!(second.get("op").and_then(|v| v.as_str()), Some("d"));
        assert_eq!(sink.snapshot_stats().applied, 2);
    }

    #[test]
    fn flush_appends_to_file_once_and_drains_buffer() {
        let dir = std::env::temp_dir()
            .join("metl-jsonl-sink")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cdm.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut sink = JsonlSink::new().with_path(&path);
        sink.apply(&out(1, 1.0), CdcOp::Create);
        let first_line = sink.lines().next().unwrap().to_string();
        sink.flush().unwrap();
        // file mode drains the written buffer but keeps the total count
        assert!(sink.records().is_empty());
        assert_eq!(sink.len(), 1);
        sink.flush().unwrap(); // watermark: no duplicate append
        sink.apply(&out(2, 2.0), CdcOp::Update);
        sink.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], first_line);
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.snapshot_stats().applied, 2);
    }

    #[test]
    fn apply_at_skips_redelivered_offsets() {
        use crate::sink::DeliveryTag;
        let mut sink = JsonlSink::new();
        let t0 = DeliveryTag { partition: 0, offset: 0 };
        let t1 = DeliveryTag { partition: 0, offset: 1 };
        sink.apply_at(t0, &out(1, 1.0), CdcOp::Create);
        sink.apply_at(t1, &out(2, 2.0), CdcOp::Create);
        sink.flush().unwrap();
        // crash between flush and commit: both records replay
        sink.apply_at(t0, &out(1, 1.0), CdcOp::Create);
        sink.apply_at(t1, &out(2, 2.0), CdcOp::Create);
        assert_eq!(sink.len(), 2, "append log must not double-append");
        assert_eq!(sink.snapshot_stats().duplicates, 2);
    }

    /// A failed flush drops un-durable records AND rolls their offset
    /// watermarks back — the redelivery must re-apply, not be deduped.
    #[test]
    fn failed_flush_rolls_back_dedupe_watermark() {
        use crate::sink::DeliveryTag;
        let dir = std::env::temp_dir()
            .join("metl-jsonl-sink-wm")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // the "file" is a directory: opening for append fails
        let mut sink = JsonlSink::new().with_path(&dir);
        let t0 = DeliveryTag { partition: 0, offset: 0 };
        sink.apply_at(t0, &out(1, 1.0), CdcOp::Create);
        assert!(sink.flush().is_err());
        assert_eq!(sink.len(), 0);
        // redelivery of the dropped record applies cleanly
        sink.apply_at(t0, &out(1, 1.0), CdcOp::Create);
        assert_eq!(sink.len(), 1);
    }

    /// At-least-once: a failed flush drops the un-durable tail and rolls
    /// back the count, so the egress redelivery re-applies cleanly
    /// instead of double-appending.
    #[test]
    fn failed_flush_drops_undurable_tail_for_redelivery() {
        let dir = std::env::temp_dir()
            .join("metl-jsonl-sink-err")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // the "file" is a directory: opening for append fails
        let mut sink = JsonlSink::new().with_path(&dir);
        sink.apply(&out(1, 1.0), CdcOp::Create);
        assert!(sink.flush().is_err());
        assert_eq!(sink.len(), 0, "rolled back, awaiting redelivery");
        assert!(sink.records().is_empty());
        // the redelivered apply counts exactly once
        sink.apply(&out(1, 1.0), CdcOp::Create);
        assert_eq!(sink.len(), 1);
    }
}
