//! Auditing mirror sink: a lightweight egress-side error-management lane
//! (paper §3.4 "additional error-management procedures") that shadows the
//! CDM stream without storing payloads.
//!
//! It keeps per-op counters, a bounded ring of the most recent records,
//! and two audit ledgers:
//!
//! - **tombstones** — every delete that went out to the consumers (the
//!   records a warehouse reload must re-tombstone after an offset reset);
//! - **anomalies** — records violating the dense-discipline CDM contract
//!   (§5.5: no nulls, non-empty), which indicate a mapper regression and
//!   would otherwise only surface as corrupt downstream tables.

use std::any::Any;
use std::collections::VecDeque;

use super::{SinkConnector, SinkStats};
use crate::cdm::{CdmVersionNo, EntityId};
use crate::message::cdc::CdcOp;
use crate::message::OutMessage;

/// Payload-free fingerprint of one mirrored record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditRecord {
    pub op: CdcOp,
    pub key: u64,
    pub entity: EntityId,
    pub version: CdmVersionNo,
    pub ts_us: u64,
}

/// The auditing mirror (backend name `"audit"`).
#[derive(Debug)]
pub struct AuditMirrorSink {
    capacity: usize,
    recent: VecDeque<AuditRecord>,
    per_op: [u64; 4],
    pub mirrored: u64,
    pub tombstones: u64,
    /// Most recent dense-contract violation descriptions (upsert payload
    /// empty or carrying nulls), bounded by the ring capacity; the
    /// lifetime total is [`Self::anomaly_count`].
    pub anomalies: Vec<String>,
    /// Total dense-contract violations observed.
    pub anomaly_count: u64,
}

impl AuditMirrorSink {
    /// Mirror with a ring of the `capacity` most recent records.
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            recent: VecDeque::new(),
            per_op: [0; 4],
            mirrored: 0,
            tombstones: 0,
            anomalies: Vec::new(),
            anomaly_count: 0,
        }
    }

    fn op_index(op: CdcOp) -> usize {
        match op {
            CdcOp::Create => 0,
            CdcOp::Update => 1,
            CdcOp::Delete => 2,
            CdcOp::SnapshotRead => 3,
        }
    }

    /// Mirrored records of one CDC op kind.
    pub fn count_of(&self, op: CdcOp) -> u64 {
        self.per_op[Self::op_index(op)]
    }

    /// Most recent records, oldest first (bounded by the ring capacity).
    pub fn recent(&self) -> impl Iterator<Item = &AuditRecord> {
        self.recent.iter()
    }
}

impl SinkConnector for AuditMirrorSink {
    fn name(&self) -> &str {
        "audit"
    }

    fn apply(&mut self, msg: &OutMessage, op: CdcOp) {
        self.mirrored += 1;
        self.per_op[Self::op_index(op)] += 1;
        if op == CdcOp::Delete {
            self.tombstones += 1;
        } else if !msg.is_dense_valid() {
            self.anomaly_count += 1;
            // bounded like `recent`: a misbehaving mapper must not grow
            // the auditor without bound in a long-running deployment
            if self.anomalies.len() == self.capacity {
                self.anomalies.remove(0);
            }
            self.anomalies.push(format!(
                "dense-contract violation: key {} entity {} w{} at ts {}",
                msg.key, msg.entity.0, msg.version.0, msg.ts_us
            ));
        }
        if self.recent.len() == self.capacity {
            self.recent.pop_front();
        }
        self.recent.push_back(AuditRecord {
            op,
            key: msg.key,
            entity: msg.entity,
            version: msg.version,
            ts_us: msg.ts_us,
        });
    }

    fn snapshot_stats(&self) -> SinkStats {
        SinkStats {
            applied: self.mirrored,
            duplicates: 0,
            dropped: self.anomaly_count,
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cdm::CdmAttrId;
    use crate::message::StateI;
    use crate::util::json::Json;

    fn out(key: u64, fields: Vec<(CdmAttrId, Json)>) -> OutMessage {
        OutMessage {
            key,
            entity: EntityId(1),
            version: CdmVersionNo(1),
            state: StateI(0),
            ts_us: key * 10,
            fields,
        }
    }

    #[test]
    fn mirrors_ops_and_ledgers_tombstones() {
        let mut audit = AuditMirrorSink::new(8);
        let dense = vec![(CdmAttrId(0), Json::Num(1.0))];
        audit.apply(&out(1, dense.clone()), CdcOp::Create);
        audit.apply(&out(1, dense.clone()), CdcOp::Update);
        audit.apply(&out(1, dense), CdcOp::Delete);
        assert_eq!(audit.mirrored, 3);
        assert_eq!(audit.count_of(CdcOp::Create), 1);
        assert_eq!(audit.count_of(CdcOp::Delete), 1);
        assert_eq!(audit.tombstones, 1);
        assert!(audit.anomalies.is_empty());
        assert_eq!(audit.snapshot_stats().applied, 3);
    }

    #[test]
    fn flags_dense_contract_violations() {
        let mut audit = AuditMirrorSink::new(8);
        audit.apply(&out(2, vec![(CdmAttrId(0), Json::Null)]), CdcOp::Create);
        audit.apply(&out(3, Vec::new()), CdcOp::Update);
        assert_eq!(audit.anomalies.len(), 2);
        assert_eq!(audit.anomaly_count, 2);
        assert_eq!(audit.snapshot_stats().dropped, 2);
        // the description ledger is bounded, the total is not
        let mut bounded = AuditMirrorSink::new(2);
        for k in 0..5 {
            bounded.apply(&out(k, Vec::new()), CdcOp::Create);
        }
        assert_eq!(bounded.anomalies.len(), 2);
        assert_eq!(bounded.anomaly_count, 5);
    }

    #[test]
    fn recent_ring_is_bounded() {
        let mut audit = AuditMirrorSink::new(2);
        for k in 0..5 {
            audit.apply(
                &out(k, vec![(CdmAttrId(0), Json::Num(k as f64))]),
                CdcOp::Create,
            );
        }
        let recent: Vec<u64> = audit.recent().map(|r| r.key).collect();
        assert_eq!(recent, vec![3, 4]);
        assert_eq!(audit.mirrored, 5);
    }
}
