//! **Algorithm 1** — the baseline sparse & sequential mapper (paper §4.5).
//!
//! Maps one sparse incoming message `ᵢMIn_v^o` to `ᵢm'` outgoing messages,
//! one per mapping block in the column `ᵢ𝒞𝔐𝔅_v^o` — *including* null
//! blocks, producing messages whose payload is all `"null"` objects. The
//! outgoing message is pre-constructed with every CDM attribute paired
//! with `"null"`, then 1-elements replace the nulls via the mapping
//! function `ncd_q ← m_qp · nad_p`.

use super::MapError;
use crate::cdm::CdmTree;
use crate::matrix::{blocks, MappingMatrix};
use crate::message::{InMessage, OutMessage, StateI};
use crate::schema::SchemaTree;
use crate::util::json::Json;

/// Baseline mapper holding references to the uncompacted system.
pub struct BaselineMapper<'a> {
    pub matrix: &'a MappingMatrix,
    pub tree: &'a SchemaTree,
    pub cdm: &'a CdmTree,
    pub state: StateI,
}

impl<'a> BaselineMapper<'a> {
    pub fn new(
        matrix: &'a MappingMatrix,
        tree: &'a SchemaTree,
        cdm: &'a CdmTree,
        state: StateI,
    ) -> Self {
        Self { matrix, tree, cdm, state }
    }

    /// Map one incoming message to `ᵢm'` outgoing messages (Alg 1).
    pub fn map(&self, msg: &InMessage) -> Result<Vec<OutMessage>, MapError> {
        if msg.state != self.state {
            return Err(MapError::StateMismatch {
                message: msg.state,
                dmm: self.state,
            });
        }
        if let Some(attr) = super::conflicting_dup(msg) {
            return Err(MapError::MalformedPayload { attr });
        }
        let sv = self
            .tree
            .version(msg.schema, msg.version)
            .ok_or(MapError::UnknownColumn {
                schema: msg.schema,
                version: msg.version,
            })?;
        let mut outs = Vec::new();
        // line 2: the column of blocks matching the incoming indices —
        // the baseline iterates ALL (r, w), null blocks included.
        for entity in self.cdm.entities() {
            for &w in &entity.versions {
                // a listed-but-undefined version is a torn §5.1 delete:
                // dead-letter the record, don't crash the shard worker
                let cv = self.cdm.version(entity.id, w).ok_or(
                    MapError::DeadCdmVersion { entity: entity.id, w },
                )?;
                // line 4: pre-construct the all-null outgoing message
                let mut out = OutMessage {
                    key: msg.key,
                    entity: entity.id,
                    version: w,
                    state: self.state,
                    ts_us: msg.ts_us,
                    fields: cv
                        .attrs
                        .iter()
                        .map(|&q| (q, Json::Null))
                        .collect(),
                };
                // line 5: all m_qp != 0 of the block
                let ext = blocks::BlockExtent {
                    rows: cv.row_start()..cv.row_start() + cv.height(),
                    cols: sv.col_start()..sv.col_start() + sv.width(),
                };
                for (q, p) in self
                    .matrix
                    .ones_in(ext.rows.clone(), ext.cols.clone())
                {
                    let attr = sv.attrs[p - ext.cols.start];
                    // lines 7-8: the mapping function ncd <- m_qp * nad_p
                    let nad = msg.nad(attr);
                    let ncd = 1 * nad; // m_qp == 1 here
                    if ncd == 1 {
                        // lines 9-11: replace the "null" object; a missing
                        // object despite nad==1 is a malformed payload
                        let data = msg
                            .data_object(attr)
                            .ok_or(MapError::MalformedPayload { attr })?
                            .clone();
                        let slot = q - ext.rows.start;
                        out.fields[slot].1 = data;
                    }
                }
                outs.push(out);
            }
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::schema::VersionNo;

    fn incoming(t: &SchemaTree, values: &[(usize, Json)]) -> InMessage {
        let s1 = t.schema_by_name("s1").unwrap();
        let sv = t.version(s1, VersionNo(1)).unwrap();
        let mut fields: Vec<_> =
            sv.attrs.iter().map(|&a| (a, Json::Null)).collect();
        for (i, v) in values {
            fields[*i].1 = v.clone();
        }
        InMessage {
            key: 1,
            schema: s1,
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 10,
            fields,
        }
    }

    #[test]
    fn maps_one_message_to_all_blocks() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let mapper = BaselineMapper::new(&m, &t, &c, StateI(0));
        let msg = incoming(
            &t,
            &[(0, Json::Num(11.0)), (1, Json::Num(22.0)), (2, Json::Num(33.0))],
        );
        let outs = mapper.map(&msg).unwrap();
        // ᵢm' = all (entity, version) pairs: be1(v1,v2) + be2(v1) + be3(v1)
        assert_eq!(outs.len(), 4);
        // be1.v2: c3<-a1=11, c4<-a3=33
        let be1 = c.entity_by_name("be1").unwrap();
        let out = outs
            .iter()
            .find(|o| o.entity == be1 && o.version == crate::cdm::CdmVersionNo(2))
            .unwrap();
        assert_eq!(out.fields[0].1.as_f64(), Some(11.0));
        assert_eq!(out.fields[1].1.as_f64(), Some(33.0));
        // be2.v1 is a null block for s1 → all-null payload
        let be2 = c.entity_by_name("be2").unwrap();
        let out = outs.iter().find(|o| o.entity == be2).unwrap();
        assert!(out.fields.iter().all(|(_, v)| v.is_null()));
        // be3.v1: c6<-a2=22, c7<-a1=11
        let be3 = c.entity_by_name("be3").unwrap();
        let out = outs.iter().find(|o| o.entity == be3).unwrap();
        assert_eq!(out.fields[0].1.as_f64(), Some(22.0));
        assert_eq!(out.fields[1].1.as_f64(), Some(11.0));
    }

    #[test]
    fn null_data_objects_stay_null() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let mapper = BaselineMapper::new(&m, &t, &c, StateI(0));
        // only a2 carries data
        let msg = incoming(&t, &[(1, Json::Num(22.0))]);
        let outs = mapper.map(&msg).unwrap();
        let be1 = c.entity_by_name("be1").unwrap();
        let out = outs
            .iter()
            .find(|o| o.entity == be1 && o.version == crate::cdm::CdmVersionNo(2))
            .unwrap();
        // c3 maps a1 which is null → ncd = 1 * 0 = 0 → stays null
        assert!(out.fields[0].1.is_null());
    }

    #[test]
    fn state_mismatch_is_error() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let mapper = BaselineMapper::new(&m, &t, &c, StateI(5));
        let msg = incoming(&t, &[]);
        assert_eq!(
            mapper.map(&msg).unwrap_err(),
            MapError::StateMismatch { message: StateI(0), dmm: StateI(5) }
        );
    }

    #[test]
    fn unknown_version_is_error() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let mapper = BaselineMapper::new(&m, &t, &c, StateI(0));
        let mut msg = incoming(&t, &[]);
        msg.version = VersionNo(99);
        assert!(matches!(
            mapper.map(&msg).unwrap_err(),
            MapError::UnknownColumn { .. }
        ));
    }

    #[test]
    fn torn_cdm_delete_is_error_not_panic() {
        let (t, mut c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let be1 = c.entity_by_name("be1").unwrap();
        // be1.v1 stays listed on the entity but loses its definition
        c.drop_version_definition(be1, crate::cdm::CdmVersionNo(1));
        let mapper = BaselineMapper::new(&m, &t, &c, StateI(0));
        let msg = incoming(&t, &[(0, Json::Num(1.0))]);
        assert_eq!(
            mapper.map(&msg).unwrap_err(),
            MapError::DeadCdmVersion {
                entity: be1,
                w: crate::cdm::CdmVersionNo(1)
            }
        );
    }

    #[test]
    fn nad_payload_disagreement_is_error_not_panic() {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let mapper = BaselineMapper::new(&m, &t, &c, StateI(0));
        let s1 = t.schema_by_name("s1").unwrap();
        let sv = t.version(s1, VersionNo(1)).unwrap();
        let msg = InMessage {
            key: 1,
            schema: s1,
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 0,
            // duplicate a1 entries with conflicting nullness: nad says 0,
            // the payload carries data — Alg 1 would silently drop what
            // Alg 6 maps, so the record must dead-letter
            fields: vec![
                (sv.attrs[0], Json::Null),
                (sv.attrs[0], Json::Num(7.0)),
            ],
        };
        assert_eq!(
            mapper.map(&msg).unwrap_err(),
            MapError::MalformedPayload { attr: sv.attrs[0] }
        );
    }
}
