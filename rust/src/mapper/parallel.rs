//! **Algorithm 6** — parallel & dense mapping with `ᵢ𝔇𝔓𝔐` (paper §5.5).
//!
//! The simplified mapping function: every stored element has value 1 and
//! every present attribute has `nad_p = 1`, so *finding* the element with
//! index p in the dense set IS the mapping — `1 * 1 = 1` — and the data
//! object is relabelled to `c_q` by set intersection. Three parallelism
//! levels: messages (stream), blocks (independent mapping paths), and
//! elements (linearly independent rows/columns of the permutation
//! matrices). Element-level work is a handful of lookups, so this
//! implementation parallelizes at the block and message levels and keeps
//! the element loop tight (the paper's own implementation reserves the
//! block split as "reserve capacity", §6.4).

use std::sync::Arc;

use super::kernel::{self, KernelMode};
use super::MapError;
use crate::cache::DcpmCache;
use crate::matrix::dpm::{DpmBlock, DpmSet};
use crate::message::{InMessage, OutMessage, StateI};
use crate::util::threadpool::par_map;

/// Parallel mapper over a DMM snapshot + column cache.
pub struct ParallelMapper {
    dpm: Arc<DpmSet>,
    cache: Arc<DcpmCache>,
    /// Parallelize across blocks when a column has at least this many
    /// (scalar lane only — the native kernel is single-pass per message).
    pub block_parallel_threshold: usize,
    pub threads: usize,
    /// Which lane [`ParallelMapper::map`] runs
    /// ([`KernelMode::Native`] by default).
    pub kernel: KernelMode,
}

impl ParallelMapper {
    pub fn new(dpm: Arc<DpmSet>, cache: Arc<DcpmCache>) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::with_threads(dpm, cache, threads)
    }

    /// Construct without the `available_parallelism` syscall — the hot
    /// path builds one mapper per event (cheap Arc clones only).
    pub fn with_threads(
        dpm: Arc<DpmSet>,
        cache: Arc<DcpmCache>,
        threads: usize,
    ) -> Self {
        Self {
            dpm,
            cache,
            block_parallel_threshold: 4,
            threads,
            kernel: KernelMode::default(),
        }
    }

    /// Select the mapping lane (`runtime.kernel` / `--kernel`).
    pub fn with_kernel(mut self, kernel: KernelMode) -> Self {
        self.kernel = kernel;
        self
    }

    /// The trace lane this mapper's events execute on.
    pub fn lane(&self) -> crate::trace::Lane {
        self.kernel.into()
    }

    pub fn state(&self) -> StateI {
        self.dpm.state
    }

    /// The snapshot this mapper currently maps against.
    pub fn dpm(&self) -> &Arc<DpmSet> {
        &self.dpm
    }

    /// Swap in a new DMM snapshot after an update (state i+1).
    pub fn replace_dpm(&mut self, dpm: Arc<DpmSet>) {
        self.dpm = dpm;
    }

    /// Map one dense incoming message (Alg 6 inner loop). Returns only
    /// non-empty outgoing messages.
    pub fn map(&self, msg: &InMessage) -> Result<Vec<OutMessage>, MapError> {
        if msg.state != self.dpm.state {
            return Err(MapError::StateMismatch {
                message: msg.state,
                dmm: self.dpm.state,
            });
        }
        if let Some(attr) = super::conflicting_dup(msg) {
            return Err(MapError::MalformedPayload { attr });
        }
        if self.kernel == KernelMode::Native {
            // native lane: compiled per-column plan, presence bitset,
            // permutation gather — one pass over the fields
            let (column, plan) =
                self.cache.plan(&self.dpm, msg.schema, msg.version);
            if column.is_empty() {
                return Err(MapError::UnknownColumn {
                    schema: msg.schema,
                    version: msg.version,
                });
            }
            return Ok(kernel::with_scratch(|s| plan.map_message(msg, s)));
        }
        // line 3: ᵢ𝒟𝒞𝒫𝓜_v^o lookup through the cache (O(1) warm)
        let column = self.cache.column(&self.dpm, msg.schema, msg.version);
        if column.is_empty() {
            return Err(MapError::UnknownColumn {
                schema: msg.schema,
                version: msg.version,
            });
        }
        // line 4: each block in the column — an independent mapping path
        let map_block = |block: &Arc<DpmBlock>| self.map_one_block(msg, block);
        let outs: Vec<Option<OutMessage>> =
            if column.len() >= self.block_parallel_threshold {
                par_map(self.threads, &column, map_block)
            } else {
                column.iter().map(map_block).collect()
            };
        Ok(outs.into_iter().flatten().collect())
    }

    /// One independent mapping path: message × block → optional output.
    fn map_one_block(
        &self,
        msg: &InMessage,
        block: &DpmBlock,
    ) -> Option<OutMessage> {
        // line 5: create message with empty payload
        let mut fields = Vec::with_capacity(block.elements.len());
        // line 6: ∀ m_qp ∈ DPM block — the simplified set-intersection
        // mapping function (1 * 1 = 1)
        for &(q, p) in &block.elements {
            // "if there is ad_p ∈ MIn for the same index p": dense
            // messages hold ~10 fields; linear scan beats hashing here.
            if let Some((_, data)) =
                msg.fields.iter().find(|(a, v)| *a == p && !v.is_null())
            {
                fields.push((q, data.clone()));
            }
        }
        // line 12: only send out non-empty payloads
        if fields.is_empty() {
            return None;
        }
        Some(OutMessage {
            key: msg.key,
            entity: block.key.entity,
            version: block.key.w,
            state: msg.state,
            ts_us: msg.ts_us,
            fields,
        })
    }

    /// Map with the §3.4 state-sync retry folded in: on a state mismatch
    /// the message is restamped to this snapshot's state and mapped once
    /// more. Returns the outputs plus whether a restamp happened (the
    /// caller owns the `sync_retries` metric). Used by the single lane and
    /// by every shard worker of the sharded mapping lane.
    pub fn map_or_restamp(
        &self,
        msg: &InMessage,
    ) -> Result<(Vec<OutMessage>, bool), MapError> {
        match self.map(msg) {
            Ok(outs) => Ok((outs, false)),
            Err(MapError::StateMismatch { .. }) => {
                let mut restamped = msg.clone();
                restamped.state = self.state();
                Ok((self.map(&restamped)?, true))
            }
            Err(e) => Err(e),
        }
    }

    /// Map a batch of messages in parallel (the stream level of §5.5).
    /// Per-message results keep input order; errors are per-message.
    pub fn map_batch(
        &self,
        msgs: &[InMessage],
    ) -> Vec<Result<Vec<OutMessage>, MapError>> {
        par_map(self.threads, msgs, |m| self.map(m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::message::StateI;
    use crate::schema::{SchemaTree, VersionNo};
    use crate::util::json::Json;

    fn setup() -> (SchemaTree, crate::cdm::CdmTree, ParallelMapper) {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = Arc::new(
            DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap(),
        );
        let cache = Arc::new(DcpmCache::new(StateI(0)));
        let mapper = ParallelMapper::new(dpm, cache);
        (t, c, mapper)
    }

    fn dense_msg(t: &SchemaTree, idx_vals: &[(usize, f64)]) -> InMessage {
        let s1 = t.schema_by_name("s1").unwrap();
        let sv = t.version(s1, VersionNo(1)).unwrap();
        InMessage {
            key: 9,
            schema: s1,
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 5,
            fields: idx_vals
                .iter()
                .map(|&(i, v)| (sv.attrs[i], Json::Num(v)))
                .collect(),
        }
    }

    #[test]
    fn dense_mapping_emits_only_nonempty() {
        let (t, c, mapper) = setup();
        let msg = dense_msg(&t, &[(0, 11.0), (2, 33.0)]); // a1, a3
        let outs = mapper.map(&msg).unwrap();
        // be1.v2 gets c3<-a1, c4<-a3; be3.v1 gets c7<-a1 (c6<-a2 absent);
        // be2 has no s1 block at all.
        assert_eq!(outs.len(), 2);
        let be1 = c.entity_by_name("be1").unwrap();
        let o1 = outs.iter().find(|o| o.entity == be1).unwrap();
        assert_eq!(o1.fields.len(), 2);
        assert!(o1.is_dense_valid());
        let be3 = c.entity_by_name("be3").unwrap();
        let o3 = outs.iter().find(|o| o.entity == be3).unwrap();
        assert_eq!(o3.fields.len(), 1);
        assert_eq!(o3.fields[0].1.as_f64(), Some(11.0));
    }

    #[test]
    fn all_unmapped_attrs_produce_nothing() {
        let (t, _c, mapper) = setup();
        // a message carrying only attributes mapped by nothing
        let s1 = t.schema_by_name("s1").unwrap();
        let sv = t.version(s1, VersionNo(2)).unwrap();
        let msg = InMessage {
            key: 1,
            schema: s1,
            version: VersionNo(2),
            state: StateI(0),
            ts_us: 0,
            fields: vec![(sv.attrs[0], Json::Null)], // null → dense empty
        };
        let outs = mapper.map(&msg).unwrap();
        assert!(outs.is_empty());
    }

    #[test]
    fn matches_baseline_semantics() {
        // Alg 6 == dense(Alg 1 minus all-null outputs)
        use crate::mapper::baseline::BaselineMapper;
        let (t, c, mapper) = setup();
        let m = fig5_matrix(&t, &c);
        let baseline = BaselineMapper::new(&m, &t, &c, StateI(0));
        let sparse = dense_msg(&t, &[(0, 1.0), (1, 2.0), (2, 3.0)]);
        let base_outs: Vec<_> = baseline
            .map(&sparse)
            .unwrap()
            .into_iter()
            .map(|o| OutMessage {
                fields: o
                    .fields
                    .into_iter()
                    .filter(|(_, v)| !v.is_null())
                    .collect(),
                ..o
            })
            .filter(|o| !o.fields.is_empty())
            .collect();
        let mut fast_outs = mapper.map(&sparse).unwrap();
        fast_outs.sort_by_key(|o| (o.entity, o.version));
        let mut base_sorted = base_outs;
        base_sorted.sort_by_key(|o| (o.entity, o.version));
        assert_eq!(fast_outs, base_sorted);
    }

    #[test]
    fn state_mismatch_detected() {
        let (t, _c, mapper) = setup();
        let mut msg = dense_msg(&t, &[(0, 1.0)]);
        msg.state = StateI(9);
        assert!(matches!(
            mapper.map(&msg).unwrap_err(),
            MapError::StateMismatch { .. }
        ));
    }

    fn scalar_twin(mapper: &ParallelMapper) -> ParallelMapper {
        ParallelMapper::with_threads(
            Arc::clone(mapper.dpm()),
            Arc::new(DcpmCache::new(mapper.state())),
            1,
        )
        .with_kernel(KernelMode::Scalar)
    }

    #[test]
    fn native_and_scalar_lanes_agree() {
        let (t, _c, native) = setup();
        assert_eq!(native.kernel, KernelMode::Native);
        let scalar = scalar_twin(&native);
        for fields in [
            vec![(0, 11.0)],
            vec![(1, 22.0)],
            vec![(0, 1.0), (1, 2.0), (2, 3.0)],
            vec![(2, 9.0), (0, 8.0)], // out-of-order fields
            vec![],
        ] {
            let msg = dense_msg(&t, &fields);
            assert_eq!(native.map(&msg), scalar.map(&msg), "{fields:?}");
        }
    }

    #[test]
    fn conflicting_duplicate_attr_is_rejected_by_both_lanes() {
        let (t, _c, native) = setup();
        let scalar = scalar_twin(&native);
        let s1 = t.schema_by_name("s1").unwrap();
        let sv = t.version(s1, VersionNo(1)).unwrap();
        let msg = InMessage {
            key: 3,
            schema: s1,
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 0,
            // nad(a1) = 0 (first entry null) but a data object exists —
            // the lanes would disagree; both must refuse instead
            fields: vec![
                (sv.attrs[0], Json::Null),
                (sv.attrs[0], Json::Num(5.0)),
            ],
        };
        let expected = MapError::MalformedPayload { attr: sv.attrs[0] };
        assert_eq!(native.map(&msg).unwrap_err(), expected);
        assert_eq!(scalar.map(&msg).unwrap_err(), expected);
        // the benign direction (non-null first, null dup later) still maps
        let benign = InMessage {
            fields: vec![
                (sv.attrs[0], Json::Num(5.0)),
                (sv.attrs[0], Json::Null),
            ],
            ..msg
        };
        assert_eq!(native.map(&benign), scalar.map(&benign));
        assert!(!native.map(&benign).unwrap().is_empty());
    }

    #[test]
    fn batch_maps_in_order() {
        let (t, _c, mapper) = setup();
        let msgs: Vec<_> = (0..64)
            .map(|k| {
                let mut m = dense_msg(&t, &[(0, k as f64)]);
                m.key = k;
                m
            })
            .collect();
        let results = mapper.map_batch(&msgs);
        assert_eq!(results.len(), 64);
        for (k, r) in results.iter().enumerate() {
            let outs = r.as_ref().unwrap();
            assert!(outs.iter().all(|o| o.key == k as u64));
        }
    }
}
