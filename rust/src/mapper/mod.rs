//! The two mapping engines of the paper:
//!
//! - [`baseline`] — Algorithm 1: sparse, sequential, over raw matrix
//!   blocks; produces *all* possible outgoing messages including all-null
//!   ones (§4.5). Kept as the reference semantics and the bench baseline.
//! - [`parallel`] — Algorithm 6: dense, set-based, over `ᵢ𝔇𝔓𝔐` columns;
//!   only non-null attributes, only non-empty outputs, parallel over
//!   blocks and messages (§5.5).
//!
//! Both check the distributed-state precondition (§3.4): a message whose
//! state `i` differs from the DMM's is a sync error, surfaced as
//! [`MapError::StateMismatch`] and routed to error management.

pub mod baseline;
pub mod parallel;

use crate::message::StateI;
use crate::schema::{SchemaId, VersionNo};

/// Mapping failures surfaced to the coordinator's error management.
#[derive(Debug, Clone, PartialEq)]
pub enum MapError {
    /// §3.4: "a new schema version has been pulled from the registry for a
    /// Kafka-message, but this version is not known to METL yet."
    StateMismatch { message: StateI, dmm: StateI },
    /// The message's schema version has no mapping column (not registered
    /// or all blocks deleted).
    UnknownColumn { schema: SchemaId, version: VersionNo },
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::StateMismatch { message, dmm } => write!(
                f,
                "message state {message:?} out of sync with DMM state {dmm:?}"
            ),
            MapError::UnknownColumn { schema, version } => {
                write!(f, "no mapping column for schema {schema:?} v{}", version.0)
            }
        }
    }
}

impl std::error::Error for MapError {}
