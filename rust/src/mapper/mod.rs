//! The two mapping engines of the paper:
//!
//! - [`baseline`] — Algorithm 1: sparse, sequential, over raw matrix
//!   blocks; produces *all* possible outgoing messages including all-null
//!   ones (§4.5). Kept as the reference semantics and the bench baseline.
//! - [`parallel`] — Algorithm 6: dense, set-based, over `ᵢ𝔇𝔓𝔐` columns;
//!   only non-null attributes, only non-empty outputs, parallel over
//!   blocks and messages (§5.5).
//!
//! Both check the distributed-state precondition (§3.4): a message whose
//! state `i` differs from the DMM's is a sync error, surfaced as
//! [`MapError::StateMismatch`] and routed to error management.

pub mod baseline;
pub mod kernel;
pub mod parallel;

use crate::cdm::{CdmVersionNo, EntityId};
use crate::message::{InMessage, StateI};
use crate::schema::{AttrId, SchemaId, VersionNo};

/// Mapping failures surfaced to the coordinator's error management.
#[derive(Debug, Clone, PartialEq)]
pub enum MapError {
    /// §3.4: "a new schema version has been pulled from the registry for a
    /// Kafka-message, but this version is not known to METL yet."
    StateMismatch { message: StateI, dmm: StateI },
    /// The message's schema version has no mapping column (not registered
    /// or all blocks deleted).
    UnknownColumn { schema: SchemaId, version: VersionNo },
    /// A CDM version listed on its entity has no definition in the tree —
    /// a torn §5.1 delete. Previously a baseline-lane panic.
    DeadCdmVersion { entity: EntityId, w: CdmVersionNo },
    /// The message's `nad` view disagrees with its payload: an attribute
    /// appears as "null" and *also* carries a data object. The lanes would
    /// diverge on such input (Alg 1 reads `nad` of the first entry, Alg 6
    /// scans for any non-null entry), so it dead-letters instead.
    /// Previously a baseline-lane panic (`expect("nad==1")`).
    MalformedPayload { attr: AttrId },
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::StateMismatch { message, dmm } => write!(
                f,
                "message state {message:?} out of sync with DMM state {dmm:?}"
            ),
            MapError::UnknownColumn { schema, version } => {
                write!(f, "no mapping column for schema {schema:?} v{}", version.0)
            }
            MapError::DeadCdmVersion { entity, w } => write!(
                f,
                "CDM version v{} of entity {entity:?} is listed but undefined",
                w.0
            ),
            MapError::MalformedPayload { attr } => write!(
                f,
                "attribute {attr:?} is null and non-null in the same payload"
            ),
        }
    }
}

impl std::error::Error for MapError {}

/// Detect the realizable nad/payload disagreement: an attribute whose
/// *first* entry is "null" (so `nad_p = 0`) while a later duplicate entry
/// carries a data object. Alg 1 would silently drop the value and Alg 6
/// would map it — every lane rejects such messages up front with
/// [`MapError::MalformedPayload`] instead. Dense messages carry no nulls,
/// so the scan is free on the optimized path.
pub(crate) fn conflicting_dup(msg: &InMessage) -> Option<AttrId> {
    for (i, (attr, value)) in msg.fields.iter().enumerate() {
        if !value.is_null()
            && msg.fields[..i]
                .iter()
                .any(|(a, v)| a == attr && v.is_null())
        {
            return Some(*attr);
        }
    }
    None
}
