//! Native block-permutation mapping kernel — the bulk fast path.
//!
//! This is the Rust port of the Python bulk kernels
//! (`python/compile/kernels/block_map.py`, `permute_extract.py`, `ref.py`):
//! there, a mapping block applies the paper's mapping function
//! `ncd_q ← m_qp · nad_p` to a batch of presence vectors as a 0/1 matmul
//! producing a presence plane and a source-index plane. Here the same two
//! planes are computed natively, without the PJRT runtime: a **presence
//! bitset** over column-major slot indices (one bit per live matrix column
//! of the `ᵢ𝒟𝒞𝒫𝓜` column super-set) and a **source-field table** (which
//! incoming field feeds each slot — the `src_idx` plane). Each block then
//! reduces to a permutation *gather*: rank-many bit tests plus payload
//! clones, instead of re-scanning the message fields per element as the
//! scalar Alg-6 lane does.
//!
//! Per message the cost is O(|fields| + Σ rank) against the scalar lane's
//! O(Σ rank · |fields|); the [`ColumnPlan`] is built once per cached
//! column and shared through the [`PlanCache`], whose entries are
//! validated by **pointer identity** against the column-cache `Arc` — an
//! epoch swap that drops a column through the targeted-eviction journal
//! (`DcpmCache::advance`) therefore invalidates the plan with no extra
//! wiring, while unaffected warm columns keep their plans.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock, Weak};

use crate::cdm::{CdmAttrId, CdmVersionNo, EntityId};
use crate::matrix::dpm::DpmBlock;
use crate::message::{InMessage, OutMessage};
use crate::schema::{SchemaId, VersionNo};

/// Which mapping lane serves bulk/batch traffic
/// (`runtime.kernel` config key / `--kernel` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// The native block-permutation kernel (default).
    #[default]
    Native,
    /// The scalar Alg-6 per-element lane, kept as fallback and as the
    /// bench comparison baseline.
    Scalar,
}

impl std::str::FromStr for KernelMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "native" => Ok(KernelMode::Native),
            "scalar" => Ok(KernelMode::Scalar),
            other => {
                Err(format!("unknown kernel mode {other:?} (native|scalar)"))
            }
        }
    }
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelMode::Native => write!(f, "native"),
            KernelMode::Scalar => write!(f, "scalar"),
        }
    }
}

/// One block's gather table: output attribute × slot index, in the
/// block's element order (sorted by `q`) so outputs are bit-identical to
/// the scalar lane's.
#[derive(Debug, Clone)]
struct BlockPlan {
    entity: EntityId,
    w: CdmVersionNo,
    /// `(c_q, p - base)` pairs — the permutation as slot gathers.
    gather: Vec<(CdmAttrId, u32)>,
}

/// Compiled mapping plan for one `ᵢ𝒟𝒞𝒫𝓜` column super-set.
///
/// Slot indexing exploits the matrix layout: schema-version attribute ids
/// are contiguous ascending (each version owns a column range), so
/// `p - base` is a dense index and the presence plane is a bitset, no
/// hashing anywhere on the mapping path.
#[derive(Debug, Clone)]
pub struct ColumnPlan {
    /// Smallest global column index `p` referenced by any block.
    base: u32,
    /// Number of slots: `max(p) - base + 1` (0 for an empty column).
    width: usize,
    blocks: Vec<BlockPlan>,
}

impl ColumnPlan {
    /// Compile a column's blocks into gather tables. Block order and
    /// per-block element order are preserved, which is what makes the
    /// native lane's output identical to the scalar lane's.
    pub fn build(column: &[Arc<DpmBlock>]) -> ColumnPlan {
        let ps = column
            .iter()
            .flat_map(|b| b.elements.iter().map(|&(_, p)| p.0));
        let base = ps.clone().min().unwrap_or(0);
        let width = ps.max().map(|hi| (hi - base) as usize + 1).unwrap_or(0);
        let blocks = column
            .iter()
            .map(|b| BlockPlan {
                entity: b.key.entity,
                w: b.key.w,
                gather: b
                    .elements
                    .iter()
                    .map(|&(q, p)| (q, p.0 - base))
                    .collect(),
            })
            .collect();
        ColumnPlan { base, width, blocks }
    }

    /// Number of blocks in the plan.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Total gather elements (the column's Σ rank).
    pub fn n_elements(&self) -> usize {
        self.blocks.iter().map(|b| b.gather.len()).sum()
    }

    /// Map one message through the plan. Semantics match the scalar Alg-6
    /// lane exactly: the first non-null field per attribute wins, fields
    /// appear in block element order, empty outputs are dropped.
    pub fn map_message(
        &self,
        msg: &InMessage,
        scratch: &mut Scratch,
    ) -> Vec<OutMessage> {
        scratch.reset(self.width);
        // Presence + src-idx planes (ref.py: presence, src_idx) in one
        // pass over the message fields.
        for (i, (attr, value)) in msg.fields.iter().enumerate() {
            if value.is_null() {
                continue;
            }
            let p = attr.0;
            if p < self.base {
                continue;
            }
            let slot = (p - self.base) as usize;
            if slot >= self.width {
                continue;
            }
            let (word, bit) = (slot / 64, slot % 64);
            if scratch.mask[word] & (1 << bit) == 0 {
                scratch.mask[word] |= 1 << bit;
                scratch.field_of[slot] = i as u32;
            }
        }
        // Per-block permutation gather.
        let mut outs = Vec::with_capacity(self.blocks.len());
        for block in &self.blocks {
            let mut fields = Vec::with_capacity(block.gather.len());
            for &(q, slot) in &block.gather {
                let slot = slot as usize;
                if scratch.mask[slot / 64] & (1 << (slot % 64)) != 0 {
                    let src = scratch.field_of[slot] as usize;
                    fields.push((q, msg.fields[src].1.clone()));
                }
            }
            if fields.is_empty() {
                continue; // dense discipline: no empty outputs (§5.5)
            }
            outs.push(OutMessage {
                key: msg.key,
                entity: block.entity,
                version: block.w,
                state: msg.state,
                ts_us: msg.ts_us,
                fields,
            });
        }
        outs
    }
}

/// Reusable per-thread working memory for [`ColumnPlan::map_message`]:
/// the presence bitset and the source-field table.
#[derive(Debug, Default)]
pub struct Scratch {
    mask: Vec<u64>,
    field_of: Vec<u32>,
}

impl Scratch {
    fn reset(&mut self, width: usize) {
        let words = width.div_ceil(64);
        self.mask.clear();
        self.mask.resize(words, 0);
        // field_of is only read where the mask bit is set — grow, don't
        // clear.
        if self.field_of.len() < width {
            self.field_of.resize(width, 0);
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// Run `f` with this thread's kernel scratch (zero allocation on the warm
/// path).
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Plan-cache counters (bench + dashboard material).
#[derive(Debug, Default)]
pub struct PlanStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl PlanStats {
    /// `(hits, misses)` snapshot for exposition.
    pub fn counts(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

/// Cache of compiled [`ColumnPlan`]s, keyed like the column cache.
///
/// An entry is valid only while its [`Weak`] upgrades to the *same* `Arc`
/// the column cache currently serves: targeted eviction replaces a
/// column's `Arc`, so the stale plan misses and recompiles, while columns
/// that survived an epoch swap warm keep their plans. The `Weak` makes
/// ABA impossible — a recycled allocation address can't masquerade as the
/// old column, because a successful upgrade proves the old allocation is
/// still alive.
#[derive(Default)]
pub struct PlanCache {
    #[allow(clippy::type_complexity)]
    plans: RwLock<
        HashMap<
            (SchemaId, VersionNo),
            (Weak<Vec<Arc<DpmBlock>>>, Arc<ColumnPlan>),
        >,
    >,
    pub stats: PlanStats,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get (or compile) the plan for `column` as currently cached under
    /// `key`.
    pub fn plan_for(
        &self,
        key: (SchemaId, VersionNo),
        column: &Arc<Vec<Arc<DpmBlock>>>,
    ) -> Arc<ColumnPlan> {
        if let Some((weak, plan)) = self.plans.read().unwrap().get(&key) {
            if let Some(live) = weak.upgrade() {
                if Arc::ptr_eq(&live, column) {
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    return Arc::clone(plan);
                }
            }
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(ColumnPlan::build(column));
        self.plans
            .write()
            .unwrap()
            .insert(key, (Arc::downgrade(column), Arc::clone(&plan)));
        plan
    }

    /// Drop one key (rides the targeted-eviction path).
    pub fn remove(&self, key: &(SchemaId, VersionNo)) {
        self.plans.write().unwrap().remove(key);
    }

    /// Drop everything (rides the full-eviction path).
    pub fn clear(&self) {
        self.plans.write().unwrap().clear();
    }

    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::dpm::DpmSet;
    use crate::matrix::fixtures::{fig5_matrix, fig5_trees};
    use crate::message::StateI;
    use crate::util::json::Json;

    fn fig5_column() -> (Arc<Vec<Arc<DpmBlock>>>, crate::schema::SchemaTree) {
        let (t, c) = fig5_trees();
        let m = fig5_matrix(&t, &c);
        let dpm = DpmSet::from_matrix(&m, &t, &c, StateI(0)).unwrap();
        let s1 = t.schema_by_name("s1").unwrap();
        (Arc::new(dpm.column(s1, VersionNo(1))), t)
    }

    fn msg(t: &crate::schema::SchemaTree, idx_vals: &[(usize, f64)]) -> InMessage {
        let s1 = t.schema_by_name("s1").unwrap();
        let sv = t.version(s1, VersionNo(1)).unwrap();
        InMessage {
            key: 4,
            schema: s1,
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 1,
            fields: idx_vals
                .iter()
                .map(|&(i, v)| (sv.attrs[i], Json::Num(v)))
                .collect(),
        }
    }

    #[test]
    fn plan_shape_matches_column() {
        let (col, _) = fig5_column();
        let plan = ColumnPlan::build(&col);
        // s1.v1 feeds be1.v2 (2 elements) + be3.v1 (2 elements)
        assert_eq!(plan.n_blocks(), 2);
        assert_eq!(plan.n_elements(), 4);
        // s1.v1 owns columns a1..a3; all referenced ps are inside
        assert!(plan.width >= 1 && plan.width <= 3);
    }

    #[test]
    fn empty_column_builds_empty_plan() {
        let plan = ColumnPlan::build(&[]);
        assert_eq!(plan.n_blocks(), 0);
        assert_eq!(plan.width, 0);
        let m = InMessage {
            key: 0,
            schema: SchemaId(0),
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 0,
            fields: vec![],
        };
        let outs = with_scratch(|s| plan.map_message(&m, s));
        assert!(outs.is_empty());
    }

    #[test]
    fn maps_like_the_scalar_lane() {
        let (col, t) = fig5_column();
        let plan = ColumnPlan::build(&col);
        let m = msg(&t, &[(0, 11.0), (2, 33.0)]); // a1, a3
        let outs = with_scratch(|s| plan.map_message(&m, s));
        // be1.v2 gets c3<-a1, c4<-a3; be3.v1 gets c7<-a1
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].fields.len(), 2);
        assert_eq!(outs[1].fields.len(), 1);
        assert!(outs.iter().all(|o| o.is_dense_valid()));
    }

    #[test]
    fn nulls_and_out_of_range_attrs_skip() {
        let (col, t) = fig5_column();
        let plan = ColumnPlan::build(&col);
        let s1 = t.schema_by_name("s1").unwrap();
        let sv = t.version(s1, VersionNo(1)).unwrap();
        let m = InMessage {
            key: 0,
            schema: s1,
            version: VersionNo(1),
            state: StateI(0),
            ts_us: 0,
            fields: vec![
                (sv.attrs[0], Json::Null),            // null → absent
                (crate::schema::AttrId(999), Json::Num(1.0)), // unmapped
            ],
        };
        let outs = with_scratch(|s| plan.map_message(&m, s));
        assert!(outs.is_empty());
    }

    #[test]
    fn plan_cache_hits_on_same_arc_and_misses_on_replacement() {
        let (col, t) = fig5_column();
        let s1 = t.schema_by_name("s1").unwrap();
        let cache = PlanCache::new();
        let key = (s1, VersionNo(1));
        let p1 = cache.plan_for(key, &col);
        let p2 = cache.plan_for(key, &col);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 1);
        // a *replaced* column Arc (same contents) must recompile
        let replaced = Arc::new((*col).clone());
        let p3 = cache.plan_for(key, &replaced);
        assert!(!Arc::ptr_eq(&p2, &p3));
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn dead_column_arc_never_validates_a_plan() {
        let (col, t) = fig5_column();
        let s1 = t.schema_by_name("s1").unwrap();
        let cache = PlanCache::new();
        let key = (s1, VersionNo(1));
        cache.plan_for(key, &col);
        drop(col); // the cached Weak is now dead
        let (fresh, _) = fig5_column();
        cache.plan_for(key, &fresh);
        assert_eq!(cache.stats.misses.load(Ordering::Relaxed), 2);
        assert_eq!(cache.stats.hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn kernel_mode_parses() {
        assert_eq!("native".parse::<KernelMode>(), Ok(KernelMode::Native));
        assert_eq!("scalar".parse::<KernelMode>(), Ok(KernelMode::Scalar));
        assert!("pallas".parse::<KernelMode>().is_err());
        assert_eq!(KernelMode::Native.to_string(), "native");
        assert_eq!(KernelMode::Scalar.to_string(), "scalar");
        assert_eq!(KernelMode::default(), KernelMode::Native);
    }
}
