//! Adversarial workload engine: composable hostile [`Scenario`]s layered
//! over the polite day trace — the "make the generator mean" ROADMAP
//! item. Each scenario stresses one production pathology the paper's EOS
//! deployment lives with: Zipfian hot-key/hot-schema skew, burst/drain
//! cycles, late/out-of-order CDC (bounded reordering), duplicate
//! delivery (the broker is at-least-once), an initial-load storm racing
//! live CDC on the same topic, and schema changes landing mid-burst on
//! the hottest schema.
//!
//! Everything is driven by one seeded [`Rng`], so a `(seed, scenario)`
//! pair replays byte-identically — the golden-fixture test in
//! `tests/adversarial_scenarios.rs` pins one such trace. The
//! [`super::scenario::ScenarioRunner`] resolves [`HostileOp`]s against a
//! live pipeline, applies the [`shuffle_bounded`]/[`duplicate_delivery`]
//! transforms between resolution and publication, and checks the
//! conformance invariants.

use std::collections::HashMap;
use std::fmt;

use crate::config::PipelineConfig;
use crate::util::rng::{Rng, Zipf};
use crate::workload::DmlKind;

/// Zipfian universe of hot-key ranks (rank 0 = oldest live key).
const KEY_RANKS: usize = 64;
/// Skew exponent over services (hot-schema concentration).
const SVC_EXPONENT: f64 = 1.2;
/// Skew exponent over key ranks (hot-key concentration).
const KEY_EXPONENT: f64 = 1.1;

/// One hostile workload shape. `Uniform` is the polite baseline the
/// bench compares against; the other six are the adversaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Uniform service/key selection, steady cadence — the control.
    Uniform,
    /// Zipfian hot-key + hot-schema skew: a handful of services and the
    /// oldest few keys absorb most of the writes.
    Zipf,
    /// Burst/drain cycles: long flushless bursts alternating with
    /// one-op-per-flush quiet stretches.
    Burst,
    /// Late/out-of-order delivery: each flushed batch is reordered within
    /// a bounded displacement window (per-key order preserved — Kafka's
    /// actual guarantee).
    Shuffle,
    /// At-least-once duplicate delivery: producer-retry re-publishes land
    /// adjacent to their originals on the CDC topic.
    Duplicate,
    /// Initial-load storm: a full table snapshot is published onto the
    /// same topic the live stream uses, racing in-flight CDC.
    LoadStorm,
    /// Schema changes arrive mid-burst on the hottest schema while its
    /// old-version events are still in flight.
    HotSchemaChange,
}

impl Scenario {
    /// Every scenario, baseline first.
    pub const ALL: [Scenario; 7] = [
        Scenario::Uniform,
        Scenario::Zipf,
        Scenario::Burst,
        Scenario::Shuffle,
        Scenario::Duplicate,
        Scenario::LoadStorm,
        Scenario::HotSchemaChange,
    ];

    /// The six adversaries (everything but the uniform control).
    pub const HOSTILE: [Scenario; 6] = [
        Scenario::Zipf,
        Scenario::Burst,
        Scenario::Shuffle,
        Scenario::Duplicate,
        Scenario::LoadStorm,
        Scenario::HotSchemaChange,
    ];

    /// Stable CLI/bench name (the `--scenario` axis).
    pub fn name(self) -> &'static str {
        match self {
            Scenario::Uniform => "uniform",
            Scenario::Zipf => "zipf",
            Scenario::Burst => "burst",
            Scenario::Shuffle => "shuffle",
            Scenario::Duplicate => "duplicate",
            Scenario::LoadStorm => "load-storm",
            Scenario::HotSchemaChange => "hot-schema-change",
        }
    }

    /// Parse a `--scenario` value.
    pub fn from_name(name: &str) -> Option<Scenario> {
        Scenario::ALL.iter().copied().find(|s| s.name() == name)
    }

    /// The delivery-transform knobs the runner applies per flushed batch.
    pub fn params(self) -> ScenarioParams {
        ScenarioParams {
            shuffle_bound: match self {
                Scenario::Shuffle => 32,
                _ => 0,
            },
            duplicate_p: match self {
                Scenario::Duplicate => 0.15,
                _ => 0.0,
            },
        }
    }
}

impl fmt::Display for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-batch delivery-transform knobs (see [`Scenario::params`]).
#[derive(Debug, Clone, Copy)]
pub struct ScenarioParams {
    /// Max positions any event may be displaced by [`shuffle_bounded`].
    pub shuffle_bound: usize,
    /// Probability an event is re-published by [`duplicate_delivery`].
    pub duplicate_p: f64,
}

/// One step of a hostile trace. Unlike [`super::TraceOp`], DMLs carry an
/// optional hot-key rank and explicit `Drain` steps mark the batch
/// boundaries where the runner applies the delivery transforms, publishes
/// and dispatches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HostileOp {
    /// A DML intent; `rank` targets the rank-th oldest live key (Zipfian
    /// hot-key skew), `None` picks uniformly.
    Dml { service: usize, kind: DmlKind, rank: Option<u64> },
    /// Evolve the service's schema (mid-burst when no `Drain` precedes).
    SchemaChange { service: usize },
    /// Snapshot the service's table onto the live CDC topic (initial-load
    /// storm racing the buffered stream).
    SnapshotStorm { service: usize },
    /// Flush boundary: transform, publish and dispatch the buffer.
    Drain,
}

fn roll_kind(rng: &mut Rng) -> DmlKind {
    let roll = rng.f64();
    if roll < 0.70 {
        DmlKind::Insert
    } else if roll < 0.95 {
        DmlKind::Update
    } else {
        DmlKind::Delete
    }
}

/// Generate the hostile trace for `(cfg, scenario)` — fully determined by
/// the caller's `rng` seed. `cfg.trace_events` DML intents with the day
/// trace's 70/25/5 mix; the scenario shapes cadence, skew and the storm /
/// schema-change placement.
pub fn hostile_trace(
    cfg: &PipelineConfig,
    scenario: Scenario,
    rng: &mut Rng,
) -> Vec<HostileOp> {
    let n = cfg.trace_events;
    // hottest-first service permutation: which schema is hot is itself
    // seed-dependent, so scenarios don't all hammer service 0
    let mut order: Vec<usize> = (0..cfg.n_services).collect();
    rng.shuffle(&mut order);
    let svc_zipf = Zipf::new(order.len(), SVC_EXPONENT);
    let key_zipf = Zipf::new(KEY_RANKS, KEY_EXPONENT);
    let skewed =
        matches!(scenario, Scenario::Zipf | Scenario::HotSchemaChange);
    let mut dml = |rng: &mut Rng| -> HostileOp {
        let service = if skewed {
            order[svc_zipf.sample(rng)]
        } else {
            order[rng.gen_range(order.len() as u64) as usize]
        };
        let kind = roll_kind(rng);
        let rank = if skewed && kind != DmlKind::Insert {
            Some(key_zipf.sample(rng) as u64)
        } else {
            None
        };
        HostileOp::Dml { service, kind, rank }
    };
    let mut ops: Vec<HostileOp> = Vec::with_capacity(n + n / 8 + 4);
    match scenario {
        Scenario::Uniform
        | Scenario::Zipf
        | Scenario::Shuffle
        | Scenario::Duplicate => {
            let flush_every = match scenario {
                Scenario::Shuffle => 32,
                Scenario::Duplicate => 24,
                _ => 16,
            };
            for i in 0..n {
                ops.push(dml(rng));
                if (i + 1) % flush_every == 0 {
                    ops.push(HostileOp::Drain);
                }
            }
        }
        Scenario::Burst => {
            // 48-op flushless bursts alternating with per-op-flushed
            // quiet stretches — the backlog saw-tooth
            let mut i = 0;
            while i < n {
                let burst = 48.min(n - i);
                for _ in 0..burst {
                    ops.push(dml(rng));
                }
                ops.push(HostileOp::Drain);
                i += burst;
                let quiet = 8.min(n - i);
                for _ in 0..quiet {
                    ops.push(dml(rng));
                    ops.push(HostileOp::Drain);
                }
                i += quiet;
            }
        }
        Scenario::LoadStorm => {
            // the hottest service's full table snapshots onto the live
            // topic twice, racing whatever the buffer holds
            let storm_at = [n / 4, n / 2];
            for i in 0..n {
                if storm_at.contains(&i) {
                    ops.push(HostileOp::SnapshotStorm { service: order[0] });
                }
                ops.push(dml(rng));
                if (i + 1) % 16 == 0 {
                    ops.push(HostileOp::Drain);
                }
            }
        }
        Scenario::HotSchemaChange => {
            // 40-op bursts; each change lands at offset 20 into a burst —
            // never on a drain boundary — on the hottest schema
            let changes = cfg.schema_changes.max(1);
            let stride = n.max(1) / (changes + 1);
            let mut change_at: Vec<usize> = (1..=changes)
                .map(|c| ((c * stride) / 40) * 40 + 20)
                .filter(|&at| at < n)
                .collect();
            change_at.dedup();
            for i in 0..n {
                if change_at.contains(&i) {
                    ops.push(HostileOp::SchemaChange { service: order[0] });
                }
                ops.push(dml(rng));
                if (i + 1) % 40 == 0 {
                    ops.push(HostileOp::Drain);
                }
            }
        }
    }
    ops.push(HostileOp::Drain);
    ops
}

/// Bounded out-of-order shuffle: every item lands within `bound`
/// positions of where it started, and items sharing a key keep their
/// relative order (exactly Kafka's guarantee — cross-key reordering only).
///
/// Construction: item `i` gets rank `i + U[0, bound]`; a stable sort by
/// rank displaces nothing by more than `bound`. Per-key order is then
/// restored by reassigning each key's original indices, ascending, to
/// that key's output positions, ascending — a sorted matching, which
/// never increases any item's displacement beyond the bound (swapping two
/// out-of-order assignments moves both items strictly inward).
pub fn shuffle_bounded<T: Clone>(
    items: &[T],
    key_of: impl Fn(&T) -> u64,
    bound: usize,
    rng: &mut Rng,
) -> Vec<T> {
    if bound == 0 || items.len() < 2 {
        return items.to_vec();
    }
    let mut ranked: Vec<(usize, usize)> = items
        .iter()
        .enumerate()
        .map(|(i, _)| (i + rng.gen_range(bound as u64 + 1) as usize, i))
        .collect();
    ranked.sort_by_key(|&(rank, i)| (rank, i));
    let mut slots: Vec<usize> = ranked.into_iter().map(|(_, i)| i).collect();
    // per-key restoration (groups are independent, so HashMap iteration
    // order cannot change the result)
    let mut positions_of: HashMap<u64, Vec<usize>> = HashMap::new();
    for (pos, &orig) in slots.iter().enumerate() {
        positions_of.entry(key_of(&items[orig])).or_default().push(pos);
    }
    for positions in positions_of.values() {
        let mut origs: Vec<usize> =
            positions.iter().map(|&p| slots[p]).collect();
        origs.sort_unstable();
        for (&pos, orig) in positions.iter().zip(origs) {
            slots[pos] = orig;
        }
    }
    slots.into_iter().map(|i| items[i].clone()).collect()
}

/// Producer-retry duplicate delivery: each item is re-published adjacent
/// to its original with probability `p` (a retried produce lands right
/// after the record it duplicates). Returns the expanded batch and the
/// number of duplicates injected.
pub fn duplicate_delivery<T: Clone>(
    items: &[T],
    p: f64,
    rng: &mut Rng,
) -> (Vec<T>, usize) {
    let mut out = Vec::with_capacity(items.len() + items.len() / 4);
    let mut dups = 0;
    for item in items {
        out.push(item.clone());
        if rng.chance(p) {
            out.push(item.clone());
            dups += 1;
        }
    }
    (out, dups)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dml_count(ops: &[HostileOp]) -> usize {
        ops.iter().filter(|o| matches!(o, HostileOp::Dml { .. })).count()
    }

    #[test]
    fn names_round_trip() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::from_name(s.name()), Some(s));
            assert_eq!(format!("{s}"), s.name());
        }
        assert_eq!(Scenario::from_name("nope"), None);
        assert!(!Scenario::HOSTILE.contains(&Scenario::Uniform));
    }

    #[test]
    fn traces_are_deterministic_and_complete() {
        let cfg = PipelineConfig::small();
        for s in Scenario::ALL {
            let a = hostile_trace(&cfg, s, &mut Rng::seed_from(9));
            let b = hostile_trace(&cfg, s, &mut Rng::seed_from(9));
            assert_eq!(a, b, "{s}");
            assert_eq!(dml_count(&a), cfg.trace_events, "{s}");
            assert_eq!(a.last(), Some(&HostileOp::Drain), "{s}");
        }
    }

    #[test]
    fn zipf_trace_concentrates_on_hot_service() {
        let mut cfg = PipelineConfig::small();
        cfg.trace_events = 1000;
        let ops = hostile_trace(&cfg, Scenario::Zipf, &mut Rng::seed_from(3));
        let mut counts = vec![0usize; cfg.n_services];
        for op in &ops {
            if let HostileOp::Dml { service, .. } = op {
                counts[*service] += 1;
            }
        }
        let max = *counts.iter().max().unwrap();
        assert!(
            max * 2 > cfg.trace_events,
            "hot service should take most writes: {counts:?}"
        );
        // hot-key ranks ride along on updates/deletes
        assert!(ops.iter().any(
            |o| matches!(o, HostileOp::Dml { rank: Some(_), .. })
        ));
    }

    #[test]
    fn hot_schema_change_lands_mid_burst() {
        let mut cfg = PipelineConfig::small();
        cfg.trace_events = 240;
        let ops = hostile_trace(
            &cfg,
            Scenario::HotSchemaChange,
            &mut Rng::seed_from(5),
        );
        let at: Vec<usize> = ops
            .iter()
            .enumerate()
            .filter(|(_, o)| matches!(o, HostileOp::SchemaChange { .. }))
            .map(|(i, _)| i)
            .collect();
        assert!(!at.is_empty());
        for i in at {
            assert!(ops[i - 1] != HostileOp::Drain, "change on a boundary");
            assert!(ops[i + 1] != HostileOp::Drain, "change on a boundary");
        }
    }

    #[test]
    fn load_storm_includes_snapshots() {
        let cfg = PipelineConfig::small();
        let ops =
            hostile_trace(&cfg, Scenario::LoadStorm, &mut Rng::seed_from(7));
        let storms = ops
            .iter()
            .filter(|o| matches!(o, HostileOp::SnapshotStorm { .. }))
            .count();
        assert_eq!(storms, 2);
    }

    #[test]
    fn shuffle_bounded_respects_bound_and_key_order() {
        let items: Vec<(u64, usize)> =
            (0..200).map(|i| (i as u64 % 7, i)).collect();
        let mut rng = Rng::seed_from(11);
        let out = shuffle_bounded(&items, |it| it.0, 9, &mut rng);
        // multiset preserved
        let mut a = items.clone();
        let mut b = out.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // displacement bound
        for (pos, it) in out.iter().enumerate() {
            assert!(
                pos.abs_diff(it.1) <= 9,
                "item {it:?} displaced to {pos}"
            );
        }
        // per-key relative order preserved
        for k in 0..7u64 {
            let seq: Vec<usize> =
                out.iter().filter(|it| it.0 == k).map(|it| it.1).collect();
            assert!(seq.windows(2).all(|w| w[0] < w[1]), "key {k}: {seq:?}");
        }
        // and it actually reorders something
        assert_ne!(out, items);
    }

    #[test]
    fn shuffle_bound_zero_is_identity() {
        let items: Vec<(u64, usize)> = (0..20).map(|i| (i as u64, i)).collect();
        let out = shuffle_bounded(&items, |it| it.0, 0, &mut Rng::seed_from(1));
        assert_eq!(out, items);
    }

    #[test]
    fn duplicate_delivery_is_adjacent() {
        let items: Vec<usize> = (0..500).collect();
        let (out, dups) =
            duplicate_delivery(&items, 0.2, &mut Rng::seed_from(13));
        assert_eq!(out.len(), items.len() + dups);
        assert!(dups > 50, "p=0.2 over 500 should inject plenty: {dups}");
        // every duplicate sits right after its original
        let mut seen = 0;
        for (i, v) in out.iter().enumerate() {
            if i > 0 && out[i - 1] == *v {
                seen += 1;
            }
        }
        assert_eq!(seen, dups);
    }
}
