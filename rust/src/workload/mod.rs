//! Workload generation: the simulated EOS/FX landscape (schemata, CDM,
//! databases, mapping matrix) and day traces of CDC events + schema-change
//! storms — the substitute for the paper's production system (DESIGN.md
//! §2, "EOS production traces" row).
//!
//! Everything is seeded and deterministic so paper-figure regenerations
//! are reproducible. [`TraceOp::SchemaChange`] steps resolve through the
//! online evolution lane ([`crate::coordinator::evolution`]): the
//! evolved field list ([`evolved_fields`]) is published as a
//! registry-style change event and applied with one epoch swap while
//! mapping continues.
//!
//! The polite day trace is only half the story: [`adversarial`] layers
//! hostile [`adversarial::Scenario`]s over it (Zipfian skew, burst/drain,
//! bounded reordering, duplicate delivery, initial-load storms,
//! mid-burst schema changes) and [`scenario`] runs them through the full
//! pipeline with conformance invariants.

pub mod adversarial;
pub mod scenario;

use crate::cdm::{CdmType, CdmTree};
use crate::config::PipelineConfig;
use crate::matrix::MappingMatrix;
use crate::schema::{ExtractType, SchemaTree, VersionNo};
use crate::source::{MicroserviceDb, Table};
use crate::util::rng::Rng;

/// A generated microservice landscape.
pub struct Landscape {
    pub tree: SchemaTree,
    pub cdm: CdmTree,
    /// One database per service; one table per database (table ↔ schema).
    pub dbs: Vec<MicroserviceDb>,
    /// Ground-truth mapping matrix `ᵢM`.
    pub matrix: MappingMatrix,
}

const EXT_TYPES: &[ExtractType] = &[
    ExtractType::Int32,
    ExtractType::Int64,
    ExtractType::Float64,
    ExtractType::Varchar,
    ExtractType::Boolean,
    ExtractType::MicroTimestamp,
    ExtractType::Decimal,
];

fn field_name(j: usize) -> String {
    const NAMES: &[&str] = &[
        "id", "value", "currency", "time", "status", "customer", "amount",
        "rate", "due_date", "account", "region", "channel", "score",
        "category", "flag",
    ];
    if j < NAMES.len() {
        NAMES[j].to_string()
    } else {
        format!("col{j}")
    }
}

/// Generate the full landscape for a config.
pub fn generate(cfg: &PipelineConfig) -> Landscape {
    let mut rng = Rng::seed_from(cfg.seed);
    let mut tree = SchemaTree::new();
    let mut cdm = CdmTree::new();

    // --- CDM: business entities, one live version each -----------------
    for e in 0..cfg.n_entities {
        let id = cdm.add_entity(&format!("Entity{e}"));
        let fields: Vec<(String, CdmType, String)> = (0..cfg.attrs_per_entity)
            .map(|j| {
                (
                    format!("{}_{j}", field_name(j)),
                    CdmType::generalize(EXT_TYPES[j % EXT_TYPES.len()]),
                    format!("Business meaning of {} (entity {e})", field_name(j)),
                )
            })
            .collect();
        cdm.add_version(id, &fields);
    }

    // --- Extracting schemata with version histories ---------------------
    for s in 0..cfg.n_services {
        let service = format!("svc{s}");
        let sid = tree.add_schema(
            &format!("{service}.main"),
            &format!("src.{service}.main"),
        );
        let mut fields: Vec<(String, ExtractType, bool)> = (0
            ..cfg.attrs_per_schema)
            .map(|j| {
                (
                    field_name(j),
                    EXT_TYPES[rng.gen_range(EXT_TYPES.len() as u64) as usize],
                    j != 0, // first field is the mandatory key
                )
            })
            .collect();
        tree.add_version(sid, &fields);
        let mut next_fresh = cfg.attrs_per_schema;
        for vi in 1..cfg.versions_per_schema {
            // alternate: add a column / remove the last optional column —
            // the single-attribute-change discipline of §3.3
            if vi % 2 == 1 || fields.len() <= 2 {
                fields.push((
                    field_name(next_fresh),
                    EXT_TYPES[rng.gen_range(EXT_TYPES.len() as u64) as usize],
                    true,
                ));
                next_fresh += 1;
            } else {
                let victim = 1 + rng.gen_range(fields.len() as u64 - 1) as usize;
                fields.remove(victim);
            }
            tree.add_version(sid, &fields);
        }
    }

    // --- Mapping matrix --------------------------------------------------
    let matrix = generate_matrix(&tree, &cdm, cfg, &mut rng);

    // --- Databases (empty; populate() fills rows) ------------------------
    let dbs = (0..cfg.n_services)
        .map(|s| {
            let service = format!("svc{s}");
            let sid = tree.schema_by_name(&format!("{service}.main")).unwrap();
            let live = tree.latest_version(sid).unwrap();
            let mut db = MicroserviceDb::new(&service, &service);
            db.add_table(Table::new("main", sid, live));
            db
        })
        .collect();

    Landscape { tree, cdm, dbs, matrix }
}

/// Build `ᵢM` for a generated tree pair: schema s maps to entity
/// s % n_entities; v1 blocks are seeded 1:1 mappings, later versions copy
/// their predecessor through `≡` (the duplication that makes the matrix
/// both huge and compressible, §5.4.1).
fn generate_matrix(
    tree: &SchemaTree,
    cdm: &CdmTree,
    cfg: &PipelineConfig,
    rng: &mut Rng,
) -> MappingMatrix {
    let mut m = MappingMatrix::new(cdm.n_attr_ids(), tree.n_attr_ids());
    for (s_idx, schema) in tree.schemas().enumerate() {
        let entity = cdm
            .entity_by_name(&format!("Entity{}", s_idx % cfg.n_entities))
            .unwrap();
        let w = *cdm.versions_of(entity).last().unwrap();
        let cv = cdm.version(entity, w).unwrap();
        let versions: Vec<VersionNo> = schema.versions.clone();
        for (vi, &v) in versions.iter().enumerate() {
            let sv = tree.version(schema.id, v).unwrap();
            if vi == 0 {
                // seed block: attr j -> entity row j (1:1), filtered by
                // mapped_fraction
                for (j, &p) in sv.attrs.iter().enumerate() {
                    if j < cv.attrs.len() && rng.chance(cfg.mapped_fraction) {
                        m.set(cv.attrs[j].index(), p.index(), true);
                    }
                }
            } else {
                // copy previous version through equivalences (Alg 5 case 3
                // re-applied as history); fresh attributes occasionally get
                // a new free row (a user completing a semi-automated update)
                let prev = tree.version(schema.id, versions[vi - 1]).unwrap();
                let mut used_rows: Vec<usize> = Vec::new();
                for &p_prev in &prev.attrs {
                    if let Some(p_new) =
                        tree.equivalent_in(p_prev, schema.id, v)
                    {
                        for &q in &cv.attrs {
                            if m.get(q.index(), p_prev.index()) {
                                m.set(q.index(), p_new.index(), true);
                                used_rows.push(q.index());
                            }
                        }
                    }
                }
                // most version updates only duplicate the pattern (§5.4.1);
                // occasionally a user maps the fresh attribute too
                for &p in &sv.attrs {
                    let attr = tree.attr(p);
                    if attr.equiv.is_none()
                        && rng.chance(0.2 * cfg.mapped_fraction)
                    {
                        if let Some(q) = cv
                            .attrs
                            .iter()
                            .map(|a| a.index())
                            .find(|qi| !used_rows.contains(qi))
                        {
                            // ensure 1:1: the column is fresh by construction
                            m.set(q, p.index(), true);
                            used_rows.push(q);
                        }
                    }
                }
            }
        }
    }
    m
}

/// Populate every database table with `rows_per_table` random rows
/// (without emitting CDC events — pre-existing data for snapshot tests).
pub fn populate(landscape: &mut Landscape, rows_per_table: usize, rng: &mut Rng) {
    for db in &mut landscape.dbs {
        for t in 0..db.tables.len() {
            let (schema, version) =
                (db.tables[t].schema, db.tables[t].live_version);
            for k in 0..rows_per_table {
                let row = crate::source::random_row(
                    &landscape.tree,
                    schema,
                    version,
                    k as u64,
                    rng,
                    0.3,
                );
                // direct insert without CDC (historic data)
                let ev = db.apply(
                    &landscape.tree,
                    crate::source::Dml::Insert { table: t, row },
                    crate::message::StateI(0),
                    0,
                );
                debug_assert!(ev.is_some());
            }
        }
    }
}

/// One step of a generated day trace.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceOp {
    /// A DML intent against a service's table; the pipeline resolves it
    /// against current rows.
    Dml { service: usize, kind: DmlKind },
    /// A schema-change storm step: register a new version for the service
    /// (the §3.3 semi-automated workflow trigger).
    SchemaChange { service: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmlKind {
    Insert,
    Update,
    Delete,
}

/// Generate the §7-style day trace: `trace_events` DML intents with a
/// 70/25/5 insert/update/delete mix, interleaved with `schema_changes`
/// evenly spaced storms (the paper: "the DMM-update is triggered several
/// times a day, which evicts all caches").
pub fn day_trace(cfg: &PipelineConfig, rng: &mut Rng) -> Vec<TraceOp> {
    let mut ops = Vec::with_capacity(cfg.trace_events + cfg.schema_changes);
    for _ in 0..cfg.trace_events {
        let service = rng.gen_range(cfg.n_services as u64) as usize;
        let roll = rng.f64();
        let kind = if roll < 0.70 {
            DmlKind::Insert
        } else if roll < 0.95 {
            DmlKind::Update
        } else {
            DmlKind::Delete
        };
        ops.push(TraceOp::Dml { service, kind });
    }
    // interleave schema changes at even spacing
    if cfg.schema_changes > 0 {
        let stride = ops.len().max(1) / (cfg.schema_changes + 1);
        for c in 0..cfg.schema_changes {
            let at = ((c + 1) * stride + c).min(ops.len());
            let service = rng.gen_range(cfg.n_services as u64) as usize;
            ops.insert(at, TraceOp::SchemaChange { service });
        }
    }
    ops
}

/// Evolve one schema by a single attribute change (add a fresh column),
/// returning the new field list — used to resolve `TraceOp::SchemaChange`.
pub fn evolved_fields(
    tree: &SchemaTree,
    schema: crate::schema::SchemaId,
) -> Vec<(String, ExtractType, bool)> {
    let latest = tree.latest_version(schema).expect("schema has versions");
    let mut fields = tree.field_list(schema, latest).expect("live");
    fields.push((
        format!("evo{}", tree.n_attr_ids()),
        ExtractType::Varchar,
        true,
    ));
    fields
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::blocks;
    use crate::matrix::dpm::DpmSet;
    use crate::message::StateI;

    #[test]
    fn generate_is_deterministic() {
        let cfg = PipelineConfig::small();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.tree.n_attr_ids(), b.tree.n_attr_ids());
    }

    #[test]
    fn landscape_shape_matches_config() {
        let cfg = PipelineConfig::small();
        let l = generate(&cfg);
        assert_eq!(l.tree.n_schemas(), cfg.n_services);
        assert_eq!(l.cdm.n_entities(), cfg.n_entities);
        assert_eq!(l.dbs.len(), cfg.n_services);
        for s in l.tree.schemas() {
            assert_eq!(s.versions.len(), cfg.versions_per_schema);
        }
    }

    #[test]
    fn matrix_respects_one_to_one_constraint() {
        let cfg = PipelineConfig::small();
        let l = generate(&cfg);
        // Alg 2 would fail on any constraint violation
        let dpm =
            DpmSet::from_matrix(&l.matrix, &l.tree, &l.cdm, StateI(0)).unwrap();
        assert!(dpm.n_elements() > 0);
    }

    #[test]
    fn versioned_blocks_duplicate_patterns() {
        // later versions must mostly repeat v1's pattern through ≡ —
        // the compressibility the paper exploits
        let cfg = PipelineConfig::small();
        let l = generate(&cfg);
        let dusb = crate::matrix::dusb::DusbSet::from_matrix(
            &l.matrix, &l.tree, &l.cdm, StateI(0),
        )
        .unwrap();
        let dpm =
            DpmSet::from_matrix(&l.matrix, &l.tree, &l.cdm, StateI(0)).unwrap();
        assert!(
            dusb.n_elements() * 2 <= dpm.n_elements(),
            "dusb {} vs dpm {}: version dedupe should save >=50%",
            dusb.n_elements(),
            dpm.n_elements()
        );
    }

    #[test]
    fn most_blocks_are_null() {
        // the paper's 99% null-block deletion premise
        let cfg = PipelineConfig::paper_day();
        let l = generate(&cfg);
        let keys = blocks::all_block_keys(&l.tree, &l.cdm);
        let nonnull = keys
            .iter()
            .filter(|k| {
                let ext = blocks::block_extent(&l.tree, &l.cdm, **k).unwrap();
                !blocks::is_null_block(&l.matrix, &ext)
            })
            .count();
        assert!(
            (nonnull as f64) < keys.len() as f64 * 0.15,
            "nonnull {nonnull}/{}",
            keys.len()
        );
    }

    #[test]
    fn populate_fills_tables() {
        let cfg = PipelineConfig::small();
        let mut l = generate(&cfg);
        let mut rng = Rng::seed_from(1);
        populate(&mut l, 10, &mut rng);
        assert!(l.dbs.iter().all(|db| db.tables[0].len() == 10));
    }

    #[test]
    fn day_trace_mix_and_storms() {
        let cfg = PipelineConfig::paper_day();
        let mut rng = Rng::seed_from(cfg.seed);
        let ops = day_trace(&cfg, &mut rng);
        let dml = ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Dml { .. }))
            .count();
        let changes = ops
            .iter()
            .filter(|o| matches!(o, TraceOp::SchemaChange { .. }))
            .count();
        assert_eq!(dml, cfg.trace_events);
        assert_eq!(changes, cfg.schema_changes);
        let inserts = ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Dml { kind: DmlKind::Insert, .. }))
            .count();
        assert!(inserts as f64 > 0.6 * dml as f64);
    }

    #[test]
    fn evolved_fields_adds_exactly_one() {
        let cfg = PipelineConfig::small();
        let l = generate(&cfg);
        let schema = l.tree.schemas().next().unwrap().id;
        let before = l
            .tree
            .version(schema, l.tree.latest_version(schema).unwrap())
            .unwrap()
            .attrs
            .len();
        let fields = evolved_fields(&l.tree, schema);
        assert_eq!(fields.len(), before + 1);
    }
}
