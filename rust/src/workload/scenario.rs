//! Scenario conformance runner: drives one hostile
//! [`Scenario`](super::adversarial::Scenario) through the full pipeline
//! (sharded mapping lane + per-sink egress groups) and checks the
//! invariant trio every adversary must preserve:
//!
//! 1. **Restart equivalence** — a cold pipeline built with the final
//!    schema that replays the recorded CDC topic verbatim converges to
//!    the same sink state ([`verify_restart_equivalence`]).
//! 2. **Zero silent drops** — every produced record is mapped,
//!    dead-lettered or deduped, and the counters prove it
//!    ([`check_accounting`]).
//! 3. **At-least-once dedupe** — the runner crashes every egress lane
//!    between flush and commit ([`crate::coordinator::egress::SinkHandle::
//!    drain_crash_before_commit`]) and redelivers; backends must absorb
//!    the replay exactly.
//!
//! The runner buffers resolved CDC events and applies the scenario's
//! delivery transforms ([`super::adversarial::shuffle_bounded`],
//! [`super::adversarial::duplicate_delivery`]) at each flush boundary —
//! hostile *delivery*, not hostile data. One seeded [`Rng`] drives trace
//! generation and transforms, so `(seed, scenario)` replays
//! byte-identically.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::adversarial::{
    duplicate_delivery, hostile_trace, shuffle_bounded, HostileOp, Scenario,
};
use crate::config::PipelineConfig;
use crate::coordinator::pipeline::Pipeline;
use crate::coordinator::shard::{run_sharded_session, ShardReport};
use crate::message::cdc::CdcEvent;
use crate::sink::{DwSink, JsonlSink, MlSink};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Relative tolerance for cross-run ML moment comparison: the multiset
/// of observations is identical but cross-key Welford accumulation order
/// differs between a sharded live run and a sequential replay.
const ML_REL_TOL: f64 = 1e-6;

/// Drives one `(cfg, scenario, seed, shards)` combination; see the
/// module docs for what it asserts.
pub struct ScenarioRunner {
    pub cfg: PipelineConfig,
    pub scenario: Scenario,
    /// Seeds the trace + delivery-transform [`Rng`] (independent of the
    /// landscape seed in `cfg.seed`).
    pub seed: u64,
    pub shards: usize,
    /// Crash every egress lane between flush and commit after the
    /// session, then redeliver — doubling deliveries so the sinks'
    /// offset-watermark dedupe is exercised on every run.
    pub exercise_redelivery: bool,
}

/// What one scenario run produced (inputs to the conformance checks).
#[derive(Debug)]
pub struct ScenarioOutcome {
    pub scenario: Scenario,
    pub events_in: u64,
    pub out_messages: u64,
    pub dead_letters: u64,
    /// Records on the CDC topic (= events the pipeline must account for).
    pub published: u64,
    /// Producer-retry duplicates the delivery transform injected.
    pub duplicates_published: usize,
    /// Initial-load rows the snapshot storms published.
    pub snapshot_rows: usize,
    /// Services whose schema evolved, in application order — the cold
    /// replay applies the same log upfront.
    pub schema_change_log: Vec<usize>,
    /// Records applied (but never committed) by the crash exercise.
    pub crash_deliveries: usize,
    /// Event traces the tracer completed (finish or dead-letter); must
    /// equal `events_in` when tracing is on — a missing trace means an
    /// event left the pipeline unobserved.
    pub traces_completed: u64,
    /// Spans lost to the tracer's bounded buffers — surfaced so a drop
    /// is a loud conformance failure, never a silent gap in the export.
    pub spans_dropped: u64,
    pub report: ShardReport,
}

impl ScenarioRunner {
    pub fn new(cfg: PipelineConfig, scenario: Scenario) -> Self {
        let seed = cfg.seed ^ 0xAD5E;
        Self { cfg, scenario, seed, shards: 1, exercise_redelivery: true }
    }

    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Build a pipeline, run the scenario, drain the sinks. The returned
    /// pipeline holds the final state for inspection / verification.
    pub fn run(&self) -> Result<(Pipeline, ScenarioOutcome)> {
        let pipeline = Pipeline::new(self.cfg.clone())?;
        let outcome = self.drive(&pipeline)?;
        Ok((pipeline, outcome))
    }

    /// Run plus the full invariant trio; the conformance-suite entry.
    pub fn run_and_verify(&self) -> Result<ScenarioOutcome> {
        let (pipeline, outcome) = self.run()?;
        check_accounting(&pipeline, &outcome)?;
        verify_restart_equivalence(&pipeline, &outcome, &self.cfg)?;
        Ok(outcome)
    }

    /// Drive the hostile trace against a live shard pool: resolve DMLs
    /// into a buffer; at each flush boundary shuffle within the bound,
    /// inject producer-retry duplicates, publish, dispatch. Schema
    /// changes flush first (their burst is already racing the workers),
    /// snapshot storms publish past the buffer so the initial load races
    /// buffered live CDC.
    fn drive(&self, pipeline: &Pipeline) -> Result<ScenarioOutcome> {
        let mut rng = Rng::seed_from(self.seed);
        let ops = hostile_trace(&self.cfg, self.scenario, &mut rng);
        let params = self.scenario.params();
        let mut buffer: Vec<CdcEvent> = Vec::new();
        let mut duplicates_published = 0usize;
        let mut snapshot_rows = 0usize;
        let mut schema_change_log: Vec<usize> = Vec::new();
        let (report, driven) = run_sharded_session(
            pipeline,
            self.shards,
            |dispatch| -> Result<()> {
                let mut flush = |buffer: &mut Vec<CdcEvent>,
                                 rng: &mut Rng,
                                 dispatch: &mut dyn FnMut()| {
                    if buffer.is_empty() {
                        dispatch();
                        return;
                    }
                    let batch = shuffle_bounded(
                        buffer,
                        |ev| {
                            ev.mapping_payload().map(|m| m.key).unwrap_or(0)
                        },
                        params.shuffle_bound,
                        rng,
                    );
                    let (batch, dups) =
                        duplicate_delivery(&batch, params.duplicate_p, rng);
                    duplicates_published += dups;
                    buffer.clear();
                    for ev in batch {
                        pipeline.publish_event(ev);
                    }
                    dispatch();
                };
                for op in &ops {
                    match op {
                        HostileOp::Dml { service, kind, rank } => {
                            if let Some(ev) =
                                pipeline.resolve_dml(*service, *kind, *rank)?
                            {
                                buffer.push(ev);
                            }
                        }
                        HostileOp::SchemaChange { service } => {
                            flush(&mut buffer, &mut rng, dispatch);
                            pipeline.apply_schema_change(*service)?;
                            schema_change_log.push(*service);
                        }
                        HostileOp::SnapshotStorm { service } => {
                            snapshot_rows +=
                                pipeline.publish_snapshot(*service);
                        }
                        HostileOp::Drain => {
                            flush(&mut buffer, &mut rng, dispatch)
                        }
                    }
                }
                flush(&mut buffer, &mut rng, dispatch);
                Ok(())
            },
        );
        driven?;
        let crash_deliveries = if self.exercise_redelivery {
            pipeline
                .sinks
                .iter()
                .map(|handle| handle.drain_crash_before_commit())
                .sum()
        } else {
            0
        };
        pipeline.drain_sinks();
        Ok(ScenarioOutcome {
            scenario: self.scenario,
            events_in: pipeline.metrics.events_in.get(),
            out_messages: pipeline.metrics.messages_out.get(),
            dead_letters: pipeline.metrics.dead_letters.get(),
            published: pipeline.cdc_topic.total_records(),
            duplicates_published,
            snapshot_rows,
            schema_change_log,
            crash_deliveries,
            traces_completed: pipeline.metrics.trace.traces.get(),
            spans_dropped: pipeline.metrics.trace.spans_dropped.get(),
            report,
        })
    }
}

/// Invariant 2 + 3: zero silent drops and exact at-least-once dedupe,
/// proven by counter conservation. Every CDC record is consumed and
/// either transformed or dead-lettered; every CDM delivery to every sink
/// is applied, deduped or intentionally dropped — nothing vanishes
/// uncounted.
pub fn check_accounting(
    pipeline: &Pipeline,
    outcome: &ScenarioOutcome,
) -> Result<()> {
    let s = outcome.scenario;
    ensure!(
        outcome.events_in == outcome.published,
        "{s}: {} of {} published CDC records consumed",
        outcome.events_in,
        outcome.published
    );
    let transformed = pipeline.metrics.transformations.get();
    ensure!(
        transformed + outcome.dead_letters == outcome.events_in,
        "{s}: {} transformed + {} dead-lettered != {} in",
        transformed,
        outcome.dead_letters,
        outcome.events_in
    );
    ensure!(
        outcome.dead_letters == pipeline.dlq.len() as u64,
        "{s}: dead-letter counter diverged from DLQ contents"
    );
    if pipeline.tracer.enabled() {
        // trace conservation: every consumed event completed exactly one
        // trace, and no span fell out of the bounded buffers unnoticed
        ensure!(
            outcome.traces_completed == outcome.events_in,
            "{s}: {} traces completed for {} events consumed",
            outcome.traces_completed,
            outcome.events_in
        );
        ensure!(
            outcome.spans_dropped == 0,
            "{s}: {} spans dropped by the tracer's bounded buffers",
            outcome.spans_dropped
        );
    }
    let cdm_total = pipeline.out_topic.total_records();
    for handle in &pipeline.sinks {
        let stats = handle.stats();
        // the crash exercise delivered every CDM record twice
        let deliveries =
            if outcome.crash_deliveries > 0 { 2 * cdm_total } else { cdm_total };
        ensure!(
            stats.applied + stats.duplicates + stats.dropped == deliveries,
            "{s}/{}: applied {} + duplicates {} + dropped {} != {} delivered",
            handle.name(),
            stats.applied,
            stats.duplicates,
            stats.dropped,
            deliveries
        );
        ensure!(
            handle.lag() == 0,
            "{s}/{}: egress lag {} after final drain",
            handle.name(),
            handle.lag()
        );
    }
    Ok(())
}

/// Invariant 1: cold-restart equivalence. A fresh pipeline (same config
/// ⇒ same generated landscape) applies the recorded schema-change log
/// upfront — the "restart with the final schema" — then replays the live
/// run's CDC topic **verbatim** (duplicates, reorderings and storms
/// included) and drains once. DW state must match exactly; ML moments up
/// to accumulation-order rounding; the JSONL log per key up to the state
/// stamp (cold maps everything at the final state, live restamped along
/// the way).
pub fn verify_restart_equivalence(
    live: &Pipeline,
    outcome: &ScenarioOutcome,
    cfg: &PipelineConfig,
) -> Result<()> {
    let s = outcome.scenario;
    let cold = Pipeline::new(cfg.clone())?;
    for &service in &outcome.schema_change_log {
        cold.apply_schema_change(service)?;
    }
    for partition in 0..live.cdc_topic.n_partitions() {
        for rec in live.cdc_topic.fetch(partition, 0, usize::MAX) {
            cold.process_event(&rec.value);
        }
    }
    cold.drain_sinks();
    ensure!(
        cold.metrics.dead_letters.get() == outcome.dead_letters,
        "{s}: cold replay dead-lettered {} vs live {}",
        cold.metrics.dead_letters.get(),
        outcome.dead_letters
    );
    if live.sink("dw").is_some() {
        ensure!(
            dw_dump(live) == dw_dump(&cold),
            "{s}: DW state diverged between live run and cold replay"
        );
    }
    if live.sink("ml").is_some() {
        compare_ml(live, &cold, s)?;
    }
    if live.sink("jsonl").is_some() {
        ensure!(
            jsonl_by_key(live) == jsonl_by_key(&cold),
            "{s}: JSONL per-key streams diverged"
        );
    }
    Ok(())
}

/// Canonical DW dump: every materialized row as a sorted line.
pub fn dw_dump(pipeline: &Pipeline) -> Vec<String> {
    pipeline
        .with_sink("dw", |dw: &DwSink| {
            let mut rows: Vec<String> = dw
                .tables()
                .flat_map(|((entity, w), table)| {
                    table.rows().map(move |(key, fields)| {
                        let mut fields: Vec<String> = fields
                            .iter()
                            .map(|(attr, v)| format!("{}={}", attr.0, v.to_string()))
                            .collect();
                        fields.sort();
                        format!(
                            "e{}w{}k{key}:{}",
                            entity.0,
                            w.0,
                            fields.join(",")
                        )
                    })
                })
                .collect();
            rows.sort();
            rows
        })
        .unwrap_or_default()
}

/// ML features keyed `(entity, attr)` → (count, mean, variance).
pub fn ml_features(pipeline: &Pipeline) -> HashMap<(u64, u64), (u64, f64, f64)> {
    pipeline
        .with_sink("ml", |ml: &MlSink| {
            ml.features()
                .map(|((entity, attr), stat)| {
                    (
                        (entity.0 as u64, attr.0 as u64),
                        (stat.count, stat.mean(), stat.variance()),
                    )
                })
                .collect()
        })
        .unwrap_or_default()
}

fn compare_ml(live: &Pipeline, cold: &Pipeline, s: Scenario) -> Result<()> {
    let a = ml_features(live);
    let b = ml_features(cold);
    ensure!(
        a.len() == b.len(),
        "{s}: ML feature sets differ ({} vs {})",
        a.len(),
        b.len()
    );
    for (key, (count, mean, var)) in &a {
        let Some((bc, bm, bv)) = b.get(key) else {
            anyhow::bail!("{s}: ML feature {key:?} missing from cold replay");
        };
        ensure!(
            count == bc,
            "{s}: ML feature {key:?} count {} vs {}",
            count,
            bc
        );
        let close = |x: f64, y: f64| {
            (x - y).abs() <= ML_REL_TOL * (1.0 + x.abs().max(y.abs()))
        };
        ensure!(
            close(*mean, *bm) && close(*var, *bv),
            "{s}: ML feature {key:?} moments diverged: ({mean}, {var}) vs ({bm}, {bv})"
        );
    }
    Ok(())
}

/// Per-key JSONL line streams, with the state stamp normalized away (the
/// only field a legitimate restamp may change).
pub fn jsonl_by_key(pipeline: &Pipeline) -> HashMap<u64, Vec<String>> {
    pipeline
        .with_sink("jsonl", |sink: &JsonlSink| {
            let mut by_key: HashMap<u64, Vec<String>> = HashMap::new();
            for (key, line) in sink.records() {
                by_key.entry(*key).or_default().push(normalized_line(line));
            }
            by_key
        })
        .unwrap_or_default()
}

fn normalized_line(line: &str) -> String {
    let parsed = json::parse(line).expect("sink lines are valid JSON");
    match parsed {
        Json::Obj(entries) => Json::Obj(
            entries.into_iter().filter(|(k, _)| k != "state").collect(),
        )
        .to_string(),
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> PipelineConfig {
        let mut cfg = PipelineConfig::small();
        cfg.trace_events = 96;
        cfg.sinks = vec!["dw".into(), "ml".into(), "jsonl".into()];
        cfg
    }

    #[test]
    fn uniform_scenario_passes_all_invariants() {
        let outcome = ScenarioRunner::new(small_cfg(), Scenario::Uniform)
            .run_and_verify()
            .unwrap();
        assert_eq!(outcome.events_in, 96);
        assert_eq!(outcome.dead_letters, 0);
        assert!(outcome.crash_deliveries > 0, "redelivery was exercised");
        // trace conservation rode along (tracing is on by default)
        assert_eq!(outcome.traces_completed, 96);
        assert_eq!(outcome.spans_dropped, 0);
    }

    #[test]
    fn duplicate_scenario_publishes_more_than_resolved() {
        let outcome = ScenarioRunner::new(small_cfg(), Scenario::Duplicate)
            .run_and_verify()
            .unwrap();
        assert!(outcome.duplicates_published > 0);
        assert_eq!(
            outcome.published,
            96 + outcome.duplicates_published as u64
        );
    }

    #[test]
    fn runner_is_seed_deterministic() {
        let run = || {
            let (p, o) =
                ScenarioRunner::new(small_cfg(), Scenario::Shuffle)
                    .seed(77)
                    .run()
                    .unwrap();
            (dw_dump(&p), o.published)
        };
        assert_eq!(run(), run());
    }
}
