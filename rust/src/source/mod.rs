//! Microservice database + Debezium-sim connector (paper §3, pillar 1),
//! and the ingress half of the pluggable connector API.
//!
//! Substitution for the paper's 80-microservice FX system: each simulated
//! service owns a database with tables whose *live schema* tracks a
//! registered extracting-schema version. DML against a table produces CDC
//! events shaped like fig 2 (before/after images); the connector publishes
//! them to the broker in commit order and supports snapshot mode for
//! initial loads.
//!
//! # The `SourceConnector` trait
//!
//! [`SourceConnector`] is the ingress mirror of
//! [`crate::sink::SinkConnector`]: an object-safe seam the coordinator
//! holds instead of a concrete connector type, so a Debezium-sim, a file
//! replayer, or a real CDC client plug into the pipeline through
//! [`PipelineBuilder::source`](crate::coordinator::pipeline::PipelineBuilder::source)
//! without touching the coordinator core. Implementors publish CDC events
//! in commit order (per-key order is the contract the whole mapping lane
//! rests on), serve table snapshots for initial loads (§3.4/§6.4), and
//! expose cheap counters via [`SourceConnector::snapshot_stats`].
//! [`Connector`] is the built-in Debezium-sim implementation.
//!
//! # The `SchemaChangeSource` trait
//!
//! CDC connectors also observe **schema changes**: Debezium publishes DDL
//! statements to a schema-change topic, and the Apicurio-sim registry
//! emits version events. [`SchemaChangeSource`] is the ingress seam for
//! that control stream — implementors enqueue [`SchemaChangeEvent`]s (a
//! new full field list or a version retirement, with the observed DDL
//! riding along) and the online evolution lane
//! ([`crate::coordinator::evolution::EvolutionController`]) polls and
//! applies them while mapping continues. [`DdlQueue`] is the built-in
//! queue-backed implementation.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::broker::Topic;
use crate::message::cdc::{CdcEvent, CdcOp, CdcSource};
use crate::message::{InMessage, StateI};
use crate::schema::{ExtractType, SchemaId, SchemaTree, VersionNo};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A table row: values in schema-version field order.
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    pub key: u64,
    pub values: Vec<Json>,
}

/// One source table bound to an extracting schema.
#[derive(Debug)]
pub struct Table {
    pub name: String,
    pub schema: SchemaId,
    /// The schema version new writes conform to (bumped on migrations).
    pub live_version: VersionNo,
    rows: BTreeMap<u64, Row>,
}

impl Table {
    pub fn new(name: &str, schema: SchemaId, version: VersionNo) -> Self {
        Self { name: name.to_string(), schema, live_version: version, rows: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn row(&self, key: u64) -> Option<&Row> {
        self.rows.get(&key)
    }

    pub fn keys(&self) -> impl Iterator<Item = u64> + '_ {
        self.rows.keys().copied()
    }
}

/// One microservice database.
pub struct MicroserviceDb {
    pub service: String,
    pub db_name: String,
    pub tables: Vec<Table>,
}

/// A DML operation against a table.
#[derive(Debug, Clone)]
pub enum Dml {
    Insert { table: usize, row: Row },
    Update { table: usize, row: Row },
    Delete { table: usize, key: u64 },
}

impl MicroserviceDb {
    pub fn new(service: &str, db_name: &str) -> Self {
        Self { service: service.to_string(), db_name: db_name.to_string(), tables: Vec::new() }
    }

    pub fn add_table(&mut self, table: Table) -> usize {
        self.tables.push(table);
        self.tables.len() - 1
    }

    fn message_for(
        &self,
        tree: &SchemaTree,
        table: &Table,
        row: &Row,
        state: StateI,
        ts_us: u64,
    ) -> InMessage {
        let sv = tree
            .version(table.schema, table.live_version)
            .expect("live version registered");
        debug_assert_eq!(sv.attrs.len(), row.values.len(), "row width matches live schema");
        InMessage {
            key: row.key,
            schema: table.schema,
            version: table.live_version,
            state,
            ts_us,
            fields: sv.attrs.iter().copied().zip(row.values.iter().cloned()).collect(),
        }
    }

    /// Apply one DML op, returning the CDC event it generates (fig 2
    /// semantics: create has empty before, delete has empty after).
    pub fn apply(
        &mut self,
        tree: &SchemaTree,
        op: Dml,
        state: StateI,
        ts_us: u64,
    ) -> Option<CdcEvent> {
        let (table_idx, cdc_op, before_row, after_row) = match op {
            Dml::Insert { table, row } => {
                let prev = self.tables[table].rows.insert(row.key, row.clone());
                if prev.is_some() {
                    // primary-key violation: roll back, no event
                    let prev = prev.unwrap();
                    self.tables[table].rows.insert(prev.key, prev);
                    return None;
                }
                (table, CdcOp::Create, None, Some(row))
            }
            Dml::Update { table, row } => {
                match self.tables[table].rows.insert(row.key, row.clone()) {
                    Some(prev) => (table, CdcOp::Update, Some(prev), Some(row)),
                    None => {
                        self.tables[table].rows.remove(&row.key);
                        return None; // update of a missing row
                    }
                }
            }
            Dml::Delete { table, key } => {
                match self.tables[table].rows.remove(&key) {
                    Some(prev) => (table, CdcOp::Delete, Some(prev), None),
                    None => return None,
                }
            }
        };
        let table = &self.tables[table_idx];
        Some(CdcEvent {
            op: cdc_op,
            before: before_row.map(|r| self.message_for(tree, table, &r, state, ts_us)),
            after: after_row.map(|r| self.message_for(tree, table, &r, state, ts_us)),
            source: CdcSource {
                connector: "postgresql".into(),
                db: self.db_name.clone(),
                table: table.name.clone(),
            },
            ts_us,
        })
    }

    /// Migrate a table to a new live version; values for attributes absent
    /// in the old version become Null (backward-compatible adds).
    pub fn migrate_table(
        &mut self,
        tree: &SchemaTree,
        table: usize,
        new_version: VersionNo,
    ) {
        let t = &mut self.tables[table];
        let old_sv = tree.version(t.schema, t.live_version).expect("old version");
        let new_sv = tree.version(t.schema, new_version).expect("new version");
        for row in t.rows.values_mut() {
            let mut new_values = Vec::with_capacity(new_sv.attrs.len());
            for &attr in &new_sv.attrs {
                // carry values across equivalences; else null
                let root = tree.equiv_root(attr);
                let old_pos = old_sv
                    .attrs
                    .iter()
                    .position(|a| tree.equiv_root(*a) == root);
                new_values.push(
                    old_pos.map(|i| row.values[i].clone()).unwrap_or(Json::Null),
                );
            }
            row.values = new_values;
        }
        t.live_version = new_version;
    }
}

/// Cheap counters snapshot of one source connector.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SourceStats {
    /// CDC events published to source topics.
    pub published: u64,
    /// Rows emitted through snapshot reads (initial loads).
    pub snapshot_rows: u64,
}

/// An ingress backend: extracts CDC events from source systems and serves
/// snapshot reads for initial loads. Object-safe; see the module docs.
pub trait SourceConnector: Send + Sync {
    /// Stable connector name (topic prefix for the Debezium-sim).
    fn name(&self) -> &str;

    /// Source-topic name for one table (Debezium `prefix.db.table`).
    fn topic_for(&self, db: &MicroserviceDb, table: &Table) -> String;

    /// Publish one event to its topic, keyed by row key (per-key order is
    /// the contract: same key → same partition → commit order preserved).
    fn publish(&self, topic: &Topic<std::sync::Arc<CdcEvent>>, ev: CdcEvent);

    /// Snapshot an entire table as SnapshotRead events (Debezium op "r")
    /// — the initial-load path (§3.4, §6.4).
    fn snapshot(
        &self,
        tree: &SchemaTree,
        db: &MicroserviceDb,
        table_idx: usize,
        state: StateI,
        ts_us: u64,
    ) -> Vec<CdcEvent>;

    /// Counters snapshot; must be cheap and non-blocking.
    fn snapshot_stats(&self) -> SourceStats;
}

/// Debezium-sim connector: publishes CDC events from a database to the
/// broker's source topics in near real-time, and supports snapshot reads
/// for initial loads.
pub struct Connector {
    pub prefix: String,
    published: AtomicU64,
    snapshot_rows: AtomicU64,
}

impl Connector {
    pub fn new(prefix: &str) -> Self {
        Self {
            prefix: prefix.to_string(),
            published: AtomicU64::new(0),
            snapshot_rows: AtomicU64::new(0),
        }
    }
}

impl SourceConnector for Connector {
    fn name(&self) -> &str {
        &self.prefix
    }

    fn topic_for(&self, db: &MicroserviceDb, table: &Table) -> String {
        format!("{}.{}.{}", self.prefix, db.db_name, table.name)
    }

    fn publish(&self, topic: &Topic<std::sync::Arc<CdcEvent>>, ev: CdcEvent) {
        let key = ev
            .mapping_payload()
            .map(|m| m.key)
            .unwrap_or_default();
        topic.produce(key, std::sync::Arc::new(ev));
        self.published.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(
        &self,
        tree: &SchemaTree,
        db: &MicroserviceDb,
        table_idx: usize,
        state: StateI,
        ts_us: u64,
    ) -> Vec<CdcEvent> {
        let table = &db.tables[table_idx];
        let events: Vec<CdcEvent> = table
            .rows
            .values()
            .map(|row| CdcEvent {
                op: CdcOp::SnapshotRead,
                before: None,
                after: Some(db.message_for(tree, table, row, state, ts_us)),
                source: CdcSource {
                    connector: "postgresql".into(),
                    db: db.db_name.clone(),
                    table: table.name.clone(),
                },
                ts_us,
            })
            .collect();
        self.snapshot_rows
            .fetch_add(events.len() as u64, Ordering::Relaxed);
        events
    }

    fn snapshot_stats(&self) -> SourceStats {
        SourceStats {
            published: self.published.load(Ordering::Relaxed),
            snapshot_rows: self.snapshot_rows.load(Ordering::Relaxed),
        }
    }
}

/// The change one [`SchemaChangeEvent`] proposes.
#[derive(Debug, Clone, PartialEq)]
pub enum SchemaChange {
    /// A new version of the schema: the registry-style *full* field list
    /// `(name, type, optional)` the next version should carry.
    AddVersion { fields: Vec<(String, ExtractType, bool)> },
    /// Retirement of one registered version (Alg-5 case 1 trigger).
    DropVersion { v: VersionNo },
}

/// A Debezium-style schema-change event observed on the wire: the DDL the
/// connector saw plus the structured change the evolution lane validates
/// and applies.
#[derive(Debug, Clone, PartialEq)]
pub struct SchemaChangeEvent {
    pub schema: SchemaId,
    /// Human-readable DDL (the schema-change-topic payload).
    pub ddl: String,
    pub change: SchemaChange,
    /// Observation timestamp, µs.
    pub ts_us: u64,
}

impl SchemaChangeEvent {
    /// A new-version event carrying the full field list.
    pub fn add_version(
        schema: SchemaId,
        fields: Vec<(String, ExtractType, bool)>,
        ts_us: u64,
    ) -> Self {
        let ddl = format!(
            "ALTER TABLE s{} -- registry proposes {} attribute(s)",
            schema.0,
            fields.len()
        );
        Self { schema, ddl, change: SchemaChange::AddVersion { fields }, ts_us }
    }

    /// A version-retirement event.
    pub fn drop_version(schema: SchemaId, v: VersionNo, ts_us: u64) -> Self {
        Self {
            schema,
            ddl: format!("DROP VERSION v{} OF s{}", v.0, schema.0),
            change: SchemaChange::DropVersion { v },
            ts_us,
        }
    }
}

/// An ingress backend for the schema-change control stream (Debezium DDL
/// topic / registry webhook sim). Object-safe; the evolution lane polls
/// it between mapping batches, so implementations must be cheap and
/// non-blocking.
pub trait SchemaChangeSource: Send + Sync {
    /// Stable source name (metrics/debug label).
    fn name(&self) -> &str;

    /// Enqueue one observed change, in arrival order.
    fn publish_change(&self, ev: SchemaChangeEvent);

    /// Drain the events observed since the last poll, in arrival order.
    fn poll_changes(&self) -> Vec<SchemaChangeEvent>;

    /// Events observed but not yet polled — the `epoch_lag` gauge feed.
    fn pending(&self) -> usize;
}

/// Built-in queue-backed [`SchemaChangeSource`]: the Debezium
/// schema-change-topic simulation the pipeline wires by default. Tests
/// and the CLI push events in; the evolution lane drains them.
#[derive(Debug, Default)]
pub struct DdlQueue {
    queue: Mutex<VecDeque<SchemaChangeEvent>>,
    observed: AtomicU64,
}

impl DdlQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total events ever observed (monotonic; `pending` is the backlog).
    pub fn observed(&self) -> u64 {
        self.observed.load(Ordering::Relaxed)
    }
}

impl SchemaChangeSource for DdlQueue {
    fn name(&self) -> &str {
        "ddl"
    }

    fn publish_change(&self, ev: SchemaChangeEvent) {
        self.queue.lock().unwrap().push_back(ev);
        self.observed.fetch_add(1, Ordering::Relaxed);
    }

    fn poll_changes(&self) -> Vec<SchemaChangeEvent> {
        self.queue.lock().unwrap().drain(..).collect()
    }

    fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

/// Generate a random row for a schema version (used by workloads/tests).
pub fn random_row(
    tree: &SchemaTree,
    schema: SchemaId,
    version: VersionNo,
    key: u64,
    rng: &mut Rng,
    null_prob: f64,
) -> Row {
    use crate::schema::ExtractType as T;
    let sv = tree.version(schema, version).expect("version");
    let values = sv
        .attrs
        .iter()
        .map(|&a| {
            let attr = tree.attr(a);
            if attr.optional && rng.chance(null_prob) {
                return Json::Null;
            }
            match attr.ty {
                T::Int32 => Json::Num(rng.gen_range(1 << 20) as f64),
                T::Int64 | T::MicroTimestamp => {
                    Json::Num((1_600_000_000_000_000u64 + rng.gen_range(1 << 40)) as f64)
                }
                T::Float32 | T::Float64 | T::Decimal => {
                    Json::Num((rng.gen_range(1_000_000) as f64) / 100.0)
                }
                T::Boolean => Json::Bool(rng.chance(0.5)),
                T::Varchar => Json::Str(format!("v{}", rng.gen_range(100_000))),
                T::Bytes => Json::Str(format!("{:016x}", rng.next_u64())),
                T::DebeziumDate => Json::Num(rng.gen_range(20_000) as f64),
                T::Uuid => Json::Str(format!(
                    "{:08x}-{:04x}-4{:03x}-{:04x}-{:012x}",
                    rng.gen_range(u32::MAX as u64),
                    rng.gen_range(u16::MAX as u64),
                    rng.gen_range(1 << 12),
                    rng.gen_range(u16::MAX as u64),
                    rng.gen_range(1u64 << 48),
                )),
            }
        })
        .collect();
    Row { key, values }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::ExtractType;

    fn setup() -> (SchemaTree, MicroserviceDb, usize) {
        let mut tree = SchemaTree::new();
        let s = tree.add_schema("payments.incoming", "src.payments.incoming");
        let v = tree.add_version(
            s,
            &[
                ("id".into(), ExtractType::Int64, false),
                ("value".into(), ExtractType::Decimal, true),
            ],
        );
        let mut db = MicroserviceDb::new("payments", "payments");
        let t = db.add_table(Table::new("incoming", s, v));
        (tree, db, t)
    }

    #[test]
    fn insert_emits_create_with_empty_before() {
        let (tree, mut db, t) = setup();
        let row = Row { key: 1, values: vec![Json::Num(1.0), Json::Num(10.0)] };
        let ev = db
            .apply(&tree, Dml::Insert { table: t, row }, StateI(0), 5)
            .unwrap();
        assert_eq!(ev.op, CdcOp::Create);
        assert!(ev.before.is_none());
        assert!(ev.is_well_formed());
        assert_eq!(db.tables[t].len(), 1);
    }

    #[test]
    fn update_carries_both_images() {
        let (tree, mut db, t) = setup();
        let r1 = Row { key: 1, values: vec![Json::Num(1.0), Json::Num(10.0)] };
        let r2 = Row { key: 1, values: vec![Json::Num(1.0), Json::Num(20.0)] };
        db.apply(&tree, Dml::Insert { table: t, row: r1 }, StateI(0), 1);
        let ev = db
            .apply(&tree, Dml::Update { table: t, row: r2 }, StateI(0), 2)
            .unwrap();
        assert_eq!(ev.op, CdcOp::Update);
        let before = ev.before.unwrap();
        let after = ev.after.unwrap();
        assert_eq!(before.fields[1].1.as_f64(), Some(10.0));
        assert_eq!(after.fields[1].1.as_f64(), Some(20.0));
    }

    #[test]
    fn delete_emits_before_image_only() {
        let (tree, mut db, t) = setup();
        let r1 = Row { key: 9, values: vec![Json::Num(9.0), Json::Null] };
        db.apply(&tree, Dml::Insert { table: t, row: r1 }, StateI(0), 1);
        let ev = db
            .apply(&tree, Dml::Delete { table: t, key: 9 }, StateI(0), 2)
            .unwrap();
        assert_eq!(ev.op, CdcOp::Delete);
        assert!(ev.after.is_none());
        assert!(db.tables[t].is_empty());
    }

    #[test]
    fn invalid_dml_produces_no_event() {
        let (tree, mut db, t) = setup();
        assert!(db
            .apply(&tree, Dml::Delete { table: t, key: 1 }, StateI(0), 1)
            .is_none());
        let row = Row { key: 1, values: vec![Json::Num(1.0), Json::Null] };
        assert!(db
            .apply(&tree, Dml::Update { table: t, row }, StateI(0), 1)
            .is_none());
        // duplicate insert
        let row = Row { key: 2, values: vec![Json::Num(2.0), Json::Null] };
        db.apply(&tree, Dml::Insert { table: t, row: row.clone() }, StateI(0), 1)
            .unwrap();
        assert!(db
            .apply(&tree, Dml::Insert { table: t, row }, StateI(0), 2)
            .is_none());
        assert_eq!(db.tables[t].len(), 1);
    }

    #[test]
    fn snapshot_reads_all_rows() {
        let (tree, mut db, t) = setup();
        for k in 0..5 {
            let row = Row { key: k, values: vec![Json::Num(k as f64), Json::Null] };
            db.apply(&tree, Dml::Insert { table: t, row }, StateI(0), k);
        }
        let conn = Connector::new("src");
        let snap = conn.snapshot(&tree, &db, t, StateI(0), 99);
        assert_eq!(snap.len(), 5);
        assert!(snap.iter().all(|e| e.op == CdcOp::SnapshotRead && e.is_well_formed()));
        assert_eq!(
            conn.snapshot_stats(),
            SourceStats { published: 0, snapshot_rows: 5 }
        );
        assert_eq!(conn.topic_for(&db, &db.tables[t]), "src.payments.incoming");
    }

    #[test]
    fn migration_carries_equivalent_values() {
        let (mut tree, mut db, t) = setup();
        let s = db.tables[t].schema;
        let row = Row { key: 1, values: vec![Json::Num(1.0), Json::Num(10.0)] };
        db.apply(&tree, Dml::Insert { table: t, row }, StateI(0), 1);
        // v2 adds "currency"
        let v2 = tree.add_version(
            s,
            &[
                ("id".into(), ExtractType::Int64, false),
                ("value".into(), ExtractType::Decimal, true),
                ("currency".into(), ExtractType::Varchar, true),
            ],
        );
        db.migrate_table(&tree, t, v2);
        assert_eq!(db.tables[t].live_version, v2);
        let r = db.tables[t].row(1).unwrap();
        assert_eq!(r.values[0].as_f64(), Some(1.0));
        assert_eq!(r.values[1].as_f64(), Some(10.0));
        assert!(r.values[2].is_null());
    }

    #[test]
    fn ddl_queue_preserves_arrival_order() {
        let q = DdlQueue::new();
        assert_eq!(q.pending(), 0);
        q.publish_change(SchemaChangeEvent::add_version(
            SchemaId(1),
            vec![("a".into(), ExtractType::Int64, true)],
            5,
        ));
        q.publish_change(SchemaChangeEvent::drop_version(
            SchemaId(1),
            VersionNo(1),
            6,
        ));
        assert_eq!(q.pending(), 2);
        assert_eq!(q.observed(), 2);
        let drained = q.poll_changes();
        assert_eq!(drained.len(), 2);
        assert!(matches!(drained[0].change, SchemaChange::AddVersion { .. }));
        assert!(matches!(
            drained[1].change,
            SchemaChange::DropVersion { v: VersionNo(1) }
        ));
        assert!(drained[0].ddl.contains("ALTER TABLE"));
        assert_eq!(q.pending(), 0);
        assert!(q.poll_changes().is_empty());
    }

    #[test]
    fn random_rows_match_width() {
        let (tree, db, t) = setup();
        let mut rng = Rng::seed_from(1);
        let table = &db.tables[t];
        let row = random_row(&tree, table.schema, table.live_version, 7, &mut rng, 0.3);
        assert_eq!(row.values.len(), 2);
    }
}
