//! Offline stand-in for the `anyhow` error crate, implementing exactly the
//! subset the metl crate uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` macros. No registry access
//! is available in the build image (see DESIGN.md §2), so this vendored
//! path dependency keeps `use anyhow::...` call sites source-compatible.
//!
//! Differences from the real crate: the error is a flattened message (the
//! source chain is folded into the string at construction) and `Context`
//! accepts any `Display` error, which is a superset of the real bound.

use std::fmt;

/// A flattened, message-carrying error value.
pub struct Error(String);

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// `?` conversion from any standard error. `Error` itself deliberately does
// not implement `std::error::Error`, exactly like the real anyhow, so this
// blanket impl cannot overlap the reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// `anyhow::Result<T>`: a `std::result::Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors and empty options.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily computed context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<u32> {
        let n: u32 = s.parse().context("not a number")?;
        if n == 0 {
            bail!("zero is not allowed (got {s:?})");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_and_context() {
        assert_eq!(parse_num("7").unwrap(), 7);
        let err = parse_num("x").unwrap_err();
        assert!(err.to_string().starts_with("not a number:"));
        let err = parse_num("0").unwrap_err();
        assert!(err.to_string().contains("zero is not allowed"));
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u8).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x = {}", 5).to_string(), "x = 5");
        let k = "key";
        assert_eq!(anyhow!("missing {k}").to_string(), "missing key");
    }

    #[test]
    fn context_on_anyhow_result() {
        let inner: Result<()> = Err(anyhow!("inner"));
        let outer = inner.context("outer").unwrap_err();
        assert_eq!(outer.to_string(), "outer: inner");
    }
}
