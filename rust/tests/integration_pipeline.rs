//! Integration tests over the full pipeline: fig-1 data flow, §3.4 sync
//! semantics, at-least-once delivery, horizontal scaling equivalence, the
//! hybrid store restart, and the wire codec through the broker.

use std::sync::Arc;

use metl::broker::Consumer;
use metl::config::PipelineConfig;
use metl::sink::{DwSink, MlSink};
use metl::coordinator::pipeline::Pipeline;
use metl::coordinator::scaler;
use metl::message::codec;
use metl::message::StateI;
use metl::util::rng::Rng;
use metl::workload::{self, DmlKind, TraceOp};

fn trace(cfg: &PipelineConfig, n: usize, changes: usize) -> Vec<TraceOp> {
    let mut c = cfg.clone();
    c.trace_events = n;
    c.schema_changes = changes;
    let mut rng = Rng::seed_from(cfg.seed);
    workload::day_trace(&c, &mut rng)
}

#[test]
fn full_day_trace_paper_shape() {
    let cfg = PipelineConfig::paper_day();
    let ops = trace(&cfg, 400, 3);
    let p = Pipeline::new(cfg).unwrap();
    let report = p.run_trace(&ops).unwrap();
    assert_eq!(report.events, 400);
    assert_eq!(report.dmm_updates, 3);
    assert_eq!(report.dead_letters, 0);
    assert_eq!(p.state.current(), StateI(3));
    // sinks saw data
    assert!(p.with_sink("dw", |dw: &DwSink| dw.total_rows()).unwrap() > 0);
    assert!(p.with_sink("ml", |ml: &MlSink| ml.observations).unwrap() > 0);
    // the mapping latency channel recorded every transformation
    assert_eq!(p.metrics.map_latency.count(), 400);
}

/// At-least-once: a crashed sink consumer (poll without commit) re-reads
/// the same records; the DW stays correct because upserts are idempotent.
#[test]
fn at_least_once_redelivery_is_idempotent() {
    let cfg = PipelineConfig::small();
    let p = Pipeline::new(cfg).unwrap();
    for _ in 0..30 {
        p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
            .unwrap();
    }
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    loop {
        let batch = consumer.poll(64);
        if batch.is_empty() {
            break;
        }
        for (_, rec) in &batch {
            p.process_event(&rec.value);
        }
        consumer.commit();
    }
    // the DW's own consumer group crashes after applying: offsets reset,
    // everything re-delivers, idempotent upserts absorb it — while the ML
    // group's offsets are untouched by the DW replay
    let dw_handle = p.sink("dw").unwrap();
    let first = dw_handle.drain();
    assert!(first > 0);
    let rows_after_first =
        p.with_sink("dw", |dw: &DwSink| dw.total_rows()).unwrap();
    // "restart": reset this group to the beginning, re-deliver everything
    dw_handle.reset_to_beginning();
    let second = dw_handle.drain();
    assert_eq!(first, second, "full redelivery");
    let (rows, dupes) = p
        .with_sink("dw", |dw: &DwSink| (dw.total_rows(), dw.total_duplicates()))
        .unwrap();
    assert_eq!(rows, rows_after_first, "idempotent upserts");
    assert_eq!(dupes as usize, second, "all re-applies deduped");
    // the ML group still has the full topic ahead of it
    assert_eq!(p.sink("ml").unwrap().lag(), p.out_topic.total_records());
}

/// Horizontal scaling must be semantically transparent: same outputs
/// reach the DW whether 1 or 4 instances drain the backlog.
#[test]
fn scaled_processing_equivalent_to_single() {
    let build = || {
        let cfg = PipelineConfig::small();
        let p = Pipeline::new(cfg).unwrap();
        for i in 0..120 {
            p.resolve_op(&TraceOp::Dml {
                service: i % 4,
                kind: DmlKind::Insert,
            })
            .unwrap();
        }
        p
    };
    let p1 = build();
    let p4 = build();
    scaler::run_scaled(&p1, 1);
    scaler::run_scaled(&p4, 4);
    p1.drain_sinks();
    p4.drain_sinks();
    assert_eq!(
        p1.metrics.messages_out.get(),
        p4.metrics.messages_out.get()
    );
    let dw_state = |p: &Pipeline| {
        p.with_sink("dw", |dw: &DwSink| (dw.total_rows(), dw.total_upserts()))
            .unwrap()
    };
    assert_eq!(dw_state(&p1), dw_state(&p4));
}

/// §3.4: events extracted under state i are still mappable after the DMM
/// moves to i+1 (restamp retry), and the retry counter records it.
#[test]
fn events_across_state_transition_survive() {
    let cfg = PipelineConfig::small();
    let p = Pipeline::new(cfg).unwrap();
    // queue events at state 0
    for _ in 0..10 {
        p.resolve_op(&TraceOp::Dml { service: 2, kind: DmlKind::Insert })
            .unwrap();
    }
    // schema change on a DIFFERENT service moves global state to 1
    p.apply_schema_change(3).unwrap();
    assert_eq!(p.state.current(), StateI(1));
    // now process the stale-state backlog
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    loop {
        let batch = consumer.poll(64);
        if batch.is_empty() {
            break;
        }
        for (_, rec) in &batch {
            p.process_event(&rec.value);
        }
        consumer.commit();
    }
    assert_eq!(p.metrics.dead_letters.get(), 0);
    assert_eq!(p.metrics.sync_retries.get(), 10);
    assert!(p.metrics.messages_out.get() > 0);
}

/// The store restart path reproduces the live DMM including updates.
#[test]
fn store_restart_reproduces_dmm() {
    let dir = metl::util::tmp::TestDir::new("it-store");
    let cfg = PipelineConfig::small();
    let p = Pipeline::new(cfg).unwrap().with_store(dir.path()).unwrap();
    p.apply_schema_change(0).unwrap();
    p.apply_schema_change(1).unwrap();
    let live = p.dmm.snapshot();
    // simulate restart: wipe, restore from store
    p.dmm.publish(Arc::new(metl::matrix::dpm::DpmSet::new(StateI(0))));
    assert!(p.restore_from_store().unwrap());
    let restored = p.dmm.snapshot();
    assert!(live.same_elements(&restored));
    assert_eq!(restored.state, StateI(2));
    // audit trail has both updates
    assert_eq!(p.store.as_ref().unwrap().read_log().unwrap().len(), 2);
}

/// Wire-level check: a CDC envelope serialized to JSON survives the trip
/// through codec encode/decode and maps to the same outputs (the broker
/// in production carries bytes; the codec is the boundary).
#[test]
fn codec_roundtrip_preserves_mapping() {
    let cfg = PipelineConfig::small();
    let p = Pipeline::new(cfg).unwrap();
    p.resolve_op(&TraceOp::Dml { service: 1, kind: DmlKind::Insert })
        .unwrap();
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    let batch = consumer.poll(1);
    let ev = &batch[0].1.value;
    let land = p.landscape.read().unwrap();
    let wire = codec::encode_cdc(ev, &land.tree).to_string();
    let back = codec::decode_cdc(&wire, &land.tree).unwrap();
    assert_eq!(&back, &**ev);
    drop(land);
    let direct = p.map_event(ev).unwrap();
    let via_wire = p.map_event(&back).unwrap();
    assert_eq!(direct, via_wire);
    assert!(!direct.is_empty());
}

/// Reverse search and version progression views work on live pipelines.
#[test]
fn inspection_views_on_live_pipeline() {
    let cfg = PipelineConfig::small();
    let p = Pipeline::new(cfg).unwrap();
    p.apply_schema_change(0).unwrap();
    let land = p.landscape.read().unwrap();
    let dpm = p.dmm.snapshot();
    let entity = land.cdm.entities().next().unwrap().id;
    let w = *land.cdm.versions_of(entity).last().unwrap();
    let text = metl::coordinator::inspect::reverse_search(
        &dpm, &land.tree, &land.cdm, entity, w,
    );
    assert!(text.contains("reverse search"));
    let schema = land.tree.schemas().next().unwrap().id;
    let text = metl::coordinator::inspect::version_progression(
        &dpm, &land.tree, &land.cdm, schema,
    );
    // the evolved version appears in the progression
    assert!(text.contains(&format!("v{}", cfg_versions() + 1)));
}

fn cfg_versions() -> u32 {
    PipelineConfig::small().versions_per_schema as u32
}
