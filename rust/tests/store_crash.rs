//! Kill-at-every-write-point fault injection over the durable matrix
//! store. A counting dry run sizes the sweep, then the same scenario is
//! replayed once per mutating filesystem operation — power-cut and
//! torn-write flavours — killing the "process" at exactly that op. After
//! every crash the directory is reopened with real IO and recovery must
//! reproduce the cold-built DMM for however many updates turned durable:
//!
//!   acked <= recovered <= attempted
//!
//! (an update whose WAL commit returned is *acked* and must never be
//! lost; an update cut down mid-persist may or may not have reached the
//! log, but recovery must land on a consistent prefix either way).

use std::path::Path;
use std::sync::Arc;

use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::message::StateI;
use metl::metrics::StoreMetrics;
use metl::store::{FaultIo, FaultMode, MatrixStore, RealIo, StoreConfig, StoreIo};
use metl::util::tmp::TestDir;

/// Schema changes attempted per scenario, round-robin over the services.
const CHANGES: usize = 5;

fn cfg() -> PipelineConfig {
    PipelineConfig::small()
}

fn store_cfg() -> StoreConfig {
    // threshold 2 puts snapshot segment writes, manifest swaps and GC
    // inside the sweep, so those write points are crash-tested too
    StoreConfig { segment_update_threshold: 2, ..Default::default() }
}

fn open_store(dir: &Path, io: Arc<dyn StoreIo>) -> anyhow::Result<MatrixStore> {
    MatrixStore::open_with(
        dir,
        store_cfg(),
        io,
        Arc::new(StoreMetrics::default()),
    )
}

/// Run the scenario against `io`: attach a store to a fresh pipeline and
/// apply [`CHANGES`] schema changes. Returns how many were acknowledged
/// (an `Ok` from `apply_schema_change` means the WAL commit returned).
fn run_scenario(dir: &Path, io: Arc<dyn StoreIo>) -> usize {
    let p = Pipeline::new(cfg()).unwrap();
    let store = match open_store(dir, io) {
        Ok(s) => s,
        Err(_) => return 0, // crashed opening the store
    };
    let p = match p.attach_store(store) {
        Ok(p) => p,
        Err(_) => return 0, // crashed writing the initial snapshot
    };
    let mut acked = 0;
    for i in 0..CHANGES {
        if p.apply_schema_change(i % 4).is_ok() {
            acked += 1;
        }
    }
    acked
}

/// Reopen `dir` with real IO and recover. Returns the pipeline and the
/// number of durable WAL records found.
fn recover_pipeline(dir: &Path) -> (Pipeline, usize) {
    let store = open_store(dir, Arc::new(RealIo::default())).unwrap();
    let recovered = store.wal_records().len();
    let p = Pipeline::new(cfg()).unwrap().attach_store(store).unwrap();
    assert!(p.restore_from_store().unwrap());
    (p, recovered)
}

/// The recovered pipeline must equal a cold build that applied the first
/// `n` changes of the same deterministic sequence.
fn assert_equivalent(recovered: &Pipeline, n: usize, ctx: &str) {
    let cold = Pipeline::new(cfg()).unwrap();
    for i in 0..n {
        cold.apply_schema_change(i % 4).unwrap();
    }
    assert_eq!(
        recovered.state.current(),
        cold.state.current(),
        "{ctx}: state diverged after {n} recovered changes"
    );
    assert_eq!(recovered.state.current(), StateI(n as u64));
    assert!(
        recovered.dmm.snapshot().same_elements(&cold.dmm.snapshot()),
        "{ctx}: recovered DMM != cold DMM after {n} changes"
    );
}

#[test]
fn kill_at_every_write_point_loses_no_acked_update() {
    // dry run in counting mode sizes the sweep
    let count_dir = TestDir::new("crash-count");
    let counter = Arc::new(FaultIo::counting());
    let full = run_scenario(
        count_dir.path(),
        Arc::clone(&counter) as Arc<dyn StoreIo>,
    );
    assert_eq!(full, CHANGES, "fault-free run must ack every change");
    let total_ops = counter.ops_attempted();
    assert!(
        total_ops > 20,
        "sweep unexpectedly small: {total_ops} write points"
    );

    for mode in [FaultMode::Power, FaultMode::Torn] {
        for n in 1..=total_ops {
            let ctx = format!("{mode:?} crash at write op {n}/{total_ops}");
            let dir = TestDir::new(&format!("crash-{mode:?}-{n}"));
            let io = Arc::new(FaultIo::new(n, mode));
            let acked =
                run_scenario(dir.path(), Arc::clone(&io) as Arc<dyn StoreIo>);
            assert!(io.did_crash(), "{ctx}: fault never fired");
            // reopen with real IO: recovery must succeed at every point,
            // i.e. no torn segment/manifest is ever observable
            let (p, recovered) = recover_pipeline(dir.path());
            assert!(
                acked <= recovered && recovered <= CHANGES,
                "{ctx}: acked {acked}, recovered {recovered}"
            );
            assert_equivalent(&p, recovered, &ctx);
        }
    }
}

/// StateI(0) recovery (crash before any change) is not a special case:
/// the initial snapshot alone restores the ground-truth DMM.
#[test]
fn recovery_of_untouched_store_is_initial_state() {
    let dir = TestDir::new("crash-initial");
    {
        let _p = Pipeline::new(cfg()).unwrap().with_store(dir.path()).unwrap();
        // killed before any schema change
    }
    let (p, recovered) = recover_pipeline(dir.path());
    assert_eq!(recovered, 0);
    assert_equivalent(&p, 0, "no changes");
}

/// Single-schema point recovery goes through the sparse index and must
/// read under 10% of the store's total bytes (the acceptance bound).
#[test]
fn point_recovery_reads_fraction_of_store() {
    let dir = TestDir::new("crash-point");
    let mut c = PipelineConfig::small();
    c.n_services = 24;
    c.n_entities = 12;
    let p = Pipeline::new(c.clone()).unwrap().with_store(dir.path()).unwrap();
    // a WAL tail past the initial snapshot
    p.apply_schema_change(0).unwrap();
    p.apply_schema_change(1).unwrap();
    let store = p.store.as_ref().unwrap();
    let schema = {
        let land = p.landscape.read().unwrap();
        land.dbs[5].tables[0].schema
    };
    let pr = store.recover_schema(schema).unwrap().unwrap();
    assert_eq!(pr.schema, schema);
    assert!(pr.bytes_read > 0);
    assert!(!pr.versions.is_empty());
    assert!(pr.groups > 0);
    assert!(
        pr.bytes_read * 10 < pr.store_bytes,
        "point recovery read {} of {} store bytes (>= 10%)",
        pr.bytes_read,
        pr.store_bytes
    );

    // full recovery on a fresh instance stays inside the configured
    // budget and replays exactly the WAL tail
    let p2 = Pipeline::new(c).unwrap().with_store(dir.path()).unwrap();
    assert!(p2.restore_from_store().unwrap());
    assert_eq!(p2.metrics.store.replayed_updates.get(), 2);
    assert_eq!(p2.state.current(), StateI(2));
    assert!(p2.dmm.snapshot().same_elements(&p.dmm.snapshot()));
    let budget = p2.store.as_ref().unwrap().config().recovery_budget_ms;
    assert!(
        p2.metrics.store.recovery_ms.get() <= budget,
        "recovery took {}ms, budget {}ms",
        p2.metrics.store.recovery_ms.get(),
        budget
    );
}
