//! End-to-end test of the online schema-evolution lane: a live change
//! storm (add an attribute + remove an attribute + one retype that must
//! be rejected) applied mid-stream while 4 shards keep mapping produces
//! the same warehouse state as a cold restart that saw the final schema
//! before any traffic — zero dropped or mis-mapped messages, the epoch
//! gauge incremented exactly once per accepted change.
//!
//! DML is driven with deterministic values (a pure function of attribute
//! name + key) instead of the pipeline's seeded generator, so the live
//! and cold runs write byte-identical rows wherever their schemas agree.

use metl::config::PipelineConfig;
use metl::coordinator::evolution::ChangeOutcome;
use metl::coordinator::pipeline::Pipeline;
use metl::coordinator::shard;
use metl::matrix::dpm::DpmSet;
use metl::message::StateI;
use metl::schema::{ExtractType, SchemaId};
use metl::sink::DwSink;
use metl::source::{Dml, Row, SchemaChangeEvent};
use metl::util::json::Json;
use metl::workload::Landscape;

fn evo_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::small();
    cfg.sinks = vec!["dw".into()];
    cfg
}

/// Deterministic, non-null value for one attribute — identical across
/// runs and independent of the attribute's position in the version.
fn value_for(ty: ExtractType, key: u64, name: &str) -> Json {
    match ty {
        ExtractType::Varchar | ExtractType::Bytes | ExtractType::Uuid => {
            Json::Str(format!("{name}-{key}"))
        }
        ExtractType::Boolean => Json::Bool(key % 2 == 0),
        _ => Json::Num((key * 31 + name.len() as u64) as f64),
    }
}

/// Apply one deterministic DML against a service's table (at whatever
/// schema version is live right now) and publish the CDC event.
fn push_dml(p: &Pipeline, service: usize, key: u64, update: bool) {
    let mut land = p.landscape.write().unwrap();
    let state = p.state.current();
    let Landscape { tree, dbs, .. } = &mut *land;
    let db = &mut dbs[service];
    let (schema, version) = (db.tables[0].schema, db.tables[0].live_version);
    let sv = tree.version(schema, version).unwrap();
    let values: Vec<Json> = sv
        .attrs
        .iter()
        .map(|&a| {
            let at = tree.attr(a);
            value_for(at.ty, key, &at.name)
        })
        .collect();
    let row = Row { key, values };
    let dml = if update {
        Dml::Update { table: 0, row }
    } else {
        Dml::Insert { table: 0, row }
    };
    let ev = db
        .apply(tree, dml, state, key.wrapping_mul(1000))
        .expect("dml applies");
    p.connector().publish(&p.cdc_topic, ev);
}

/// The latest registered field list of a schema.
fn fields_of(p: &Pipeline, schema: SchemaId) -> Vec<(String, ExtractType, bool)> {
    let land = p.landscape.read().unwrap();
    let latest = land.tree.latest_version(schema).unwrap();
    land.tree.field_list(schema, latest).unwrap()
}

fn push_change(
    p: &Pipeline,
    schema: SchemaId,
    fields: Vec<(String, ExtractType, bool)>,
) -> ChangeOutcome {
    p.evolution
        .source()
        .publish_change(SchemaChangeEvent::add_version(schema, fields, 0));
    p.evolution.pump(p).pop().unwrap()
}

/// The three-step change storm against `schema`: add one optional
/// attribute, remove one optional attribute, retype the key attribute
/// (the retype must be rejected under `Compatibility::Full`). Returns
/// the three outcomes.
fn change_storm(p: &Pipeline, schema: SchemaId) -> [ChangeOutcome; 3] {
    // (1) add one optional attribute
    let mut add = fields_of(p, schema);
    add.push(("evolved_col".into(), ExtractType::Varchar, true));
    let o1 = push_change(p, schema, add);
    // (2) remove one optional attribute (the one the source retired)
    let mut remove = fields_of(p, schema);
    let victim = remove
        .iter()
        .position(|(name, _, _)| name == "evolved_col")
        .expect("the evolved attribute to remove");
    remove.remove(victim);
    let o2 = push_change(p, schema, remove);
    // (3) retype the key attribute — incompatible, must be rejected
    let mut retype = fields_of(p, schema);
    retype[0].1 = if retype[0].1 == ExtractType::Varchar {
        ExtractType::Int64
    } else {
        ExtractType::Varchar
    };
    let o3 = push_change(p, schema, retype);
    [o1, o2, o3]
}

/// The materialized warehouse state, canonically ordered.
type DwDump = Vec<(u32, u32, u64, Vec<(u32, Json)>)>;

fn dw_dump(p: &Pipeline) -> DwDump {
    let mut out: DwDump = p
        .with_sink("dw", |dw: &DwSink| {
            let mut rows = Vec::new();
            for ((entity, w), table) in dw.tables() {
                for (key, fields) in table.rows() {
                    let mut fields: Vec<(u32, Json)> = fields
                        .iter()
                        .map(|(q, v)| (q.0, v.clone()))
                        .collect();
                    fields.sort_by_key(|(q, _)| *q);
                    rows.push((entity.0, w.0, key, fields));
                }
            }
            rows
        })
        .unwrap();
    out.sort_by(|a, b| (a.0, a.1, a.2).cmp(&(b.0, b.1, b.2)));
    out
}

/// Drive the deterministic traffic through a live 4-shard pool: phase-1
/// inserts (keys 1..=60) are dispatched, then `mid` runs while the
/// workers are still mapping them (the live run applies the change storm
/// there; the cold run is a no-op because its storm already ran), then
/// phase-3 updates every phase-1 key and inserts fresh keys (61..=100).
fn run_traffic<R>(p: &Pipeline, mid: impl FnOnce(&Pipeline) -> R) -> R {
    let (report, out) = shard::run_sharded_session(p, 4, |dispatch| {
        for key in 1..=60u64 {
            push_dml(p, (key % 4) as usize, key, false);
        }
        dispatch();
        // mid-stream: the workers are still chewing the dispatched backlog
        let out = mid(p);
        for key in 1..=60u64 {
            push_dml(p, (key % 4) as usize, key, true);
        }
        for key in 61..=100u64 {
            push_dml(p, (key % 4) as usize, key, false);
        }
        out
    });
    assert_eq!(report.processed, 160);
    assert_eq!(report.shards, 4);
    p.drain_sinks();
    out
}

#[test]
fn live_change_storm_matches_cold_restart_across_4_shards() {
    // ---- live run: the storm lands while 4 shards drain the backlog ----
    let live = Pipeline::new(evo_cfg()).unwrap();
    let schema = live.landscape.read().unwrap().dbs[0].tables[0].schema;
    let [o1, o2, o3] = run_traffic(&live, |p| change_storm(p, schema));
    assert!(o1.is_applied(), "add accepted: {o1:?}");
    assert!(o2.is_applied(), "remove accepted: {o2:?}");
    assert!(
        matches!(&o3, ChangeOutcome::Rejected { reason, .. }
            if reason.contains("type changes")),
        "retype rejected: {o3:?}"
    );

    // zero dropped or mis-mapped messages, one epoch per accepted change
    assert_eq!(live.metrics.dead_letters.get(), 0);
    assert_eq!(live.dlq.len(), 0);
    assert_eq!(live.metrics.events_in.get(), 160);
    assert_eq!(live.metrics.dmm_epoch.get(), 2);
    assert_eq!(live.metrics.dmm_updates.get(), 2);
    assert_eq!(live.metrics.rejected_changes.get(), 1);
    assert_eq!(live.state.current(), StateI(2));
    assert_eq!(live.metrics.update_latency.count(), 2);

    // the live DMM equals a recompute from the mirrored ground truth
    {
        let land = live.landscape.read().unwrap();
        let recomputed = DpmSet::from_matrix(
            &land.matrix,
            &land.tree,
            &land.cdm,
            live.state.current(),
        )
        .unwrap();
        assert!(live.dmm.snapshot().same_elements(&recomputed));
    }

    // ---- cold restart: same changes applied before any traffic --------
    let cold = Pipeline::new(evo_cfg()).unwrap();
    let [c1, c2, c3] = change_storm(&cold, schema);
    assert!(c1.is_applied() && c2.is_applied() && !c3.is_applied());
    run_traffic(&cold, |_| ());
    assert_eq!(cold.metrics.dead_letters.get(), 0);

    // identical final schema trees...
    assert_eq!(fields_of(&live, schema), fields_of(&cold, schema));
    // ...and identical warehouse contents: every phase-1 key was
    // re-written post-change, so both runs materialize the same rows
    let live_dw = dw_dump(&live);
    let cold_dw = dw_dump(&cold);
    assert!(!live_dw.is_empty());
    assert_eq!(live_dw, cold_dw);
}

#[test]
fn in_band_unknown_version_heals_mid_stream() {
    // the source migrates before the control event reaches METL: rows
    // arrive stamped with a (schema, version) the DMM has no column for
    let p = Pipeline::new(evo_cfg()).unwrap();
    let (schema, v_new) = {
        let mut land = p.landscape.write().unwrap();
        let schema = land.dbs[0].tables[0].schema;
        let latest = land.tree.latest_version(schema).unwrap();
        let mut fields = land.tree.field_list(schema, latest).unwrap();
        fields.push(("late_registry_col".into(), ExtractType::Varchar, true));
        let v = land.tree.add_version(schema, &fields);
        let Landscape { tree, dbs, .. } = &mut *land;
        dbs[0].migrate_table(tree, 0, v);
        (schema, v)
    };
    for key in 1..=10u64 {
        push_dml(&p, 0, key, false);
    }
    let report = shard::run_sharded_drain(&p, 2);
    assert_eq!(report.processed, 10);
    // the lane patched the column in-band: no drops, one epoch, state+1
    assert_eq!(p.metrics.dead_letters.get(), 0);
    assert_eq!(p.evolution.in_band_updates(), 1);
    assert!(!p.dmm.snapshot().column(schema, v_new).is_empty());
    assert_eq!(p.metrics.dmm_epoch.get(), 1);
    assert_eq!(p.state.current(), StateI(1));
    p.drain_sinks();
    let rows = p
        .with_sink("dw", |dw: &DwSink| {
            dw.tables().map(|(_, t)| t.len()).sum::<usize>()
        })
        .unwrap();
    assert!(rows > 0);
}

#[test]
fn rejected_change_leaves_mapping_untouched() {
    let p = Pipeline::new(evo_cfg()).unwrap();
    let schema = p.landscape.read().unwrap().dbs[0].tables[0].schema;
    let before_fields = fields_of(&p, schema);
    let mut retype = before_fields.clone();
    retype[0].1 = if retype[0].1 == ExtractType::Varchar {
        ExtractType::Int64
    } else {
        ExtractType::Varchar
    };
    let outcome = push_change(&p, schema, retype);
    assert!(matches!(outcome, ChangeOutcome::Rejected { .. }));
    assert_eq!(p.metrics.rejected_changes.get(), 1);
    assert_eq!(p.metrics.dmm_epoch.get(), 0);
    assert_eq!(p.metrics.dmm_updates.get(), 0);
    assert_eq!(p.state.current(), StateI(0));
    assert_eq!(fields_of(&p, schema), before_fields);
    // traffic keeps flowing at the old state with zero retries or drops
    for key in 1..=8u64 {
        push_dml(&p, 0, key, false);
    }
    let report = shard::run_sharded_drain(&p, 2);
    assert_eq!(report.processed, 8);
    assert_eq!(p.metrics.dead_letters.get(), 0);
    assert_eq!(p.metrics.sync_retries.get(), 0);
}

#[test]
fn targeted_eviction_keeps_unaffected_columns_warm() {
    // single-lane variant: after an accepted change on schema A, the
    // shared cache still serves schema B's column without a rebuild
    let p = Pipeline::new(evo_cfg()).unwrap();
    let schema_a = p.landscape.read().unwrap().dbs[0].tables[0].schema;
    // warm the cache for both schemas
    for key in 1..=8u64 {
        push_dml(&p, 0, key, false);
        push_dml(&p, 1, key, false);
    }
    let mut consumer =
        metl::broker::Consumer::new(p.cdc_topic.clone(), 0, 1);
    for (_, rec) in consumer.poll(usize::MAX) {
        p.process_event(&rec.value);
    }
    let warm_len = p.cache.len();
    assert!(warm_len >= 2);
    // one accepted change on schema A
    let mut add = fields_of(&p, schema_a);
    add.push(("warm_test_col".into(), ExtractType::Varchar, true));
    assert!(push_change(&p, schema_a, add).is_applied());
    // targeted eviction dropped at most the affected column
    assert!(p.cache.len() >= warm_len - 1);
    assert_eq!(
        p.cache
            .stats
            .targeted_evictions
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    assert_eq!(
        p.cache.stats.evictions.load(std::sync::atomic::Ordering::Relaxed),
        0
    );
    // schema B's column is served as a hit under the new state
    let hits_before =
        p.cache.stats.hits.load(std::sync::atomic::Ordering::Relaxed);
    push_dml(&p, 1, 99, false);
    for (_, rec) in consumer.poll(usize::MAX) {
        p.process_event(&rec.value);
    }
    assert!(
        p.cache.stats.hits.load(std::sync::atomic::Ordering::Relaxed)
            > hits_before
    );
}
