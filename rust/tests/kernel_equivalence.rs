//! Equivalence suite for the native block-permutation kernel: over seeded
//! random landscapes and random message batches (nulls, deletes, unmapped
//! columns), the native lane, the scalar Alg-6 lane and the Alg-1 baseline
//! must produce identical `OutMessage` sets — and warming a plan cache
//! across a mid-batch epoch swap must equal a cold restart against the
//! updated DMM.

use std::sync::Arc;

use metl::cache::DcpmCache;
use metl::config::PipelineConfig;
use metl::mapper::baseline::BaselineMapper;
use metl::mapper::kernel::KernelMode;
use metl::mapper::parallel::ParallelMapper;
use metl::mapper::MapError;
use metl::matrix::dpm::DpmSet;
use metl::matrix::update::{prepare_update, ChangeCase};
use metl::message::{InMessage, OutMessage, StateI};
use metl::util::rng::Rng;
use metl::workload::{self, Landscape};

/// Randomized config within paper-plausible bounds (mirrors
/// `prop_invariants::random_cfg`).
fn random_cfg(rng: &mut Rng) -> PipelineConfig {
    let mut cfg = PipelineConfig::small();
    cfg.n_services = 2 + rng.gen_range(6) as usize;
    cfg.attrs_per_schema = 3 + rng.gen_range(8) as usize;
    cfg.versions_per_schema = 1 + rng.gen_range(6) as usize;
    cfg.n_entities = 1 + rng.gen_range(4) as usize;
    cfg.attrs_per_entity = 3 + rng.gen_range(10) as usize;
    cfg.mapped_fraction = 0.2 + rng.f64() * 0.7;
    cfg.seed = rng.next_u64();
    cfg
}

/// A random message for (schema, version), with nulls at `null_prob` and
/// occasionally an extra field carrying an attribute no mapping column
/// knows (the kernel must skip out-of-range slots, not index past the
/// bitset).
fn random_msg(
    land: &Landscape,
    schema: metl::schema::SchemaId,
    version: metl::schema::VersionNo,
    key: u64,
    state: StateI,
    rng: &mut Rng,
) -> InMessage {
    let sv = land.tree.version(schema, version).unwrap();
    let row = metl::source::random_row(
        &land.tree, schema, version, key, rng, 0.4,
    );
    let mut fields: Vec<_> =
        sv.attrs.iter().copied().zip(row.values).collect();
    if rng.chance(0.25) {
        // an unmapped column id far outside every version's range
        fields.push((
            metl::schema::AttrId(90_000 + rng.gen_range(100) as u32),
            metl::util::json::Json::Num(1.0),
        ));
    }
    InMessage { key, schema, version, state, ts_us: 0, fields }
}

fn map_sorted(
    mapper: &ParallelMapper,
    msg: &InMessage,
) -> Result<Vec<OutMessage>, MapError> {
    mapper.map(msg).map(|mut outs| {
        outs.sort_by_key(|o| (o.entity, o.version));
        outs
    })
}

/// Three-way agreement: native ≡ scalar ≡ dense-filtered Alg 1 over random
/// landscapes × random batches.
#[test]
fn prop_native_scalar_baseline_agree() {
    let mut meta = Rng::seed_from(0x6E47_1BE);
    for trial in 0..12 {
        let cfg = random_cfg(&mut meta);
        let land = workload::generate(&cfg);
        let dpm = Arc::new(
            DpmSet::from_matrix(&land.matrix, &land.tree, &land.cdm, StateI(0))
                .unwrap(),
        );
        let native = ParallelMapper::with_threads(
            Arc::clone(&dpm),
            Arc::new(DcpmCache::new(StateI(0))),
            1,
        )
        .with_kernel(KernelMode::Native);
        let scalar = ParallelMapper::with_threads(
            Arc::clone(&dpm),
            Arc::new(DcpmCache::new(StateI(0))),
            1,
        )
        .with_kernel(KernelMode::Scalar);
        let baseline = BaselineMapper::new(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        );
        let mut rng = Rng::seed_from(cfg.seed ^ 0xBA7C);
        for k in 0..25u64 {
            let s_idx = rng.gen_range(cfg.n_services as u64) as usize;
            let node = land.tree.schemas().nth(s_idx).unwrap();
            let v = *rng.choose(&node.versions).unwrap();
            let msg =
                random_msg(&land, node.id, v, k, StateI(0), &mut rng);
            // native and scalar agree bit for bit — same Ok order, same Err
            assert_eq!(
                native.map(&msg),
                scalar.map(&msg),
                "trial {trial} msg {k}: native vs scalar"
            );
            // both agree with the densified Alg-1 ground truth; a version
            // with zero mapped blocks is UnknownColumn on the dense lanes
            // while Alg 1 emits all-null outputs — both mean "nothing
            // reaches the CDM"
            let fast = match map_sorted(&native, &msg) {
                Ok(outs) => outs,
                Err(MapError::UnknownColumn { .. }) => vec![],
                Err(e) => panic!("trial {trial} msg {k}: {e}"),
            };
            let mut slow: Vec<OutMessage> = baseline
                .map(&msg)
                .unwrap()
                .into_iter()
                .map(|o| OutMessage {
                    fields: o
                        .fields
                        .into_iter()
                        .filter(|(_, val)| !val.is_null())
                        .collect(),
                    ..o
                })
                .filter(|o| !o.fields.is_empty())
                .collect();
            slow.sort_by_key(|o| (o.entity, o.version));
            assert_eq!(fast, slow, "trial {trial} msg {k}: vs baseline");
        }
    }
}

/// Mid-batch epoch swap ≡ cold restart: warm the plan cache, apply an
/// Alg-5 update with **targeted** eviction (only the changed column's plan
/// drops; the rest stay warm), and require every post-swap output to equal
/// a cold mapper built directly over the new DMM.
#[test]
fn prop_epoch_swap_equals_cold_restart() {
    let mut meta = Rng::seed_from(0x5AFE_CA5E);
    for trial in 0..8 {
        let cfg = random_cfg(&mut meta);
        let mut land = workload::generate(&cfg);
        let dpm0 = DpmSet::from_matrix(
            &land.matrix, &land.tree, &land.cdm, StateI(0),
        )
        .unwrap();
        let warm_cache = Arc::new(DcpmCache::new(StateI(0)));
        let mut warm = ParallelMapper::with_threads(
            Arc::new(dpm0.clone()),
            Arc::clone(&warm_cache),
            1,
        )
        .with_kernel(KernelMode::Native);

        // phase 1: warm every column's plan
        let mut rng = Rng::seed_from(cfg.seed ^ 0x77A5);
        let schemas: Vec<_> =
            land.tree.schemas().map(|s| (s.id, s.versions.clone())).collect();
        for (schema, versions) in &schemas {
            for &v in versions {
                let msg =
                    random_msg(&land, *schema, v, 1, StateI(0), &mut rng);
                let _ = warm.map(&msg);
            }
        }
        assert!(
            !warm_cache.plans.is_empty(),
            "trial {trial}: warm-up compiled no plans"
        );

        // phase 2: an Alg-5 case-3 change, published with targeted eviction
        let schema = schemas[trial % schemas.len()].0;
        let fields = workload::evolved_fields(&land.tree, schema);
        let v_new = land.tree.add_version(schema, &fields);
        let (dpm1, _report) = prepare_update(
            &dpm0,
            &land.tree,
            &land.cdm,
            ChangeCase::AddedSchemaVersion { schema, v: v_new },
            StateI(1),
        );
        warm_cache.advance(StateI(1), Some(&[(schema, v_new)]));
        warm.replace_dpm(Arc::new(dpm1.clone()));

        // phase 3: every output after the swap equals a cold restart
        let cold = ParallelMapper::with_threads(
            Arc::new(dpm1),
            Arc::new(DcpmCache::new(StateI(1))),
            1,
        )
        .with_kernel(KernelMode::Native);
        let hits_before =
            warm_cache.plans.stats.hits.load(std::sync::atomic::Ordering::Relaxed);
        for (schema, versions) in &schemas {
            for &v in versions {
                for k in 0..3u64 {
                    let msg = random_msg(
                        &land, *schema, v, 10 + k, StateI(1), &mut rng,
                    );
                    assert_eq!(
                        warm.map(&msg),
                        cold.map(&msg),
                        "trial {trial}: swap ≠ cold restart ({schema:?} v{})",
                        v.0
                    );
                }
            }
        }
        // the new version's column maps identically too
        let msg = random_msg(&land, schema, v_new, 99, StateI(1), &mut rng);
        assert_eq!(warm.map(&msg), cold.map(&msg), "trial {trial}: new column");
        // targeted eviction kept unaffected plans warm: the post-swap pass
        // must have hit the plan cache, not recompiled everything
        let hits_after =
            warm_cache.plans.stats.hits.load(std::sync::atomic::Ordering::Relaxed);
        assert!(
            hits_after > hits_before,
            "trial {trial}: post-swap mapping never hit a warm plan"
        );
    }
}

/// Full-pipeline determinism across lanes: the same seeded day trace
/// (inserts, updates, deletes, schema-change storms) through a native and
/// a scalar pipeline yields identical CDM topic contents.
#[test]
fn day_trace_is_kernel_invariant() {
    use metl::broker::Consumer;
    use metl::coordinator::pipeline::{OutRecord, Pipeline};

    let run = |kernel: KernelMode| {
        let mut cfg = PipelineConfig::small();
        cfg.kernel = kernel;
        let mut rng = Rng::seed_from(cfg.seed);
        let ops = workload::day_trace(&cfg, &mut rng);
        let p = Pipeline::new(cfg).unwrap();
        let report = p.run_trace(&ops).unwrap();
        let mut consumer: Consumer<OutRecord> =
            Consumer::new(p.out_topic.clone(), 0, 1);
        let mut records: Vec<(metl::message::cdc::CdcOp, OutMessage)> =
            consumer
                .poll(usize::MAX)
                .into_iter()
                .map(|(_, rec)| (rec.value.0, rec.value.1.clone()))
                .collect();
        records.sort_by_key(|(_, o)| (o.key, o.entity, o.version, o.ts_us));
        (report, records)
    };
    let (rn, native) = run(KernelMode::Native);
    let (rs, scalar) = run(KernelMode::Scalar);
    assert_eq!(rn.events, rs.events);
    assert_eq!(rn.out_messages, rs.out_messages);
    assert_eq!(rn.dead_letters, rs.dead_letters);
    assert_eq!(rn.dmm_updates, rs.dmm_updates);
    assert!(!native.is_empty());
    assert_eq!(native, scalar);
}
