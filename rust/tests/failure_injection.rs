//! Failure-injection tests: the §3.4 "distributed systems produce problems
//! of their own" lane — out-of-sync states, unknown versions, malformed
//! envelopes, store corruption, constraint violations, consumer crashes.

use std::sync::Arc;

use metl::broker::Consumer;
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::matrix::blocks::{self, BlockExtent};
use metl::matrix::dpm::DpmSet;
use metl::matrix::MappingMatrix;
use metl::message::cdc::{CdcEvent, CdcOp, CdcSource};
use metl::message::{InMessage, StateI};
use metl::schema::VersionNo;
use metl::util::json::Json;
use metl::workload::{DmlKind, TraceOp};

fn src() -> CdcSource {
    CdcSource { connector: "pg".into(), db: "x".into(), table: "t".into() }
}

/// A message referencing a schema version METL never learned about must
/// dead-letter, not crash or silently drop.
#[test]
fn unknown_schema_version_dead_letters() {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    let land = p.landscape.read().unwrap();
    let schema = land.dbs[0].tables[0].schema;
    let sv = land
        .tree
        .version(schema, VersionNo(1))
        .unwrap()
        .clone();
    drop(land);
    let ghost = CdcEvent {
        op: CdcOp::Create,
        before: None,
        after: Some(InMessage {
            key: 1,
            schema,
            version: VersionNo(250), // never registered
            state: StateI(0),
            ts_us: 0,
            fields: vec![(sv.attrs[0], Json::Num(1.0))],
        }),
        source: src(),
        ts_us: 0,
    };
    p.process_event(&Arc::new(ghost));
    assert_eq!(p.metrics.dead_letters.get(), 1);
    assert_eq!(p.dlq.len(), 1);
    // the DLQ can be drained for reprocessing after a fix
    let drained = p.dlq.drain();
    assert_eq!(drained[0].event.op, CdcOp::Create);
    assert!(p.dlq.is_empty());
}

/// Deeply out-of-sync messages (future state) still restamp-retry; the
/// mapping only fails if the column is genuinely missing.
#[test]
fn future_state_message_recovers() {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
        .unwrap();
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    let batch = consumer.poll(1);
    let mut ev = (*batch[0].1.value).clone();
    if let Some(after) = &mut ev.after {
        after.state = StateI(40); // from a future configuration
    }
    p.process_event(&Arc::new(ev));
    assert_eq!(p.metrics.sync_retries.get(), 1);
    assert_eq!(p.metrics.dead_letters.get(), 0);
}

/// Malformed wire payloads are decode errors, not panics.
#[test]
fn malformed_wire_payloads_rejected() {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    let land = p.landscape.read().unwrap();
    for garbage in [
        "",
        "{",
        "[1,2,3]",
        r#"{"payload": 5}"#,
        r#"{"payload": {"op": "zz", "source": {}}}"#,
        r#"{"payload": {"op": "c", "before": null, "after": {"schemaId": 0,
            "version": 1, "payload": {"ghost": 1}}, "source": {}}}"#,
    ] {
        assert!(
            metl::message::codec::decode_cdc(garbage, &land.tree).is_err(),
            "{garbage}"
        );
    }
}

/// A corrupted manifest or segment fails loudly on restore (they are
/// rename-swapped atomically, so corruption there is operator-level
/// damage, not a crash artifact); the pipeline keeps the live DMM. A
/// corrupt WAL *tail* is the expected crash artifact and is truncated
/// silently on reopen instead.
#[test]
fn corrupted_store_fails_loudly() {
    let dir = metl::util::tmp::TestDir::new("fi-store");
    let p = Pipeline::new(PipelineConfig::small())
        .unwrap()
        .with_store(dir.path())
        .unwrap();
    let manifest = dir.join("MANIFEST.json");
    let good = std::fs::read(&manifest).unwrap();
    // corrupt the manifest: reopening the store fails loudly
    std::fs::write(&manifest, "{\"segment\": [{\"bad\"").unwrap();
    assert!(metl::store::MatrixStore::open(dir.path()).is_err());
    // valid JSON with the wrong shape also errors
    std::fs::write(&manifest, "{\"state\": 3}").unwrap();
    assert!(metl::store::MatrixStore::open(dir.path()).is_err());
    // live DMM untouched throughout
    assert!(p.dmm.snapshot().n_elements() > 0);
    // restore the manifest but truncate the segment: loud restore failure
    std::fs::write(&manifest, &good).unwrap();
    let seg = {
        let m = p.store.as_ref().unwrap().manifest().unwrap();
        dir.join(&m.segment)
    };
    let seg_bytes = std::fs::read(&seg).unwrap();
    std::fs::write(&seg, &seg_bytes[..seg_bytes.len() / 2]).unwrap();
    let p2 = Pipeline::new(PipelineConfig::small()).unwrap();
    let p2 = p2
        .attach_store(metl::store::MatrixStore::open(dir.path()).unwrap())
        .unwrap();
    assert!(p2.restore_from_store().is_err());
    // a torn WAL tail is tolerated: valid prefix survives, tail drops
    std::fs::write(&seg, &seg_bytes).unwrap();
    std::fs::write(dir.join("wal.log"), [0xDE, 0xAD, 0xBE, 0xEF, 0x01, 0x02])
        .unwrap();
    let store = metl::store::MatrixStore::open(dir.path()).unwrap();
    assert!(store.wal_records().is_empty());
}

/// 1:1 constraint violations (double-mapped attribute) are rejected by
/// Alg 2 with a precise diagnosis, as §4.5 demands.
#[test]
fn constraint_violation_rejected_with_diagnosis() {
    let mut m = MappingMatrix::new(4, 4);
    m.set(0, 0, true);
    m.set(0, 1, true); // c0 fed by two attributes
    let ext = BlockExtent { rows: 0..4, cols: 0..4 };
    let err = blocks::largest_permutation(&m, &ext).unwrap_err();
    assert_eq!(err.kind, "row");
    assert_eq!(err.index, 0);
    // the greedy import path salvages a valid sub-permutation instead
    let (kept, dropped) = blocks::largest_permutation_greedy(&m, &ext);
    assert_eq!(kept.len(), 1);
    assert_eq!(dropped, 1);
}

/// A consumer crash between poll and commit redelivers events; METL's
/// counters show the duplicates, the DW absorbs them.
#[test]
fn consumer_crash_redelivery() {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    for _ in 0..10 {
        p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
            .unwrap();
    }
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    // first attempt: process everything but "crash" before commit
    let batch = consumer.poll(64);
    assert_eq!(batch.len(), 10);
    for (_, rec) in &batch {
        p.process_event(&rec.value);
    }
    consumer.rewind_to_committed(); // crash + restart
    let batch = consumer.poll(64);
    assert_eq!(batch.len(), 10, "redelivered");
    for (_, rec) in &batch {
        p.process_event(&rec.value);
    }
    consumer.commit();
    assert_eq!(p.metrics.events_in.get(), 20); // at-least-once: 2x processed
    // the sinks deduplicate by key+payload
    p.drain_sinks();
    let (rows, dupes) = p
        .with_sink("dw", |dw: &metl::sink::DwSink| {
            (dw.total_rows(), dw.total_duplicates())
        })
        .unwrap();
    assert_eq!(rows, 10);
    assert!(dupes > 0);
}

/// A registered version's column vanishing from the DMM mid-stream no
/// longer dead-letters: the in-band evolution lane re-derives the column
/// from the previous version (Alg-5 case 3) and the event maps against
/// the fresh epoch. Events of a version the registry *never* saw still
/// dead-letter — the §3.4 offset-reset + initial-load recovery applies,
/// exercised here by re-deriving the DMM from ground truth and replaying.
#[test]
fn version_deletion_mid_stream() {
    let p = Pipeline::new(PipelineConfig::small()).unwrap();
    p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
        .unwrap();
    let land = p.landscape.read().unwrap();
    let schema = land.dbs[0].tables[0].schema;
    let live = land.dbs[0].tables[0].live_version;
    drop(land);
    // drop the live version's column from the DMM (operator mistake sim)
    {
        let mut dpm = (*p.dmm.snapshot()).clone();
        dpm.remove_column(schema, live);
        p.dmm.publish(Arc::new(dpm));
        p.cache.evict_all(p.state.current());
    }
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    for (_, rec) in consumer.poll(64) {
        p.process_event(&rec.value);
    }
    // the in-band lane healed the column: no dead letters, one patch epoch
    assert_eq!(p.dlq.len(), 0);
    assert_eq!(p.evolution.in_band_updates(), 1);
    assert!(!p.dmm.snapshot().column(schema, live).is_empty());
    assert!(p.metrics.messages_out.get() > 0);

    // a version the registry never saw cannot heal: it dead-letters, and
    // the recovery is re-deriving the DMM from ground truth + DLQ replay
    let rogue = Arc::new(metl::message::cdc::CdcEvent {
        op: metl::message::cdc::CdcOp::Create,
        before: None,
        after: Some(metl::message::InMessage {
            key: 123,
            schema,
            version: metl::schema::VersionNo(99),
            state: p.state.current(),
            ts_us: 1,
            fields: vec![(
                metl::schema::AttrId(0),
                metl::util::json::Json::Num(1.0),
            )],
        }),
        source: metl::message::cdc::CdcSource {
            connector: "postgresql".into(),
            db: "svc0".into(),
            table: "main".into(),
        },
        ts_us: 1,
    });
    p.process_event(&rogue);
    assert_eq!(p.dlq.len(), 1, "unregistered version dead-letters");
    // recovery: restore the DMM (re-derive from ground truth) keeps the
    // pipeline mappable for registered traffic
    {
        let land = p.landscape.read().unwrap();
        let dpm = DpmSet::from_matrix(
            &land.matrix,
            &land.tree,
            &land.cdm,
            p.state.current(),
        )
        .unwrap();
        p.dmm.publish(Arc::new(dpm));
        p.cache.evict_all(p.state.current());
    }
    p.resolve_op(&TraceOp::Dml { service: 0, kind: DmlKind::Insert })
        .unwrap();
    let before_dead = p.metrics.dead_letters.get();
    for (_, rec) in consumer.poll(64) {
        p.process_event(&rec.value);
    }
    assert_eq!(p.metrics.dead_letters.get(), before_dead);
}
