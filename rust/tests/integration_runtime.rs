//! Integration tests over the PJRT runtime: the XLA bulk lane must be
//! output-equivalent to the Alg-6 lane on randomized landscapes (the
//! cross-layer contract between L1/L2 kernels and the L3 coordinator).
//!
//! All tests skip gracefully when `artifacts/` is absent (run
//! `make artifacts` first); `make test` always builds artifacts.

use std::path::PathBuf;

use metl::config::PipelineConfig;
use metl::coordinator::batcher::InitialLoader;
use metl::coordinator::pipeline::Pipeline;
use metl::runtime::BulkRuntime;
use metl::util::rng::Rng;
use metl::workload;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_all_variants() {
    let dir = require_artifacts!();
    let rt = BulkRuntime::load(&dir).unwrap();
    assert!(rt.n_variants() >= 2, "256 and 1024 batch variants");
    let (p, q) = rt.block_dims();
    assert_eq!((p, q), (128, 128));
    assert_eq!(rt.platform, "cpu");
}

/// The mapping function on the MXU path: presence = M·x and src indices
/// match a host-side evaluation for random sub-permutations.
#[test]
fn bulk_map_matches_host_reference() {
    let dir = require_artifacts!();
    let rt = BulkRuntime::load(&dir).unwrap();
    let mut rng = Rng::seed_from(42);
    for trial in 0..5 {
        // random sub-permutation within 128x128
        let rank = 1 + rng.gen_range(40) as usize;
        let mut qs: Vec<usize> = (0..128).collect();
        let mut ps: Vec<usize> = (0..128).collect();
        rng.shuffle(&mut qs);
        rng.shuffle(&mut ps);
        let elements: Vec<(usize, usize)> =
            qs.iter().zip(&ps).take(rank).map(|(&q, &p)| (q, p)).collect();
        // random presence lists
        let presence: Vec<Vec<usize>> = (0..300)
            .map(|_| {
                let n = rng.gen_range(20) as usize;
                rng.sample_indices(128, n)
            })
            .collect();
        let mapped = rt.bulk_map_block(&elements, &presence).unwrap();
        for (msg, got) in presence.iter().zip(&mapped) {
            let mut expect: Vec<(usize, usize)> = elements
                .iter()
                .copied()
                .filter(|(_, p)| msg.contains(p))
                .collect();
            expect.sort();
            let mut got = got.clone();
            got.sort();
            assert_eq!(got, expect, "trial {trial}");
        }
    }
}

/// End-to-end lane equivalence: the XLA bulk initial load and the Alg-6
/// fallback produce identical DW contents over random landscapes.
#[test]
fn bulk_lane_equivalent_to_alg6_lane() {
    let dir = require_artifacts!();
    let mut meta = Rng::seed_from(0xB011);
    for trial in 0..3 {
        let mut cfg = PipelineConfig::small();
        cfg.seed = meta.next_u64();
        cfg.attrs_per_schema = 4 + meta.gen_range(8) as usize;
        let build = |cfg: &PipelineConfig| {
            let mut land = workload::generate(cfg);
            let mut rng = Rng::seed_from(cfg.seed ^ 2);
            workload::populate(&mut land, 150, &mut rng);
            Pipeline::from_landscape(cfg.clone(), land).unwrap()
        };
        let p_bulk = build(&cfg);
        let p_fall = build(&cfg);
        let bulk = InitialLoader { runtime: BulkRuntime::try_load(&dir) };
        let fall = InitialLoader { runtime: None };
        for service in 0..2 {
            let rb = bulk.initial_load(&p_bulk, service).unwrap();
            let rf = fall.initial_load(&p_fall, service).unwrap();
            assert!(rb.used_bulk, "trial {trial}");
            assert!(!rf.used_bulk);
            assert_eq!(rb.rows, rf.rows);
            assert_eq!(rb.out_messages, rf.out_messages, "trial {trial}");
        }
        p_bulk.drain_sinks();
        p_fall.drain_sinks();
        let dw_state = |p: &Pipeline| {
            p.with_sink("dw", |dw: &metl::sink::DwSink| {
                (dw.total_rows(), dw.total_upserts())
            })
            .unwrap()
        };
        assert_eq!(dw_state(&p_bulk), dw_state(&p_fall), "trial {trial}");
    }
}

/// Empty blocks and empty batches are handled without executing garbage.
#[test]
fn bulk_map_degenerate_inputs() {
    let dir = require_artifacts!();
    let rt = BulkRuntime::load(&dir).unwrap();
    // empty element set: everything unmapped
    let mapped = rt.bulk_map_block(&[], &[vec![0, 1], vec![]]).unwrap();
    assert!(mapped.iter().all(|m| m.is_empty()));
    // empty batch
    let mapped = rt.bulk_map_block(&[(0, 0)], &[]).unwrap();
    assert!(mapped.is_empty());
}
