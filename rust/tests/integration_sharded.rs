//! End-to-end determinism of the sharded mapping lane: a seeded day trace
//! (including schema-change storms mid-trace) must produce the same
//! per-key CDM stream whether 1 or 4 shards map it.
//!
//! Comparison is per key, in order: a key lives in one CDC partition and
//! one shard, so its outputs must arrive in production order under any
//! shard count. The `state` stamp is excluded — an event produced at state
//! i may map before or after a racing epoch swap (restamped to i+1), which
//! changes the stamp but, by the update/map commutativity invariant, never
//! the payload. Cross-key interleaving across shards is unspecified,
//! exactly like Kafka ordering across partitions.

use std::collections::HashMap;

use metl::cdm::{CdmAttrId, CdmVersionNo, EntityId};
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::coordinator::shard;
use metl::message::cdc::CdcOp;
use metl::sink::{DwSink, JsonlSink};
use metl::util::json::{self, Json};
use metl::util::rng::Rng;
use metl::workload::{self, TraceOp};

/// Everything observable about one mapped record except the state stamp.
type NormRecord = (CdcOp, EntityId, CdmVersionNo, u64, Vec<(CdmAttrId, Json)>);

fn test_cfg() -> PipelineConfig {
    let mut cfg = PipelineConfig::small();
    cfg.trace_events = 300;
    cfg.schema_changes = 2; // two storms mid-trace
    // the JSONL lakehouse sink rides along to prove a new SinkConnector
    // backend passes the shard-equivalence e2e unchanged
    cfg.sinks = vec!["dw".into(), "ml".into(), "jsonl".into()];
    cfg
}

/// A JSONL line with the state stamp dropped: an event produced at state
/// i may map before or after a racing epoch swap (restamped to i+1),
/// which changes the stamp but never the payload.
fn normalized_line(line: &str) -> String {
    let mut value = json::parse(line).unwrap();
    if let Json::Obj(members) = &mut value {
        members.retain(|(k, _)| k != "state");
    }
    value.to_string()
}

/// The JSONL sink's records grouped per key, normalized, in apply order.
fn jsonl_by_key(p: &Pipeline) -> HashMap<u64, Vec<String>> {
    p.with_sink("jsonl", |sink: &JsonlSink| {
        let mut by_key: HashMap<u64, Vec<String>> = HashMap::new();
        for (key, line) in sink.records() {
            by_key.entry(*key).or_default().push(normalized_line(line));
        }
        by_key
    })
    .unwrap()
}

fn run_with_shards(
    ops: &[TraceOp],
    shards: usize,
) -> (Pipeline, HashMap<u64, Vec<NormRecord>>) {
    let cfg = test_cfg();
    let p = Pipeline::new(cfg).unwrap();
    let report = p.run_trace_sharded(ops, shards).unwrap();
    assert_eq!(report.events, 300, "{shards} shards");
    assert_eq!(report.dmm_updates, 2, "{shards} shards");
    assert_eq!(report.dead_letters, 0, "{shards} shards");
    // collect the CDM stream per key; within a partition the log order is
    // the append order, and one key lives in exactly one partition
    let mut by_key: HashMap<u64, Vec<NormRecord>> = HashMap::new();
    for partition in 0..p.out_topic.n_partitions() {
        for rec in p.out_topic.fetch(partition, 0, usize::MAX) {
            let (op, msg) = &*rec.value;
            by_key.entry(msg.key).or_default().push((
                *op,
                msg.entity,
                msg.version,
                msg.ts_us,
                msg.fields.clone(),
            ));
        }
    }
    (p, by_key)
}

#[test]
fn sharded_trace_equivalent_across_shard_counts() {
    let cfg = test_cfg();
    let mut rng = Rng::seed_from(cfg.seed);
    let ops = workload::day_trace(&cfg, &mut rng);
    assert!(ops
        .iter()
        .any(|op| matches!(op, TraceOp::SchemaChange { .. })));

    let (p1, keyed1) = run_with_shards(&ops, 1);
    let (p4, keyed4) = run_with_shards(&ops, 4);

    assert_eq!(
        p1.metrics.messages_out.get(),
        p4.metrics.messages_out.get(),
        "same number of CDM messages"
    );
    assert_eq!(keyed1.len(), keyed4.len(), "same key sets");
    for (key, records1) in &keyed1 {
        let records4 = keyed4
            .get(key)
            .unwrap_or_else(|| panic!("key {key} missing under 4 shards"));
        assert_eq!(records1, records4, "per-key stream for key {key}");
    }

    // the sinks converge to identical warehouse state
    let rows = |p: &Pipeline| {
        p.with_sink("dw", |dw: &DwSink| dw.total_rows()).unwrap()
    };
    assert_eq!(rows(&p1), rows(&p4));
    // ...and the pluggable JSONL backend sees the same per-key stream
    let jsonl1 = jsonl_by_key(&p1);
    let jsonl4 = jsonl_by_key(&p4);
    assert_eq!(jsonl1.len(), jsonl4.len(), "same jsonl key sets");
    for (key, lines1) in &jsonl1 {
        let lines4 = jsonl4.get(key).unwrap_or_else(|| {
            panic!("key {key} missing in jsonl under 4 shards")
        });
        assert_eq!(lines1, lines4, "per-key jsonl stream for key {key}");
    }
    // both lanes advanced through the same two state transitions
    assert_eq!(p1.state.current(), p4.state.current());
    assert!(p4.metrics.dmm_epoch.get() >= 2);
}

/// High-shard variant of the equivalence e2e: 8 and 16 shards — more
/// shards than CDC partitions, so several workers idle-park while others
/// own multiple keys — must reproduce the 1-shard per-key stream bit for
/// bit. Gated behind `METL_HIGH_SHARDS=1` (CI `concurrency` job) so the
/// default test run stays fast.
#[test]
fn sharded_trace_equivalent_at_high_shard_counts() {
    if std::env::var("METL_HIGH_SHARDS").as_deref() != Ok("1") {
        eprintln!("skipping: set METL_HIGH_SHARDS=1 to run");
        return;
    }
    let cfg = test_cfg();
    let mut rng = Rng::seed_from(cfg.seed);
    let ops = workload::day_trace(&cfg, &mut rng);

    let (p1, keyed1) = run_with_shards(&ops, 1);
    for shards in [8usize, 16] {
        let (pn, keyedn) = run_with_shards(&ops, shards);
        assert_eq!(
            p1.metrics.messages_out.get(),
            pn.metrics.messages_out.get(),
            "same number of CDM messages at {shards} shards"
        );
        assert_eq!(keyed1.len(), keyedn.len(), "key sets at {shards} shards");
        for (key, records1) in &keyed1 {
            let recordsn = keyedn.get(key).unwrap_or_else(|| {
                panic!("key {key} missing under {shards} shards")
            });
            assert_eq!(records1, recordsn, "key {key} at {shards} shards");
        }
        let jsonl1 = jsonl_by_key(&p1);
        let jsonln = jsonl_by_key(&pn);
        assert_eq!(jsonl1, jsonln, "jsonl streams at {shards} shards");
        assert_eq!(p1.state.current(), pn.state.current());
    }
}

#[test]
fn sharded_trace_spreads_work_across_shards() {
    let cfg = test_cfg();
    let mut rng = Rng::seed_from(cfg.seed);
    let ops = workload::day_trace(&cfg, &mut rng);
    let p = Pipeline::new(test_cfg()).unwrap();
    p.run_trace_sharded(&ops, 4).unwrap();
    let per_shard = p.metrics.shard.events_per_shard();
    assert_eq!(per_shard.iter().sum::<u64>(), 300);
    // the small profile has 4 services hashed over 4 shards: every shard
    // that owns a schema saw traffic
    assert!(per_shard.iter().filter(|&&c| c > 0).count() >= 2);
}

#[test]
fn sharded_trace_matches_single_lane_run_trace() {
    // the sharded lane and the classic single lane agree on the per-key
    // stream for a storm-free trace (no restamp nondeterminism at all)
    let mut cfg = test_cfg();
    cfg.schema_changes = 0;
    let mut rng = Rng::seed_from(cfg.seed);
    let ops = workload::day_trace(&cfg, &mut rng);

    let single = Pipeline::new(cfg.clone()).unwrap();
    single.run_trace(&ops).unwrap();
    let sharded = Pipeline::new(cfg).unwrap();
    shard::run_sharded_trace(&sharded, &ops, 3).unwrap();

    assert_eq!(
        single.metrics.messages_out.get(),
        sharded.metrics.messages_out.get()
    );
    let rows = |p: &Pipeline| {
        p.with_sink("dw", |dw: &DwSink| dw.total_rows()).unwrap()
    };
    assert_eq!(rows(&single), rows(&sharded));
}
