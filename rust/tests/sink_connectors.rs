//! Integration tests over the pluggable connector API: per-sink
//! consumer-group independence (a stalled backend never blocks the
//! others and loses nothing while stalled), config-driven sink selection,
//! and the "new backend = one trait impl + one builder call" seam.

use std::any::Any;
use std::collections::HashMap;

use metl::broker::Consumer;
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::message::cdc::CdcOp;
use metl::message::OutMessage;
use metl::sink::{JsonlSink, MlSink, SinkConnector, SinkStats};
use metl::util::json;
use metl::workload::{DmlKind, TraceOp};

/// Produce `n` DML ops and map everything currently in the CDC topic
/// (without touching any sink consumer group).
fn produce_and_map(
    p: &Pipeline,
    consumer: &mut Consumer<std::sync::Arc<metl::message::cdc::CdcEvent>>,
    n: usize,
    kind: DmlKind,
) {
    for i in 0..n {
        p.resolve_op(&TraceOp::Dml { service: i % 4, kind }).unwrap();
    }
    loop {
        let batch = consumer.poll(256);
        if batch.is_empty() {
            break;
        }
        for (_, rec) in &batch {
            p.process_event(&rec.value);
        }
        consumer.commit();
    }
}

/// Per-key (op, ts) sequence as the CDM topic recorded it.
fn topic_stream_by_key(p: &Pipeline) -> HashMap<u64, Vec<(String, u64)>> {
    let mut by_key: HashMap<u64, Vec<(String, u64)>> = HashMap::new();
    for partition in 0..p.out_topic.n_partitions() {
        for rec in p.out_topic.fetch(partition, 0, usize::MAX) {
            let (op, msg) = &*rec.value;
            by_key
                .entry(msg.key)
                .or_default()
                .push((op.code().to_string(), msg.ts_us));
        }
    }
    by_key
}

/// Per-key (op, ts) sequence as the JSONL backend applied it.
fn jsonl_stream_by_key(p: &Pipeline) -> HashMap<u64, Vec<(String, u64)>> {
    p.with_sink("jsonl", |sink: &JsonlSink| {
        let mut by_key: HashMap<u64, Vec<(String, u64)>> = HashMap::new();
        for (key, line) in sink.records() {
            let value = json::parse(line).unwrap();
            let op = value.get("op").and_then(|v| v.as_str()).unwrap().to_string();
            let ts = value.get("ts_us").and_then(|v| v.as_u64()).unwrap();
            by_key.entry(*key).or_default().push((op, ts));
        }
        by_key
    })
    .unwrap()
}

/// Satellite: stall one sink (simply never drain its group), assert the
/// other groups' lag stays 0 across multiple rounds, then let the stalled
/// backend catch up and verify it saw the complete per-key stream in
/// production order.
#[test]
fn stalled_sink_does_not_block_others_and_catches_up_in_order() {
    let mut cfg = PipelineConfig::small();
    cfg.sinks = vec!["dw".into(), "ml".into(), "jsonl".into()];
    let p = Pipeline::new(cfg).unwrap();
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);

    // round 1: inserts — drain everything except the "slow warehouse"
    produce_and_map(&p, &mut consumer, 40, DmlKind::Insert);
    let total_round1 = p.out_topic.total_records();
    assert!(total_round1 > 0);
    p.sink("dw").unwrap().drain();
    p.sink("ml").unwrap().drain();
    assert_eq!(p.sink("dw").unwrap().lag(), 0);
    assert_eq!(p.sink("ml").unwrap().lag(), 0);
    assert_eq!(p.sink("jsonl").unwrap().lag(), total_round1);

    // round 2: updates + deletes on the same keys (per-key order now
    // matters) — the healthy sinks stay at lag 0, the stalled one grows
    produce_and_map(&p, &mut consumer, 30, DmlKind::Update);
    produce_and_map(&p, &mut consumer, 10, DmlKind::Delete);
    let total = p.out_topic.total_records();
    assert!(total > total_round1);
    p.sink("dw").unwrap().drain();
    p.sink("ml").unwrap().drain();
    assert_eq!(p.sink("dw").unwrap().lag(), 0, "healthy sink blocked");
    assert_eq!(p.sink("ml").unwrap().lag(), 0, "healthy sink blocked");
    assert_eq!(p.sink("jsonl").unwrap().lag(), total);

    // the stalled backend catches up: nothing lost, per-key total order
    // identical to the CDM topic's production order
    let applied = p.sink("jsonl").unwrap().drain();
    assert_eq!(applied as u64, total);
    assert_eq!(p.sink("jsonl").unwrap().lag(), 0);
    assert_eq!(jsonl_stream_by_key(&p), topic_stream_by_key(&p));

    // per-sink metrics gauges reflect the independent groups
    let rows = p.metrics.sinks.rows();
    assert_eq!(rows.len(), 3);
    for row in &rows {
        assert_eq!(row.lag, 0, "sink {}", row.name);
        assert_eq!(row.drained, total, "sink {}", row.name);
        assert_eq!(row.flush_errors, 0, "sink {}", row.name);
    }
}

/// Acceptance: a new backend is one `SinkConnector` impl plus one builder
/// call — no coordinator changes.
#[derive(Default)]
struct CountingSink {
    seen: u64,
    deletes: u64,
}

impl SinkConnector for CountingSink {
    fn name(&self) -> &str {
        "counting"
    }

    fn apply(&mut self, _msg: &OutMessage, op: CdcOp) {
        self.seen += 1;
        if op == CdcOp::Delete {
            self.deletes += 1;
        }
    }

    fn snapshot_stats(&self) -> SinkStats {
        SinkStats { applied: self.seen, duplicates: 0, dropped: 0 }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[test]
fn custom_backend_plugs_in_via_builder() {
    let p = Pipeline::builder(PipelineConfig::small())
        .sink(MlSink::new())
        .sink(CountingSink::default())
        .build()
        .unwrap();
    let ops: Vec<TraceOp> = (0..25)
        .map(|i| TraceOp::Dml { service: i % 4, kind: DmlKind::Insert })
        .collect();
    p.run_trace(&ops).unwrap();
    let seen = p
        .with_sink("counting", |c: &CountingSink| c.seen)
        .unwrap();
    assert_eq!(seen, p.metrics.messages_out.get());
    assert_eq!(p.sink("counting").unwrap().lag(), 0);
    // the dashboard grew a row for it without any coordinator changes
    assert!(p.dashboard().contains("sink counting"));
}

#[test]
fn config_selects_sinks_end_to_end() {
    let text = r#"
        [runtime]
        sinks = ["jsonl", "audit"]
    "#;
    let cfg = PipelineConfig::parse(text).unwrap();
    let p = Pipeline::new(cfg).unwrap();
    let names: Vec<&str> = p.sinks.iter().map(|h| h.name()).collect();
    assert_eq!(names, vec!["jsonl", "audit"]);
    let ops: Vec<TraceOp> = (0..20)
        .map(|i| TraceOp::Dml { service: i % 4, kind: DmlKind::Insert })
        .collect();
    p.run_trace(&ops).unwrap();
    let out = p.metrics.messages_out.get();
    assert!(out > 0);
    for handle in &p.sinks {
        assert_eq!(handle.stats().applied, out, "sink {}", handle.name());
        assert_eq!(handle.lag(), 0);
    }
}
