//! Restart end-to-end: a pipeline killed mid-stream and restored from its
//! store must be indistinguishable from a cold build that applied the
//! same schema changes — same DMM, same state, same mapping outputs —
//! under both the native kernel and the scalar Alg-6 lane. The in-process
//! restore drill additionally proves the targeted-eviction contract:
//! unaffected cached columns (and their compiled plans) stay warm.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use metl::broker::Consumer;
use metl::config::PipelineConfig;
use metl::coordinator::pipeline::Pipeline;
use metl::mapper::kernel::KernelMode;
use metl::message::StateI;
use metl::workload::{DmlKind, TraceOp};

fn dml(service: usize) -> TraceOp {
    TraceOp::Dml { service, kind: DmlKind::Insert }
}

/// Drain the CDC topic through `p`.
fn pump(p: &Pipeline, consumer: &mut Consumer<Arc<metl::message::cdc::CdcEvent>>) {
    loop {
        let batch = consumer.poll(64);
        if batch.is_empty() {
            break;
        }
        for (_, rec) in &batch {
            p.process_event(&rec.value);
        }
        consumer.commit();
    }
}

/// Kill a store-backed pipeline mid-stream, restore a fresh instance from
/// the directory, and check it maps identically to a cold build with the
/// final schema landscape.
fn restart_equivalence(kernel: KernelMode) {
    let dir = metl::util::tmp::TestDir::new("sr-restart");
    let mut cfg = PipelineConfig::small();
    cfg.kernel = kernel;

    // first life: stream + two schema changes, then an unclean death
    // (events still in flight, no shutdown hook)
    {
        let p = Pipeline::new(cfg.clone())
            .unwrap()
            .with_store(dir.path())
            .unwrap();
        let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
        for i in 0..20 {
            p.resolve_op(&dml(i % 4)).unwrap();
        }
        pump(&p, &mut consumer);
        p.apply_schema_change(0).unwrap();
        for i in 0..10 {
            p.resolve_op(&dml(i % 4)).unwrap();
        }
        pump(&p, &mut consumer);
        p.apply_schema_change(1).unwrap();
        for i in 0..10 {
            p.resolve_op(&dml(i % 4)).unwrap();
        }
        // killed here: the last batch never processed
    }

    // second life: restore from the store
    let restored = Pipeline::new(cfg.clone())
        .unwrap()
        .with_store(dir.path())
        .unwrap();
    assert!(restored.restore_from_store().unwrap());
    assert_eq!(restored.state.current(), StateI(2));

    // cold reference: fresh build, same change sequence, no store
    let cold = Pipeline::new(cfg).unwrap();
    cold.apply_schema_change(0).unwrap();
    cold.apply_schema_change(1).unwrap();
    assert_eq!(cold.state.current(), StateI(2));
    assert!(restored.dmm.snapshot().same_elements(&cold.dmm.snapshot()));

    // identical mapping behaviour on an identical event stream: generate
    // events on the cold instance (fresh rng == restored instance's) and
    // map each one through both pipelines
    for i in 0..16 {
        cold.resolve_op(&dml(i % 4)).unwrap();
    }
    let mut consumer = Consumer::new(cold.cdc_topic.clone(), 0, 1);
    let mut mapped = 0;
    for (_, rec) in consumer.poll(64) {
        let via_cold = cold.map_event(&rec.value).unwrap();
        let via_restored = restored.map_event(&rec.value).unwrap();
        assert_eq!(via_cold, via_restored, "outputs diverged after restore");
        assert!(!via_cold.is_empty());
        mapped += 1;
    }
    assert_eq!(mapped, 16);
    assert_eq!(restored.metrics.dead_letters.get(), 0);
}

#[test]
fn restart_matches_cold_build_native_kernel() {
    restart_equivalence(KernelMode::Native);
}

#[test]
fn restart_matches_cold_build_scalar_kernel() {
    restart_equivalence(KernelMode::Scalar);
}

/// In-process restore (the operator's "reload from disk" drill): columns
/// and compiled plans of schemas the WAL tail never touched keep their
/// `Arc` identity — the plan cache stays warm and serves hits — while the
/// affected column is rebuilt.
#[test]
fn in_process_restore_keeps_unaffected_columns_warm() {
    let dir = metl::util::tmp::TestDir::new("sr-warm");
    let p = Pipeline::new(PipelineConfig::small())
        .unwrap()
        .with_store(dir.path())
        .unwrap();
    // warm the cache across all services
    let mut consumer = Consumer::new(p.cdc_topic.clone(), 0, 1);
    for s in 0..4 {
        p.resolve_op(&dml(s)).unwrap();
    }
    pump(&p, &mut consumer);
    // one WAL-era change on service 3 only
    p.apply_schema_change(3).unwrap();
    let (unaffected, u_live, affected, a_live) = {
        let land = p.landscape.read().unwrap();
        (
            land.dbs[0].tables[0].schema,
            land.dbs[0].tables[0].live_version,
            land.dbs[3].tables[0].schema,
            land.dbs[3].tables[0].live_version,
        )
    };
    let dpm = p.dmm.snapshot();
    let (col_u, plan_u) = p.cache.plan(&dpm, unaffected, u_live);
    let col_a = p.cache.column(&dpm, affected, a_live);

    let live = p.dmm.snapshot();
    assert!(p.restore_from_store().unwrap());
    let recovered = p.dmm.snapshot();
    assert!(live.same_elements(&recovered));
    assert_eq!(recovered.state, StateI(1));

    // the unaffected column survived the restore: same Arc, served as a
    // cache hit, and its compiled plan did not recompile
    let hits_before = p.cache.stats.hits.load(Ordering::Relaxed);
    let (col_u2, plan_u2) = p.cache.plan(&recovered, unaffected, u_live);
    assert!(Arc::ptr_eq(&col_u, &col_u2), "unaffected column was evicted");
    assert!(Arc::ptr_eq(&plan_u, &plan_u2), "warm plan was recompiled");
    assert_eq!(p.cache.stats.hits.load(Ordering::Relaxed), hits_before + 1);

    // the affected column was evicted and rebuilt from the recovered DMM
    let col_a2 = p.cache.column(&recovered, affected, a_live);
    assert!(!Arc::ptr_eq(&col_a, &col_a2), "affected column kept stale Arc");
    assert!(!col_a2.is_empty());
}
