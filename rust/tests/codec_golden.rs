//! Golden-fixture tests for the wire codec (`message/codec.rs`): the
//! checked-in `fixtures/cdc_golden.json` pins the exact envelope shape
//! (fig 2) for a create, an update and a delete tombstone, including null
//! data objects, plus one CDM out-message with business descriptions. Any
//! unintentional wire-format change trips the structural comparison; the
//! roundtrip half proves decode(encode(x)) == x on the same payloads.

use metl::message::cdc::{CdcEvent, CdcOp, CdcSource};
use metl::message::{codec, InMessage, OutMessage, StateI};
use metl::schema::{ExtractType, SchemaId, SchemaTree, VersionNo};
use metl::util::json::{parse, Json};

const GOLDEN: &str = include_str!("fixtures/cdc_golden.json");

fn tree() -> (SchemaTree, SchemaId, VersionNo) {
    let mut t = SchemaTree::new();
    let s = t.add_schema("payments.incoming", "fx.payments.incoming");
    let v = t.add_version(
        s,
        &[
            ("id".into(), ExtractType::Int64, false),
            ("value".into(), ExtractType::Decimal, true),
            ("currency".into(), ExtractType::Varchar, true),
            ("time".into(), ExtractType::MicroTimestamp, true),
        ],
    );
    (t, s, v)
}

fn cdm() -> metl::cdm::CdmTree {
    let mut c = metl::cdm::CdmTree::new();
    let e = c.add_entity("Payment");
    c.add_version(
        e,
        &[
            (
                "amount".into(),
                metl::cdm::CdmType::Number,
                "Payment amount".into(),
            ),
            (
                "time".into(),
                metl::cdm::CdmType::Timestamp,
                "Time of the payment".into(),
            ),
        ],
    );
    c
}

fn source() -> CdcSource {
    CdcSource {
        connector: "postgresql".into(),
        db: "payments".into(),
        table: "incoming".into(),
    }
}

/// The row image before the update: one null data object ("time").
fn image_v1(t: &SchemaTree, s: SchemaId, v: VersionNo) -> InMessage {
    let sv = t.version(s, v).unwrap();
    InMessage {
        key: 32201,
        schema: s,
        version: v,
        state: StateI(0),
        ts_us: 1_700_000_000_000_001,
        fields: vec![
            (sv.attrs[0], Json::Num(32201.0)),
            (sv.attrs[1], Json::Num(10.5)),
            (sv.attrs[2], Json::Str("EUR".into())),
            (sv.attrs[3], Json::Null),
        ],
    }
}

/// The row image after the update: "currency" went null, "time" filled.
fn image_v2(t: &SchemaTree, s: SchemaId, v: VersionNo) -> InMessage {
    let sv = t.version(s, v).unwrap();
    InMessage {
        ts_us: 1_700_000_000_000_002,
        fields: vec![
            (sv.attrs[0], Json::Num(32201.0)),
            (sv.attrs[1], Json::Num(11.0)),
            (sv.attrs[2], Json::Null),
            (sv.attrs[3], Json::Num(1_700_000_000_000_000.0)),
        ],
        ..image_v1(t, s, v)
    }
}

fn golden_events(t: &SchemaTree, s: SchemaId, v: VersionNo) -> Vec<CdcEvent> {
    vec![
        CdcEvent {
            op: CdcOp::Create,
            before: None,
            after: Some(image_v1(t, s, v)),
            source: source(),
            ts_us: 11,
        },
        CdcEvent {
            op: CdcOp::Update,
            before: Some(image_v1(t, s, v)),
            after: Some(image_v2(t, s, v)),
            source: source(),
            ts_us: 12,
        },
        // the tombstone: empty "after", the before image maps the key
        CdcEvent {
            op: CdcOp::Delete,
            before: Some(image_v2(t, s, v)),
            after: None,
            source: source(),
            ts_us: 13,
        },
    ]
}

fn golden_out(c: &metl::cdm::CdmTree) -> OutMessage {
    let e = c.entity_by_name("Payment").unwrap();
    let w = metl::cdm::CdmVersionNo(1);
    let cv = c.version(e, w).unwrap();
    OutMessage {
        key: 32201,
        entity: e,
        version: w,
        state: StateI(0),
        ts_us: 1_700_000_000_000_002,
        fields: vec![
            (cv.attrs[0], Json::Num(11.0)),
            (cv.attrs[1], Json::Num(1_700_000_000_000_000.0)),
        ],
    }
}

#[test]
fn encoding_matches_checked_in_golden_fixture() {
    let (t, s, v) = tree();
    let c = cdm();
    let mut expected = Json::obj();
    expected.set(
        "cdc",
        Json::Arr(
            golden_events(&t, s, v)
                .iter()
                .map(|ev| codec::encode_cdc(ev, &t))
                .collect(),
        ),
    );
    expected.set("out", codec::encode_out(&golden_out(&c), &c));
    let golden = parse(GOLDEN).expect("golden fixture parses");
    assert_eq!(golden, expected, "wire format drifted from the fixture");
}

#[test]
fn golden_fixture_decodes_to_the_same_events() {
    let (t, s, v) = tree();
    let golden = parse(GOLDEN).unwrap();
    let entries = golden.get("cdc").and_then(Json::as_arr).unwrap();
    let expected = golden_events(&t, s, v);
    assert_eq!(entries.len(), expected.len());
    for (entry, want) in entries.iter().zip(&expected) {
        let decoded = codec::decode_cdc(&entry.to_string(), &t).unwrap();
        assert_eq!(&decoded, want);
    }
}

#[test]
fn cdc_roundtrip_including_tombstone_and_nulls() {
    let (t, s, v) = tree();
    for ev in golden_events(&t, s, v) {
        let wire = codec::encode_cdc(&ev, &t).to_string();
        let back = codec::decode_cdc(&wire, &t).unwrap();
        assert_eq!(back, ev);
        assert!(back.is_well_formed());
    }
    // the tombstone maps its before image (DW tombstones by key)
    let delete = &golden_events(&t, s, v)[2];
    assert_eq!(delete.mapping_payload().unwrap().key, 32201);
    // null data objects survive the trip as explicit nulls
    let update = &golden_events(&t, s, v)[1];
    let wire = codec::encode_cdc(update, &t).to_string();
    let back = codec::decode_cdc(&wire, &t).unwrap();
    let after = back.after.unwrap();
    let sv = t.version(s, v).unwrap();
    assert!(after.data_object(sv.attrs[2]).is_none(), "currency is null");
    assert_eq!(after.nad(sv.attrs[2]), 0);
    assert_eq!(after.non_null_count(), 3);
}

#[test]
fn in_message_roundtrip_through_wire() {
    let (t, s, v) = tree();
    for msg in [image_v1(&t, s, v), image_v2(&t, s, v)] {
        let wire = codec::encode_in(&msg, &t).to_string();
        let back = codec::decode_in(&wire, &t).unwrap();
        assert_eq!(back, msg);
    }
}
